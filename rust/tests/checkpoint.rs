//! Checkpoint round-trip integration tests: the acceptance gate for
//! `train --save` / `infer --load`.
//!
//! For every arithmetic (float32, half, fixed, dynamic) and both
//! topology families (maxout MLP on clusters, maxout conv net on
//! digits) a trained model is checkpointed, written to disk, read back,
//! and proven bit-exact two ways:
//!
//! * **logits identity** — a [`Network`] restored from the disk
//!   round-trip produces u32-bit-identical logits to one restored from
//!   the in-memory checkpoint, on a real eval batch;
//! * **infer identity** — a fresh backend loaded with the checkpoint's
//!   parameters recomputes the *exact* train-time test error
//!   (`f64::to_bits` equality), which is the check `lpdnn infer --load`
//!   enforces.
//!
//! File-level corruption (garbage JSON, a foreign format version, a
//! tampered field) must surface as distinct message-carrying errors —
//! the counterpart of the in-module unit tests, but through real files.

use lpdnn::arith::RoundMode;
use lpdnn::checkpoint::Checkpoint;
use lpdnn::config::{
    Arithmetic, ConvStageSpec, DataConfig, ExperimentConfig, TopologySpec, TrainConfig,
};
use lpdnn::coordinator::Session;
use lpdnn::data::{Batcher, Dataset};
use lpdnn::golden::{Network, StepOptions};
use lpdnn::runtime::{Backend, BackendSpec};
use lpdnn::tensor::{Pcg32, Tensor};

/// The four arithmetics of the paper, at tiny widths where relevant.
fn arithmetics() -> Vec<Arithmetic> {
    vec![
        Arithmetic::Float32,
        Arithmetic::Half,
        Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 },
        Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 1e-4,
            update_every_examples: 64,
            init_int_bits: 3,
            warmup_steps: 2,
        },
    ]
}

fn cfg_for(name: &str, spec: TopologySpec, dataset: &str, arith: Arithmetic) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        model: spec.name.clone(),
        topology: Some(spec),
        arithmetic: arith,
        train: TrainConfig { steps: 4, seed: 77, ..Default::default() },
        data: DataConfig { dataset: dataset.into(), n_train: 128, n_test: 48 },
        ..Default::default()
    }
}

fn mlp_cfg(name: &str, arith: Arithmetic) -> ExperimentConfig {
    let mut spec = TopologySpec::mlp(vec![8, 6], 2);
    spec.train_batch = 8;
    spec.eval_batch = 8;
    cfg_for(name, spec, "clusters", arith)
}

fn conv_cfg(name: &str, arith: Arithmetic) -> ExperimentConfig {
    let mut spec = TopologySpec::conv_net(
        vec![ConvStageSpec { channels: 3, ksize: 3, pool: 2 }],
        vec![6],
        2,
    );
    spec.train_batch = 8;
    spec.eval_batch = 8;
    cfg_for(name, spec, "digits", arith)
}

fn param_bits(params: &[Tensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.data().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Eval-time step options matching what `lpdnn serve` uses (the
/// deterministic forward: round-half-away, no dropout).
fn eval_opts(half: bool, int_domain: bool) -> StepOptions {
    StepOptions {
        mode: RoundMode::HalfAway,
        half,
        dropout: None,
        fused: true,
        conv_direct: false,
        int_domain,
        ..Default::default()
    }
}

/// Train `cfg`, checkpoint it, push the checkpoint through a real file,
/// and assert both bit-exactness properties.
fn assert_round_trip(cfg: ExperimentConfig, tag: &str) {
    let mut session = Session::new(BackendSpec::native());
    let result = session.run(cfg.clone()).unwrap();
    let params = session.params_host().unwrap();

    let ckpt = Checkpoint::from_run(&cfg, &result, params).unwrap();
    let path = std::env::temp_dir().join(format!("lpdnn_test_ckpt_{tag}.json"));
    let path_str = path.to_str().unwrap();
    ckpt.save(path_str).unwrap();
    let loaded = Checkpoint::load(path_str).unwrap();
    let _ = std::fs::remove_file(&path);

    // The JSON round trip preserves every parameter bit (sign of -0.0,
    // denormals, all grid values) and the scale table.
    assert_eq!(param_bits(&ckpt.params), param_bits(&loaded.params), "{tag}: param bits");
    assert_eq!(ckpt.int_bits, loaded.int_bits, "{tag}: scale table");
    assert_eq!(
        ckpt.test_error.to_bits(),
        loaded.test_error.to_bits(),
        "{tag}: stored test error"
    );

    // Logits identity: networks restored from the in-memory checkpoint
    // and from the disk round-trip agree bit-for-bit on a real batch,
    // in both the float-domain and integer-domain fused paths.
    let ra = ckpt.restore().unwrap();
    let rb = loaded.restore().unwrap();
    assert_eq!(ra.ctrl.int_bits_vec(), rb.ctrl.int_bits_vec(), "{tag}: restored scales");
    let rng = Pcg32::seeded(loaded.seed);
    let ds = Dataset::generate(&loaded.dataset, loaded.n_train, loaded.n_test, &rng).unwrap();
    let (x, _, _) = Batcher::eval_batches(&ds.test, ra.spec.eval_batch, ra.n_classes)
        .into_iter()
        .next()
        .unwrap();
    let net_a = Network::from_topology_shaped(&ra.spec, ra.in_shape, ra.n_classes).unwrap();
    let net_b = Network::from_topology_shaped(&rb.spec, rb.in_shape, rb.n_classes).unwrap();
    for int_domain in [false, true] {
        let la = net_a.eval_logits_opt(&ckpt.params, &x, &ra.ctrl, &eval_opts(ra.half, int_domain));
        let lb =
            net_b.eval_logits_opt(&loaded.params, &x, &rb.ctrl, &eval_opts(rb.half, int_domain));
        let ba: Vec<u32> = la.data().iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = lb.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "{tag}: logits drifted (int_domain={int_domain})");
    }

    // Infer identity: a fresh backend fed the loaded parameters
    // recomputes the train-time test error exactly.
    let infer_cfg = loaded.to_config();
    infer_cfg.validate().unwrap();
    let mut backend = BackendSpec::native().create().unwrap();
    let model = backend.begin_run(&infer_cfg).unwrap();
    backend.load_params(loaded.params.clone()).unwrap();
    let mut errors = 0usize;
    let mut total = 0usize;
    for (x, y, n_real) in Batcher::eval_batches(&ds.test, model.eval_batch, model.n_classes) {
        errors += backend.eval_errors(&rb.ctrl, &x, &y, n_real).unwrap();
        total += n_real;
    }
    let err = errors as f64 / total as f64;
    assert_eq!(
        err.to_bits(),
        loaded.test_error.to_bits(),
        "{tag}: restored eval {err} vs train-time {}",
        loaded.test_error
    );
}

#[test]
fn mlp_checkpoints_round_trip_bit_exactly_across_arithmetics() {
    for arith in arithmetics() {
        let tag = format!("mlp_{}", arith.label().replace('/', "_"));
        assert_round_trip(mlp_cfg(&format!("ck-{tag}"), arith), &tag);
    }
}

#[test]
fn conv_checkpoints_round_trip_bit_exactly_across_arithmetics() {
    for arith in arithmetics() {
        let tag = format!("conv_{}", arith.label().replace('/', "_"));
        assert_round_trip(conv_cfg(&format!("ck-{tag}"), arith), &tag);
    }
}

/// A saved checkpoint, as text, for the corruption tests.
fn saved_checkpoint_text(tag: &str) -> String {
    let cfg = mlp_cfg(&format!("ck-neg-{tag}"), Arithmetic::Fixed {
        bits_comp: 20,
        bits_up: 20,
        int_bits: 5,
    });
    let mut session = Session::new(BackendSpec::native());
    let result = session.run(cfg.clone()).unwrap();
    let params = session.params_host().unwrap();
    let ckpt = Checkpoint::from_run(&cfg, &result, params).unwrap();
    let path = std::env::temp_dir().join(format!("lpdnn_test_ckpt_neg_{tag}.json"));
    let path_str = path.to_str().unwrap();
    ckpt.save(path_str).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    text
}

fn load_text(tag: &str, text: &str) -> lpdnn::Result<Checkpoint> {
    let path = std::env::temp_dir().join(format!("lpdnn_test_ckpt_bad_{tag}.json"));
    std::fs::write(&path, text).unwrap();
    let out = Checkpoint::load(path.to_str().unwrap());
    let _ = std::fs::remove_file(&path);
    out
}

#[test]
fn corrupted_files_fail_with_distinct_errors() {
    let text = saved_checkpoint_text("base");

    // Garbage bytes: a JSON-level parse error naming the file.
    let err = load_text("garbage", "{ definitely not json").unwrap_err();
    assert!(format!("{err:#}").contains("not valid JSON"), "{err:#}");

    // A future format version is rejected before anything else.
    assert!(text.contains("\"version\": 1"), "fixture drifted");
    let err = load_text("version", &text.replace("\"version\": 1", "\"version\": 99")).unwrap_err();
    assert!(format!("{err:#}").contains("unsupported checkpoint version 99"), "{err:#}");

    // Tampering with any field breaks the checksum.
    assert!(text.contains("\"seed\": 77"), "fixture drifted");
    let err = load_text("tamper", &text.replace("\"seed\": 77", "\"seed\": 78")).unwrap_err();
    assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");

    // The untampered text still loads (the fixture replacements above
    // really did exercise the failure paths, not a broken fixture).
    load_text("intact", &text).unwrap();
}
