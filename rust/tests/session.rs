//! Session API integration tests: parallel-sweep determinism, the
//! observer event stream, per-point loss CSVs, and the serializable
//! sweep report (golden file + round-trip through `config/json.rs`).

use std::sync::Arc;

use lpdnn::config::{Arithmetic, DataConfig, ExperimentConfig, TrainConfig};
use lpdnn::coordinator::{
    LossCsvObserver, ObserverEvent, RecordingObserver, RunReport, Session, SweepOutcome,
    SweepPoint, SweepReport, SweepRowReport,
};
use lpdnn::runtime::BackendSpec;

fn clusters_cfg(name: &str, arith: Arithmetic, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        model: "pi_mlp".into(),
        arithmetic: arith,
        train: TrainConfig {
            steps,
            lr_start: 0.15,
            lr_end: 0.02,
            seed: 2024,
            max_norm: 3.0,
            ..Default::default()
        },
        data: DataConfig { dataset: "clusters".into(), n_train: 256, n_test: 128 },
        ..Default::default()
    }
}

/// A 4-point mini-sweep (two fixed widths, float16, and the paper's
/// dynamic 10/12 with warmup) on clusters/pi_mlp.
fn mini_sweep(jobs: usize) -> SweepOutcome {
    let baseline = clusters_cfg("det-base", Arithmetic::Float32, 8);
    let mut points = Vec::new();
    for bits in [20i32, 10] {
        let mut cfg = clusters_cfg(&format!("det-fixed-{bits}"), Arithmetic::Float32, 8);
        cfg.arithmetic = Arithmetic::Fixed { bits_comp: bits, bits_up: bits, int_bits: 5 };
        points.push(SweepPoint { label: format!("fixed-{bits}"), cfg });
    }
    points.push(SweepPoint {
        label: "half".into(),
        cfg: clusters_cfg("det-half", Arithmetic::Half, 8),
    });
    let dynamic = Arithmetic::Dynamic {
        bits_comp: 10,
        bits_up: 12,
        max_overflow_rate: 1e-4,
        update_every_examples: 128,
        init_int_bits: 3,
        warmup_steps: 8,
    };
    points.push(SweepPoint {
        label: "dynamic-10-12".into(),
        cfg: clusters_cfg("det-dyn", dynamic, 8),
    });
    let mut session = Session::new(BackendSpec::native()).with_jobs(jobs);
    session.sweep(&baseline, &points).unwrap()
}

/// The acceptance gate for parallel sweeps: `jobs = 4` rows must be
/// bit-identical to `jobs = 1` — same test errors, same final int_bits,
/// same tail losses, same order.
#[test]
fn parallel_sweep_is_bit_identical_to_sequential() {
    let seq = mini_sweep(1);
    let par = mini_sweep(4);
    assert_eq!(seq.baseline.test_error, par.baseline.test_error);
    assert_eq!(seq.rows.len(), 4);
    assert_eq!(par.rows.len(), 4);
    for (a, b) in seq.rows.iter().zip(&par.rows) {
        assert_eq!(a.label, b.label, "rows must come back in point order");
        assert_eq!(a.test_error, b.test_error, "{}: test error drifted", a.label);
        assert_eq!(a.normalized, b.normalized, "{}: normalization drifted", a.label);
        assert_eq!(
            a.result.final_int_bits, b.result.final_int_bits,
            "{}: scale trajectory drifted",
            a.label
        );
        assert_eq!(
            a.result.train_loss, b.result.train_loss,
            "{}: tail loss drifted",
            a.label
        );
        assert_eq!(a.result.metrics.losses, b.result.metrics.losses);
    }
}

#[test]
fn observer_receives_typed_event_stream() {
    let rec = Arc::new(RecordingObserver::new());
    let mut session = Session::new(BackendSpec::native()).with_observer(rec.clone());
    let mut cfg = clusters_cfg("obs", Arithmetic::Float32, 6);
    cfg.train.eval_every = 2;
    let r = session.run(cfg).unwrap();

    let events = rec.take();
    let steps = events
        .iter()
        .filter(|e| matches!(e, ObserverEvent::Step { .. }))
        .count();
    assert_eq!(steps, 6, "one step event per SGD step");
    let evals = events
        .iter()
        .filter(|e| matches!(e, ObserverEvent::Eval { .. }))
        .count();
    // eval_every=2 over 6 steps: periodic after steps 2 and 4, plus the
    // final evaluation
    assert_eq!(evals, 3);
    match events.last().unwrap() {
        ObserverEvent::RunEnd { label, test_error } => {
            assert_eq!(label, "obs");
            assert_eq!(*test_error, r.test_error);
        }
        other => panic!("last event should be RunEnd, got {other:?}"),
    }
}

#[test]
fn loss_csv_observer_writes_one_file_per_sweep_point() {
    let dir = std::env::temp_dir().join("lpdnn_test_sweep_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let base_path = dir.join("loss.csv");

    let baseline = clusters_cfg("csv-base", Arithmetic::Float32, 4);
    let mut point_cfg = clusters_cfg("csv-p20", Arithmetic::Float32, 4);
    point_cfg.arithmetic = Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 };
    let points = vec![SweepPoint { label: "p20".into(), cfg: point_cfg }];

    let mut session = Session::new(BackendSpec::native())
        .with_observer(Arc::new(LossCsvObserver::per_label(&base_path)));
    session.sweep(&baseline, &points).unwrap();

    for name in ["loss-csv-base.csv", "loss-p20.csv"] {
        let path = dir.join(name);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("expected {path:?}: {e}"));
        assert!(text.starts_with("step,loss"), "{name} is a loss curve");
        assert_eq!(text.lines().count(), 5, "{name}: header + one line per step");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn golden_report() -> SweepReport {
    SweepReport {
        backend: "native".into(),
        jobs: 2,
        baseline: RunReport {
            name: "fig-baseline".into(),
            label: "fig-baseline".into(),
            backend: "native".into(),
            test_error: 0.125,
            train_loss: 0.5,
            final_int_bits: vec![3, -2, 0],
            steps: 40,
            wallclock_secs: 1.5,
            int_gemm_sites: Default::default(),
        },
        rows: vec![
            SweepRowReport {
                label: "10".into(),
                normalized: 1.25,
                run: RunReport {
                    name: "fig-10".into(),
                    label: "10".into(),
                    backend: "native".into(),
                    test_error: 0.15625,
                    train_loss: 0.75,
                    final_int_bits: vec![],
                    steps: 40,
                    wallclock_secs: 2.0,
                    int_gemm_sites: Default::default(),
                },
            },
            SweepRowReport {
                label: "12".into(),
                normalized: 1.0,
                run: RunReport {
                    name: "fig-12".into(),
                    label: "12".into(),
                    backend: "native".into(),
                    test_error: 0.125,
                    train_loss: 0.625,
                    final_int_bits: vec![4],
                    steps: 40,
                    wallclock_secs: 0.5,
                    int_gemm_sites: Default::default(),
                },
            },
        ],
    }
}

/// The emitted JSON is golden: byte-for-byte stable across releases
/// (sorted keys, fixed indentation, versioned schema).
#[test]
fn sweep_report_serialization_matches_golden_file() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/sweep_report_golden.json");
    let golden = std::fs::read_to_string(path).expect("golden file");
    assert_eq!(golden_report().to_json_string(), golden);
}

/// And the golden document round-trips: config/json.rs parses it back
/// into an identical report.
#[test]
fn sweep_report_roundtrips_through_config_json() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/sweep_report_golden.json");
    let golden = std::fs::read_to_string(path).expect("golden file");
    let doc = lpdnn::config::json::parse(&golden).expect("golden parses");
    let report = SweepReport::from_json(&doc).expect("golden deserializes");
    assert_eq!(report, golden_report());
    // serialize → parse → serialize is a fixed point
    let again = lpdnn::config::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(SweepReport::from_json(&again).unwrap(), report);
}

/// A real (tiny) sweep produces a report whose JSON parses back with
/// the same rows — the same check CI's sweep smoke step performs on the
/// CLI output.
#[test]
fn real_sweep_report_roundtrips() {
    let baseline = clusters_cfg("rep-base", Arithmetic::Float32, 4);
    let mut cfg = clusters_cfg("rep-p", Arithmetic::Float32, 4);
    cfg.arithmetic = Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 };
    let points = vec![SweepPoint { label: "20".into(), cfg }];
    let mut session = Session::new(BackendSpec::native()).with_jobs(2);
    let outcome = session.sweep(&baseline, &points).unwrap();

    let report = SweepReport::from_outcome(&outcome, session.jobs());
    let parsed = lpdnn::config::json::parse(&report.to_json_string()).unwrap();
    let back = SweepReport::from_json(&parsed).unwrap();
    assert_eq!(back.rows.len(), 1);
    assert_eq!(back.rows[0].label, "20");
    assert_eq!(back.rows[0].run.test_error, outcome.rows[0].test_error);
    assert_eq!(back.baseline.test_error, outcome.baseline.test_error);
    assert_eq!(back.jobs, 2);
}
