//! Early integration smoke test: the pi_mlp_fixed_train artifact produced by
//! `python -m compile.aot` must parse, compile and execute on the PJRT CPU
//! client of xla_extension 0.5.1 (the whole AOT bridge in one test).
//!
//! Run `make artifacts` first; the test is skipped if artifacts are
//! missing. The whole file needs the `xla` crate, so it only compiles
//! with `--features pjrt`.

#![cfg(feature = "pjrt")]

use xla::{HloModuleProto, Literal, PjRtClient, XlaComputation};

fn zeros(dims: &[i64]) -> Literal {
    let n: i64 = dims.iter().product();
    Literal::vec1(&vec![0f32; n as usize]).reshape(dims).unwrap()
}

fn filled(dims: &[i64], v: f32) -> Literal {
    let n: i64 = dims.iter().product();
    Literal::vec1(&vec![v; n as usize]).reshape(dims).unwrap()
}

#[test]
fn pi_mlp_train_artifact_executes() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/pi_mlp_fixed_train.hlo.txt");
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not built (run `make artifacts`)");
        return;
    }
    let client = PjRtClient::cpu().expect("cpu client");
    let proto = HloModuleProto::from_text_file(path).expect("parse hlo text");
    let exe = client.compile(&XlaComputation::from_proto(&proto)).expect("compile");

    let (u, k, b, g, nl) = (128i64, 4i64, 64i64, 24i64, 3i64);
    let mut inputs: Vec<Literal> = Vec::new();
    // params w0,b0,w1,b1,w2,b2 (tiny nonzero weights so loss is finite)
    inputs.push(filled(&[k, 784, u], 0.01));
    inputs.push(zeros(&[k, u]));
    inputs.push(filled(&[k, u, u], 0.01));
    inputs.push(zeros(&[k, u]));
    inputs.push(filled(&[u, 10], 0.01));
    inputs.push(zeros(&[10]));
    // velocities
    inputs.push(zeros(&[k, 784, u]));
    inputs.push(zeros(&[k, u]));
    inputs.push(zeros(&[k, u, u]));
    inputs.push(zeros(&[k, u]));
    inputs.push(zeros(&[u, 10]));
    inputs.push(zeros(&[10]));
    // x, y
    inputs.push(filled(&[b, 784], 0.5));
    let mut y = vec![0f32; (b * 10) as usize];
    for i in 0..b as usize {
        y[i * 10 + (i % 10)] = 1.0;
    }
    inputs.push(Literal::vec1(&y).reshape(&[b, 10]).unwrap());
    // lr, mom, maxnorm, seed
    inputs.push(Literal::from(0.1f32));
    inputs.push(Literal::from(0.5f32));
    inputs.push(Literal::from(0.0f32));
    inputs.push(Literal::from(42.0f32));
    // rates, steps, maxvs (all zero = no dropout, float32 passthrough)
    inputs.push(zeros(&[nl]));
    inputs.push(zeros(&[g]));
    inputs.push(zeros(&[g]));

    let result = exe.execute::<Literal>(&inputs).expect("execute")[0][0]
        .to_literal_sync()
        .expect("to literal");
    let outs = result.to_tuple().expect("tuple outputs");
    assert_eq!(outs.len(), 12 + 2, "params' + vels' + loss + overflow");

    let loss = outs[12].get_first_element::<f32>().expect("loss");
    assert!(loss.is_finite() && loss > 0.0, "loss = {loss}");

    let overflow = outs[13].to_vec::<f32>().expect("overflow");
    assert_eq!(overflow.len(), (g * 3) as usize);
    // n_total of group l0.z = k * batch * units
    assert_eq!(overflow[2 * 3 + 2], (k * b * u) as f32);
    println!("smoke ok: loss={loss}");
}
