//! Data-parallel parity: the acceptance gate for `--dp-workers` /
//! `LPDNN_DP_WORKERS`.
//!
//! The sharded step's contract is that the worker count is a pure
//! throughput knob — it must never change a bit. For every worker count
//! N ∈ {1, 2, 3, 4} (including uneven shard tails), [`Network::train_step`]
//! has to produce exactly the 1-worker step's `f32::to_bits` loss, the
//! exact `QuantStats` overflow matrix, and u32-bit-identical parameters
//! and velocities, across:
//!
//! * fixed and float32/float16 arithmetics,
//! * deterministic and stochastic rounding (the per-site counter-based
//!   streams are keyed on full-batch element indices, so shard
//!   boundaries are invisible to them),
//! * simulated and integer-domain fused GEMMs (`int_domain`),
//! * dropout on and off (masks are pre-drawn full-batch by the driver),
//! * the maxout-MLP and conv topologies.
//!
//! On top of single-step parity, a dynamic-scaling run proves the whole
//! control loop is worker-count-invariant: merged overflow counters feed
//! [`ScaleController::after_batch`], so the scale-move decision log and
//! final per-group formats at N=4 replay N=1 exactly. A property test
//! pins the reduction itself: the fixed binary-tree merge of worker
//! stats equals a flat left fold for any worker count and any counters.

use lpdnn::arith::{FixedFormat, QuantStats, RoundMode};
use lpdnn::coordinator::ScaleController;
use lpdnn::golden::{merge_stats_tree, Dropout, Network, StepOptions};
use lpdnn::tensor::{Pcg32, Tensor};
use lpdnn::testing::{
    forall, mlp_batch, mlp_state, spatial_batch, tiny_conv_spec, tiny_mlp, topology_state,
    TINY_CONV_CLASSES, TINY_CONV_SHAPE,
};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Step-trace of a short training run: per-step (loss bits, overflow
/// bits) plus the final parameter and velocity bits.
type Trace = (Vec<(u32, Vec<u32>)>, Vec<Vec<u32>>, Vec<Vec<u32>>);

fn run_steps(
    net: &Network,
    state: impl Fn() -> (lpdnn::golden::Params, lpdnn::golden::Params),
    x: &Tensor,
    y: &Tensor,
    ctrl: &ScaleController,
    opts: impl Fn() -> StepOptions,
    steps: usize,
) -> Trace {
    let (mut params, mut vels) = state();
    let mut trace = Vec::new();
    for _ in 0..steps {
        let out = net.train_step(&mut params, &mut vels, x, y, 0.1, 0.5, 2.0, ctrl, opts());
        trace.push((out.loss.to_bits(), bits(out.overflow.data())));
    }
    let p = params.iter().map(|t| bits(t.data())).collect();
    let v = vels.iter().map(|t| bits(t.data())).collect();
    (trace, p, v)
}

fn assert_traces_equal(tag: &str, got: &Trace, want: &Trace) {
    assert_eq!(got.0, want.0, "{tag}: loss/overflow trace diverged");
    for (i, (a, b)) in got.1.iter().zip(&want.1).enumerate() {
        assert_eq!(a, b, "{tag}: param {i} bits diverged");
    }
    for (i, (a, b)) in got.2.iter().zip(&want.2).enumerate() {
        assert_eq!(a, b, "{tag}: vel {i} bits diverged");
    }
}

/// Batch 10 over N=3 shards as 4+3+3 and N=4 as 3+3+2+2 — the uneven
/// tails are the cases a row-count bug would corrupt first.
const UNEVEN_BATCH: usize = 10;

/// Worker counts beyond the batch clamp to the batch, so N=16 on a
/// 10-row batch is also legal (and must also be bit-identical).
const WORKER_COUNTS: [usize; 4] = [2, 3, 4, 16];

#[test]
fn mlp_dp_steps_bit_identical_across_worker_counts() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    let cases: Vec<(&str, ScaleController, bool)> = vec![
        (
            "fixed 10.3/12.0",
            ScaleController::fixed(
                net.n_groups(),
                FixedFormat::new(10, 3),
                FixedFormat::new(12, 0),
            ),
            false,
        ),
        (
            "float32",
            ScaleController::fixed(net.n_groups(), FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            false,
        ),
        (
            "float16",
            ScaleController::fixed(net.n_groups(), FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            true,
        ),
    ];
    let (x, y) = mlp_batch(s, UNEVEN_BATCH, 0xD9A1);
    for (label, ctrl, half) in &cases {
        for mode in [RoundMode::HalfAway, RoundMode::Stochastic] {
            for int_domain in [false, true] {
                let opts = |dp: usize| {
                    move || StepOptions {
                        mode,
                        half: *half,
                        dropout: None,
                        fused: true,
                        int_domain,
                        dp_workers: dp,
                        ..Default::default()
                    }
                };
                let serial = run_steps(&net, || mlp_state(s, 0x5EED), &x, &y, ctrl, opts(1), 3);
                for n in WORKER_COUNTS {
                    let dp = run_steps(&net, || mlp_state(s, 0x5EED), &x, &y, ctrl, opts(n), 3);
                    let tag =
                        format!("mlp {label} {mode:?} int_domain={int_domain} dp_workers={n}");
                    assert_traces_equal(&tag, &dp, &serial);
                }
            }
        }
    }
}

#[test]
fn conv_dp_steps_bit_identical_across_worker_counts() {
    let spec = tiny_conv_spec();
    let net = Network::from_topology_shaped(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES).unwrap();
    let ctrl =
        ScaleController::fixed(net.n_groups(), FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    // batch 6: N=4 shards as 2+2+1+1, so single-row conv shards run too
    let (x, y) = spatial_batch(TINY_CONV_SHAPE, 6, TINY_CONV_CLASSES, 0xC0DE);
    let state = || topology_state(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES, 0xF00D);
    for mode in [RoundMode::HalfAway, RoundMode::Stochastic] {
        for int_domain in [false, true] {
            let opts = |dp: usize| {
                move || StepOptions {
                    mode,
                    int_domain,
                    dp_workers: dp,
                    ..Default::default()
                }
            };
            let serial = run_steps(&net, state, &x, &y, &ctrl, opts(1), 2);
            for n in [2, 3, 4] {
                let dp = run_steps(&net, state, &x, &y, &ctrl, opts(n), 2);
                let tag = format!("conv {mode:?} int_domain={int_domain} dp_workers={n}");
                assert_traces_equal(&tag, &dp, &serial);
            }
        }
    }
}

/// Dropout masks are pre-drawn full-batch by the driver (graph order,
/// one RNG stream), so sharding must not perturb the draw sequence —
/// the strictest mask-order test is simply bit-parity under dropout.
#[test]
fn dropout_dp_steps_bit_identical() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    let ctrl =
        ScaleController::fixed(net.n_groups(), FixedFormat::new(12, 3), FixedFormat::new(12, 0));
    let (x, y) = mlp_batch(s, UNEVEN_BATCH, 0xD80);
    for (ri, rh) in [(0.2f32, 0.5f32), (0.0, 0.5), (0.2, 0.0)] {
        let opts = |dp: usize| {
            move || StepOptions {
                dropout: Some(Dropout {
                    input_rate: ri,
                    hidden_rate: rh,
                    rng: Pcg32::seeded(0xABCD),
                }),
                dp_workers: dp,
                ..Default::default()
            }
        };
        let serial = run_steps(&net, || mlp_state(s, 7), &x, &y, &ctrl, opts(1), 2);
        for n in [2, 4] {
            let dp = run_steps(&net, || mlp_state(s, 7), &x, &y, &ctrl, opts(n), 2);
            assert_traces_equal(&format!("dropout ({ri},{rh}) dp_workers={n}"), &dp, &serial);
        }
    }
}

/// Thread scheduling is real at N=4 (scoped OS threads), so repeat runs
/// guard against any nondeterminism the parity matrix could mask.
#[test]
fn dp_step_repeats_are_bit_deterministic() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    let ctrl =
        ScaleController::fixed(net.n_groups(), FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    let (x, y) = mlp_batch(s, UNEVEN_BATCH, 0x11);
    let opts = || StepOptions {
        mode: RoundMode::Stochastic,
        dp_workers: 4,
        ..Default::default()
    };
    let a = run_steps(&net, || mlp_state(s, 9), &x, &y, &ctrl, opts, 3);
    let b = run_steps(&net, || mlp_state(s, 9), &x, &y, &ctrl, opts, 3);
    assert_traces_equal("repeat at dp_workers=4", &a, &b);
}

/// End-to-end dynamic scaling: merged worker overflow counters drive the
/// controller's per-group scale moves, so an N=4 run must replay the
/// N=1 run's decision log, final formats, and parameter bits exactly.
#[test]
fn dynamic_scaling_run_is_worker_count_invariant() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    let (x, y) = mlp_batch(s, 16, 0xD1CE);
    let steps = 8;
    let run = |dp: usize| {
        let mut ctrl = ScaleController::dynamic(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
            1e-3,
            32, // update every 2 steps at batch 16
        );
        let (mut params, mut vels) = mlp_state(s, 0x5EED);
        let mut losses = Vec::new();
        for t in 0..steps {
            let opts = StepOptions { dp_workers: dp, ..Default::default() };
            let out = net.train_step(&mut params, &mut vels, &x, &y, 0.1, 0.5, 2.0, &ctrl, opts);
            losses.push(out.loss.to_bits());
            ctrl.observe_matrix(&out.overflow);
            ctrl.after_batch(16, t);
        }
        let pbits: Vec<Vec<u32>> = params.iter().map(|t| bits(t.data())).collect();
        (losses, ctrl.decisions_log.clone(), ctrl.int_bits_vec(), pbits)
    };
    let serial = run(1);
    let dp = run(4);
    assert_eq!(dp.0, serial.0, "dynamic: loss trace");
    assert_eq!(dp.1, serial.1, "dynamic: scale-move decision log");
    assert_eq!(dp.2, serial.2, "dynamic: final int_bits table");
    assert_eq!(dp.3, serial.3, "dynamic: param bits");
    assert!(
        !serial.1.is_empty(),
        "fixture drifted: the dynamic run made no scale moves, so the \
         decision-log comparison proved nothing"
    );
}

/// The reduction contract in isolation: for any worker count and any
/// counter values, the fixed binary-tree merge equals a flat left fold
/// (u64 counter sums are associative), and a single worker's stats pass
/// through unchanged.
#[test]
fn merge_stats_tree_equals_flat_fold_for_any_schedule() {
    forall("merge_stats_tree flat ≡ tree", |g| {
        let n_workers = g.usize_range(1, 6);
        let n_groups = g.usize_range(1, 8);
        let levels: Vec<Vec<QuantStats>> = (0..n_workers)
            .map(|_| {
                (0..n_groups)
                    .map(|_| QuantStats {
                        n_over: g.u32() as u64,
                        n_half: g.u32() as u64,
                        n_total: g.u32() as u64,
                    })
                    .collect()
            })
            .collect();
        let mut flat = vec![QuantStats::default(); n_groups];
        for w in &levels {
            for (acc, st) in flat.iter_mut().zip(w) {
                acc.merge(*st);
            }
        }
        let tree = merge_stats_tree(levels.clone());
        assert_eq!(tree.len(), n_groups);
        for (a, b) in tree.iter().zip(&flat) {
            assert_eq!((a.n_over, a.n_half, a.n_total), (b.n_over, b.n_half, b.n_total));
        }
        if n_workers == 1 {
            for (a, b) in tree.iter().zip(&levels[0]) {
                assert_eq!((a.n_over, a.n_half, a.n_total), (b.n_over, b.n_half, b.n_total));
            }
        }
    });
}
