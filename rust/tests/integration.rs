//! Integration tests over the full training stack, backend-agnostic.
//!
//! These run on the self-contained native backend by default — no
//! artifacts, no Python, no external crates — so `cargo test` exercises
//! real end-to-end training on a fresh checkout. With `--features pjrt`
//! (plus `make artifacts`) the same suite also cross-validates the
//! compiled path (see `pjrt_bridge` below and tests/pjrt_smoke.rs).

use lpdnn::config::{Arithmetic, DataConfig, ExperimentConfig, TrainConfig};
use lpdnn::coordinator::Session;
use lpdnn::runtime::BackendSpec;

fn cfg(name: &str, arith: Arithmetic, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        model: "pi_mlp".into(),
        arithmetic: arith,
        train: TrainConfig {
            steps,
            lr_start: 0.15,
            lr_end: 0.02,
            eval_every: 0,
            seed: 99,
            max_norm: 3.0,
            ..Default::default()
        },
        data: DataConfig { dataset: "digits".into(), n_train: 512, n_test: 256 },
        ..Default::default()
    }
}

fn run(c: ExperimentConfig) -> lpdnn::coordinator::RunResult {
    Session::new(BackendSpec::native()).run(c).unwrap()
}

#[test]
fn float32_training_learns() {
    let r = run(cfg("it-f32", Arithmetic::Float32, 40));
    assert_eq!(r.backend_name, "native");
    assert!(r.test_error < 0.35, "error {:.3}", r.test_error);
    assert!(r.train_loss < 0.8, "loss {}", r.train_loss);
    // loss curve is recorded for every step
    assert_eq!(r.metrics.losses.len(), 40);
    // loss must actually decrease
    let first = r.metrics.losses[0].1;
    assert!(r.train_loss < first * 0.5, "{first} -> {}", r.train_loss);
}

#[test]
fn dynamic_fixed_point_trains_and_moves_scales() {
    // Without sensible initial scales the gradient groups quantize to
    // zero (the paper's own observation — section 9.3 finds initial
    // scaling factors by training at higher precision first), so the
    // canonical dynamic run uses a short high-precision warmup.
    let arith = Arithmetic::Dynamic {
        bits_comp: 10,
        bits_up: 12,
        max_overflow_rate: 1e-4,
        update_every_examples: 512,
        init_int_bits: 3,
        warmup_steps: 20,
    };
    let r = run(cfg("it-dyn", arith, 40));
    assert!(r.test_error < 0.4, "error {:.3}", r.test_error);
    // the controller must have moved at least some scales away from init
    assert!(
        r.final_int_bits.iter().any(|&b| b != 3),
        "no scale moves: {:?}",
        r.final_int_bits
    );
}

#[test]
fn warmup_transfers_scales() {
    let arith = Arithmetic::Dynamic {
        bits_comp: 10,
        bits_up: 12,
        max_overflow_rate: 1e-4,
        update_every_examples: 100_000, // never tick during main phase
        init_int_bits: 3,
        warmup_steps: 24,
    };
    let r = run(cfg("it-warm", arith, 10));
    // with no main-phase ticks, any deviation from init came from warmup
    assert!(
        r.final_int_bits.iter().any(|&b| b != 3),
        "warmup had no effect: {:?}",
        r.final_int_bits
    );
}

#[test]
fn half_precision_close_to_float32() {
    let f32r = run(cfg("it-f32b", Arithmetic::Float32, 30));
    let halfr = run(cfg("it-half", Arithmetic::Half, 30));
    // Paper Table 3: half ≈ float32. Allow generous slack at tiny budget.
    assert!(
        halfr.test_error <= f32r.test_error + 0.1,
        "half {:.3} vs f32 {:.3}",
        halfr.test_error,
        f32r.test_error
    );
}

#[test]
fn severe_quantization_degrades() {
    let good = run(cfg("it-base", Arithmetic::Float32, 30));
    let bad_arith = Arithmetic::Fixed { bits_comp: 6, bits_up: 6, int_bits: 5 };
    let bad = run(cfg("it-bad", bad_arith, 30));
    // the paper's cliff: 6-bit fixed point must be clearly worse
    assert!(
        bad.test_error > good.test_error + 0.1,
        "expected degradation: bad {:.3} vs good {:.3}",
        bad.test_error,
        good.test_error
    );
}

#[test]
fn deterministic_given_seed() {
    let a = run(cfg("it-det", Arithmetic::Float32, 10));
    let b = run(cfg("it-det", Arithmetic::Float32, 10));
    assert_eq!(a.test_error, b.test_error);
    assert_eq!(a.metrics.losses, b.metrics.losses);
}

#[test]
fn dropout_training_stays_finite_and_deterministic() {
    let mut c = cfg("it-drop", Arithmetic::Float32, 20);
    c.train.dropout_input = 0.2;
    c.train.dropout_hidden = 0.5;
    let a = run(c.clone());
    let b = run(c);
    assert!(a.metrics.losses.iter().all(|&(_, l)| l.is_finite()));
    assert_eq!(a.metrics.losses, b.metrics.losses, "dropout must be seeded");
}

#[test]
fn one_session_serves_many_runs() {
    // sweep-style reuse: one session (and its backend) across runs
    let mut session = Session::new(BackendSpec::native());
    let a = session.run(cfg("it-multi-a", Arithmetic::Float32, 8)).unwrap();
    let b = session
        .run(cfg(
            "it-multi-b",
            Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 },
            8,
        ))
        .unwrap();
    assert!(a.test_error.is_finite() && b.test_error.is_finite());
    assert!(session.supports_model("pi_mlp").unwrap());
}

/// Cross-validation of the compiled PJRT path against the golden model —
/// only meaningful (and only compiled) with `--features pjrt`; skips at
/// runtime when `make artifacts` has not run.
#[cfg(feature = "pjrt")]
mod pjrt_bridge {
    use super::*;
    use lpdnn::arith::FixedFormat;
    use lpdnn::coordinator::ScaleController;
    use lpdnn::runtime::{Engine, Manifest};

    fn setup() -> Option<(Engine, Manifest)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping integration test: run `make artifacts` first");
            return None;
        }
        let manifest = Manifest::load(dir).expect("manifest loads");
        let engine = Engine::cpu().expect("PJRT cpu client");
        Some((engine, manifest))
    }

    /// The golden pure-rust train step must agree with the compiled
    /// artifact.
    #[test]
    fn golden_model_matches_compiled_step() {
        use lpdnn::golden::{self, MlpShape};
        use lpdnn::runtime::literal_util::*;
        use lpdnn::tensor::{ops, Pcg32, Tensor};
        use xla::Literal;

        let Some((engine, manifest)) = setup() else { return };
        let (engine, manifest) = (&engine, &manifest);
        let model = manifest.model("pi_mlp").unwrap();
        let exe = engine.load(manifest.artifact("pi_mlp", "fixed", "train").unwrap()).unwrap();

        let shape = MlpShape::for_dataset("digits", 128, 4).unwrap();
        let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(14, 1));

        // identical initial state for both paths, pre-quantized onto the grid
        let mut rng = Pcg32::seeded(4242);
        let mut params: Vec<Tensor> = model
            .params
            .iter()
            .map(|s| {
                let mut t = s.init.realize(&s.shape, &mut rng);
                lpdnn::arith::Quantizer::from_format(ctrl.format(s.group()))
                    .apply_slice(t.data_mut());
                t
            })
            .collect();
        let mut vels: Vec<Tensor> =
            model.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();

        let batch = model.train_batch;
        let mut drng = Pcg32::seeded(777);
        let x = Tensor::from_vec(
            &[batch, 784],
            (0..batch * 784).map(|_| drng.uniform()).collect(),
        );
        let labels: Vec<usize> = (0..batch).map(|_| drng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);

        let (lr, mom, max_norm) = (0.1f32, 0.5f32, 2.0f32);

        // --- compiled path ---
        let mut inputs: Vec<Literal> = Vec::new();
        for p in &params {
            inputs.push(tensor_to_literal(p).unwrap());
        }
        for v in &vels {
            inputs.push(tensor_to_literal(v).unwrap());
        }
        inputs.push(tensor_to_literal(&x).unwrap());
        inputs.push(tensor_to_literal(&y).unwrap());
        inputs.push(scalar(lr));
        inputs.push(scalar(mom));
        inputs.push(scalar(max_norm));
        inputs.push(scalar(0.0)); // seed (dropout off anyway)
        inputs.push(slice_to_literal(&vec![0.0; 3], &[3]).unwrap()); // rates = 0
        inputs.push(slice_to_literal(&ctrl.steps_vec(), &[24]).unwrap());
        inputs.push(slice_to_literal(&ctrl.maxvs_vec(), &[24]).unwrap());
        let out = exe.run(&inputs).unwrap();
        let dev_loss = literal_to_scalar(&out[12]).unwrap();
        let dev_overflow = literal_to_tensor(&out[13]).unwrap();
        let dev_params: Vec<Tensor> =
            (0..6).map(|i| literal_to_tensor(&out[i]).unwrap()).collect();

        // --- golden path ---
        let gout = golden::train_step(
            shape,
            &mut params,
            &mut vels,
            &x,
            &y,
            lr,
            mom,
            max_norm,
            &ctrl,
            lpdnn::arith::RoundMode::HalfAway,
        );

        // losses agree to float32 reassociation tolerance
        assert!(
            (gout.loss - dev_loss).abs() < 2e-3,
            "loss: golden {} vs device {dev_loss}",
            gout.loss
        );

        // overflow totals agree exactly; over/half counts within a whisker
        // (values that land exactly on a counting threshold can tip either
        // way under different accumulation orders)
        for g in 0..24 {
            assert_eq!(
                gout.overflow.at2(g, 2),
                dev_overflow.at2(g, 2),
                "n_total mismatch in group {g}"
            );
            for col in 0..2 {
                let a = gout.overflow.at2(g, col);
                let b = dev_overflow.at2(g, col);
                let tol = 2.0 + 0.002 * gout.overflow.at2(g, 2);
                assert!((a - b).abs() <= tol, "group {g} col {col}: golden {a} vs device {b}");
            }
        }

        // updated parameters agree elementwise up to one quantization step
        for (i, (gp, dp)) in params.iter().zip(&dev_params).enumerate() {
            let spec = &model.params[i];
            let step = ctrl.format(spec.group()).step();
            let mut max_diff = 0.0f32;
            for (a, b) in gp.data().iter().zip(dp.data()) {
                max_diff = max_diff.max((a - b).abs());
            }
            assert!(
                max_diff <= step + 1e-6,
                "{}: max diff {max_diff} > step {step}",
                spec.name
            );
            // and the overwhelming majority agree exactly
            let same = gp
                .data()
                .iter()
                .zip(dp.data())
                .filter(|(a, b)| a == b)
                .count();
            let frac = same as f64 / gp.len() as f64;
            assert!(frac > 0.99, "{}: only {frac:.4} exact agreement", spec.name);
        }
    }
}
