//! Native backend tests: parity with the golden step, and a fast
//! end-to-end session smoke run that needs no AOT artifacts — the
//! acceptance gate for the self-contained training path.

use lpdnn::arith::{FixedFormat, Quantizer, RoundMode};
use lpdnn::config::{Arithmetic, DataConfig, ExperimentConfig, TrainConfig};
use lpdnn::coordinator::{ScaleController, Session, SweepPoint};
use lpdnn::golden::{self, MlpShape};
use lpdnn::runtime::{Backend, BackendSpec, ModelInfo, NativeBackend, StepParams};
use lpdnn::tensor::{ops, Pcg32, Tensor};

fn digits_cfg(name: &str, arith: Arithmetic, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        model: "pi_mlp".into(),
        arithmetic: arith,
        train: TrainConfig {
            steps,
            lr_start: 0.15,
            lr_end: 0.02,
            seed: 4242,
            max_norm: 3.0,
            ..Default::default()
        },
        data: DataConfig { dataset: "digits".into(), n_train: 512, n_test: 256 },
        ..Default::default()
    }
}

/// NativeBackend must produce EXACTLY the golden step's losses and
/// updates when driven from identical state (it is the golden model
/// behind the Backend trait — any drift is a plumbing bug).
#[test]
fn native_backend_matches_golden_step_exactly() {
    let cfg = digits_cfg("parity", Arithmetic::Fixed { bits_comp: 12, bits_up: 14, int_bits: 3 }, 1);
    let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(14, 3));

    // --- backend path ---
    let mut backend = NativeBackend::new();
    let model = backend.begin_run(&cfg).unwrap();
    let mut rng = Pcg32::seeded(777);
    backend.init_state(&ctrl, &mut rng).unwrap();
    let params_before = backend.params_host().unwrap();

    // --- golden path from the identical state ---
    let shape = MlpShape::for_dataset("digits", 128, 4).unwrap();
    let mut gparams = params_before.clone();
    let mut gvels: Vec<Tensor> =
        model.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();

    // one deterministic batch in dataset layout [n, 28, 28, 1]
    let mut drng = Pcg32::seeded(4141);
    let batch = model.train_batch;
    let x = Tensor::from_vec(
        &[batch, 28, 28, 1],
        (0..batch * 784).map(|_| drng.uniform()).collect(),
    );
    let labels: Vec<usize> = (0..batch).map(|_| drng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);

    let (lr, mom, max_norm) = (0.1f32, 0.5f32, 2.0f32);
    let hp = StepParams {
        lr,
        momentum: mom,
        max_norm,
        dropout_input: 0.0,
        dropout_hidden: 0.0,
        t: 0,
    };
    let out = backend.train_step(&ctrl, &x, &y, &hp).unwrap();

    let x_flat = x.clone().reshape(&[batch, 784]);
    let gout = golden::train_step(
        shape, &mut gparams, &mut gvels, &x_flat, &y, lr, mom, max_norm, &ctrl,
        RoundMode::HalfAway,
    );

    assert_eq!(out.loss, gout.loss, "losses must be bit-identical");
    assert_eq!(out.overflow.data(), gout.overflow.data(), "overflow matrices");
    let params_after = backend.params_host().unwrap();
    for (i, (bp, gp)) in params_after.iter().zip(&gparams).enumerate() {
        assert_eq!(bp.data(), gp.data(), "param {i} updates must be bit-identical");
    }
    // and the step actually changed the parameters
    assert!(params_after
        .iter()
        .zip(&params_before)
        .any(|(a, b)| a.data() != b.data()));
}

/// Fast end-to-end session smoke test on the synthetic digits dataset:
/// trains, learns, evaluates — with zero artifacts on disk.
#[test]
fn native_session_end_to_end_smoke() {
    let mut session = Session::new(BackendSpec::native());
    let r = session.run(digits_cfg("smoke", Arithmetic::Float32, 40)).unwrap();
    assert_eq!(r.backend_name, "native");
    assert_eq!(r.steps_run, 40);
    assert!(r.test_error < 0.35, "error {:.3}", r.test_error);
    let first = r.metrics.losses[0].1;
    assert!(r.train_loss < first * 0.5, "{first} -> {}", r.train_loss);
}

/// The paper's headline arithmetic end to end on the native path:
/// dynamic 10/12 with warmup stays in the same league as float32.
#[test]
fn native_dynamic_10_12_close_to_float32() {
    let mut session = Session::new(BackendSpec::native());
    let base = session.run(digits_cfg("n-f32", Arithmetic::Float32, 60)).unwrap();
    let arith = Arithmetic::Dynamic {
        bits_comp: 10,
        bits_up: 12,
        max_overflow_rate: 1e-4,
        update_every_examples: 512,
        init_int_bits: 3,
        warmup_steps: 20,
    };
    let dynr = session.run(digits_cfg("n-dyn", arith, 60)).unwrap();
    assert!(
        dynr.test_error <= base.test_error + 0.15,
        "dynamic {:.3} vs float32 {:.3}",
        dynr.test_error,
        base.test_error
    );
}

/// Session::sweep drives many runs over one shared native backend.
#[test]
fn sweep_runs_on_native_backend() {
    let mut session = Session::new(BackendSpec::native());
    let baseline = digits_cfg("sw-base", Arithmetic::Float32, 8);
    let points: Vec<SweepPoint> = [20i32, 8]
        .iter()
        .map(|&bits| {
            let mut cfg = baseline.clone();
            cfg.name = format!("sw-{bits}");
            cfg.arithmetic = Arithmetic::Fixed { bits_comp: bits, bits_up: bits, int_bits: 5 };
            SweepPoint { label: format!("{bits}"), cfg }
        })
        .collect();
    let outcome = session.sweep(&baseline, &points).unwrap();
    assert!(outcome.baseline_error().is_finite());
    assert_eq!(outcome.rows.len(), 2);
    assert!(outcome.rows.iter().all(|r| r.normalized.is_finite()));
}

/// Eval batches with wrap-padding: only the first n_real examples count.
#[test]
fn eval_errors_honors_n_real() {
    let cfg = digits_cfg("eval", Arithmetic::Float32, 1);
    let mut backend = NativeBackend::new();
    backend.begin_run(&cfg).unwrap();
    let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
    let mut rng = Pcg32::seeded(5);
    backend.init_state(&ctrl, &mut rng).unwrap();
    let n = 16;
    let x = Tensor::from_vec(&[n, 784], (0..n * 784).map(|_| rng.uniform()).collect());
    let labels: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);
    let full = backend.eval_errors(&ctrl, &x, &y, n).unwrap();
    let half = backend.eval_errors(&ctrl, &x, &y, n / 2).unwrap();
    assert!(full <= n);
    assert!(half <= full, "fewer counted examples cannot yield more errors");
}

/// pi_mlp_wide doubles the hidden units (paper 9.2/9.3 width ablation)
/// and must run natively too.
#[test]
fn native_wide_model_runs() {
    let wide = ModelInfo::builtin("pi_mlp_wide").unwrap();
    assert_eq!(wide.params[0].shape, vec![4, 784, 256]);
    let mut cfg = digits_cfg("wide", Arithmetic::Float32, 6);
    cfg.model = "pi_mlp_wide".into();
    let r = Session::new(BackendSpec::native()).run(cfg).unwrap();
    assert!(r.test_error.is_finite());
}

/// Builtin model metadata must agree with the golden test topology and
/// the manifest conventions (group table layout, init specs).
#[test]
fn builtin_model_is_consistent() {
    let m = ModelInfo::builtin("pi_mlp").unwrap();
    assert_eq!(m.n_layers, 3);
    assert_eq!(m.n_groups, 24);
    assert_eq!(m.group_names.len(), 24);
    assert_eq!(m.input_shape, vec![784]);
    assert_eq!(m.params.len(), 6);
    assert_eq!(m.params[0].group(), 0);
    assert_eq!(m.params[1].group(), 1);
    assert_eq!(m.params[4].group(), 16); // l2.w
    assert_eq!(m.group_names[0], "l0.w");
    assert_eq!(m.group_names[23], "l2.dh");
    // the conv nets are builtin topologies too (im2col-lowered natively)
    let conv = ModelInfo::builtin("conv").unwrap();
    assert_eq!((conv.n_layers, conv.n_groups), (4, 32));
    assert_eq!(conv.input_shape, vec![28, 28, 1]);
    assert!(ModelInfo::builtin("resnet").is_none());

    // init realizes to the declared shapes and quantizes cleanly
    let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    let mut rng = Pcg32::seeded(9);
    for spec in &m.params {
        let mut t = spec.init.realize(&spec.shape, &mut rng);
        Quantizer::from_format(ctrl.format(spec.group())).apply_slice(t.data_mut());
        assert_eq!(t.shape(), &spec.shape[..]);
        assert_eq!(t.len(), spec.len());
    }
}
