//! Serve-pipeline integration tests: the acceptance gate for
//! `lpdnn serve`.
//!
//! The server's contract is that batching is a pure latency/throughput
//! trade — it must never change an answer. Every response from
//! [`serve_closed_loop`] has to be u32-bit-identical to a direct
//! single-example forward pass of the same checkpoint, whatever the
//! batch composition (max-batch 1 vs deep batches), producer
//! concurrency, worker count, or integer-domain kernel setting.

use std::sync::Arc;
use std::time::Duration;

use lpdnn::checkpoint::Checkpoint;
use lpdnn::config::{
    Arithmetic, ConvStageSpec, DataConfig, ExperimentConfig, TopologySpec, TrainConfig,
};
use lpdnn::coordinator::Session;
use lpdnn::data::{Dataset, Split};
use lpdnn::golden::Network;
use lpdnn::runtime::BackendSpec;
use lpdnn::serve::{eval_options, serve_closed_loop, serve_open_loop, ServeOptions};
use lpdnn::tensor::{ops, Pcg32, Tensor};

/// Train a tiny model and capture it as a checkpoint (the serve
/// entrypoint's input).
fn trained_checkpoint(spec: TopologySpec, dataset: &str) -> Checkpoint {
    let cfg = ExperimentConfig {
        name: format!("serve-{}", spec.name),
        model: spec.name.clone(),
        topology: Some(spec),
        // fixed-point arithmetic so the integer-domain kernels engage
        arithmetic: Arithmetic::Fixed { bits_comp: 12, bits_up: 14, int_bits: 4 },
        train: TrainConfig { steps: 4, seed: 99, ..Default::default() },
        data: DataConfig { dataset: dataset.into(), n_train: 128, n_test: 48 },
        ..Default::default()
    };
    let mut session = Session::new(BackendSpec::native());
    let result = session.run(cfg.clone()).unwrap();
    let params = session.params_host().unwrap();
    Checkpoint::from_run(&cfg, &result, params).unwrap()
}

fn fixed_mlp_checkpoint() -> Checkpoint {
    let mut spec = TopologySpec::mlp(vec![8, 6], 2);
    spec.train_batch = 8;
    spec.eval_batch = 8;
    trained_checkpoint(spec, "clusters")
}

fn conv_checkpoint() -> Checkpoint {
    let mut spec = TopologySpec::conv_net(
        vec![ConvStageSpec { channels: 3, ksize: 3, pool: 2 }],
        vec![6],
        2,
    );
    spec.train_batch = 8;
    spec.eval_batch = 8;
    trained_checkpoint(spec, "digits")
}

fn test_split(ckpt: &Checkpoint) -> Split {
    let rng = Pcg32::seeded(ckpt.seed);
    Dataset::generate(&ckpt.dataset, ckpt.n_train, ckpt.n_test, &rng).unwrap().test
}

/// The reference: a batch-of-one forward pass per split example, under
/// the exact [`StepOptions`] the server uses. Returns each example's
/// logits bit pattern and prediction.
fn direct_forwards(
    restored: &lpdnn::checkpoint::Restored,
    params: &[Tensor],
    split: &Split,
    opts: &ServeOptions,
) -> Vec<(Vec<u32>, usize)> {
    let net = Network::from_topology_shaped(&restored.spec, restored.in_shape, restored.n_classes)
        .unwrap();
    let params: lpdnn::golden::Params = params.to_vec();
    let sopts = eval_options(restored, opts);
    (0..split.len())
        .map(|i| {
            let mut dims = vec![1];
            dims.extend(restored.in_shape.dims());
            let x = Tensor::from_vec(&dims, split.example(i).to_vec());
            let logits = net.eval_logits_opt(&params, &x, &restored.ctrl, &sopts);
            let pred = ops::argmax_rows(&logits)[0];
            (logits.data().iter().map(|v| v.to_bits()).collect(), pred)
        })
        .collect()
}

#[test]
fn responses_are_bit_identical_to_single_example_forwards() {
    let ckpt = fixed_mlp_checkpoint();
    let restored = ckpt.restore().unwrap();
    let split = test_split(&ckpt);
    let params = Arc::new(ckpt.params.clone());
    let requests = 40;

    for int_domain in [false, true] {
        let base = ServeOptions {
            requests,
            max_wait: Duration::from_micros(500),
            queue_cap: 16,
            fused: true,
            int_domain,
            ..Default::default()
        };
        let expected = direct_forwards(&restored, &params, &split, &base);
        let expected_errors = (0..requests)
            .filter(|id| expected[id % split.len()].1 != split.labels[id % split.len()])
            .count();

        // degenerate batching, a balanced setup, and an oversubscribed
        // one — answers must not depend on any of it
        for (max_batch, concurrency, workers) in [(1, 1, 1), (8, 4, 2), (4, 8, 3)] {
            let opts = ServeOptions { max_batch, concurrency, workers, ..base.clone() };
            let report = serve_closed_loop(&restored, Arc::clone(&params), &split, &opts)
                .unwrap();
            let tag = format!("int_domain={int_domain} mb={max_batch} c={concurrency} w={workers}");

            assert_eq!(report.responses.len(), requests, "{tag}: response count");
            for (i, r) in report.responses.iter().enumerate() {
                assert_eq!(r.id, i, "{tag}: responses sorted by id");
                let (want_bits, want_pred) = &expected[r.id % split.len()];
                let bits: Vec<u32> = r.logits.iter().map(|v| v.to_bits()).collect();
                assert_eq!(&bits, want_bits, "{tag}: logits drifted for request {i}");
                assert_eq!(r.pred, *want_pred, "{tag}: prediction drifted for request {i}");
            }
            assert_eq!(report.errors, expected_errors, "{tag}: error count");
            assert_eq!(
                report.batch_sizes.iter().sum::<usize>(),
                requests,
                "{tag}: every request shipped in exactly one batch"
            );
            assert!(report.max_fill() <= max_batch, "{tag}: batch cap respected");
            // each worker pre-packs every weight layer exactly once at
            // startup and never re-packs in the steady state (weights
            // and scales are frozen while serving)
            let net = Network::from_topology_shaped(
                &restored.spec,
                restored.in_shape,
                restored.n_classes,
            )
            .unwrap();
            let want_packs =
                if int_domain { (workers * net.n_compute_layers()) as u64 } else { 0 };
            assert_eq!(
                report.weight_pack_builds, want_packs,
                "{tag}: weight packs must be exactly one per worker per layer"
            );
            // dispatch proof: with the integer domain on, no site records
            // `disabled` and at least the hidden layers (whose activations
            // sit on the computation grid) ride the integer kernels; with
            // it off, every dispatch records `disabled`. The raw dataset
            // inputs need not sit on any grid, so layer 0 is allowed to
            // fall back simulated — hence no simulated()==0 assert here.
            let d = &report.int_gemm_dispatch;
            assert!(d.total() > 0, "{tag}: dispatch counters recorded");
            if int_domain {
                assert_eq!(d.disabled, 0, "{tag}: integer domain on, nothing disabled");
                assert!(d.int + d.split > 0, "{tag}: integer kernels served requests");
            } else {
                assert_eq!(
                    d.disabled,
                    d.total(),
                    "{tag}: integer domain off, every dispatch disabled"
                );
            }
            assert!(
                report.latency_percentile(0.99) >= report.latency_percentile(0.50),
                "{tag}: percentiles ordered"
            );
            assert!(report.throughput_rps() > 0.0, "{tag}: throughput measured");
        }
    }
}

#[test]
fn conv_checkpoints_serve_bit_identically() {
    let ckpt = conv_checkpoint();
    let restored = ckpt.restore().unwrap();
    let split = test_split(&ckpt);
    let params = Arc::new(ckpt.params.clone());
    let opts = ServeOptions {
        requests: 16,
        concurrency: 4,
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_micros(500),
        queue_cap: 16,
        fused: true,
        int_domain: true,
        ..Default::default()
    };
    let expected = direct_forwards(&restored, &params, &split, &opts);
    let report = serve_closed_loop(&restored, params, &split, &opts).unwrap();
    for r in &report.responses {
        let (want_bits, want_pred) = &expected[r.id % split.len()];
        let bits: Vec<u32> = r.logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(&bits, want_bits, "conv logits drifted for request {}", r.id);
        assert_eq!(r.pred, *want_pred);
    }
    // conv weight slabs (im2col filter matrices) prepack per worker too
    let net =
        Network::from_topology_shaped(&restored.spec, restored.in_shape, restored.n_classes)
            .unwrap();
    assert_eq!(
        report.weight_pack_builds,
        (opts.workers * net.n_compute_layers()) as u64,
        "conv: one prepack per worker per weight layer"
    );
}

/// Open-loop (seeded-Poisson) load generation is a different arrival
/// process, not a different computation: every response must still be
/// bit-identical to the direct single-example forwards, the report must
/// carry the arrival rate instead of a concurrency, and latency
/// percentiles must stay ordered (queueing delay under a burst counts
/// against the server — `submitted` is stamped at the scheduled arrival,
/// before any back-pressure).
#[test]
fn open_loop_responses_are_bit_identical_and_report_the_rate() {
    let ckpt = fixed_mlp_checkpoint();
    let restored = ckpt.restore().unwrap();
    let split = test_split(&ckpt);
    let params = Arc::new(ckpt.params.clone());
    let requests = 24;
    let opts = ServeOptions {
        requests,
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_micros(500),
        queue_cap: 8,
        fused: true,
        int_domain: true,
        // fast enough that the test finishes quickly, slow enough that
        // batches of several different sizes form
        open_rate: 4000.0,
        open_seed: 7,
        ..Default::default()
    };
    let expected = direct_forwards(&restored, &params, &split, &opts);
    let report = serve_open_loop(&restored, Arc::clone(&params), &split, &opts).unwrap();

    assert_eq!(report.responses.len(), requests, "open loop: response count");
    for (i, r) in report.responses.iter().enumerate() {
        assert_eq!(r.id, i, "open loop: responses sorted by id");
        let (want_bits, want_pred) = &expected[r.id % split.len()];
        let bits: Vec<u32> = r.logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(&bits, want_bits, "open loop: logits drifted for request {i}");
        assert_eq!(r.pred, *want_pred, "open loop: prediction drifted for request {i}");
    }
    assert_eq!(
        report.batch_sizes.iter().sum::<usize>(),
        requests,
        "open loop: every request shipped in exactly one batch"
    );
    assert!(report.max_fill() <= opts.max_batch, "open loop: batch cap respected");
    assert!(
        report.latency_percentile(0.99) >= report.latency_percentile(0.50),
        "open loop: percentiles ordered"
    );
    let json = report.table().to_json().to_string_pretty();
    assert!(json.contains("open_rate_rps"), "open loop report lists the rate: {json}");
    assert!(!json.contains("\"concurrency\""), "open loop report drops concurrency: {json}");

    // identical seed and rate replay the identical arrival schedule, so
    // the answers (already proven bit-exact) come with a deterministic
    // request→batch assignment under a drained queue; a different seed
    // still answers every request correctly
    let again = serve_open_loop(&restored, Arc::clone(&params), &split, &opts).unwrap();
    assert_eq!(again.responses.len(), requests);
    let reseeded = serve_open_loop(
        &restored,
        Arc::clone(&params),
        &split,
        &ServeOptions { open_seed: 8, ..opts.clone() },
    )
    .unwrap();
    for r in &reseeded.responses {
        let (want_bits, _) = &expected[r.id % split.len()];
        let bits: Vec<u32> = r.logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(&bits, want_bits, "open loop reseeded: logits drifted for request {}", r.id);
    }
}

#[test]
fn serve_rejects_degenerate_options_with_clear_errors() {
    let ckpt = fixed_mlp_checkpoint();
    let restored = ckpt.restore().unwrap();
    let split = test_split(&ckpt);
    let params = Arc::new(ckpt.params.clone());
    for (patch, needle) in [
        (ServeOptions { requests: 0, ..Default::default() }, "--requests"),
        (ServeOptions { concurrency: 0, ..Default::default() }, "--concurrency"),
        (ServeOptions { workers: 0, ..Default::default() }, "--workers"),
        (ServeOptions { max_batch: 0, ..Default::default() }, "--max-batch"),
    ] {
        let err = serve_closed_loop(&restored, Arc::clone(&params), &split, &patch).unwrap_err();
        assert!(format!("{err}").contains(needle), "{err}");
    }
    // a parameter set that does not match the model is refused up front
    let mut short = ckpt.params.clone();
    short.pop();
    let err =
        serve_closed_loop(&restored, Arc::new(short), &split, &ServeOptions::default())
            .unwrap_err();
    assert!(format!("{err}").contains("parameter tensors"), "{err}");
}
