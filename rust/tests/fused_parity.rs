//! Cross-kernel parity: the fused quantize-aware GEMM kernels
//! (`matmul_sl_q` / `matmul_nt_sl_q` / `matmul_tn_sl_q` and their
//! `_threads` variants) must be **bit-identical** — exact `u32` output
//! bits *and* exact `QuantStats` counters — to the two-pass reference
//! (plain kernel → bias add → `QuantEpilogue::run` sweep), across:
//!
//! * all three orientations (NN with/without bias, NT, TN),
//! * all four arithmetics (float32 passthrough, fixed, dynamic-regime
//!   fixed, float16 simulation),
//! * all four rounding modes (stochastic via the counter-based stream),
//! * explicit thread counts {1, 2, 4} — on top of which CI runs the
//!   whole suite under `LPDNN_THREADS` ∈ {1, 4} to cover the
//!   auto-threaded entry points,
//! * degenerate shapes (1×1×1, zero-depth reductions, zero-batch TN).
//!
//! A second layer asserts the same at the training-step level: the
//! golden model with `StepOptions::fused` on/off produces identical loss
//! bits, parameters, velocities and overflow matrices.

use lpdnn::arith::{ElemRng, FixedFormat, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use lpdnn::coordinator::ScaleController;
use lpdnn::golden::{self, StepOptions};
use lpdnn::tensor::{ops, Pcg32};
use lpdnn::testing::{mlp_batch, mlp_state, ROUND_MODES, tiny_mlp};

const THREADS: [usize; 3] = [1, 2, 4];

/// Shapes as (m, kd, n) for NN / (m, ua, ib) for NT / (ba, ia, ub) for
/// TN: degenerate, odd/non-divisible, and chunk-edge cases.
const SHAPES: [(usize, usize, usize); 6] =
    [(1, 1, 1), (5, 0, 3), (0, 4, 4), (7, 13, 9), (8, 3, 1), (33, 17, 40)];

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The four arithmetics as epilogues (mode applies to the fixed grids).
fn arithmetics(mode: RoundMode) -> Vec<(&'static str, QuantEpilogue)> {
    let mk = |f: FixedFormat| {
        let mut q = Quantizer::from_format(f);
        q.mode = mode;
        QuantEpilogue::new(q)
    };
    vec![
        ("float32", mk(FixedFormat::FLOAT32)),
        ("fixed 12.3", mk(FixedFormat::new(12, 3))),
        ("dynamic 10.-2", mk(FixedFormat::new(10, -2))),
        ("float16", QuantEpilogue::half_sim()),
    ]
}

fn rand_vec(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Reference: plain NN kernel, bias sweep, then one epilogue sweep.
fn two_pass_nn(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    let mut out = ops::matmul_sl_threads(a, b, m, kd, n, 1);
    if let Some(bs) = bias {
        for row in out.chunks_mut(n) {
            for (o, &bv) in row.iter_mut().zip(bs) {
                *o += bv;
            }
        }
    }
    let st = epi.run(&mut out, 0);
    (out, st)
}

fn two_pass_nt(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    let mut out = ops::matmul_nt_sl_threads(a, b, m, ua, ib, 1);
    let st = epi.run(&mut out, 0);
    (out, st)
}

fn two_pass_tn(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    let mut out = ops::matmul_tn_sl_threads(a, b, ba, ia, ub, 1);
    let st = epi.run(&mut out, 0);
    (out, st)
}

/// Attach the counter-based sample stream when the mode needs one, so
/// stochastic rounding is exercised with real (index-keyed) samples.
fn with_stream(epi: QuantEpilogue, mode: RoundMode, seed: u64) -> QuantEpilogue {
    if mode == RoundMode::Stochastic {
        epi.with_rng(ElemRng::new(seed))
    } else {
        epi
    }
}

#[test]
fn fused_nn_bit_identical_to_two_pass() {
    let mut rng = Pcg32::seeded(0xF05E_D001);
    for mode in ROUND_MODES {
        for (label, epi) in arithmetics(mode) {
            for (m, kd, n) in SHAPES {
                let a = rand_vec(&mut rng, m * kd, 2.0);
                let b = rand_vec(&mut rng, kd * n, 2.0);
                let bias = rand_vec(&mut rng, n, 1.0);
                for use_bias in [false, true] {
                    let bias = use_bias.then_some(&bias[..]);
                    let epi = with_stream(epi, mode, 0xA11C_E5ED);
                    let (want, want_st) = two_pass_nn(&a, &b, bias, m, kd, n, epi);
                    for t in THREADS {
                        let (got, got_st) =
                            ops::matmul_sl_q_threads(&a, &b, bias, m, kd, n, epi, t);
                        assert_eq!(
                            bits(&got),
                            bits(&want),
                            "nn {label} {mode:?} {m}x{kd}x{n} bias={use_bias} t={t}"
                        );
                        assert_eq!(
                            got_st, want_st,
                            "nn stats {label} {mode:?} {m}x{kd}x{n} bias={use_bias} t={t}"
                        );
                    }
                    // auto-threaded wrapper (thread count from env/plan)
                    let (got, got_st) = ops::matmul_sl_q(&a, &b, bias, m, kd, n, epi);
                    assert_eq!(bits(&got), bits(&want), "nn auto {label} {mode:?}");
                    assert_eq!(got_st, want_st, "nn auto stats {label} {mode:?}");
                }
            }
        }
    }
}

#[test]
fn fused_nt_bit_identical_to_two_pass() {
    let mut rng = Pcg32::seeded(0xF05E_D002);
    for mode in ROUND_MODES {
        for (label, epi) in arithmetics(mode) {
            for (m, ua, ib) in SHAPES {
                let a = rand_vec(&mut rng, m * ua, 2.0);
                let b = rand_vec(&mut rng, ib * ua, 2.0);
                let epi = with_stream(epi, mode, 0xBEE5_EED5);
                let (want, want_st) = two_pass_nt(&a, &b, m, ua, ib, epi);
                for t in THREADS {
                    let (got, got_st) = ops::matmul_nt_sl_q_threads(&a, &b, m, ua, ib, epi, t);
                    assert_eq!(bits(&got), bits(&want), "nt {label} {mode:?} {m}x{ua}x{ib} t={t}");
                    assert_eq!(got_st, want_st, "nt stats {label} {mode:?} t={t}");
                }
                let (got, got_st) = ops::matmul_nt_sl_q(&a, &b, m, ua, ib, epi);
                assert_eq!(bits(&got), bits(&want), "nt auto {label} {mode:?}");
                assert_eq!(got_st, want_st, "nt auto stats {label} {mode:?}");
            }
        }
    }
}

#[test]
fn fused_tn_bit_identical_to_two_pass() {
    let mut rng = Pcg32::seeded(0xF05E_D003);
    for mode in ROUND_MODES {
        for (label, epi) in arithmetics(mode) {
            for (ba, ia, ub) in SHAPES {
                let a = rand_vec(&mut rng, ba * ia, 2.0);
                let b = rand_vec(&mut rng, ba * ub, 2.0);
                let epi = with_stream(epi, mode, 0xC0DE_D00D);
                let (want, want_st) = two_pass_tn(&a, &b, ba, ia, ub, epi);
                for t in THREADS {
                    let (got, got_st) = ops::matmul_tn_sl_q_threads(&a, &b, ba, ia, ub, epi, t);
                    assert_eq!(bits(&got), bits(&want), "tn {label} {mode:?} {ba}x{ia}x{ub} t={t}");
                    assert_eq!(got_st, want_st, "tn stats {label} {mode:?} t={t}");
                }
                let (got, got_st) = ops::matmul_tn_sl_q(&a, &b, ba, ia, ub, epi);
                assert_eq!(bits(&got), bits(&want), "tn auto {label} {mode:?}");
                assert_eq!(got_st, want_st, "tn auto stats {label} {mode:?}");
            }
        }
    }
}

#[test]
fn fused_base_offsets_match_offset_reference() {
    // Multi-call sites (per-filter maxout tiles) pass a flat-index base;
    // the fused samples/stats must equal a reference sweep at that offset.
    let mut rng = Pcg32::seeded(0xF05E_D004);
    let mut q = Quantizer::from_format(FixedFormat::new(8, 1));
    q.mode = RoundMode::Stochastic;
    let (m, kd, n) = (6usize, 5usize, 7usize);
    let a = rand_vec(&mut rng, m * kd, 2.0);
    let b = rand_vec(&mut rng, kd * n, 2.0);
    for base in [0u64, 1, 42, 10_000] {
        let epi = QuantEpilogue::new(q).with_rng(ElemRng::new(99)).with_base(base);
        let (want, want_st) = two_pass_nn(&a, &b, None, m, kd, n, epi);
        for t in THREADS {
            let (got, got_st) = ops::matmul_sl_q_threads(&a, &b, None, m, kd, n, epi, t);
            assert_eq!(bits(&got), bits(&want), "base={base} t={t}");
            assert_eq!(got_st, want_st, "base={base} t={t}");
        }
    }
    // distinct bases draw distinct samples (streams really are indexed)
    let e0 = QuantEpilogue::new(q).with_rng(ElemRng::new(99));
    let (out0, _) = ops::matmul_sl_q(&a, &b, None, m, kd, n, e0);
    let (out1, _) = ops::matmul_sl_q(&a, &b, None, m, kd, n, e0.with_base(1_000_000));
    assert_ne!(bits(&out0), bits(&out1));
}

#[test]
fn fused_passthrough_short_circuits_to_plain_kernel() {
    // float32 passthrough: the fused kernel must return exactly the plain
    // kernel's product (plus bias) with totals-only stats.
    let mut rng = Pcg32::seeded(0xF05E_D005);
    let (m, kd, n) = (9usize, 11usize, 6usize);
    let a = rand_vec(&mut rng, m * kd, 2.0);
    let b = rand_vec(&mut rng, kd * n, 2.0);
    let epi = QuantEpilogue::new(Quantizer::float32());
    assert!(epi.is_noop());
    let plain = ops::matmul_sl(&a, &b, m, kd, n);
    for t in THREADS {
        let (got, st) = ops::matmul_sl_q_threads(&a, &b, None, m, kd, n, epi, t);
        assert_eq!(bits(&got), bits(&plain), "t={t}");
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: (m * n) as u64 });
    }
}

/// Train-step-level parity: fused vs two-pass golden steps from identical
/// state must agree bit-for-bit in loss, params, velocities and the
/// overflow matrix — per arithmetic, per rounding mode.
#[test]
fn train_step_fused_bit_identical_to_two_pass() {
    let s = tiny_mlp();
    let arith_cases: [(&str, ScaleController, bool); 4] = [
        (
            "float32",
            ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            false,
        ),
        (
            "fixed 10.3/12.0",
            ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0)),
            false,
        ),
        (
            "dynamic-regime 8.2/14.1",
            ScaleController::fixed(24, FixedFormat::new(8, 2), FixedFormat::new(14, 1)),
            false,
        ),
        (
            "float16",
            ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            true,
        ),
    ];
    for (label, ctrl, half) in &arith_cases {
        for mode in ROUND_MODES {
            let (x, y) = mlp_batch(s, 16, 0xBA7C);
            let run = |fused: bool| {
                let (mut params, mut vels) = mlp_state(s, 0x5EED);
                let mut losses = Vec::new();
                for _ in 0..3 {
                    let out = golden::train_step_opt(
                        s,
                        &mut params,
                        &mut vels,
                        &x,
                        &y,
                        0.1,
                        0.5,
                        2.0,
                        ctrl,
                        StepOptions {
                            mode,
                            half: *half,
                            dropout: None,
                            fused,
                            ..Default::default()
                        },
                    );
                    losses.push((out.loss.to_bits(), bits(out.overflow.data())));
                }
                (losses, params, vels)
            };
            let (l_fused, p_fused, v_fused) = run(true);
            let (l_two, p_two, v_two) = run(false);
            assert_eq!(l_fused, l_two, "{label} {mode:?}: loss/overflow diverged");
            for (i, (pf, pt)) in p_fused.iter().zip(&p_two).enumerate() {
                assert_eq!(bits(pf.data()), bits(pt.data()), "{label} {mode:?}: param {i}");
            }
            for (i, (vf, vt)) in v_fused.iter().zip(&v_two).enumerate() {
                assert_eq!(bits(vf.data()), bits(vt.data()), "{label} {mode:?}: vel {i}");
            }
        }
    }
}

/// Eval parity: forward-only logits agree between a fused and a two-pass
/// *train* probe (zero LR, so the forward is the only signal), for the
/// quantized arithmetics. `eval_logits` itself follows the session-wide
/// fused default, which both probes bracket.
#[test]
fn eval_logits_consistent_with_zero_lr_step_under_fusion() {
    let s = tiny_mlp();
    let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
    let (mut params, _) = mlp_state(s, 7);
    // pre-quantize storage as the Trainer does at init
    for (i, p) in params.iter_mut().enumerate() {
        let g = (i / 2) * 8 + if i % 2 == 0 { 0 } else { 1 };
        Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
    }
    let (x, y) = mlp_batch(s, 8, 8);
    let probe = |fused: bool| {
        let (_, mut vels) = mlp_state(s, 7);
        let mut p = params.clone();
        golden::train_step_opt(
            s,
            &mut p,
            &mut vels,
            &x,
            &y,
            0.0,
            0.0,
            0.0,
            &ctrl,
            StepOptions { fused, ..Default::default() },
        )
        .loss
        .to_bits()
    };
    assert_eq!(probe(true), probe(false));
    let logits = golden::eval_logits(s, &params, &x, &ctrl, RoundMode::HalfAway, false);
    let logp = ops::log_softmax(&logits);
    let mut loss = 0.0f64;
    for i in 0..8 * s.n_classes {
        loss -= (y.data()[i] * logp.data()[i]) as f64;
    }
    let loss = (loss / 8.0) as f32;
    assert_eq!(loss.to_bits(), probe(true), "eval forward drifted from train forward");
}
