//! Graph-boundary parity: the layer-graph executor ([`Network`]) must be
//! **bit-identical** — exact `u32` loss/parameter/velocity bits *and*
//! exact `QuantStats` overflow counters — to the frozen pre-refactor
//! monolithic step (`golden::reference`) on the builtin 2-hidden-layer
//! topology, across:
//!
//! * all four arithmetics (float32 passthrough, fixed, dynamic-regime
//!   fixed, float16 simulation),
//! * all four rounding modes (stochastic via the counter-based per-site
//!   streams),
//! * fused and two-pass quantization paths (`StepOptions::fused`),
//! * dropout on and off (mask draw order is part of the contract),
//! * any thread count — CI re-runs this suite under `LPDNN_THREADS`
//!   ∈ {1, 4}, covering the auto-threaded kernel entry points.
//!
//! A second layer exercises what the monolith never could: topologies
//! with ≥3 hidden layers parsed from a TOML `[topology]` spec, trained
//! end to end with dynamic fixed point adopting per-layer scales.

use lpdnn::arith::{FixedFormat, RoundMode};
use lpdnn::config::{ExperimentConfig, TopologySpec};
use lpdnn::coordinator::{ScaleController, Session};
use lpdnn::golden::{self, Dropout, MlpShape, Network, StepOptions};
use lpdnn::runtime::{BackendSpec, ModelInfo};
use lpdnn::tensor::{ops, Pcg32, Tensor};
use lpdnn::testing::{mlp_batch, mlp_state, ROUND_MODES, tiny_mlp};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The four arithmetics as (label, controller, half) — the same matrix
/// `tests/fused_parity.rs` uses, sized for tiny_mlp's 24 groups.
fn arith_cases() -> Vec<(&'static str, ScaleController, bool)> {
    vec![
        (
            "float32",
            ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            false,
        ),
        (
            "fixed 10.3/12.0",
            ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0)),
            false,
        ),
        (
            "dynamic-regime 8.2/14.1",
            ScaleController::fixed(24, FixedFormat::new(8, 2), FixedFormat::new(14, 1)),
            false,
        ),
        (
            "float16",
            ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            true,
        ),
    ]
}

/// Three steps of graph-vs-monolith from identical state: loss bits,
/// overflow-matrix bits, parameter bits, velocity bits — all equal.
#[test]
fn graph_pi_mlp_bit_identical_to_monolith() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    assert_eq!(net.n_groups(), 24);
    for (label, ctrl, half) in &arith_cases() {
        for mode in ROUND_MODES {
            for fused in [true, false] {
                let (x, y) = mlp_batch(s, 16, 0xBA7C);
                let opts = || StepOptions {
                    mode,
                    half: *half,
                    dropout: None,
                    fused,
                    ..Default::default()
                };
                let run_graph = |net: &Network| {
                    let (mut params, mut vels) = mlp_state(s, 0x5EED);
                    let mut trace = Vec::new();
                    for _ in 0..3 {
                        let out = net.train_step(
                            &mut params, &mut vels, &x, &y, 0.1, 0.5, 2.0, ctrl, opts(),
                        );
                        trace.push((out.loss.to_bits(), bits(out.overflow.data())));
                    }
                    (trace, params, vels)
                };
                let run_mono = || {
                    let (mut params, mut vels) = mlp_state(s, 0x5EED);
                    let mut trace = Vec::new();
                    for _ in 0..3 {
                        let out = golden::reference::train_step_opt(
                            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 2.0, ctrl, opts(),
                        );
                        trace.push((out.loss.to_bits(), bits(out.overflow.data())));
                    }
                    (trace, params, vels)
                };
                let (t_g, p_g, v_g) = run_graph(&net);
                let (t_m, p_m, v_m) = run_mono();
                assert_eq!(
                    t_g, t_m,
                    "{label} {mode:?} fused={fused}: loss/overflow diverged"
                );
                for (i, (a, b)) in p_g.iter().zip(&p_m).enumerate() {
                    assert_eq!(
                        bits(a.data()),
                        bits(b.data()),
                        "{label} {mode:?} fused={fused}: param {i}"
                    );
                }
                for (i, (a, b)) in v_g.iter().zip(&v_m).enumerate() {
                    assert_eq!(
                        bits(a.data()),
                        bits(b.data()),
                        "{label} {mode:?} fused={fused}: vel {i}"
                    );
                }
            }
        }
    }
}

/// Dropout parity: mask draw order through the graph's DropoutLayers
/// must replay the monolith's masks exactly (same single RNG stream).
#[test]
fn graph_dropout_masks_match_monolith_bit_for_bit() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
    let (x, y) = mlp_batch(s, 16, 0xD0);
    // input-only, hidden-only, and both — each changes the draw sequence
    for (ri, rh) in [(0.2f32, 0.5f32), (0.0, 0.5), (0.2, 0.0)] {
        let opts = || StepOptions {
            dropout: Some(Dropout {
                input_rate: ri,
                hidden_rate: rh,
                rng: Pcg32::seeded(0xABCD),
            }),
            ..Default::default()
        };
        let (mut pg, mut vg) = mlp_state(s, 7);
        let g = net.train_step(&mut pg, &mut vg, &x, &y, 0.1, 0.5, 2.0, &ctrl, opts());
        let (mut pm, mut vm) = mlp_state(s, 7);
        let m = golden::reference::train_step_opt(
            s, &mut pm, &mut vm, &x, &y, 0.1, 0.5, 2.0, &ctrl, opts(),
        );
        assert_eq!(g.loss.to_bits(), m.loss.to_bits(), "rates ({ri}, {rh})");
        assert_eq!(bits(g.overflow.data()), bits(m.overflow.data()));
        for (a, b) in pg.iter().zip(&pm) {
            assert_eq!(bits(a.data()), bits(b.data()), "rates ({ri}, {rh})");
        }
    }
}

/// Eval parity: forward-only logits agree bit-for-bit between the graph
/// and the monolith, for fixed grids and the float16 simulation.
#[test]
fn graph_eval_logits_bit_identical_to_monolith() {
    let s = tiny_mlp();
    let net = Network::from_mlp_shape(s);
    for (label, ctrl, half) in &arith_cases() {
        let (params, _) = mlp_state(s, 0xE7A1);
        let (x, _) = mlp_batch(s, 8, 0xE7A2);
        let got = net.eval_logits(&params, &x, ctrl, RoundMode::HalfAway, *half);
        let want = golden::reference::eval_logits(s, &params, &x, ctrl, RoundMode::HalfAway, *half);
        assert_eq!(bits(got.data()), bits(want.data()), "{label}");
    }
}

/// The public thin drivers (`golden::train_step_opt` / `eval_logits`)
/// route through the graph and stay bit-identical to the monolith too.
#[test]
fn thin_drivers_route_through_the_graph_unchanged() {
    let s = tiny_mlp();
    let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    let (x, y) = mlp_batch(s, 8, 3);
    let (mut p1, mut v1) = mlp_state(s, 4);
    let (mut p2, mut v2) = mlp_state(s, 4);
    let a = golden::train_step_opt(
        s, &mut p1, &mut v1, &x, &y, 0.1, 0.5, 2.0, &ctrl, StepOptions::default(),
    );
    let b = golden::reference::train_step_opt(
        s, &mut p2, &mut v2, &x, &y, 0.1, 0.5, 2.0, &ctrl, StepOptions::default(),
    );
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    for (t1, t2) in p1.iter().zip(&p2) {
        assert_eq!(bits(t1.data()), bits(t2.data()));
    }
    let ga = golden::eval_logits(s, &p1, &x, &ctrl, RoundMode::HalfAway, false);
    let gb = golden::reference::eval_logits(s, &p2, &x, &ctrl, RoundMode::HalfAway, false);
    assert_eq!(bits(ga.data()), bits(gb.data()));
}

/// A ≥3-hidden-layer topology from a TOML `[topology]` spec trains end
/// to end with dynamic fixed point: warmup learns per-layer exponents,
/// the controller adopts them, and the run finishes with a full
/// 32-group scale table.
#[test]
fn deep_topology_toml_trains_with_dynamic_scales() {
    let cfg = ExperimentConfig::from_toml_str(
        r#"
[experiment]
name = "depth3-dynamic"
dataset = "digits"

[topology]
hidden = [32, 32, 32]
k = 2

[arithmetic]
kind = "dynamic"
bits_comp = 10
bits_up = 12
max_overflow_rate = 1e-4
update_every_examples = 256
init_int_bits = 3
warmup_steps = 10

[train]
steps = 30
lr_start = 0.1
seed = 7

[data]
n_train = 256
n_test = 128
"#,
    )
    .unwrap();
    let topo = cfg.topology.as_ref().unwrap();
    assert_eq!(topo.hidden, vec![32, 32, 32]);
    assert_eq!(topo.n_layers(), 4);

    let mut session = Session::new(BackendSpec::native());
    let r = session.run(cfg).unwrap();
    assert_eq!(r.steps_run, 30);
    assert!(r.train_loss.is_finite());
    assert!(r.test_error.is_finite() && r.test_error <= 1.0);
    // one scale per group, 4 compute layers × 8 kinds
    assert_eq!(r.final_int_bits.len(), 32);
    // warmup adoption + runtime moves must have taken at least one group
    // off the uniform init_int_bits=3 cold start
    assert!(
        r.final_int_bits.iter().any(|&b| b != 3),
        "no per-layer scale was ever adopted: {:?}",
        r.final_int_bits
    );
}

/// The same deep topology driven directly through Network/ModelInfo:
/// bit-determinism across two identical runs (graph execution introduces
/// no hidden state), and group count comes from the graph.
#[test]
fn deep_topology_is_deterministic_and_sizes_its_controller() {
    let spec = TopologySpec::mlp(vec![24, 16, 12], 2);
    let (d_in, n_classes) = lpdnn::data::dataset_dims("clusters").unwrap();
    let net = Network::from_topology(&spec, d_in, n_classes);
    let info = ModelInfo::from_topology(&spec, d_in, n_classes);
    assert_eq!(net.n_groups(), info.n_groups);
    let ctrl = ScaleController::fixed(
        net.n_groups(),
        FixedFormat::new(10, 3),
        FixedFormat::new(12, 0),
    );
    let mut rng = Pcg32::seeded(31);
    let x = Tensor::from_vec(&[8, d_in], (0..8 * d_in).map(|_| rng.normal()).collect());
    let labels: Vec<usize> = (0..8).map(|_| rng.below(n_classes as u32) as usize).collect();
    let y = ops::one_hot(&labels, n_classes);
    let run = || {
        let mut srng = Pcg32::seeded(5);
        let mut params: Vec<Tensor> =
            info.params.iter().map(|s| s.init.realize(&s.shape, &mut srng)).collect();
        let mut vels: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let mut losses = Vec::new();
        for _ in 0..4 {
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.1,
                0.5,
                2.0,
                &ctrl,
                StepOptions::default(),
            );
            losses.push(out.loss.to_bits());
        }
        (losses, params)
    };
    let (l1, p1) = run();
    let (l2, p2) = run();
    assert_eq!(l1, l2);
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(bits(a.data()), bits(b.data()));
    }
}

/// MlpShape dims derive from the dataset (satellite: no hardcoded
/// 784/10), and the graph accepts what they produce.
#[test]
fn mlp_shape_for_dataset_builds_consistent_networks() {
    for (ds, want_d, want_c) in [("digits", 784, 10), ("svhn_like", 3072, 10)] {
        let s = MlpShape::for_dataset(ds, 16, 2).unwrap();
        assert_eq!((s.d_in, s.n_classes), (want_d, want_c));
        let net = Network::from_mlp_shape(s);
        assert_eq!(net.d_in(), want_d);
        assert_eq!(net.n_classes(), want_c);
        assert_eq!(net.n_groups(), 24);
    }
}
