//! Integer-domain GEMM parity: the dispatching `*_qd` kernels
//! (`matmul_sl_qd` / `matmul_nt_sl_qd` / `matmul_tn_sl_qd` and their
//! `_threads` variants) with `int_domain` enabled must be
//! **bit-identical** — exact `u32` output bits *and* exact `QuantStats`
//! counters — to the simulated-f32 fused kernels they dispatch over,
//! across:
//!
//! * all three orientations (NN with/without bias, NT, TN),
//! * the fixed and dynamic-regime fixed arithmetics (i8 and i16 packing),
//! * all four rounding modes (stochastic via the counter-based stream),
//! * explicit thread counts {1, 2, 4} — on top of which CI runs the
//!   whole suite under `LPDNN_THREADS` ∈ {1, 4} and
//!   `LPDNN_INT_GEMM` ∈ {0, 1} to cover the auto-threaded and
//!   env-defaulted entry points,
//! * degenerate shapes (1×1×1, zero-depth reductions, zero-batch TN).
//!
//! Every eligible case first asserts [`ops::quant_gemm_plan`] selects
//! `IntDomain` — or `Split` for the wide-grid/deep-reduction cases the
//! split-accumulator schedule makes eligible — a parity test that
//! silently fell back to the simulated kernel would prove nothing.
//! Ineligible sites (off-grid data, a violated per-product bound, a
//! dirty accumulated destination) are asserted to fall back *and* still
//! match, so the dispatch is unconditionally bit-transparent.
//!
//! A second layer asserts the same at the training-step level (the tiny
//! maxout MLP and the tiny conv topology, so the im2col-lowered conv
//! stage GEMMs ride the integer path too): `StepOptions::int_domain`
//! on/off produces identical loss bits, parameters, velocities and
//! overflow matrices. A final property shows accepted sites cannot
//! silently overflow the i32 accumulator.
//!
//! A third layer covers the **packed-operand cache**: the cached-b
//! entry points (`*_cached*`) must be bit-identical to per-call packing
//! and to the simulated kernels, and a persistent [`Network`] must
//! rebuild each weight layer's slab exactly once per `sgd_update` and
//! once per scale adoption — asserted through
//! [`Network::weight_pack_builds`], so a stale cache (which re-packs
//! unchanged values and is therefore bit-invisible) or a
//! repack-per-GEMM regression fails the count, not just the clock.

use lpdnn::arith::{ElemRng, FixedFormat, QuantEpilogue, Quantizer, RoundMode};
use lpdnn::coordinator::ScaleController;
use lpdnn::golden::{self, Network, Params, StepOptions};
use lpdnn::tensor::ops::QuantGemmImpl;
use lpdnn::tensor::{int_gemm, ops, Pcg32, Tensor};
use lpdnn::testing::{
    forall_seeded, Gen, mlp_batch, mlp_state, ROUND_MODES, spatial_batch, TINY_CONV_CLASSES,
    TINY_CONV_SHAPE, tiny_conv_spec, tiny_mlp, topology_state,
};

const THREADS: [usize; 3] = [1, 2, 4];

/// Shapes as (m, kd, n) for NN / (m, ua, ib) for NT / (ba, ia, ub) for
/// TN: degenerate, odd/non-divisible, and chunk-edge cases (mirrors
/// `tests/fused_parity.rs`).
const SHAPES: [(usize, usize, usize); 6] =
    [(1, 1, 1), (5, 0, 3), (0, 4, 4), (7, 13, 9), (8, 3, 1), (33, 17, 40)];

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The integer-eligible arithmetics: epilogue format paired with the
/// operand grid `(amax, exp)` the data is drawn on. `fixed 10.3` lands
/// in i16 packing, the negative-radix dynamic regime in i8. The deepest
/// contraction in [`SHAPES`] is 33, so `33 · 511 · 511 < 2^24` keeps
/// every case inside the accumulator bound.
fn int_arithmetics() -> Vec<(&'static str, FixedFormat, i32, i32)> {
    vec![
        ("fixed 10.3", FixedFormat::new(10, 3), 511, -6),
        ("dynamic 8.-2", FixedFormat::new(8, -2), 127, -9),
    ]
}

/// Grid-valued operand data: uniform `int · 2^exp` with `|int| ≤ amax` —
/// always packable, so the integer plan engages (asserted per case).
fn grid_vec(rng: &mut Pcg32, n: usize, amax: i32, exp: i32) -> Vec<f32> {
    let step = int_gemm::exp2f(exp);
    (0..n).map(|_| (rng.below(2 * amax as u32 + 1) as i32 - amax) as f32 * step).collect()
}

fn mk_epi(fmt: FixedFormat, mode: RoundMode) -> QuantEpilogue {
    let mut q = Quantizer::from_format(fmt);
    q.mode = mode;
    QuantEpilogue::new(q)
}

/// Attach the counter-based sample stream when the mode needs one, so
/// stochastic rounding is exercised with real (index-keyed) samples.
fn with_stream(epi: QuantEpilogue, mode: RoundMode, seed: u64) -> QuantEpilogue {
    if mode == RoundMode::Stochastic {
        epi.with_rng(ElemRng::new(seed))
    } else {
        epi
    }
}

// ---------------------------------------------------------------------------
// Kernel level
// ---------------------------------------------------------------------------

/// Simulated vs integer-domain NN across [`THREADS`], bits and stats.
#[allow(clippy::too_many_arguments)]
fn check_nn(
    ctx: &str,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) {
    for threads in THREADS {
        let (want, wst) = ops::matmul_sl_q_threads(a, b, bias, m, kd, n, epi, threads);
        let (got, gst) = ops::matmul_sl_qd_threads(a, b, bias, m, kd, n, epi, threads, true);
        assert_eq!(bits(&got), bits(&want), "{ctx} t{threads} bias={}", bias.is_some());
        assert_eq!(gst, wst, "{ctx} t{threads} bias={} stats", bias.is_some());
    }
}

/// Simulated vs integer-domain NT across [`THREADS`], bits and stats.
fn check_nt(ctx: &str, a: &[f32], b: &[f32], m: usize, ua: usize, ib: usize, epi: QuantEpilogue) {
    for threads in THREADS {
        let (want, wst) = ops::matmul_nt_sl_q_threads(a, b, m, ua, ib, epi, threads);
        let (got, gst) = ops::matmul_nt_sl_qd_threads(a, b, m, ua, ib, epi, threads, true);
        assert_eq!(bits(&got), bits(&want), "{ctx} t{threads}");
        assert_eq!(gst, wst, "{ctx} t{threads} stats");
    }
}

/// Simulated vs integer-domain TN across [`THREADS`], bits and stats.
fn check_tn(ctx: &str, a: &[f32], b: &[f32], ba: usize, ia: usize, ub: usize, epi: QuantEpilogue) {
    for threads in THREADS {
        let (want, wst) = ops::matmul_tn_sl_q_threads(a, b, ba, ia, ub, epi, threads);
        let (got, gst) = ops::matmul_tn_sl_qd_threads(a, b, ba, ia, ub, epi, threads, true);
        assert_eq!(bits(&got), bits(&want), "{ctx} t{threads}");
        assert_eq!(gst, wst, "{ctx} t{threads} stats");
    }
}

#[test]
fn int_nn_bit_identical_to_simulated() {
    let mut rng = Pcg32::seeded(0x16E3_0001);
    for mode in ROUND_MODES {
        for (label, fmt, amax, exp) in int_arithmetics() {
            let epi = with_stream(mk_epi(fmt, mode), mode, 0x16E3_A001);
            for (m, kd, n) in SHAPES {
                let a = grid_vec(&mut rng, m * kd, amax, exp);
                let b = grid_vec(&mut rng, kd * n, amax, exp);
                let bias = grid_vec(&mut rng, n, amax, exp);
                if m > 0 && n > 0 {
                    let zeros = vec![0.0f32; m * n];
                    assert_eq!(
                        ops::quant_gemm_plan(&a, &b, kd, Some(&zeros)),
                        QuantGemmImpl::IntDomain,
                        "{label} {mode:?} {m}x{kd}x{n}: case must engage"
                    );
                }
                let ctx = format!("nn {label} {mode:?} {m}x{kd}x{n}");
                check_nn(&ctx, &a, &b, None, m, kd, n, epi);
                check_nn(&ctx, &a, &b, Some(&bias), m, kd, n, epi);
            }
        }
    }
}

#[test]
fn int_nt_bit_identical_to_simulated() {
    let mut rng = Pcg32::seeded(0x16E3_0002);
    for mode in ROUND_MODES {
        for (label, fmt, amax, exp) in int_arithmetics() {
            let epi = with_stream(mk_epi(fmt, mode), mode, 0x16E3_A002);
            for (m, ua, ib) in SHAPES {
                let a = grid_vec(&mut rng, m * ua, amax, exp);
                let b = grid_vec(&mut rng, ib * ua, amax, exp);
                if m > 0 && ib > 0 {
                    assert_eq!(
                        ops::quant_gemm_plan(&a, &b, ua, None),
                        QuantGemmImpl::IntDomain,
                        "{label} {mode:?} {m}x{ua}x{ib}: case must engage"
                    );
                }
                let ctx = format!("nt {label} {mode:?} {m}x{ua}x{ib}");
                check_nt(&ctx, &a, &b, m, ua, ib, epi);
            }
        }
    }
}

#[test]
fn int_tn_bit_identical_to_simulated() {
    let mut rng = Pcg32::seeded(0x16E3_0003);
    for mode in ROUND_MODES {
        for (label, fmt, amax, exp) in int_arithmetics() {
            let epi = with_stream(mk_epi(fmt, mode), mode, 0x16E3_A003);
            for (ba, ia, ub) in SHAPES {
                let a = grid_vec(&mut rng, ba * ia, amax, exp);
                let b = grid_vec(&mut rng, ba * ub, amax, exp);
                if ia > 0 && ub > 0 {
                    let zeros = vec![0.0f32; ia * ub];
                    assert_eq!(
                        ops::quant_gemm_plan(&a, &b, ba, Some(&zeros)),
                        QuantGemmImpl::IntDomain,
                        "{label} {mode:?} {ba}x{ia}x{ub}: case must engage"
                    );
                }
                let ctx = format!("tn {label} {mode:?} {ba}x{ia}x{ub}");
                check_tn(&ctx, &a, &b, ba, ia, ub, epi);
            }
        }
    }
}

/// Sites the packer must refuse — off-grid values, a violated
/// accumulator bound, a dirty (`-0.0`) accumulated destination — fall
/// back to the simulated kernel and still match it bit-for-bit, so the
/// dispatch is transparent even when it cannot engage.
#[test]
fn ineligible_sites_fall_back_bit_identically() {
    let mut rng = Pcg32::seeded(0x16E3_0004);
    let epi = mk_epi(FixedFormat::new(10, 3), RoundMode::HalfAway);
    let (m, kd, n) = (7, 13, 9);

    // off-grid operand: 0.1 has no finite power-of-two representation
    let mut a = grid_vec(&mut rng, m * kd, 511, -6);
    let b = grid_vec(&mut rng, kd * n, 511, -6);
    a[5] = 0.1;
    assert_eq!(ops::quant_gemm_plan(&a, &b, kd, None), QuantGemmImpl::Simulated);
    check_nn("off-grid", &a, &b, None, m, kd, n, epi);

    // a deep wide-grid reduction (33 · 2047 · 2047 > 2^24) used to be
    // rejected outright; the split-accumulator schedule now takes it —
    // only the *per-product* bound (amax_a · amax_b ≤ 2^24) gates Split,
    // and 2047 · 2047 fits with room to spare
    let (ba, ia, ub) = (33, 5, 6);
    let mut wa = grid_vec(&mut rng, ba * ia, 2047, 0);
    let mut wb = grid_vec(&mut rng, ba * ub, 2047, 0);
    wa[0] = 2047.0;
    wb[0] = 2047.0;
    assert_eq!(ops::quant_gemm_plan(&wa, &wb, ba, None), QuantGemmImpl::Split);
    check_tn("wide grid rides split", &wa, &wb, ba, ia, ub, epi);

    // per-product bound: 8191 · 8191 > 2^24 — a single product already
    // overflows the exact-f32 window, so not even Split can take it
    let mut xa = grid_vec(&mut rng, ba * ia, 8191, 0);
    let mut xb = grid_vec(&mut rng, ba * ub, 8191, 0);
    xa[0] = 8191.0;
    xb[0] = 8191.0;
    assert_eq!(ops::quant_gemm_plan(&xa, &xb, ba, None), QuantGemmImpl::Simulated);
    check_tn("per-product bound", &xa, &xb, ba, ia, ub, epi);

    // dirty accumulated destination: a -0.0 must reject the int path
    // (the simulated kernels preserve its sign through `dst +=`)
    let a = grid_vec(&mut rng, m * kd, 511, -6);
    let mut dirty = vec![0.0f32; m * n];
    dirty[3] = -0.0;
    assert_eq!(ops::quant_gemm_plan(&a, &b, kd, Some(&dirty)), QuantGemmImpl::Simulated);
    let clean = vec![0.0f32; m * n];
    assert_eq!(ops::quant_gemm_plan(&a, &b, kd, Some(&clean)), QuantGemmImpl::IntDomain);
    for threads in THREADS {
        let mut want = dirty.clone();
        let wst = ops::matmul_sl_q_into_threads(&a, &b, None, &mut want, m, kd, n, epi, threads);
        let mut got = dirty.clone();
        let gst = ops::matmul_sl_qd_into_threads(
            &a, &b, None, &mut got, m, kd, n, epi, threads, true, None,
        );
        assert_eq!(bits(&got), bits(&want), "dirty dst t{threads}");
        assert_eq!(gst, wst, "dirty dst t{threads} stats");
    }

    // int_domain = false must never touch the integer path
    let (want, wst) = ops::matmul_sl_q_threads(&a, &b, None, m, kd, n, epi, 2);
    let (got, gst) = ops::matmul_sl_qd_threads(&a, &b, None, m, kd, n, epi, 2, false);
    assert_eq!(bits(&got), bits(&want), "int_domain off");
    assert_eq!(gst, wst, "int_domain off stats");
}

/// The split-eligible arithmetics as `(label, fmt, amax, exp, inner)`:
/// grids whose worst-case `inner · amax²` reduction overflows
/// `ACC_BOUND` while every individual product `amax²` still fits — the
/// sites the whole-accumulation planner used to reject outright. The
/// wide 2047-grid lands in i16 packing at inner 33; the 127-grid stays
/// in i8 and needs a deep reduction (1100 · 127² > 2^24) to trip the
/// bound.
fn split_arithmetics() -> Vec<(&'static str, FixedFormat, i32, i32, usize)> {
    vec![
        ("fixed 16.8 i16", FixedFormat::new(16, 8), 2047, -6, 33),
        ("dynamic 8.-2 i8", FixedFormat::new(8, -2), 127, -9, 1100),
    ]
}

/// Grid data with the first element pinned to `±amax · 2^exp`, so the
/// packed amax — and with it the planner's Whole/Split classification —
/// is deterministic rather than a property of the random draw.
fn split_grid_vec(rng: &mut Pcg32, n: usize, amax: i32, exp: i32, sign: f32) -> Vec<f32> {
    let mut v = grid_vec(rng, n, amax, exp);
    v[0] = sign * amax as f32 * int_gemm::exp2f(exp);
    v
}

/// Split-accumulator parity: every orientation × arithmetic × round
/// mode × thread count, uncached and against a cached weight slab, must
/// (a) select the `Split` plan — these are exactly the
/// previously-Simulated wide/deep sites — and (b) stay bit-identical in
/// output bits and `QuantStats` to the simulated fused kernels.
#[test]
fn split_plan_bit_identical_to_simulated() {
    let mut rng = Pcg32::seeded(0x16E3_0007);
    for mode in ROUND_MODES {
        for (label, fmt, amax, exp, inner) in split_arithmetics() {
            let epi = with_stream(mk_epi(fmt, mode), mode, 0x16E3_A007);

            // NN: [m, inner] @ [inner, n], plus the cached-slab flavour
            let (m, n) = (5, 4);
            let a = split_grid_vec(&mut rng, m * inner, amax, exp, 1.0);
            let b = split_grid_vec(&mut rng, inner * n, amax, exp, -1.0);
            let bias = grid_vec(&mut rng, n, amax, exp);
            let zeros = vec![0.0f32; m * n];
            assert_eq!(
                ops::quant_gemm_plan(&a, &b, inner, Some(&zeros)),
                QuantGemmImpl::Split,
                "{label} {mode:?}: NN case must ride the split plan"
            );
            let ctx = format!("split nn {label} {mode:?}");
            check_nn(&ctx, &a, &b, None, m, inner, n, epi);
            check_nn(&ctx, &a, &b, Some(&bias), m, inner, n, epi);
            let bp = int_gemm::pack(&b).expect("grid data packs");
            assert_eq!(
                ops::quant_gemm_plan_cached(&a, Some(&bp), inner, Some(&zeros)),
                QuantGemmImpl::Split,
                "{label} {mode:?}: cached NN case must ride the split plan"
            );
            for threads in THREADS {
                let (want, wst) =
                    ops::matmul_sl_q_threads(&a, &b, Some(&bias), m, inner, n, epi, threads);
                let mut got = vec![0.0f32; m * n];
                let gst = ops::matmul_sl_qd_cached_into_threads(
                    &a,
                    &b,
                    Some(&bp),
                    Some(&bias),
                    &mut got,
                    m,
                    inner,
                    n,
                    epi,
                    threads,
                    None,
                );
                assert_eq!(bits(&got), bits(&want), "{ctx} cached t{threads}");
                assert_eq!(gst, wst, "{ctx} cached t{threads} stats");
            }

            // NT: [m, ua] @ [ib, ua]^T with ua = inner, plus cached
            let (m2, ib) = (3, 4);
            let a2 = split_grid_vec(&mut rng, m2 * inner, amax, exp, 1.0);
            let b2 = split_grid_vec(&mut rng, ib * inner, amax, exp, 1.0);
            assert_eq!(
                ops::quant_gemm_plan(&a2, &b2, inner, None),
                QuantGemmImpl::Split,
                "{label} {mode:?}: NT case must ride the split plan"
            );
            let ctx = format!("split nt {label} {mode:?}");
            check_nt(&ctx, &a2, &b2, m2, inner, ib, epi);
            let bp2 = int_gemm::pack(&b2).expect("grid data packs");
            assert_eq!(
                ops::quant_gemm_plan_cached(&a2, Some(&bp2), inner, None),
                QuantGemmImpl::Split,
                "{label} {mode:?}: cached NT case must ride the split plan"
            );
            for threads in THREADS {
                let (want, wst) =
                    ops::matmul_nt_sl_q_threads(&a2, &b2, m2, inner, ib, epi, threads);
                let (got, gst) = ops::matmul_nt_sl_qd_cached_threads(
                    &a2,
                    &b2,
                    Some(&bp2),
                    m2,
                    inner,
                    ib,
                    epi,
                    threads,
                    None,
                );
                assert_eq!(bits(&got), bits(&want), "{ctx} cached t{threads}");
                assert_eq!(gst, wst, "{ctx} cached t{threads} stats");
            }

            // TN: [ba, ia]^T @ [ba, ub] with ba = inner
            let (ia, ub) = (3, 4);
            let a3 = split_grid_vec(&mut rng, inner * ia, amax, exp, -1.0);
            let b3 = split_grid_vec(&mut rng, inner * ub, amax, exp, 1.0);
            let zeros_tn = vec![0.0f32; ia * ub];
            assert_eq!(
                ops::quant_gemm_plan(&a3, &b3, inner, Some(&zeros_tn)),
                QuantGemmImpl::Split,
                "{label} {mode:?}: TN case must ride the split plan"
            );
            check_tn(&format!("split tn {label} {mode:?}"), &a3, &b3, inner, ia, ub, epi);
        }
    }
}

// ---------------------------------------------------------------------------
// Train-step level
// ---------------------------------------------------------------------------

/// Deterministic MLP state with params on the storage grid and inputs on
/// the computation grid (as the Trainer hands them to the step), so the
/// first step's GEMM sites are integer-eligible from the start.
fn quantized_mlp_fixture(comp: FixedFormat, up: FixedFormat) -> (Params, Params, Tensor, Tensor) {
    let s = tiny_mlp();
    let (mut params, vels) = mlp_state(s, 0x5EED);
    let qup = Quantizer::from_format(up);
    for p in &mut params {
        qup.apply_slice(p.data_mut());
    }
    let (mut x, y) = mlp_batch(s, 16, 0xBA7C);
    Quantizer::from_format(comp).apply_slice(x.data_mut());
    (params, vels, x, y)
}

/// Guard against a vacuous step-level parity: with the fixture state,
/// the first hidden layer's forward GEMM (x `[B, d_in]` @ w0-filter
/// `[d_in, units]` into a zeroed z) must select the integer plan.
#[test]
fn quantized_mlp_state_engages_the_integer_plan() {
    let s = tiny_mlp();
    let (params, _, x, _) =
        quantized_mlp_fixture(FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    let w0 = &params[0].data()[..s.d_in * s.units];
    let zeros = vec![0.0f32; 16 * s.units];
    assert_eq!(
        ops::quant_gemm_plan(x.data(), w0, s.d_in, Some(&zeros)),
        QuantGemmImpl::IntDomain,
        "fixture must make the forward site integer-eligible"
    );
}

#[test]
fn train_step_int_domain_bit_identical() {
    let s = tiny_mlp();
    let cases: Vec<(&str, ScaleController)> = vec![
        (
            "fixed 10.3 / 12.0",
            ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0)),
        ),
        (
            "fixed 8.1 / 10.0",
            ScaleController::fixed(24, FixedFormat::new(8, 1), FixedFormat::new(10, 0)),
        ),
        (
            "dynamic 10.3 / 12.0",
            ScaleController::dynamic(
                24,
                FixedFormat::new(10, 3),
                FixedFormat::new(12, 0),
                1e-4,
                64,
            ),
        ),
        // passthrough: nothing packs, so this checks pure fallback
        ("float32", ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32)),
    ];
    for (label, ctrl) in &cases {
        for mode in ROUND_MODES {
            let run = |int_domain: bool| {
                // group 2 is (layer 0, Z) = computation grid, group 0 is
                // (layer 0, W) = storage grid
                let (mut params, mut vels, x, y) =
                    quantized_mlp_fixture(ctrl.format(2), ctrl.format(0));
                let mut trace: Vec<Vec<u32>> = Vec::new();
                for _ in 0..3 {
                    let out = golden::train_step_opt(
                        s,
                        &mut params,
                        &mut vels,
                        &x,
                        &y,
                        0.1,
                        0.5,
                        2.0,
                        ctrl,
                        StepOptions { mode, fused: true, int_domain, ..Default::default() },
                    );
                    trace.push(vec![out.loss.to_bits()]);
                    trace.push(bits(out.overflow.data()));
                }
                for t in params.iter().chain(vels.iter()) {
                    trace.push(bits(t.data()));
                }
                trace
            };
            assert_eq!(run(true), run(false), "{label} {mode:?}");
        }
    }
}

/// The conv topology's im2col-lowered stage GEMMs ride the same `*_qd`
/// kernels — the whole step must stay bit-identical with the integer
/// domain on.
#[test]
fn conv_train_step_int_domain_bit_identical() {
    let spec = tiny_conv_spec();
    let net = Network::from_topology_shaped(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES)
        .expect("fixture topology realizes");
    let comp = FixedFormat::new(10, 3);
    let up = FixedFormat::new(12, 0);
    let ctrl = ScaleController::fixed(net.n_groups(), comp, up);
    let qup = Quantizer::from_format(up);
    let qcomp = Quantizer::from_format(comp);
    for mode in [RoundMode::HalfAway, RoundMode::Stochastic] {
        let run = |int_domain: bool| {
            let (mut params, mut vels) =
                topology_state(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES, 0xC0DE);
            for p in &mut params {
                qup.apply_slice(p.data_mut());
            }
            let (mut x, y) = spatial_batch(TINY_CONV_SHAPE, 4, TINY_CONV_CLASSES, 0xF00D);
            qcomp.apply_slice(x.data_mut());
            let mut trace: Vec<Vec<u32>> = Vec::new();
            for _ in 0..2 {
                let out = net.train_step(
                    &mut params,
                    &mut vels,
                    &x,
                    &y,
                    0.1,
                    0.5,
                    2.0,
                    &ctrl,
                    StepOptions { mode, fused: true, int_domain, ..Default::default() },
                );
                trace.push(vec![out.loss.to_bits()]);
                trace.push(bits(out.overflow.data()));
            }
            for t in params.iter().chain(vels.iter()) {
                trace.push(bits(t.data()));
            }
            trace
        };
        assert_eq!(run(true), run(false), "conv {mode:?}");
    }
}

// ---------------------------------------------------------------------------
// Packed-operand cache
// ---------------------------------------------------------------------------

/// The cached-b entry points with a pre-packed weight slab must be
/// bit-identical (bits *and* stats) to the simulated kernels — and the
/// per-call eligibility re-checks must still engage — for both the
/// forward NN orientation and the dx-projection NT orientation that
/// share one slab, across arithmetics × round modes × threads. A
/// recorded-unpackable slab (`bp = None`) must fall back transparently.
#[test]
fn cached_weight_packs_bit_identical_to_simulated() {
    let mut rng = Pcg32::seeded(0x16E3_0005);
    for mode in ROUND_MODES {
        for (label, fmt, amax, exp) in int_arithmetics() {
            let epi = with_stream(mk_epi(fmt, mode), mode, 0x16E3_A005);
            for (m, kd, n) in [(7, 13, 9), (33, 17, 40)] {
                let a = grid_vec(&mut rng, m * kd, amax, exp);
                let b = grid_vec(&mut rng, kd * n, amax, exp);
                let bias = grid_vec(&mut rng, n, amax, exp);
                let bp = int_gemm::pack(&b).expect("grid data packs");
                let zeros = vec![0.0f32; m * n];
                assert_eq!(
                    ops::quant_gemm_plan_cached(&a, Some(&bp), kd, Some(&zeros)),
                    QuantGemmImpl::IntDomain,
                    "{label} {mode:?} {m}x{kd}x{n}: cached NN case must engage"
                );
                for threads in THREADS {
                    let (want, wst) =
                        ops::matmul_sl_q_threads(&a, &b, Some(&bias), m, kd, n, epi, threads);
                    let mut got = vec![0.0f32; m * n];
                    let gst = ops::matmul_sl_qd_cached_into_threads(
                        &a,
                        &b,
                        Some(&bp),
                        Some(&bias),
                        &mut got,
                        m,
                        kd,
                        n,
                        epi,
                        threads,
                        None,
                    );
                    assert_eq!(bits(&got), bits(&want), "cached nn {label} {mode:?} t{threads}");
                    assert_eq!(gst, wst, "cached nn {label} {mode:?} t{threads} stats");
                }

                // NT (dx projection): b is [ib, ua] row-major — the SAME
                // flat slab a forward would cache serves this orientation
                let (ua, ib) = (n, kd);
                let a2 = grid_vec(&mut rng, m * ua, amax, exp);
                assert_eq!(
                    ops::quant_gemm_plan_cached(&a2, Some(&bp), ua, None),
                    QuantGemmImpl::IntDomain,
                    "{label} {mode:?} {m}x{ua}x{ib}: cached NT case must engage"
                );
                for threads in THREADS {
                    let (want, wst) = ops::matmul_nt_sl_q_threads(&a2, &b, m, ua, ib, epi, threads);
                    let (got, gst) = ops::matmul_nt_sl_qd_cached_threads(
                        &a2,
                        &b,
                        Some(&bp),
                        m,
                        ua,
                        ib,
                        epi,
                        threads,
                        None,
                    );
                    assert_eq!(bits(&got), bits(&want), "cached nt {label} {mode:?} t{threads}");
                    assert_eq!(gst, wst, "cached nt {label} {mode:?} t{threads} stats");
                }
            }
        }
    }
}

/// Per-call eligibility is re-checked even with a valid cached slab: an
/// off-grid activation, a dirty accumulated destination, or a slab the
/// cache recorded as unpackable (`None`) all fall back to the simulated
/// kernel bit-identically.
#[test]
fn cached_dispatch_still_rechecks_per_call_eligibility() {
    let mut rng = Pcg32::seeded(0x16E3_0006);
    let epi = mk_epi(FixedFormat::new(10, 3), RoundMode::HalfAway);
    let (m, kd, n) = (7, 13, 9);
    let b = grid_vec(&mut rng, kd * n, 511, -6);
    let bp = int_gemm::pack(&b).expect("grid data packs");

    // off-grid a rejects the cached path even though bp is valid
    let mut a = grid_vec(&mut rng, m * kd, 511, -6);
    a[5] = 0.1;
    assert_eq!(ops::quant_gemm_plan_cached(&a, Some(&bp), kd, None), QuantGemmImpl::Simulated);
    // dirty accumulated destination likewise
    let clean_a = grid_vec(&mut rng, m * kd, 511, -6);
    let mut dirty = vec![0.0f32; m * n];
    dirty[3] = -0.0;
    assert_eq!(
        ops::quant_gemm_plan_cached(&clean_a, Some(&bp), kd, Some(&dirty)),
        QuantGemmImpl::Simulated
    );
    // recorded-unpackable slab goes straight to simulated
    assert_eq!(ops::quant_gemm_plan_cached(&clean_a, None, kd, None), QuantGemmImpl::Simulated);

    for threads in THREADS {
        for (ctx, aa, slab) in
            [("off-grid a", &a, Some(&bp)), ("bp none", &clean_a, None)]
        {
            let (want, wst) = ops::matmul_sl_q_threads(aa, &b, None, m, kd, n, epi, threads);
            let mut got = vec![0.0f32; m * n];
            let gst = ops::matmul_sl_qd_cached_into_threads(
                aa, &b, slab, None, &mut got, m, kd, n, epi, threads, None,
            );
            assert_eq!(bits(&got), bits(&want), "{ctx} t{threads}");
            assert_eq!(gst, wst, "{ctx} t{threads} stats");
            let (want, wst) = ops::matmul_nt_sl_q_threads(aa, &b, m, kd, n, epi, threads);
            let (got, gst) =
                ops::matmul_nt_sl_qd_cached_threads(aa, &b, slab, m, kd, n, epi, threads, None);
            assert_eq!(bits(&got), bits(&want), "{ctx} nt t{threads}");
            assert_eq!(gst, wst, "{ctx} nt t{threads} stats");
        }
    }
}

/// The cache lifecycle proof for training: one persistent [`Network`]
/// re-packs each weight layer exactly once per train step (forward
/// builds, backward hits the same key, `sgd_update` invalidates) — never
/// once per GEMM — while staying bit-identical to a cold-cache network
/// (fresh `Network` per step, PR 7 behavior) and to the simulated path,
/// across round modes. A stale-cache bug or a repack-per-GEMM regression
/// breaks the builds count even where the output bits could not tell.
#[test]
fn cached_packs_rebuild_once_per_update_bit_identically() {
    let s = tiny_mlp();
    let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    for mode in ROUND_MODES {
        let run = |style: &str| -> Vec<Vec<u32>> {
            let (mut params, mut vels, x, y) =
                quantized_mlp_fixture(ctrl.format(2), ctrl.format(0));
            let net = Network::from_mlp_shape(s);
            let layers = net.n_compute_layers() as u64;
            let mut trace: Vec<Vec<u32>> = Vec::new();
            for step in 0..3u64 {
                let cold;
                let (net_ref, int_domain) = match style {
                    "cached" => (&net, true),
                    "cold" => {
                        cold = Network::from_mlp_shape(s);
                        (&cold, true)
                    }
                    _ => (&net, false),
                };
                let out = net_ref.train_step(
                    &mut params,
                    &mut vels,
                    &x,
                    &y,
                    0.1,
                    0.5,
                    2.0,
                    &ctrl,
                    StepOptions { mode, fused: true, int_domain, ..Default::default() },
                );
                trace.push(vec![out.loss.to_bits()]);
                trace.push(bits(out.overflow.data()));
                if style == "cached" {
                    assert_eq!(
                        net.weight_pack_builds(),
                        (step + 1) * layers,
                        "{mode:?}: exactly one rebuild per weight layer per step"
                    );
                }
            }
            for t in params.iter().chain(vels.iter()) {
                trace.push(bits(t.data()));
            }
            trace
        };
        let cached = run("cached");
        assert_eq!(cached, run("cold"), "{mode:?} cached vs cold-cache");
        assert_eq!(cached, run("simulated"), "{mode:?} cached vs simulated");
    }
}

/// Scale adoption re-keys the caches: after [`ScaleController::adopt_int_bits`]
/// the next forward rebuilds every slab exactly once. The weight values
/// did not change, so the rebuilt packs are byte-identical to the stale
/// ones — only the builds counter can catch a cache that failed to
/// re-key, which is exactly what this test pins down. Prepack (the serve
/// workers' startup path) must populate the same caches idempotently.
#[test]
fn scale_adoption_and_prepack_drive_the_cache_key() {
    let s = tiny_mlp();
    let mut ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
    let (params, _, x, _) = quantized_mlp_fixture(ctrl.format(2), ctrl.format(0));
    let net = Network::from_mlp_shape(s);
    let layers = net.n_compute_layers() as u64;
    let opts = StepOptions {
        mode: RoundMode::HalfAway,
        fused: true,
        int_domain: true,
        ..Default::default()
    };

    assert_eq!(net.weight_pack_builds(), 0, "fresh network: no builds");
    let l0 = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(net.weight_pack_builds(), layers, "first eval builds each slab once");
    let l1 = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(net.weight_pack_builds(), layers, "second eval is a pure cache hit");
    assert_eq!(bits(l0.data()), bits(l1.data()));

    // adopt a one-bit-wider integer part for every group: every W
    // step() moves, so every slab must re-key
    let adopted: Vec<i32> =
        (0..ctrl.n_groups()).map(|g| ctrl.format(g).int_bits + 1).collect();
    ctrl.adopt_int_bits(&adopted);
    let l2 = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(net.weight_pack_builds(), 2 * layers, "adoption re-keys every slab once");
    let l3 = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(net.weight_pack_builds(), 2 * layers, "…and only once");
    assert_eq!(bits(l2.data()), bits(l3.data()));

    // bit-identity vs a cold-cache network and the simulated path under
    // the adopted scales
    let cold = Network::from_mlp_shape(s);
    let lc = cold.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(bits(l2.data()), bits(lc.data()), "cached eval ≡ cold eval after adoption");
    let ls = net.eval_logits_opt(
        &params,
        &x,
        &ctrl,
        &StepOptions { int_domain: false, ..opts.clone() },
    );
    assert_eq!(bits(l2.data()), bits(ls.data()), "cached eval ≡ simulated after adoption");

    // worker-style prepack: populates every slab up front, is
    // idempotent, and the following eval never re-packs
    let pre = Network::from_mlp_shape(s);
    pre.prepack_int_operands(&params, &ctrl);
    assert_eq!(pre.weight_pack_builds(), layers, "prepack builds each slab once");
    pre.prepack_int_operands(&params, &ctrl);
    assert_eq!(pre.weight_pack_builds(), layers, "prepack is idempotent");
    let lp = pre.eval_logits_opt(&params, &x, &ctrl, &opts);
    assert_eq!(pre.weight_pack_builds(), layers, "eval after prepack is a pure hit");
    assert_eq!(bits(lp.data()), bits(l2.data()), "prepacked eval ≡ cached eval");
}

/// Same lifecycle on the conv topology: the im2col weight slabs cache
/// across steps (one rebuild per weight-owning layer per step) and stay
/// bit-identical to the cold-cache and simulated paths.
#[test]
fn conv_cached_packs_rebuild_once_per_update_bit_identically() {
    let spec = tiny_conv_spec();
    let comp = FixedFormat::new(10, 3);
    let up = FixedFormat::new(12, 0);
    let qup = Quantizer::from_format(up);
    let qcomp = Quantizer::from_format(comp);
    let mk = || {
        Network::from_topology_shaped(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES)
            .expect("fixture topology realizes")
    };
    let probe = mk();
    let ctrl = ScaleController::fixed(probe.n_groups(), comp, up);
    let run = |style: &str| -> Vec<Vec<u32>> {
        let (mut params, mut vels) =
            topology_state(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES, 0xC0DE);
        for p in &mut params {
            qup.apply_slice(p.data_mut());
        }
        let (mut x, y) = spatial_batch(TINY_CONV_SHAPE, 4, TINY_CONV_CLASSES, 0xF00D);
        qcomp.apply_slice(x.data_mut());
        let net = mk();
        let layers = net.n_compute_layers() as u64;
        let mut trace: Vec<Vec<u32>> = Vec::new();
        for step in 0..2u64 {
            let cold;
            let (net_ref, int_domain) = match style {
                "cached" => (&net, true),
                "cold" => {
                    cold = mk();
                    (&cold, true)
                }
                _ => (&net, false),
            };
            let out = net_ref.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.1,
                0.5,
                2.0,
                &ctrl,
                StepOptions {
                    mode: RoundMode::HalfAway,
                    fused: true,
                    int_domain,
                    ..Default::default()
                },
            );
            trace.push(vec![out.loss.to_bits()]);
            trace.push(bits(out.overflow.data()));
            if style == "cached" {
                assert_eq!(
                    net.weight_pack_builds(),
                    (step + 1) * layers,
                    "conv: exactly one rebuild per weight layer per step"
                );
            }
        }
        for t in params.iter().chain(vels.iter()) {
            trace.push(bits(t.data()));
        }
        trace
    };
    let cached = run("cached");
    assert_eq!(cached, run("cold"), "conv cached vs cold-cache");
    assert_eq!(cached, run("simulated"), "conv cached vs simulated");
}

// ---------------------------------------------------------------------------
// Overflow safety
// ---------------------------------------------------------------------------

/// Whenever the planner accepts a site, an i64 shadow of the integer
/// accumulation (worst-case: all partial products taken in magnitude)
/// stays within `ACC_BOUND` — so the i32 accumulator can never wrap, in
/// any summation order, and every f32 partial sum stays exact.
#[test]
fn accepted_sites_cannot_silently_overflow_i32() {
    forall_seeded("accepted sites fit i32", 0x16E3_0A11, |g: &mut Gen| {
        let m = g.usize_range(1, 4);
        let kd = g.usize_range(1, 64);
        let n = g.usize_range(1, 4);
        let amax = g.i32_range(1, 3000);
        let exp = g.i32_range(-12, 4);
        let step = int_gemm::exp2f(exp);
        let mut next = |g: &mut Gen| g.i32_range(-amax, amax) as f32 * step;
        let a: Vec<f32> = (0..m * kd).map(|_| next(g)).collect();
        let b: Vec<f32> = (0..kd * n).map(|_| next(g)).collect();
        if ops::quant_gemm_plan(&a, &b, kd, None) != QuantGemmImpl::IntDomain {
            return;
        }
        let (ap, bp) = (int_gemm::pack(&a).unwrap(), int_gemm::pack(&b).unwrap());
        let (sa, sb) = (int_gemm::exp2f(ap.exp), int_gemm::exp2f(bp.exp));
        for i in 0..m {
            for j in 0..n {
                let shadow: i64 = (0..kd)
                    .map(|k| {
                        let ai = (a[i * kd + k] / sa) as i64;
                        let bj = (b[k * n + j] / sb) as i64;
                        (ai * bj).abs()
                    })
                    .sum();
                assert!(
                    shadow <= int_gemm::ACC_BOUND as i64,
                    "accepted site exceeds the bound: {shadow} at ({i},{j})"
                );
            }
        }
    });
}
