//! Conv-lowering parity: the im2col-lowered conv path (every conv
//! multiply riding the fused quantized GEMM epilogues) must be
//! **bit-identical** — exact `u32` output bits *and* exact
//! [`QuantStats`] counters — to the direct nested-loop reference
//! kernels, across:
//!
//! * all four arithmetics (float32 passthrough, fixed, dynamic-regime
//!   fixed, float16 simulation),
//! * all four rounding modes (stochastic via the counter-based
//!   per-site streams),
//! * explicit GEMM thread counts {1, 4} at the kernel level, and the
//!   auto-threaded path at the step level (CI re-runs the suite under
//!   `LPDNN_THREADS` ∈ {1, 4}),
//! * fused and two-pass quantization (`StepOptions::fused`) at the
//!   full-train-step level (`StepOptions::conv_direct` as the A/B).
//!
//! A second layer exercises the end-to-end story: conv topologies
//! parsed from `[[topology.conv]]` TOML and the CLI grammar train
//! deterministically on the native backend with per-conv-layer dynamic
//! scale adoption (mirroring `tests/graph_parity.rs`).

use lpdnn::arith::{ElemRng, FixedFormat, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use lpdnn::config::{ExperimentConfig, TopologySpec};
use lpdnn::coordinator::{ScaleController, Session};
use lpdnn::golden::conv::{conv2d_direct_q, conv2d_dw_direct_q, im2col_into, ConvGeom};
use lpdnn::golden::{Network, StepOptions, STOCHASTIC_SITE_SEED};
use lpdnn::runtime::BackendSpec;
use lpdnn::tensor::{ops, Pcg32};
use lpdnn::testing::{
    spatial_batch, tiny_conv_spec, topology_state, ROUND_MODES, TINY_CONV_CLASSES,
    TINY_CONV_SHAPE,
};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The four arithmetics as kernel epilogues (mode applied per case).
fn epilogue_cases() -> Vec<(&'static str, Option<FixedFormat>)> {
    vec![
        ("float32", Some(FixedFormat::FLOAT32)),
        ("fixed 10.3", Some(FixedFormat::new(10, 3))),
        ("dynamic-regime 8.2", Some(FixedFormat::new(8, 2))),
        ("float16", None), // half_sim
    ]
}

fn make_epi(fmt: Option<FixedFormat>, mode: RoundMode) -> QuantEpilogue {
    let mut epi = match fmt {
        Some(f) => {
            let mut q = Quantizer::from_format(f);
            q.mode = mode;
            QuantEpilogue::new(q)
        }
        None => QuantEpilogue::half_sim(),
    };
    if mode == RoundMode::Stochastic {
        epi = epi.with_rng(ElemRng::for_site(STOCHASTIC_SITE_SEED, 7));
    }
    epi
}

/// An odd-sized geometry (exercises the SAME-padding borders) with a
/// patch length crossing nothing special — the kernel-level fixture.
fn geom() -> ConvGeom {
    ConvGeom { h: 9, w: 7, c_in: 3, c_out: 5, ksize: 5 }
}

/// Random image with exact zeros sprinkled in, so the zero fast-paths
/// of both kernel families fire on identical elements.
fn image(g: &ConvGeom, batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..batch * g.h * g.w * g.c_in)
        .map(|_| {
            if rng.uniform() < 0.12 {
                0.0
            } else {
                rng.normal()
            }
        })
        .collect()
}

/// (a) Forward kernels: im2col + fused GEMM ≡ direct reference, exact
/// bits + stats, across 4 arithmetics × 4 round modes × threads {1, 4}.
#[test]
fn forward_conv_im2col_matches_direct_bitwise() {
    let g = geom();
    let batch = 3;
    let x = image(&g, batch, 0xC0);
    let mut rng = Pcg32::seeded(0xC1);
    let w: Vec<f32> = (0..g.patch_len() * g.c_out).map(|_| rng.normal()).collect();
    let bias: Vec<f32> = (0..g.c_out).map(|_| rng.normal()).collect();
    let mut patches = vec![0.0f32; g.rows(batch) * g.patch_len()];
    im2col_into(&x, batch, &g, &mut patches);

    for (label, fmt) in epilogue_cases() {
        for mode in ROUND_MODES {
            let epi = make_epi(fmt, mode);
            let mut direct = vec![0.0f32; g.rows(batch) * g.c_out];
            let st_d = conv2d_direct_q(&x, &w, Some(&bias), &mut direct, batch, &g, epi);
            for threads in [1usize, 4] {
                let mut lowered = vec![0.0f32; g.rows(batch) * g.c_out];
                let st_g = ops::matmul_sl_q_into_threads(
                    &patches,
                    &w,
                    Some(&bias),
                    &mut lowered,
                    g.rows(batch),
                    g.patch_len(),
                    g.c_out,
                    epi,
                    threads,
                );
                assert_eq!(
                    bits(&direct),
                    bits(&lowered),
                    "{label} {mode:?} t={threads}: forward bits"
                );
                assert_eq!(st_d, st_g, "{label} {mode:?} t={threads}: forward stats");
            }
        }
    }
}

/// (a) Weight-gradient kernels: the direct dw reference ≡ the TN GEMM
/// over the patch matrix, same matrix of cases.
#[test]
fn dw_conv_im2col_matches_direct_bitwise() {
    let g = geom();
    let batch = 3;
    let x = image(&g, batch, 0xD0);
    let mut rng = Pcg32::seeded(0xD1);
    let dz: Vec<f32> = (0..g.rows(batch) * g.c_out).map(|_| rng.normal()).collect();
    let mut patches = vec![0.0f32; g.rows(batch) * g.patch_len()];
    im2col_into(&x, batch, &g, &mut patches);

    for (label, fmt) in epilogue_cases() {
        for mode in ROUND_MODES {
            let epi = make_epi(fmt, mode);
            let mut direct = vec![0.0f32; g.patch_len() * g.c_out];
            let st_d = conv2d_dw_direct_q(&x, &dz, &mut direct, batch, &g, epi);
            for threads in [1usize, 4] {
                let mut lowered = vec![0.0f32; g.patch_len() * g.c_out];
                let st_g = ops::matmul_tn_sl_q_into_threads(
                    &patches,
                    &dz,
                    &mut lowered,
                    g.rows(batch),
                    g.patch_len(),
                    g.c_out,
                    epi,
                    threads,
                );
                assert_eq!(
                    bits(&direct),
                    bits(&lowered),
                    "{label} {mode:?} t={threads}: dw bits"
                );
                assert_eq!(st_d, st_g, "{label} {mode:?} t={threads}: dw stats");
            }
        }
    }
}

/// The four arithmetics as scale controllers for the step-level suite,
/// sized for the tiny conv net's 32 groups.
fn arith_cases(n_groups: usize) -> Vec<(&'static str, ScaleController, bool)> {
    vec![
        (
            "float32",
            ScaleController::fixed(n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            false,
        ),
        (
            "fixed 10.3/12.0",
            ScaleController::fixed(n_groups, FixedFormat::new(10, 3), FixedFormat::new(12, 0)),
            false,
        ),
        (
            "dynamic-regime 8.2/14.1",
            ScaleController::fixed(n_groups, FixedFormat::new(8, 2), FixedFormat::new(14, 1)),
            false,
        ),
        (
            "float16",
            ScaleController::fixed(n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32),
            true,
        ),
    ]
}

/// (a) Full train steps through the graph: `conv_direct` ≡ im2col, for
/// every arithmetic × round mode × fused/two-pass — loss, overflow,
/// parameter and velocity bits all equal over two steps.
#[test]
fn conv_network_step_direct_equals_im2col_bitwise() {
    let spec = tiny_conv_spec();
    let net =
        Network::from_topology_shaped(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES).unwrap();
    assert_eq!(net.n_groups(), 32);
    let (x, y) = spatial_batch(TINY_CONV_SHAPE, 6, TINY_CONV_CLASSES, 0xBA);
    for (label, ctrl, half) in &arith_cases(net.n_groups()) {
        for mode in ROUND_MODES {
            for fused in [true, false] {
                let run = |conv_direct: bool| {
                    let (mut params, mut vels) =
                        topology_state(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES, 0x5EED);
                    let mut trace = Vec::new();
                    for _ in 0..2 {
                        let out = net.train_step(
                            &mut params,
                            &mut vels,
                            &x,
                            &y,
                            0.1,
                            0.5,
                            2.0,
                            ctrl,
                            StepOptions {
                                mode,
                                half: *half,
                                dropout: None,
                                fused,
                                conv_direct,
                                ..Default::default()
                            },
                        );
                        trace.push((out.loss.to_bits(), bits(out.overflow.data())));
                    }
                    (trace, params, vels)
                };
                let (t_i, p_i, v_i) = run(false);
                let (t_d, p_d, v_d) = run(true);
                assert_eq!(
                    t_i, t_d,
                    "{label} {mode:?} fused={fused}: loss/overflow diverged"
                );
                for (i, (a, b)) in p_i.iter().zip(&p_d).enumerate() {
                    assert_eq!(
                        bits(a.data()),
                        bits(b.data()),
                        "{label} {mode:?} fused={fused}: param {i}"
                    );
                }
                for (i, (a, b)) in v_i.iter().zip(&v_d).enumerate() {
                    assert_eq!(
                        bits(a.data()),
                        bits(b.data()),
                        "{label} {mode:?} fused={fused}: vel {i}"
                    );
                }
            }
        }
    }
}

/// (a) The overflow counters cover every conv site: one logical Z site
/// of `k·B·H·W·C_out` elements per stage, H after the pool.
#[test]
fn conv_step_counts_the_expected_site_totals() {
    use lpdnn::runtime::manifest::{group_index, KIND_H, KIND_Z};
    let spec = tiny_conv_spec();
    let net =
        Network::from_topology_shaped(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES).unwrap();
    let ctrl = ScaleController::fixed(
        net.n_groups(),
        FixedFormat::new(10, 3),
        FixedFormat::new(12, 0),
    );
    let (mut params, mut vels) =
        topology_state(&spec, TINY_CONV_SHAPE, TINY_CONV_CLASSES, 1);
    let n = 5;
    let (x, y) = spatial_batch(TINY_CONV_SHAPE, n, TINY_CONV_CLASSES, 2);
    let out = net.train_step(
        &mut params,
        &mut vels,
        &x,
        &y,
        0.1,
        0.5,
        0.0,
        &ctrl,
        StepOptions::default(),
    );
    let st = out.overflow;
    assert_eq!(st.at2(group_index(0, KIND_Z), 2), (2 * n * 8 * 8 * 3) as f32);
    assert_eq!(st.at2(group_index(0, KIND_H), 2), (n * 4 * 4 * 3) as f32);
    assert_eq!(st.at2(group_index(1, KIND_Z), 2), (2 * n * 4 * 4 * 4) as f32);
    assert_eq!(st.at2(group_index(1, KIND_H), 2), (n * 2 * 2 * 4) as f32);
}

/// (b) A conv topology from `[[topology.conv]]` TOML trains end to end
/// with dynamic fixed point adopting per-conv-layer scales, and the
/// whole run replays bit-deterministically.
#[test]
fn conv_topology_toml_trains_with_dynamic_scales_deterministically() {
    let toml = r#"
[experiment]
name = "conv-dynamic"
dataset = "cifar_like"

[topology]
k = 2
eval_batch = 64

[[topology.conv]]
channels = 4
ksize = 3

[[topology.conv]]
channels = 6
ksize = 3

[arithmetic]
kind = "dynamic"
bits_comp = 10
bits_up = 12
max_overflow_rate = 1e-4
update_every_examples = 128
init_int_bits = 3
warmup_steps = 4

[train]
steps = 10
lr_start = 0.05
seed = 7

[data]
n_train = 96
n_test = 48
"#;
    let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
    let topo = cfg.topology.as_ref().unwrap();
    assert_eq!(topo.conv.len(), 2);
    assert_eq!(topo.n_layers(), 3);

    let run = || Session::new(BackendSpec::native()).run(cfg.clone()).unwrap();
    let r = run();
    assert_eq!(r.steps_run, 10);
    assert!(r.train_loss.is_finite());
    assert!(r.test_error.is_finite() && r.test_error <= 1.0);
    // one scale row per conv stage + head, 8 kinds each
    assert_eq!(r.final_int_bits.len(), 24);
    // warmup adoption + runtime moves must have taken at least one
    // group off the uniform init_int_bits=3 cold start
    assert!(
        r.final_int_bits.iter().any(|&b| b != 3),
        "no per-conv-layer scale was ever adopted: {:?}",
        r.final_int_bits
    );
    // the whole run — warmup, adoption, training, eval — replays exactly
    let r2 = run();
    assert_eq!(r.test_error.to_bits(), r2.test_error.to_bits());
    assert_eq!(r.train_loss.to_bits(), r2.train_loss.to_bits());
    assert_eq!(r.final_int_bits, r2.final_int_bits);
}

/// (b) The CLI conv grammar end to end: parse, realize against digits,
/// train on the native backend.
#[test]
fn cli_conv_topology_trains_on_digits() {
    let spec = TopologySpec::parse_cli("c4k3p2,c6k3p1/8x1@k2").unwrap();
    assert_eq!(spec.n_layers(), 4);
    let mut cfg = ExperimentConfig::default();
    cfg.name = "cli-conv".into();
    cfg.model = spec.name.clone();
    cfg.topology = Some(spec);
    cfg.data.dataset = "digits".into();
    cfg.data.n_train = 128;
    cfg.data.n_test = 64;
    cfg.train.steps = 3;
    cfg.train.seed = 11;
    let r = Session::new(BackendSpec::native()).run(cfg).unwrap();
    assert_eq!(r.steps_run, 3);
    assert!(r.test_error.is_finite());
    assert_eq!(r.final_int_bits.len(), 32);
}

/// The stats type is re-exported where the kernel suite needs it; keep
/// a compile-time witness that the parity assertions compare the real
/// counter type (not a stand-in).
#[test]
fn quant_stats_equality_is_field_exact() {
    let a = QuantStats { n_over: 1, n_half: 2, n_total: 3 };
    let b = QuantStats { n_over: 1, n_half: 2, n_total: 3 };
    assert_eq!(a, b);
}
