//! Seeded property tests for [`Quantizer`] across every rounding mode,
//! built from the shared fixtures in `lpdnn::testing`:
//!
//! * outputs always land on the `(step, maxv)` grid, inside the
//!   representable range `[-maxv, maxv - step]`,
//! * `apply` is idempotent (a grid point maps to itself, any mode, any
//!   stochastic sample),
//! * `apply` is monotone in its input (for a shared stochastic sample),
//! * `stats_only` totals equal `apply_slice` totals on the same data,
//! * the fused kernels' `QuantEpilogue` can never drift from
//!   `apply_slice` (bit-for-bit cross-check, plus tiling invariance),
//! * the integer-domain GEMM packing (`tensor::int_gemm`) round-trips
//!   every representable grid value exactly, every builtin-topology GEMM
//!   site lowers to whole-reduction integer or split-accumulator
//!   arithmetic at the paper's multiply widths, and the split scheduler's
//!   segment length is maximal-but-safe for arbitrary operand grids.

use lpdnn::arith::{ElemRng, FixedFormat, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use lpdnn::config::TopologySpec;
use lpdnn::tensor::{int_gemm, Shape};
use lpdnn::testing::{forall_seeded, format_grid, Gen, gen_quantizer, gen_signal, ROUND_MODES};

/// A uniform sample for stochastic rounding; ignored by the other modes.
fn gen_u(g: &mut Gen) -> f32 {
    g.f32_range(0.0, 1.0)
}

#[test]
fn outputs_land_on_grid_and_in_range_for_all_modes() {
    forall_seeded("grid membership", 0x9121, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let x = g.f32_range(-4.0 * q.maxv, 4.0 * q.maxv);
        let u = gen_u(g);
        let y = q.apply_with(x, u);
        let k = y / q.step;
        assert!((k - k.round()).abs() < 1e-3, "off grid: {q:?} x={x} y={y}");
        assert!(
            y >= -q.maxv && y <= q.maxv - q.step * 0.999,
            "out of range: {q:?} x={x} y={y}"
        );
    });
}

#[test]
fn apply_is_idempotent_for_all_modes() {
    forall_seeded("idempotence", 0x9122, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let x = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let y = q.apply_with(x, gen_u(g));
        // a second pass, with any sample, must be a fixed point
        assert_eq!(q.apply_with(y, gen_u(g)), y, "{q:?} x={x} y={y}");
        assert_eq!(q.apply(y), y, "{q:?} (canonical apply)");
    });
}

#[test]
fn apply_is_monotone_for_all_modes() {
    forall_seeded("monotonicity", 0x9123, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let a = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let b = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let u = gen_u(g); // shared sample: monotone per realization
        assert!(
            q.apply_with(lo, u) <= q.apply_with(hi, u),
            "{q:?} lo={lo} hi={hi} u={u}"
        );
    });
}

#[test]
fn stats_only_totals_equal_apply_slice_totals() {
    forall_seeded("stats_only = apply_slice", 0x9124, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let xs = gen_signal(g, &q, 0, 50);
        let dry = q.stats_only(&xs);
        let mut wet = xs.clone();
        let st = q.apply_slice(&mut wet);
        assert_eq!(dry, st, "{q:?}");
        assert_eq!(dry.n_total, xs.len() as u64);
        // and the counters match their definition on the raw data
        let over = xs.iter().filter(|v| v.abs() >= q.maxv).count() as u64;
        let half = xs.iter().filter(|v| v.abs() >= q.maxv * 0.5).count() as u64;
        assert_eq!((dry.n_over, dry.n_half), (over, half), "{q:?}");
    });
}

#[test]
fn passthrough_is_identity_for_every_mode() {
    for mode in ROUND_MODES {
        let mut q = Quantizer::float32();
        q.mode = mode;
        let mut xs = vec![1.5, -2.7e30, f32::MIN_POSITIVE, 0.0];
        let orig = xs.clone();
        let st = q.apply_slice(&mut xs);
        assert_eq!(xs, orig, "{mode:?}");
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: 4 });
        assert_eq!(q.apply_with(3.21, 0.9), 3.21, "{mode:?}");
    }
}

#[test]
fn epilogue_is_bit_identical_to_apply_slice() {
    // The fused kernels' epilogue and the canonical two-pass sweep are
    // two implementations of one contract — they may never drift.
    forall_seeded("epilogue = apply_slice", 0x9125, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let xs = gen_signal(g, &q, 0, 50);
        let mut a = xs.clone();
        let mut b = xs;
        let st_a = QuantEpilogue::new(q).run(&mut a, 0);
        let st_b = q.apply_slice(&mut b);
        assert_eq!(st_a, st_b, "{q:?}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{q:?}");
        }
    });
}

#[test]
fn epilogue_tiling_is_invariant_on_the_format_grid() {
    // Fixed split points over every fixture format, with a stochastic
    // stream attached: per-tile runs at the right offsets must equal the
    // whole-tensor sweep exactly (the fused kernels' core invariant).
    for fmt in format_grid() {
        for mode in ROUND_MODES {
            let mut q = Quantizer::from_format(fmt);
            q.mode = mode;
            let epi = QuantEpilogue::new(q).with_rng(ElemRng::new(0x711E));
            let mut g = Gen::new(fmt.total_bits as u64 ^ 0xF0);
            let xs = gen_signal(&mut g, &q, 64, 64);
            let mut whole = xs.clone();
            let st_whole = epi.run(&mut whole, 0);
            let mut tiled = xs;
            let mut st = QuantStats::default();
            for (start, end) in [(0usize, 7usize), (7, 8), (8, 40), (40, 64)] {
                st.merge(epi.run(&mut tiled[start..end], start as u64));
            }
            assert_eq!(st, st_whole, "{fmt} {mode:?}");
            for (x, y) in whole.iter().zip(&tiled) {
                assert_eq!(x.to_bits(), y.to_bits(), "{fmt} {mode:?}");
            }
        }
    }
}

#[test]
fn int_packing_round_trips_every_grid_value_exactly() {
    // Any slice on a fixed-point grid is `int * 2^e` for a shared
    // power-of-two step; `int_gemm::pack` must recover that exactly.
    // Narrow formats (<= 15 total bits) always fit the i16 operand
    // window (`|int| <= 2^14`), so for them packing may never fail.
    forall_seeded("pack/unpack round trip", 0x9126, |g: &mut Gen| {
        let fmt = FixedFormat::new(g.i32_range(2, 24), g.i32_range(-4, 8));
        let mut q = Quantizer::from_format(fmt);
        q.mode = *g.choose(&ROUND_MODES);
        let mut xs = gen_signal(g, &q, 0, 60);
        q.apply_slice(&mut xs);
        let packed = int_gemm::pack(&xs);
        if fmt.total_bits <= 15 {
            assert!(packed.is_some(), "{fmt} must pack: {xs:?}");
        }
        let Some(p) = packed else { return };
        assert_eq!(p.len(), xs.len());
        for (x, y) in xs.iter().zip(p.unpack()) {
            if *x == 0.0 {
                // sign of zero may collapse (-0.0 packs as integer 0)
                assert_eq!(y, 0.0, "{fmt}");
            } else {
                assert_eq!(x.to_bits(), y.to_bits(), "{fmt} x={x} y={y}");
            }
        }
    });
}

/// Flat contraction lengths of every quantized GEMM site a topology
/// lowers to, mirroring `golden::graph`: per conv stage the im2col
/// forward (`ksize^2 * c_in`) and the weight-gradient contraction over
/// `batch * h * w` (SAME-padded pre-pool dims), per hidden dense layer
/// the forward (`d_in`) and weight-gradient (`batch`) contractions,
/// then the softmax head's forward / dW / dX triple.
fn gemm_site_inners(
    spec: &TopologySpec,
    in_shape: Shape,
    n_classes: usize,
    batch: usize,
) -> Vec<usize> {
    let (mut h, mut w, mut c) = match in_shape {
        Shape::Flat(d) => (1, 1, d),
        Shape::Spatial { h, w, c } => (h, w, c),
    };
    let mut inners = Vec::new();
    for st in &spec.conv {
        inners.push(st.ksize * st.ksize * c);
        inners.push(batch * h * w);
        c = st.channels;
        h /= st.pool;
        w /= st.pool;
    }
    let mut d = h * w * c;
    for &units in &spec.hidden {
        inners.push(d);
        inners.push(batch);
        d = units;
    }
    inners.push(d);
    inners.push(batch);
    inners.push(n_classes);
    inners
}

#[test]
fn builtin_site_shapes_lower_to_int_or_split_at_paper_widths() {
    // The bound itself must keep i32 accumulation overflow-free *and*
    // every partial sum exactly representable in a f32 mantissa.
    assert!(int_gemm::ACC_BOUND <= i32::MAX as u64);
    assert!(int_gemm::ACC_BOUND <= 1 << 24);
    let builtins = [
        ("pi_mlp", Shape::Flat(784)),
        ("pi_mlp_wide", Shape::Flat(784)),
        ("conv", Shape::Spatial { h: 28, w: 28, c: 1 }),
        ("conv32", Shape::Spatial { h: 32, w: 32, c: 3 }),
        ("pi_conv", Shape::Spatial { h: 32, w: 32, c: 3 }),
    ];
    let (mut whole, mut split, mut simulated) = (0usize, 0usize, 0usize);
    for (name, in_shape) in builtins {
        let spec = TopologySpec::builtin(name).expect("builtin topology");
        for inner in gemm_site_inners(&spec, in_shape, 10, 64) {
            for fmt in format_grid() {
                // worst-case |int| on the fmt grid: maxv is amax steps
                let amax = (fmt.maxv() / fmt.step()) as u64;
                let wc = inner as u64 * amax * amax;
                assert_eq!(
                    int_gemm::accum_bound_ok(inner, amax as u32, amax as u32),
                    wc <= int_gemm::ACC_BOUND,
                    "{name} inner={inner} {fmt}"
                );
                if wc <= int_gemm::ACC_BOUND {
                    // whole-reduction integer: can never overflow i32,
                    // whatever the summation order
                    whole += 1;
                    assert!(wc <= i32::MAX as u64, "{name} inner={inner} {fmt}");
                } else if let Some(s) = int_gemm::seg_len(amax as u32, amax as u32) {
                    // split accumulators: the first (maximal) segment's
                    // worst case itself respects the bound
                    split += 1;
                    assert!(
                        s as u64 * amax * amax <= int_gemm::ACC_BOUND,
                        "{name} inner={inner} {fmt}"
                    );
                } else {
                    // a single product exceeds the exact-f32 window, so
                    // bit-identity to the simulated kernel is
                    // fundamentally impossible — permitted only beyond
                    // the paper's Table 3 multiply widths (the 20-bit
                    // audit format), never at the widths the paper
                    // actually trains at
                    simulated += 1;
                    assert!(
                        fmt.total_bits > 12,
                        "{name} inner={inner} {fmt}: a paper-width site may not simulate"
                    );
                }
            }
        }
    }
    // With split accumulators every paper-width site lowers to integer
    // arithmetic: `whole` for shallow reductions, `split` for the deep
    // ones (e.g. the 784-deep l0 forward on the 10-bit grid). The
    // 20-bit audit format keeps the per-product gate honest.
    assert!(whole > 0, "whole={whole}");
    assert!(split > 0, "split={split}");
    assert!(simulated > 0, "simulated={simulated}");
    // the deep-l0 poster child: 784 · 512 · 512 overflows the whole-site
    // bound, yet the 10-bit grid rides Split with 64-element segments
    assert!(!int_gemm::accum_bound_ok(784, 512, 512));
    assert_eq!(int_gemm::seg_len(512, 512), Some(64));
}

/// Satellite property for the split scheduler: `seg_len` is
/// maximal-but-safe for random amax pairs — `Some(s)` means `s` worst
/// case products fit the bound and `s + 1` would not; `None` means
/// either a zero product (whole-site bound already accepts any depth)
/// or a single product beyond the exact-f32 window. Degenerate inner
/// dims (0 and 1) always satisfy the whole-site bound when a single
/// product does.
#[test]
fn seg_len_is_maximal_but_safe_for_random_amax_pairs() {
    forall_seeded("seg_len maximal-but-safe", 0x9127, |g: &mut Gen| {
        let amax_a = g.i32_range(0, 8192) as u32;
        let amax_b = g.i32_range(0, 8192) as u32;
        let inner = g.usize_range(0, 2048);
        let prod = amax_a as u64 * amax_b as u64;

        // inner-dim edges: an empty reduction always fits; a one-term
        // reduction fits exactly when the single product does
        assert!(int_gemm::accum_bound_ok(0, amax_a, amax_b));
        assert_eq!(
            int_gemm::accum_bound_ok(1, amax_a, amax_b),
            prod <= int_gemm::ACC_BOUND,
            "amax=({amax_a},{amax_b})"
        );

        match int_gemm::seg_len(amax_a, amax_b) {
            None => assert!(
                prod == 0 || prod > int_gemm::ACC_BOUND,
                "None only for zero or over-window products: ({amax_a},{amax_b})"
            ),
            Some(s) => {
                assert!(s >= 1, "a nonzero in-window product admits a segment");
                assert!(
                    s as u64 * prod <= int_gemm::ACC_BOUND,
                    "({amax_a},{amax_b}): segment worst case must fit"
                );
                assert!(
                    (s as u64 + 1) * prod > int_gemm::ACC_BOUND,
                    "({amax_a},{amax_b}): one more term would overflow — not maximal"
                );
                // when splitting is actually needed, the first segment
                // is a strict prefix of the reduction
                if !int_gemm::accum_bound_ok(inner, amax_a, amax_b) {
                    assert!(s < inner, "({amax_a},{amax_b}) inner={inner}");
                }
            }
        }
    });
}
