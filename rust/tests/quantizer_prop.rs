//! Seeded property tests for [`Quantizer`] across every rounding mode,
//! built from the shared fixtures in `lpdnn::testing`:
//!
//! * outputs always land on the `(step, maxv)` grid, inside the
//!   representable range `[-maxv, maxv - step]`,
//! * `apply` is idempotent (a grid point maps to itself, any mode, any
//!   stochastic sample),
//! * `apply` is monotone in its input (for a shared stochastic sample),
//! * `stats_only` totals equal `apply_slice` totals on the same data,
//! * the fused kernels' `QuantEpilogue` can never drift from
//!   `apply_slice` (bit-for-bit cross-check, plus tiling invariance).

use lpdnn::arith::{ElemRng, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use lpdnn::testing::{forall_seeded, format_grid, Gen, gen_quantizer, gen_signal, ROUND_MODES};

/// A uniform sample for stochastic rounding; ignored by the other modes.
fn gen_u(g: &mut Gen) -> f32 {
    g.f32_range(0.0, 1.0)
}

#[test]
fn outputs_land_on_grid_and_in_range_for_all_modes() {
    forall_seeded("grid membership", 0x9121, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let x = g.f32_range(-4.0 * q.maxv, 4.0 * q.maxv);
        let u = gen_u(g);
        let y = q.apply_with(x, u);
        let k = y / q.step;
        assert!((k - k.round()).abs() < 1e-3, "off grid: {q:?} x={x} y={y}");
        assert!(
            y >= -q.maxv && y <= q.maxv - q.step * 0.999,
            "out of range: {q:?} x={x} y={y}"
        );
    });
}

#[test]
fn apply_is_idempotent_for_all_modes() {
    forall_seeded("idempotence", 0x9122, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let x = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let y = q.apply_with(x, gen_u(g));
        // a second pass, with any sample, must be a fixed point
        assert_eq!(q.apply_with(y, gen_u(g)), y, "{q:?} x={x} y={y}");
        assert_eq!(q.apply(y), y, "{q:?} (canonical apply)");
    });
}

#[test]
fn apply_is_monotone_for_all_modes() {
    forall_seeded("monotonicity", 0x9123, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let a = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let b = g.f32_range(-3.0 * q.maxv, 3.0 * q.maxv);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let u = gen_u(g); // shared sample: monotone per realization
        assert!(
            q.apply_with(lo, u) <= q.apply_with(hi, u),
            "{q:?} lo={lo} hi={hi} u={u}"
        );
    });
}

#[test]
fn stats_only_totals_equal_apply_slice_totals() {
    forall_seeded("stats_only = apply_slice", 0x9124, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let xs = gen_signal(g, &q, 0, 50);
        let dry = q.stats_only(&xs);
        let mut wet = xs.clone();
        let st = q.apply_slice(&mut wet);
        assert_eq!(dry, st, "{q:?}");
        assert_eq!(dry.n_total, xs.len() as u64);
        // and the counters match their definition on the raw data
        let over = xs.iter().filter(|v| v.abs() >= q.maxv).count() as u64;
        let half = xs.iter().filter(|v| v.abs() >= q.maxv * 0.5).count() as u64;
        assert_eq!((dry.n_over, dry.n_half), (over, half), "{q:?}");
    });
}

#[test]
fn passthrough_is_identity_for_every_mode() {
    for mode in ROUND_MODES {
        let mut q = Quantizer::float32();
        q.mode = mode;
        let mut xs = vec![1.5, -2.7e30, f32::MIN_POSITIVE, 0.0];
        let orig = xs.clone();
        let st = q.apply_slice(&mut xs);
        assert_eq!(xs, orig, "{mode:?}");
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: 4 });
        assert_eq!(q.apply_with(3.21, 0.9), 3.21, "{mode:?}");
    }
}

#[test]
fn epilogue_is_bit_identical_to_apply_slice() {
    // The fused kernels' epilogue and the canonical two-pass sweep are
    // two implementations of one contract — they may never drift.
    forall_seeded("epilogue = apply_slice", 0x9125, |g: &mut Gen| {
        let q = gen_quantizer(g);
        let xs = gen_signal(g, &q, 0, 50);
        let mut a = xs.clone();
        let mut b = xs;
        let st_a = QuantEpilogue::new(q).run(&mut a, 0);
        let st_b = q.apply_slice(&mut b);
        assert_eq!(st_a, st_b, "{q:?}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{q:?}");
        }
    });
}

#[test]
fn epilogue_tiling_is_invariant_on_the_format_grid() {
    // Fixed split points over every fixture format, with a stochastic
    // stream attached: per-tile runs at the right offsets must equal the
    // whole-tensor sweep exactly (the fused kernels' core invariant).
    for fmt in format_grid() {
        for mode in ROUND_MODES {
            let mut q = Quantizer::from_format(fmt);
            q.mode = mode;
            let epi = QuantEpilogue::new(q).with_rng(ElemRng::new(0x711E));
            let mut g = Gen::new(fmt.total_bits as u64 ^ 0xF0);
            let xs = gen_signal(&mut g, &q, 64, 64);
            let mut whole = xs.clone();
            let st_whole = epi.run(&mut whole, 0);
            let mut tiled = xs;
            let mut st = QuantStats::default();
            for (start, end) in [(0usize, 7usize), (7, 8), (8, 40), (40, 64)] {
                st.merge(epi.run(&mut tiled[start..end], start as u64));
            }
            assert_eq!(st, st_whole, "{fmt} {mode:?}");
            for (x, y) in whole.iter().zip(&tiled) {
                assert_eq!(x.to_bits(), y.to_bits(), "{fmt} {mode:?}");
            }
        }
    }
}
