//! Paper Figure 1: final test error vs radix point position.
//!
//! Fixed point, 31-bit computations AND parameter updates (32 with sign);
//! the radix position (number of integer bits) sweeps 0..8. Errors are
//! normalized by the float32 baseline. The paper finds the optimum at
//! radix 5 (range ≈ [-32, 32]) on permutation-invariant MNIST + CIFAR10;
//! we sweep the two pi_mlp workloads (digits = PI MNIST analogue,
//! clusters = pure-PI control).

#[path = "common.rs"]
mod common;

use lpdnn::bench_support::{print_series, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::SweepPoint;

fn main() {
    let mut session = common::setup_sweep();
    let mut table = Table::new(&["workload", "radix", "test error", "normalized"]);
    for dataset in ["digits", "clusters"] {
        let baseline = common::base_cfg(&format!("fig1-base-{dataset}"), "pi_mlp", dataset);
        let points: Vec<SweepPoint> = (0..=8)
            .map(|radix| {
                let mut cfg = baseline.clone();
                cfg.name = format!("fig1-{dataset}-radix{radix}");
                cfg.arithmetic = Arithmetic::Fixed {
                    bits_comp: common::WIDE_BITS,
                    bits_up: common::WIDE_BITS,
                    int_bits: radix,
                };
                SweepPoint { label: format!("{radix}"), cfg }
            })
            .collect();

        let outcome = session.sweep(&baseline, &points).unwrap();

        println!("\n=== Figure 1 analogue ({dataset}): error vs radix position ===");
        println!("float32 baseline error: {:.2}%", 100.0 * outcome.baseline_error());
        println!("(paper: optimum at radix 5, sharp rise at small radix)\n");
        let series: Vec<(f64, f64)> = outcome
            .rows
            .iter()
            .map(|r| (r.label.parse::<f64>().unwrap(), r.normalized))
            .collect();
        print_series(
            &format!("normalized final test error, {dataset} (fixed 31/31)"),
            "radix",
            &series,
        );
        for r in &outcome.rows {
            table.row(&[
                dataset.to_string(),
                r.label.clone(),
                format!("{:.4}", r.test_error),
                format!("{:.2}x", r.normalized),
            ]);
        }
    }
    common::persist_table("fig1", &table);
}
