//! Paper Figure 4: final test error vs maximum overflow rate, per
//! computation bit-width (dynamic fixed point).
//!
//! The controller's single hyperparameter trades range against precision:
//! tolerating more overflow lets scales sit lower (finer steps), which
//! can rescue very narrow formats — but saturation errors grow. The paper
//! settles on 0.01% and notes higher rates "significantly augment the
//! final test error". Updates stay at 31 bits.

#[path = "common.rs"]
mod common;

use lpdnn::bench_support::{print_series, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::SweepPoint;

fn main() {
    let mut session = common::setup_sweep();
    let dataset = "digits";
    let baseline = common::base_cfg("fig4-base", "pi_mlp", dataset);
    let rates: Vec<f64> = vec![1e-5, 1e-4, 1e-3, 1e-2, 1e-1];
    let widths: Vec<i32> = vec![8, 10, 12];

    let mut table = Table::new(&["max overflow rate", "comp 8", "comp 10", "comp 12"]);
    let mut all_rows: Vec<Vec<f64>> = Vec::new();

    for &bits in &widths {
        let points: Vec<SweepPoint> = rates
            .iter()
            .map(|&rate| {
                let mut cfg = baseline.clone();
                cfg.name = format!("fig4-b{bits}-r{rate}");
                let mut a = common::dynamic(bits, common::WIDE_BITS, rate, cfg.data.n_train);
                if let Arithmetic::Dynamic { ref mut bits_up, .. } = a {
                    *bits_up = common::WIDE_BITS;
                }
                cfg.arithmetic = a;
                SweepPoint { label: format!("{rate}"), cfg }
            })
            .collect();

        let outcome = session.sweep(&baseline, &points).unwrap();
        println!("\n=== Figure 4 analogue: comp bits = {bits} ===");
        println!("float32 baseline error: {:.2}%", 100.0 * outcome.baseline_error());
        let series: Vec<(f64, f64)> = outcome
            .rows
            .iter()
            .map(|r| (r.label.parse::<f64>().unwrap().log10(), r.normalized))
            .collect();
        print_series(
            &format!("normalized error vs log10(max overflow rate), comp={bits}"),
            "log10(rate)",
            &series,
        );
        all_rows.push(outcome.rows.iter().map(|r| r.normalized).collect());
    }

    println!("\n=== Figure 4 summary (normalized error) ===");
    for (i, &rate) in rates.iter().enumerate() {
        table.row(&[
            format!("{rate:.0e}"),
            format!("{:.2}x", all_rows[0][i]),
            format!("{:.2}x", all_rows[1][i]),
            format!("{:.2}x", all_rows[2][i]),
        ]);
    }
    table.print();
    println!("(paper: 0.01% is the sweet spot; larger rates degrade, smaller");
    println!(" rates waste range that narrow formats cannot afford)");
    common::persist_table("fig4", &table);
}
