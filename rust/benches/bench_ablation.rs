//! Ablations for the design choices DESIGN.md calls out.
//!
//! 1. WIDTH (paper 9.2/9.3): "doubling the number of hidden units does
//!    not allow any further reduction of the bit-widths" — run pi_mlp vs
//!    pi_mlp_wide at/below the dynamic minimum widths.
//! 2. ROUNDING MODE (host golden model): half-away (canonical) vs
//!    half-even vs truncate vs stochastic at 12-bit storage.
//! 3. UPDATE INTERVAL: the controller's tick frequency.
//! 4. WARMUP: scale initialization by high-precision training (paper 9.3)
//!    vs cold uniform init.
//!
//! A flat summary of every section is persisted as `BENCH_ablation.json`
//! (versioned via [`Table::to_json`]) so ablation results can be diffed
//! across commits like `BENCH_perf.json`.

#[path = "common.rs"]
mod common;

use lpdnn::arith::{FixedFormat, RoundMode};
use lpdnn::bench_support::{scaled, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::ScaleController;
use lpdnn::golden::{MlpShape, Network, StepOptions};
use lpdnn::tensor::{init::InitSpec, Pcg32, Tensor};

fn main() {
    let mut session = common::setup();
    // every section also feeds this flat summary, persisted at the end
    // as BENCH_ablation.json so ablation results diff across commits
    // the same way BENCH_perf.json does
    let mut summary = Table::new(&["ablation", "case", "result"]);

    // ------------------------------------------------------------------
    // 1. width ablation
    // ------------------------------------------------------------------
    // NOTE: the synthetic digits task is easier than MNIST, so its
    // bit-width cliff sits lower than the paper's (fig2/fig3 locate it);
    // 5/6 bits is reliably below the cliff on this testbed.
    println!("=== ablation 1: doubling hidden units (paper 9.2/9.3) ===");
    let mut t = Table::new(&["model", "dynamic 10/12", "dynamic 5/6 (below min)"]);
    for model in ["pi_mlp", "pi_mlp_wide"] {
        let mut errs = Vec::new();
        for (bc, bu) in [(10, 12), (5, 6)] {
            let mut cfg = common::base_cfg(&format!("abl-width-{model}-{bc}"), model, "digits");
            cfg.arithmetic = common::dynamic(bc, bu, 1e-4, cfg.data.n_train);
            let r = session.run(cfg).expect("run");
            eprintln!("  {model} {bc}/{bu}: {:.2}%", 100.0 * r.test_error);
            errs.push(r.test_error);
        }
        t.row(&[
            model.to_string(),
            format!("{:.2}%", 100.0 * errs[0]),
            format!("{:.2}%", 100.0 * errs[1]),
        ]);
        summary.row(&[
            "width".into(),
            model.to_string(),
            format!(
                "10/12 {:.2}% | 5/6 {:.2}%",
                100.0 * errs[0],
                100.0 * errs[1]
            ),
        ]);
    }
    t.print();
    println!("(expected: the wide model does NOT rescue the below-minimum widths)\n");

    // ------------------------------------------------------------------
    // 2. rounding-mode ablation on the golden host model
    // ------------------------------------------------------------------
    println!("=== ablation 2: rounding modes (golden model, 12-bit storage) ===");
    let shape = MlpShape::for_dataset("digits", 64, 2).expect("digits dims");
    // one Network for the whole ablation loop (the legacy train_step
    // wrapper would rebuild the layer graph on every step)
    let net = Network::from_mlp_shape(shape);
    let steps = scaled(120);
    let rng = Pcg32::seeded(7);
    let ds = lpdnn::data::Dataset::generate("digits", 1024, 256, &rng).expect("data");
    let mut t = Table::new(&["rounding", "final train loss", "held-out loss"]);
    for mode in [
        RoundMode::HalfAway,
        RoundMode::HalfEven,
        RoundMode::Truncate,
        RoundMode::Stochastic,
    ] {
        let ctrl =
            ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let mut irng = Pcg32::seeded(42);
        let mut params = vec![
            InitSpec::GlorotUniform { fan_in: 784, fan_out: 64 }
                .realize(&[2, 784, 64], &mut irng),
            Tensor::zeros(&[2, 64]),
            InitSpec::GlorotUniform { fan_in: 64, fan_out: 64 }
                .realize(&[2, 64, 64], &mut irng),
            Tensor::zeros(&[2, 64]),
            InitSpec::GlorotUniform { fan_in: 64, fan_out: 10 }
                .realize(&[64, 10], &mut irng),
            Tensor::zeros(&[10]),
        ];
        let mut vels: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let mut batcher =
            lpdnn::data::Batcher::new(&ds.train, 64, 10, Pcg32::seeded(99));
        let mut loss = 0.0;
        for _ in 0..steps {
            let (x, y) = batcher.next_batch();
            let x = x.reshape(&[64, 784]);
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.1,
                0.5,
                3.0,
                &ctrl,
                StepOptions { mode, ..Default::default() },
            );
            loss = out.loss;
        }
        // held-out probe: a zero-LR golden step computes the cross-entropy
        // on a test batch without changing the parameters.
        let (xe, ye) = lpdnn::data::Batcher::eval_batches(&ds.test, 256, 10)
            .into_iter()
            .next()
            .map(|(x, y, _)| (x.reshape(&[256, 784]), y))
            .unwrap();
        let probe_ctrl =
            ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let mut pp = params.clone();
        let mut vv = vels.clone();
        let probe = net.train_step(
            &mut pp,
            &mut vv,
            &xe,
            &ye,
            0.0,
            0.0,
            0.0,
            &probe_ctrl,
            StepOptions { mode, ..Default::default() },
        );
        t.row(&[
            format!("{mode:?}"),
            format!("{loss:.4}"),
            format!("{:.4}", probe.loss),
        ]);
        summary.row(&[
            "rounding".into(),
            format!("{mode:?}"),
            format!("train {loss:.4} | held-out {:.4}", probe.loss),
        ]);
    }
    t.print();
    println!("(half-away is the canonical mode the artifacts implement; truncate");
    println!(" biases updates toward zero and converges worse at narrow widths)\n");

    // ------------------------------------------------------------------
    // 3. controller update interval
    // ------------------------------------------------------------------
    println!("=== ablation 3: scale update interval (dynamic 10/12) ===");
    let mut t = Table::new(&["update every (examples)", "test error", "scale moves"]);
    for every in [256usize, 1024, 4096, 16384] {
        let mut cfg = common::base_cfg(&format!("abl-int-{every}"), "pi_mlp", "digits");
        cfg.arithmetic = Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 1e-4,
            update_every_examples: every,
            init_int_bits: 3,
            warmup_steps: scaled(30),
        };
        let r = session.run(cfg).expect("run");
        let moves: usize = r.metrics.scale_moves.iter().map(|&(_, n)| n).sum();
        eprintln!("  every {every}: {:.2}% ({moves} moves)", 100.0 * r.test_error);
        t.row(&[
            format!("{every}"),
            format!("{:.2}%", 100.0 * r.test_error),
            format!("{moves}"),
        ]);
        summary.row(&[
            "update-interval".into(),
            format!("every {every}"),
            format!("{:.2}% ({moves} moves)", 100.0 * r.test_error),
        ]);
    }
    t.print();
    println!("(paper uses 10 000; too-frequent updates chase minibatch noise,");
    println!(" too-rare updates react late to shrinking gradients)\n");

    // ------------------------------------------------------------------
    // 4. warmup vs cold start
    // ------------------------------------------------------------------
    println!("=== ablation 4: scale warmup (paper 9.3) vs cold uniform init ===");
    let mut t = Table::new(&["scale init", "test error"]);
    for (label, warmup) in [("high-precision warmup", scaled(30)), ("cold (uniform int_bits=3)", 0)]
    {
        let mut cfg = common::base_cfg(&format!("abl-warm-{warmup}"), "pi_mlp", "digits");
        cfg.arithmetic = Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 1e-4,
            update_every_examples: 1024,
            init_int_bits: 3,
            warmup_steps: warmup,
        };
        let r = session.run(cfg).expect("run");
        eprintln!("  {label}: {:.2}%", 100.0 * r.test_error);
        t.row(&[label.to_string(), format!("{:.2}%", 100.0 * r.test_error)]);
        summary.row(&[
            "warmup".into(),
            label.to_string(),
            format!("{:.2}%", 100.0 * r.test_error),
        ]);
    }
    t.print();
    println!("(cold starts leave gradient groups quantizing to zero until the");
    println!(" controller walks the exponents down — the paper's reason for");
    println!(" finding initial scaling factors with a higher precision format)");

    common::persist_table("ablation", &summary);
}
