//! Paper Figure 3: final test error vs PARAMETER UPDATE bit-width.
//!
//! Computations stay at 31 bits; the storage width of θ (and the momentum
//! buffer) sweeps. This isolates the paper's section 6 argument: SGD
//! accumulates many small contributions, so parameter storage needs more
//! precision than the computations — fixed point collapses below ~19
//! bits, dynamic fixed point below ~11 bits (20/12 with sign).

#[path = "common.rs"]
mod common;

use lpdnn::bench_support::{print_series, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::SweepPoint;

fn main() {
    let mut session = common::setup_sweep();
    let dataset = "digits";
    let baseline = common::base_cfg("fig3-base", "pi_mlp", dataset);
    let widths: Vec<i32> = vec![6, 8, 10, 12, 14, 16, 18, 20, 24, 28];

    let mut table = Table::new(&["arithmetic", "update bits", "test error", "normalized"]);
    for arith_name in ["fixed", "dynamic"] {
        let points: Vec<SweepPoint> = widths
            .iter()
            .map(|&bits| {
                let mut cfg = baseline.clone();
                cfg.name = format!("fig3-{arith_name}-{bits}");
                cfg.arithmetic = match arith_name {
                    "fixed" => Arithmetic::Fixed {
                        bits_comp: common::WIDE_BITS,
                        bits_up: bits,
                        int_bits: 5,
                    },
                    _ => {
                        let mut a =
                            common::dynamic(common::WIDE_BITS, bits, 1e-4, baseline.data.n_train);
                        if let Arithmetic::Dynamic { ref mut bits_comp, .. } = a {
                            *bits_comp = common::WIDE_BITS;
                        }
                        a
                    }
                };
                SweepPoint { label: format!("{bits}"), cfg }
            })
            .collect();

        let outcome = session.sweep(&baseline, &points).unwrap();
        println!("\n=== Figure 3 analogue ({arith_name} point, {dataset}) ===");
        println!("float32 baseline error: {:.2}%", 100.0 * outcome.baseline_error());
        let series: Vec<(f64, f64)> =
            outcome.rows.iter().map(|r| (r.label.parse().unwrap(), r.normalized)).collect();
        print_series(
            &format!("normalized error vs parameter-update bits ({arith_name}, comp=31)"),
            "bits",
            &series,
        );
        println!(
            "(paper: cliff below {} bits for {arith_name})",
            if arith_name == "fixed" { 20 } else { 12 }
        );
        for r in &outcome.rows {
            table.row(&[
                arith_name.to_string(),
                r.label.clone(),
                format!("{:.4}", r.test_error),
                format!("{:.2}x", r.normalized),
            ]);
        }
    }
    common::persist_table("fig3", &table);
}
