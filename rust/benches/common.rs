//! Shared helpers for the bench binaries (each bench is its own crate;
//! included via `#[path = "common.rs"] mod common;`).
//!
//! Backends: every bench drives a [`Session`] whose backend comes from
//! `LPDNN_BACKEND` (default `native`, which needs no artifacts; `pjrt`
//! needs a build with `--features pjrt` plus `make artifacts`).
//! Workloads a backend cannot run (models missing from a pjrt manifest)
//! are skipped with a note — the native backend runs every builtin
//! topology, conv nets included, since the shape-aware layer graph.
//!
//! Parallelism: the sweep benches fan their points across the session's
//! worker pool. `LPDNN_JOBS` sets the pool size; the default is one
//! worker per core on the native backend and 1 on pjrt (each worker
//! compiles its own artifacts, so sequential reuse of one compile cache
//! is the better default there). Rows are bit-identical at any pool
//! size — only wall-clock changes.
//!
//! Budgets: every bench scales its training-step counts by
//! `LPDNN_BENCH_SCALE` (default 1.0) via `bench_support::scaled`, so a
//! quick smoke pass is `LPDNN_BENCH_SCALE=0.1 cargo bench`.

#![allow(dead_code)]

use std::sync::Arc;

use lpdnn::config::{Arithmetic, BackendKind, DataConfig, ExperimentConfig, TrainConfig};
use lpdnn::coordinator::{Session, StderrProgress};
use lpdnn::runtime::BackendSpec;

/// Sweep worker count: `LPDNN_JOBS`, defaulting to one per core on the
/// native backend and 1 on pjrt.
pub fn jobs_from_env(kind: BackendKind) -> usize {
    std::env::var("LPDNN_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| match kind {
            BackendKind::Native => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
            _ => 1,
        })
        .max(1)
}

/// Session for the single-run benches (`bench_perf`, `bench_table3`,
/// `bench_ablation`): sequential runs with the matmul kernels' full
/// parallelism, so per-run timings stay meaningful.
pub fn setup() -> Session {
    make_session(BackendSpec::from_env().expect("LPDNN_BACKEND"), 1)
}

/// Session for the sweep benches (`bench_fig1..4`): points fan out over
/// the worker pool. Sweep workers multiply with the matmul threads, so
/// when the user caps neither, split the cores between the two levels
/// rather than oversubscribing quadratically. Safe to do here — the
/// kernels read `LPDNN_THREADS` once on first use (after setup), and
/// results are bit-identical at any thread count (DESIGN.md
/// §Performance), so this only affects wall-clock.
pub fn setup_sweep() -> Session {
    let spec = BackendSpec::from_env().expect("LPDNN_BACKEND");
    let jobs = jobs_from_env(spec.kind());
    if jobs > 1 && std::env::var("LPDNN_THREADS").is_err() {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        std::env::set_var("LPDNN_THREADS", (cores / jobs).max(1).to_string());
    }
    make_session(spec, jobs)
}

/// The session under test with the stderr progress printer attached —
/// or a clear panic when the backend cannot be constructed.
fn make_session(spec: BackendSpec, jobs: usize) -> Session {
    let mut session = Session::new(spec)
        .with_jobs(jobs)
        .with_observer(Arc::new(StderrProgress::new()));
    match session.backend_name() {
        Ok(name) => eprintln!("[bench] backend: {name} (sweep jobs: {jobs})"),
        Err(e) => panic!("cannot construct {} backend: {e:#}", session.spec().label()),
    }
    session
}

/// Per-model default budgets tuned to the CPU testbed (see DESIGN.md):
/// (steps, n_train, n_test, lr_start).
pub fn budget(model: &str) -> (usize, usize, usize, f32) {
    use lpdnn::bench_support::scaled;
    // LRs are set so the NARROWEST formats in each sweep stay stable:
    // at 10-bit computations, quantization noise on the updates grows with
    // the learning rate, and conv nets random-walk into the max-norm
    // boundary (activation explosion) above ~0.02 on this budget — the
    // same fragility the paper's Table 3 shows on SVHN for dynamic 10/12.
    match model {
        "pi_mlp" | "pi_mlp_wide" => (scaled(200), 2048, 512, 0.15),
        "conv" => (scaled(120), 1024, 512, 0.02),
        "conv32" => (scaled(120), 2048, 256, 0.03),
        other => panic!("no budget for model {other}"),
    }
}

/// Base experiment config for (model, dataset) with the bench budget.
pub fn base_cfg(name: &str, model: &str, dataset: &str) -> ExperimentConfig {
    let (steps, n_train, n_test, lr) = budget(model);
    ExperimentConfig {
        name: name.into(),
        model: model.into(),
        backend: BackendKind::default(), // benches pick the backend via setup()
        topology: None,
        arithmetic: Arithmetic::Float32,
        train: TrainConfig {
            steps,
            lr_start: lr,
            lr_end: lr / 10.0,
            mom_start: 0.5,
            mom_end: 0.7,
            max_norm: 3.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            seed: 20140101, // fixed master seed: runs are fully deterministic
            eval_every: 0,
        },
        data: DataConfig { dataset: dataset.into(), n_train, n_test },
    }
}

/// The paper's canonical dynamic fixed point arithmetic with warmup.
pub fn dynamic(bits_comp: i32, bits_up: i32, max_rate: f64, n_train: usize) -> Arithmetic {
    Arithmetic::Dynamic {
        bits_comp,
        bits_up,
        max_overflow_rate: max_rate,
        // paper: every 10 000 examples; scaled to our smaller corpora so
        // the controller ticks a comparable number of times per epoch
        update_every_examples: (n_train / 2).max(512),
        init_int_bits: 3,
        warmup_steps: lpdnn::bench_support::scaled(50),
    }
}

/// Paper Figure 1/2/3 "31-bit" wide format (32 with the sign).
pub const WIDE_BITS: i32 = 31;

/// Persist a bench table as `BENCH_<name>.json` (versioned via
/// [`Table::to_json`](lpdnn::bench_support::Table::to_json)) so results
/// can be diffed across commits. A write failure only warns: the table
/// already printed, and a read-only checkout shouldn't fail the bench.
pub fn persist_table(name: &str, table: &lpdnn::bench_support::Table) {
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, table.to_json().to_string_pretty()) {
        Ok(()) => println!("(rows persisted to {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
