//! Shared helpers for the bench binaries (each bench is its own crate;
//! included via `#[path = "common.rs"] mod common;`).
//!
//! Backends: every bench runs on the backend named by `LPDNN_BACKEND`
//! (default `native`, which needs no artifacts; `pjrt` needs a build
//! with `--features pjrt` plus `make artifacts`). Workloads a backend
//! cannot run (conv models on native) are skipped with a note — see
//! EXPERIMENTS.md §Experiment index for which figure needs which.
//!
//! Budgets: every bench scales its training-step counts by
//! `LPDNN_BENCH_SCALE` (default 1.0) via `bench_support::scaled`, so a
//! quick smoke pass is `LPDNN_BENCH_SCALE=0.1 cargo bench`.

#![allow(dead_code)]

use lpdnn::config::{Arithmetic, BackendKind, DataConfig, ExperimentConfig, TrainConfig};
use lpdnn::runtime::Backend;

/// The backend under test (`LPDNN_BACKEND`, default native) — or a clear
/// message when the name is unknown or the backend cannot be constructed.
pub fn setup() -> Box<dyn Backend> {
    let kind = BackendKind::from_env().expect("LPDNN_BACKEND");
    match lpdnn::runtime::create_backend(kind) {
        Ok(b) => {
            eprintln!("[bench] backend: {}", b.name());
            b
        }
        Err(e) => panic!("cannot construct {} backend: {e:#}", kind.label()),
    }
}

/// Per-model default budgets tuned to the CPU testbed (see DESIGN.md):
/// (steps, n_train, n_test, lr_start).
pub fn budget(model: &str) -> (usize, usize, usize, f32) {
    use lpdnn::bench_support::scaled;
    // LRs are set so the NARROWEST formats in each sweep stay stable:
    // at 10-bit computations, quantization noise on the updates grows with
    // the learning rate, and conv nets random-walk into the max-norm
    // boundary (activation explosion) above ~0.02 on this budget — the
    // same fragility the paper's Table 3 shows on SVHN for dynamic 10/12.
    match model {
        "pi_mlp" | "pi_mlp_wide" => (scaled(200), 2048, 512, 0.15),
        "conv" => (scaled(120), 1024, 512, 0.02),
        "conv32" => (scaled(120), 2048, 256, 0.03),
        other => panic!("no budget for model {other}"),
    }
}

/// Base experiment config for (model, dataset) with the bench budget.
pub fn base_cfg(name: &str, model: &str, dataset: &str) -> ExperimentConfig {
    let (steps, n_train, n_test, lr) = budget(model);
    ExperimentConfig {
        name: name.into(),
        model: model.into(),
        backend: BackendKind::default(), // benches pick the backend object via setup()
        arithmetic: Arithmetic::Float32,
        train: TrainConfig {
            steps,
            lr_start: lr,
            lr_end: lr / 10.0,
            mom_start: 0.5,
            mom_end: 0.7,
            max_norm: 3.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            seed: 20140101, // fixed master seed: runs are fully deterministic
            eval_every: 0,
        },
        data: DataConfig { dataset: dataset.into(), n_train, n_test },
    }
}

/// The paper's canonical dynamic fixed point arithmetic with warmup.
pub fn dynamic(bits_comp: i32, bits_up: i32, max_rate: f64, n_train: usize) -> Arithmetic {
    Arithmetic::Dynamic {
        bits_comp,
        bits_up,
        max_overflow_rate: max_rate,
        // paper: every 10 000 examples; scaled to our smaller corpora so
        // the controller ticks a comparable number of times per epoch
        update_every_examples: (n_train / 2).max(512),
        init_int_bits: 3,
        warmup_steps: lpdnn::bench_support::scaled(50),
    }
}

/// Paper Figure 1/2/3 "31-bit" wide format (32 with the sign).
pub const WIDE_BITS: i32 = 31;
