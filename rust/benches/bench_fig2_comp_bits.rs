//! Paper Figure 2: final test error vs COMPUTATION bit-width, for fixed
//! point vs dynamic fixed point.
//!
//! Parameter updates stay at 31 bits; the computation width sweeps. For
//! fixed point the radix sits at the paper's optimum (5); dynamic fixed
//! point uses max overflow rate 0.01% (paper settings). Expected shape:
//! a cliff below ~19 bits for fixed point and below ~9 bits for dynamic
//! fixed point (sign excluded — the paper counts 20/10 with sign).

#[path = "common.rs"]
mod common;

use lpdnn::bench_support::{print_series, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::SweepPoint;

fn main() {
    let mut session = common::setup_sweep();
    let dataset = "digits";
    let baseline = common::base_cfg("fig2-base", "pi_mlp", dataset);
    let widths: Vec<i32> = vec![6, 8, 10, 12, 14, 16, 18, 20, 24, 28];

    let mut table = Table::new(&["arithmetic", "comp bits", "test error", "normalized"]);
    for arith_name in ["fixed", "dynamic"] {
        let points: Vec<SweepPoint> = widths
            .iter()
            .map(|&bits| {
                let mut cfg = baseline.clone();
                cfg.name = format!("fig2-{arith_name}-{bits}");
                cfg.arithmetic = match arith_name {
                    "fixed" => Arithmetic::Fixed {
                        bits_comp: bits,
                        bits_up: common::WIDE_BITS,
                        int_bits: 5,
                    },
                    _ => {
                        let mut a =
                            common::dynamic(bits, common::WIDE_BITS, 1e-4, baseline.data.n_train);
                        if let Arithmetic::Dynamic { ref mut bits_up, .. } = a {
                            *bits_up = common::WIDE_BITS;
                        }
                        a
                    }
                };
                SweepPoint { label: format!("{bits}"), cfg }
            })
            .collect();

        let outcome = session.sweep(&baseline, &points).unwrap();
        println!("\n=== Figure 2 analogue ({arith_name} point, {dataset}) ===");
        println!("float32 baseline error: {:.2}%", 100.0 * outcome.baseline_error());
        let series: Vec<(f64, f64)> =
            outcome.rows.iter().map(|r| (r.label.parse().unwrap(), r.normalized)).collect();
        print_series(
            &format!("normalized error vs computation bits ({arith_name}, up=31)"),
            "bits",
            &series,
        );
        println!(
            "(paper: cliff below {} bits for {arith_name})",
            if arith_name == "fixed" { 20 } else { 10 }
        );
        for r in &outcome.rows {
            table.row(&[
                arith_name.to_string(),
                r.label.clone(),
                format!("{:.4}", r.test_error),
                format!("{:.2}x", r.normalized),
            ]);
        }
    }
    common::persist_table("fig2", &table);
}
