//! Paper Table 3: final test error per arithmetic per dataset.
//!
//! | Format                  | Comp | Up | PI | MNIST | CIFAR10 | SVHN |
//!
//! Datasets map to our synthetic substitutes (DESIGN.md §Substitutions):
//! PI MNIST → pi_mlp/digits(flattened), MNIST conv → conv/digits,
//! CIFAR10 → conv32/cifar_like, SVHN → conv32/svhn_like.
//!
//! Expected shape (not absolute numbers): float16 ≈ float32;
//! fixed 20/20 slightly degraded; dynamic 10/12 close to float32 with the
//! largest gap on the SVHN-like workload (paper: 4.95% vs 2.71%).

#[path = "common.rs"]
mod common;

use lpdnn::bench_support::Table;
use lpdnn::config::Arithmetic;

fn main() {
    let mut session = common::setup();
    let workloads: Vec<(&str, &str, &str)> = vec![
        ("PI digits", "pi_mlp", "digits"),
        ("digits conv", "conv", "digits"),
        ("cifar-like", "conv32", "cifar_like"),
        ("svhn-like", "conv32", "svhn_like"),
    ];

    let mut table = Table::new(&[
        "format", "comp", "up", "PI digits", "digits conv", "cifar-like", "svhn-like",
    ]);
    let mut rows: Vec<(&str, &str, &str, Vec<f64>)> = vec![
        ("float32 (baseline)", "32", "32", vec![]),
        ("float16", "16", "16", vec![]),
        ("fixed point", "20", "20", vec![]),
        ("dynamic fixed point", "10", "12", vec![]),
    ];

    for &(wl_name, model, dataset) in &workloads {
        if !session.supports_model(model).expect("backend") {
            eprintln!(
                "  [{wl_name}] skipped: model {model} not runnable on the {} backend \
                 (needs compiled artifacts — set LPDNN_BACKEND=pjrt)",
                session.spec().label()
            );
            for row in rows.iter_mut() {
                row.3.push(f64::NAN);
            }
            continue;
        }
        let base = common::base_cfg(&format!("tbl3-{wl_name}"), model, dataset);
        let arithmetics = [
            Arithmetic::Float32,
            Arithmetic::Half,
            Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 },
            common::dynamic(10, 12, 1e-4, base.data.n_train),
        ];
        for (row, arith) in rows.iter_mut().zip(arithmetics) {
            let mut cfg = base.clone();
            cfg.name = format!("tbl3-{}-{}", wl_name, row.0);
            cfg.arithmetic = arith;
            let t0 = std::time::Instant::now();
            let r = session.run(cfg).expect("run");
            eprintln!(
                "  [{wl_name}] {}: {:.2}% ({:.0?})",
                row.0,
                100.0 * r.test_error,
                t0.elapsed()
            );
            row.3.push(r.test_error);
        }
    }

    println!("\n=== Table 3 analogue: final test error (%) ===");
    println!("(paper: float32 1.05/0.51/14.05/2.71, float16 1.10/0.51/14.14/3.02,");
    println!(" fixed-20 1.39/0.57/15.98/2.97, dynamic-10/12 1.28/0.59/14.82/4.95)\n");
    let fmt_err = |e: &f64| {
        if e.is_nan() {
            "n/a".to_string()
        } else {
            format!("{:.2}%", 100.0 * e)
        }
    };
    for (name, comp, up, errs) in &rows {
        let cells: Vec<String> = std::iter::once(name.to_string())
            .chain([comp.to_string(), up.to_string()])
            .chain(errs.iter().map(fmt_err))
            .collect();
        table.row(&cells);
    }
    table.print();

    // normalized view (the paper's figures divide by the float32 row);
    // the baseline is floored at one test-set error so a perfect float32
    // run doesn't blow the ratio up to infinity.
    println!("normalized vs float32 baseline (baseline floored at 1 error):");
    let floor = 1.0 / 512.0;
    let baseline = rows[0].3.clone();
    let mut norm = Table::new(&["format", "PI digits", "digits conv", "cifar-like", "svhn-like"]);
    for (name, _, _, errs) in &rows[1..] {
        let cells: Vec<String> = std::iter::once(name.to_string())
            .chain(errs.iter().zip(&baseline).map(|(e, b)| {
                if e.is_nan() || b.is_nan() {
                    "n/a".to_string()
                } else {
                    format!("{:.2}x", e / b.max(floor))
                }
            }))
            .collect();
        norm.row(&cells);
    }
    norm.print();
    common::persist_table("table3", &table);
}
