//! Performance micro-benchmarks: the numbers EXPERIMENTS.md §Perf tracks.
//!
//! * matmul kernels: seed-style naive loops vs blocked serial vs blocked
//!   parallel, on the pi_mlp hot-path shapes (the acceptance numbers for
//!   the parallel-matmul work)
//! * fused quantize-aware GEMM vs the two-pass quantization epilogue
//!   (per arithmetic, plus a fused-vs-two-pass full train step)
//! * end-to-end train-step latency per model on the selected backend
//! * host quantizer throughput (GB/s over f32)
//! * golden/native train step (the native backend's hot path)
//! * layer-graph executor vs the pre-refactor monolith (`graph train
//!   step` rows: depth 2 overhead per arithmetic, depths 3/4 scaling)
//! * conv im2col lowering vs the direct nested-loop reference kernels
//!   (`conv train step` rows, per arithmetic — bit-identical paths)
//! * data-parallel sharded train steps at 1/2/4 workers (`dp train
//!   step` rows, MLP + conv — bit-identical paths, speedup printed,
//!   the once-per-update weight-pack cadence asserted)
//! * integer-domain GEMM vs the simulated-f32 fused path on eligible
//!   grid operands (`int gemm` rows per orientation and arithmetic,
//!   plus the `int train step` end-to-end A/B)
//! * split-accumulator GEMM on wide-grid deep reductions the whole-site
//!   bound rejects (`split gemm` rows per orientation, vs the simulated
//!   path those sites previously ran on) and the 4-wide k-unrolled i16
//!   NT microkernel vs its rolled reference (`unrolled int gemm` row)
//! * the packed-operand cache: pre-packed weight slabs vs re-packing on
//!   every call (`packed gemm` kernel rows, the `packed train step`
//!   rebuild-cadence A/B, and the serve-style `packed eval` steady
//!   state), with `int_gemm::pack_calls` deltas asserted so a dead
//!   cache cannot masquerade as a perf result
//! * scale controller overhead per tick
//! * with `--features pjrt` + artifacts: compiled-step latency and the
//!   L3↔PJRT literal-assembly boundary
//!
//! The full table is also persisted as `BENCH_perf.json` (versioned via
//! [`Table::to_json`]) so results can be diffed across commits.

#[path = "common.rs"]
mod common;

use lpdnn::arith::{FixedFormat, QuantEpilogue, Quantizer, RoundMode};
use lpdnn::bench_support::{bench, scaled, Stats, Table};
use lpdnn::config::{Arithmetic, TopologySpec};
use lpdnn::coordinator::{ScaleController, Session};
use lpdnn::golden::{self, MlpShape, Network, StepOptions};
use lpdnn::runtime::ModelInfo;
use lpdnn::tensor::{init::InitSpec, int_gemm, ops, Pcg32, Tensor};

fn fmt_stats(s: &Stats) -> String {
    format!(
        "{:.2}ms ±{:.2} (p50 {:.2}, p90 {:.2}, n={})",
        s.mean * 1e3,
        s.sd * 1e3,
        s.p50 * 1e3,
        s.p90 * 1e3,
        s.n
    )
}

/// The seed repo's naive ikj matmul, kept verbatim as the speedup
/// reference point.
fn naive_seed_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let ub = b.shape()[1];
    let mut out = vec![0.0f32; ba * ub];
    let ad = a.data();
    let bd = b.data();
    for i in 0..ba {
        for kk in 0..ia {
            let aik = ad[i * ia + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[kk * ub..(kk + 1) * ub];
            let orow = &mut out[i * ub..(i + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    Tensor::from_vec(&[ba, ub], out)
}

/// The seed repo's naive a^T @ b loops (weight-gradient kernel), kept
/// verbatim as the TN-path speedup reference.
fn naive_seed_matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let ub = b.shape()[1];
    let mut out = vec![0.0f32; ia * ub];
    let ad = a.data();
    let bd = b.data();
    for n in 0..ba {
        let arow = &ad[n * ia..(n + 1) * ia];
        let brow = &bd[n * ub..(n + 1) * ub];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * ub..(i + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor::from_vec(&[ia, ub], out)
}

fn matmul_section(table: &mut Table) {
    let mut rng = Pcg32::seeded(99);
    let mut rand = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
    };
    // pi_mlp forward hot-path shapes (batch 64) + one sweep-scale shape
    let shapes: &[(usize, usize, usize, &str)] = &[
        (64, 784, 128, "pi_mlp l0 z (64x784x128)"),
        (64, 128, 128, "pi_mlp l1 z (64x128x128)"),
        (256, 784, 512, "wide sweep (256x784x512)"),
    ];
    let iters = scaled(40).max(10);
    for &(m, k, n, label) in shapes {
        let a = rand(&[m, k]);
        let b = rand(&[k, n]);
        let s_naive = bench(2, iters, || {
            let _ = naive_seed_matmul(&a, &b);
        });
        let s_serial = bench(2, iters, || {
            let _ = ops::par_matmul(&a, &b, 1);
        });
        let s_par = bench(2, iters, || {
            let _ = ops::matmul(&a, &b); // auto: parallel above threshold
        });
        table.row(&[
            format!("matmul {label}"),
            format!(
                "naive {:.2}ms | blocked {:.2}ms | parallel {:.2}ms | speedup {:.1}x (threads {})",
                s_naive.mean * 1e3,
                s_serial.mean * 1e3,
                s_par.mean * 1e3,
                s_naive.mean / s_par.mean.max(1e-12),
                ops::max_threads(),
            ),
        ]);
    }

    // the dw path runs the distinct TN kernel (x^T @ dz): bench it as
    // such, on the real l0 gradient shape
    {
        let x = rand(&[64, 784]);
        let dz = rand(&[64, 128]);
        let s_naive = bench(2, iters, || {
            let _ = naive_seed_matmul_tn(&x, &dz);
        });
        let s_serial = bench(2, iters, || {
            let _ = ops::matmul_tn_sl_threads(x.data(), dz.data(), 64, 784, 128, 1);
        });
        let s_par = bench(2, iters, || {
            let _ = ops::matmul_tn(&x, &dz); // auto-threaded
        });
        table.row(&[
            "matmul_tn pi_mlp l0 dw (64x784 ^T @ 64x128)".to_string(),
            format!(
                "naive {:.2}ms | blocked {:.2}ms | parallel {:.2}ms | speedup {:.1}x (threads {})",
                s_naive.mean * 1e3,
                s_serial.mean * 1e3,
                s_par.mean * 1e3,
                s_naive.mean / s_par.mean.max(1e-12),
                ops::max_threads(),
            ),
        ]);
    }
}

fn end_to_end_section(session: &mut Session, table: &mut Table) {
    for model in ["pi_mlp", "conv", "conv32"] {
        if !session.supports_model(model).expect("backend") {
            table.row(&[
                format!("{model} end-to-end per train step"),
                format!("skipped ({} backend cannot run it)", session.spec().label()),
            ]);
            continue;
        }
        let dataset = match model {
            "pi_mlp" => "digits",
            "conv" => "digits",
            _ => "cifar_like",
        };
        let mut cfg = common::base_cfg(&format!("perf-{model}"), model, dataset);
        cfg.train.steps = scaled(20).max(5);
        cfg.data.n_train = 512;
        cfg.data.n_test = 256;
        cfg.arithmetic = Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 };
        let t0 = std::time::Instant::now();
        let r = session.run(cfg).expect("run");
        let total = t0.elapsed().as_secs_f64();
        let per_step = total / r.steps_run as f64;
        table.row(&[
            format!("{model} end-to-end per train step (incl. eval amortized)"),
            format!("{:.1}ms ({} backend)", per_step * 1e3, r.backend_name),
        ]);
    }
}

/// Fresh pi_mlp-scale state for golden-step benches: (params, vels, x, y).
fn pi_mlp_step_fixture() -> (Vec<Tensor>, Vec<Tensor>, Tensor, Tensor) {
    let mut rng = Pcg32::seeded(3);
    let params = vec![
        InitSpec::GlorotUniform { fan_in: 784, fan_out: 128 }
            .realize(&[4, 784, 128], &mut rng),
        Tensor::zeros(&[4, 128]),
        InitSpec::GlorotUniform { fan_in: 128, fan_out: 128 }
            .realize(&[4, 128, 128], &mut rng),
        Tensor::zeros(&[4, 128]),
        InitSpec::GlorotUniform { fan_in: 128, fan_out: 10 }.realize(&[128, 10], &mut rng),
        Tensor::zeros(&[10]),
    ];
    let vels: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
    let x = Tensor::from_vec(&[64, 784], (0..64 * 784).map(|_| rng.uniform()).collect());
    let labels: Vec<usize> = (0..64).map(|_| rng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);
    (params, vels, x, y)
}

fn native_step_section(table: &mut Table) {
    // golden/native train step at pi_mlp scale — the native backend's
    // hot path (runs the blocked/parallel kernels)
    let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
    let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(14, 1));
    let (mut params, mut vels, x, y) = pi_mlp_step_fixture();
    let s = bench(1, scaled(10).max(3), || {
        let _ = golden::train_step(
            shape, &mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, RoundMode::HalfAway,
        );
    });
    table.row(&["native/golden train step (pi_mlp, batch 64)".into(), fmt_stats(&s)]);
}

/// Layer-graph executor vs the frozen pre-refactor monolith: the `graph
/// train step` row family. Depth 2 (where the monolith exists) reports
/// the dispatch overhead per arithmetic; depths 3/4 at the same width
/// track how the graph scales with topology depth.
fn graph_step_section(table: &mut Table) {
    let arithmetics: [(&str, FixedFormat, FixedFormat, bool); 3] = [
        ("fixed 12.3", FixedFormat::new(12, 3), FixedFormat::new(14, 1), false),
        ("float16", FixedFormat::FLOAT32, FixedFormat::FLOAT32, true),
        ("float32", FixedFormat::FLOAT32, FixedFormat::FLOAT32, false),
    ];
    let iters = scaled(10).max(3);
    let mut rng = Pcg32::seeded(17);
    let (d_in, n_classes) = lpdnn::data::dataset_dims("digits").expect("digits dims");
    let x = Tensor::from_vec(&[64, d_in], (0..64 * d_in).map(|_| rng.uniform()).collect());
    let labels: Vec<usize> = (0..64).map(|_| rng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);

    for depth in [2usize, 3, 4] {
        let spec = TopologySpec::mlp(vec![128; depth], 4);
        let net = Network::from_topology(&spec, d_in, n_classes);
        let info = ModelInfo::from_topology(&spec, d_in, n_classes);
        let state = || {
            let mut srng = Pcg32::seeded(23);
            let params: Vec<Tensor> =
                info.params.iter().map(|s| s.init.realize(&s.shape, &mut srng)).collect();
            let vels: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
            (params, vels)
        };
        for (label, comp, up, half) in arithmetics {
            let ctrl = ScaleController::fixed(net.n_groups(), comp, up);
            let (mut params, mut vels) = state();
            let s_graph = bench(1, iters, || {
                let _ = net.train_step(
                    &mut params,
                    &mut vels,
                    &x,
                    &y,
                    0.01,
                    0.5,
                    3.0,
                    &ctrl,
                    StepOptions { half, ..Default::default() },
                );
            });
            let result = if depth == 2 {
                // the monolith only exists at depth 2: report overhead
                let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
                let (mut params, mut vels) = state();
                let s_mono = bench(1, iters, || {
                    let _ = golden::reference::train_step_opt(
                        shape,
                        &mut params,
                        &mut vels,
                        &x,
                        &y,
                        0.01,
                        0.5,
                        3.0,
                        &ctrl,
                        StepOptions { half, ..Default::default() },
                    );
                });
                format!(
                    "monolith {:.2}ms | graph {:.2}ms | overhead {:+.1}%",
                    s_mono.mean * 1e3,
                    s_graph.mean * 1e3,
                    100.0 * (s_graph.mean - s_mono.mean) / s_mono.mean.max(1e-12),
                )
            } else {
                format!("graph {:.2}ms", s_graph.mean * 1e3)
            };
            table.row(&[format!("graph train step depth{depth} 128x4 ({label})"), result]);
        }
    }
}

/// Conv train steps: the im2col lowering (conv multiplies riding the
/// fused GEMM epilogues) vs the direct nested-loop reference kernels
/// (`StepOptions::conv_direct`) — bit-identical paths, so the rows are
/// pure perf A/Bs, per arithmetic, on the builtin `conv` net's
/// 28×28×1 digits geometry.
fn conv_step_section(table: &mut Table) {
    let arithmetics: [(&str, FixedFormat, FixedFormat, bool); 3] = [
        ("fixed 12.3", FixedFormat::new(12, 3), FixedFormat::new(14, 1), false),
        ("float16", FixedFormat::FLOAT32, FixedFormat::FLOAT32, true),
        ("float32", FixedFormat::FLOAT32, FixedFormat::FLOAT32, false),
    ];
    let iters = scaled(5).max(2);
    let spec = TopologySpec::builtin("conv").expect("builtin conv");
    let (in_shape, n_classes) = lpdnn::data::dataset_shape("digits").expect("digits shape");
    let net = Network::from_topology_shaped(&spec, in_shape, n_classes).expect("conv net");
    let batch = 16;
    let mut rng = Pcg32::seeded(29);
    let mut dims = vec![batch];
    dims.extend(in_shape.dims());
    let x = Tensor::from_vec(
        &dims,
        (0..batch * in_shape.len()).map(|_| rng.uniform()).collect(),
    );
    let labels: Vec<usize> = (0..batch).map(|_| rng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);
    let state = || lpdnn::testing::topology_state(&spec, in_shape, n_classes, 31);
    for (label, comp, up, half) in arithmetics {
        let ctrl = ScaleController::fixed(net.n_groups(), comp, up);
        let time_path = |conv_direct: bool| {
            let (mut params, mut vels) = state();
            bench(1, iters, || {
                let _ = net.train_step(
                    &mut params,
                    &mut vels,
                    &x,
                    &y,
                    0.01,
                    0.5,
                    3.0,
                    &ctrl,
                    StepOptions { half, conv_direct, ..Default::default() },
                );
            })
        };
        let s_direct = time_path(true);
        let s_im2col = time_path(false);
        table.row(&[
            format!("conv train step conv 28x28x1 b{batch} ({label})"),
            format!(
                "direct {:.2}ms | im2col {:.2}ms | speedup {:.2}x",
                s_direct.mean * 1e3,
                s_im2col.mean * 1e3,
                s_direct.mean / s_im2col.mean.max(1e-12),
            ),
        ]);
    }
}

/// Fused quantize-aware GEMM vs the two-pass epilogue it replaced
/// (materialize the f32 product → bias/copy sweep → `apply_slice`
/// sweep) — the rows EXPERIMENTS.md §Perf tracks for this fusion, per
/// arithmetic. The shapes are the pi_mlp sites where quantization is a
/// visible fraction of the work (shallow reductions / large outputs).
fn fused_gemm_section(table: &mut Table) {
    let mut rng = Pcg32::seeded(41);
    let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal()).collect() };
    let arithmetics: &[(&str, QuantEpilogue)] = &[
        ("fixed 12.3", QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(12, 3)))),
        ("float16", QuantEpilogue::half_sim()),
        ("float32 passthrough", QuantEpilogue::new(Quantizer::float32())),
    ];
    let iters = scaled(40).max(10);

    // NN: l1 z (64x128x128, with bias) — one maxout filter's fused tile
    let (m, kd, n) = (64usize, 128usize, 128usize);
    let a = rand(m * kd);
    let b = rand(kd * n);
    let bias = rand(n);
    for (label, epi) in arithmetics {
        let mut dst = vec![0.0f32; m * n];
        let s_two = bench(2, iters, || {
            let zj = ops::matmul_sl(&a, &b, m, kd, n);
            for (drow, zrow) in dst.chunks_mut(n).zip(zj.chunks(n)) {
                for ((d, &z), &bv) in drow.iter_mut().zip(zrow).zip(&bias) {
                    *d = z + bv;
                }
            }
            let _ = epi.run(&mut dst, 0);
        });
        let s_fused = bench(2, iters, || {
            dst.fill(0.0);
            let _ = ops::matmul_sl_q_into(&a, &b, Some(&bias), &mut dst, m, kd, n, *epi);
        });
        table.row(&[
            format!("fused gemm nn l1 z 64x128x128+bias ({label})"),
            format!(
                "two-pass {:.2}ms | fused {:.2}ms | speedup {:.2}x",
                s_two.mean * 1e3,
                s_fused.mean * 1e3,
                s_two.mean / s_fused.mean.max(1e-12),
            ),
        ]);
    }

    // TN: l0 dw (64-deep reduction onto a 784x128 output) — the shape
    // where the second pass over the big dw tensor hurts most
    let (ba, ia, ub) = (64usize, 784usize, 128usize);
    let xs = rand(ba * ia);
    let dz = rand(ba * ub);
    for (label, epi) in arithmetics {
        let mut dst = vec![0.0f32; ia * ub];
        let s_two = bench(2, iters, || {
            let dwj = ops::matmul_tn_sl(&xs, &dz, ba, ia, ub);
            dst.copy_from_slice(&dwj);
            let _ = epi.run(&mut dst, 0);
        });
        let s_fused = bench(2, iters, || {
            dst.fill(0.0);
            let _ = ops::matmul_tn_sl_q_into(&xs, &dz, &mut dst, ba, ia, ub, *epi);
        });
        table.row(&[
            format!("fused gemm tn l0 dw 64^T 784x128 ({label})"),
            format!(
                "two-pass {:.2}ms | fused {:.2}ms | speedup {:.2}x",
                s_two.mean * 1e3,
                s_fused.mean * 1e3,
                s_two.mean / s_fused.mean.max(1e-12),
            ),
        ]);
    }

    // end-to-end: a full golden train step, fused vs two-pass, on the
    // fixed arithmetic (both paths are bit-identical; only time differs)
    let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
    let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(14, 1));
    let step_iters = scaled(10).max(3);
    let time_step = |fused: bool| {
        let (mut params, mut vels, x, y) = pi_mlp_step_fixture();
        bench(1, step_iters, || {
            let _ = golden::train_step_opt(
                shape,
                &mut params,
                &mut vels,
                &x,
                &y,
                0.01,
                0.5,
                3.0,
                &ctrl,
                StepOptions { fused, ..Default::default() },
            );
        })
    };
    let s_two = time_step(false);
    let s_fused = time_step(true);
    table.row(&[
        "fused train step (pi_mlp, batch 64, fixed 12.3)".into(),
        format!(
            "two-pass {:.2}ms | fused {:.2}ms | speedup {:.2}x",
            s_two.mean * 1e3,
            s_fused.mean * 1e3,
            s_two.mean / s_fused.mean.max(1e-12),
        ),
    ]);
}

/// Integer-domain GEMM vs the simulated-f32 fused reference, on grid
/// operands (the only inputs the integer plan accepts): the `int gemm`
/// rows per orientation and arithmetic, plus the end-to-end `int train
/// step` A/B. Shapes are sized so the i32 accumulator bound holds and
/// the plan engages (asserted via `ops::quant_gemm_plan` — a silent
/// fallback must not masquerade as a perf result); the two paths are
/// bit-identical (tests/int_gemm_parity.rs), so rows are pure perf A/Bs.
fn int_gemm_section(table: &mut Table) {
    let arithmetics: &[(&str, FixedFormat)] =
        &[("fixed 10.3", FixedFormat::new(10, 3)), ("fixed 8.-2", FixedFormat::new(8, -2))];
    let iters = scaled(40).max(10);
    let mut rng = Pcg32::seeded(43);
    for &(label, fmt) in arithmetics {
        let q = Quantizer::from_format(fmt);
        let mut grid = |len: usize| -> Vec<f32> {
            let mut v: Vec<f32> = (0..len).map(|_| rng.normal() * 0.2 * q.maxv).collect();
            q.apply_slice(&mut v);
            v
        };
        let epi = QuantEpilogue::new(q);
        // deepest reduction the i32 accumulator bound admits at this
        // format's worst-case |int|, capped at the pi_mlp l0 depth
        let amax = (fmt.maxv() / fmt.step()) as u64;
        let kd = ((int_gemm::ACC_BOUND / (amax * amax)) as usize).min(784);
        let (m, n) = (64usize, 128usize);

        // NN (z sites): dst += a @ b with fused bias + quantization
        let a = grid(m * kd);
        let b = grid(kd * n);
        let bias = grid(n);
        let zeros = vec![0.0f32; m * n];
        let plan = ops::quant_gemm_plan(&a, &b, kd, Some(&zeros));
        assert_eq!(plan, ops::QuantGemmImpl::IntDomain, "nn {label}");
        let mut dst = zeros;
        let mut time_nn = |int: bool| {
            bench(2, iters, || {
                dst.fill(0.0);
                let _ = ops::matmul_sl_qd_into(&a, &b, Some(&bias), &mut dst, m, kd, n, epi, int);
            })
        };
        let s_sim = time_nn(false);
        let s_int = time_nn(true);
        table.row(&[
            format!("int gemm nn z 64x{kd}x128+bias ({label})"),
            format!(
                "simulated {:.2}ms | integer {:.2}ms | speedup {:.2}x",
                s_sim.mean * 1e3,
                s_int.mean * 1e3,
                s_sim.mean / s_int.mean.max(1e-12),
            ),
        ]);

        // NT (dx sites): out = dy @ w^T, assigning
        let dy = grid(m * kd);
        let wt = grid(n * kd);
        let plan = ops::quant_gemm_plan(&dy, &wt, kd, None);
        assert_eq!(plan, ops::QuantGemmImpl::IntDomain, "nt {label}");
        let mut time_nt = |int: bool| {
            bench(2, iters, || {
                let _ = ops::matmul_nt_sl_qd(&dy, &wt, m, kd, n, epi, int);
            })
        };
        let s_sim = time_nt(false);
        let s_int = time_nt(true);
        table.row(&[
            format!("int gemm nt dx 64x{kd} @ 128x{kd}^T ({label})"),
            format!(
                "simulated {:.2}ms | integer {:.2}ms | speedup {:.2}x",
                s_sim.mean * 1e3,
                s_int.mean * 1e3,
                s_sim.mean / s_int.mean.max(1e-12),
            ),
        ]);

        // TN (dw sites): dst += x^T @ dz; the batch is the reduction, so
        // the real l0 gradient shape is bound-safe at both arithmetics
        let (ba, ia, ub) = (64usize, 784usize, 128usize);
        let xs = grid(ba * ia);
        let dz = grid(ba * ub);
        let zeros = vec![0.0f32; ia * ub];
        let plan = ops::quant_gemm_plan(&xs, &dz, ba, Some(&zeros));
        assert_eq!(plan, ops::QuantGemmImpl::IntDomain, "tn {label}");
        let mut dw = zeros;
        let mut time_tn = |int: bool| {
            bench(2, iters, || {
                dw.fill(0.0);
                let _ = ops::matmul_tn_sl_qd_into(&xs, &dz, &mut dw, ba, ia, ub, epi, int);
            })
        };
        let s_sim = time_tn(false);
        let s_int = time_tn(true);
        table.row(&[
            format!("int gemm tn dw 64^T 784x128 ({label})"),
            format!(
                "simulated {:.2}ms | integer {:.2}ms | speedup {:.2}x",
                s_sim.mean * 1e3,
                s_int.mean * 1e3,
                s_sim.mean / s_int.mean.max(1e-12),
            ),
        ]);
    }

    // end-to-end: a full golden train step with every quantized GEMM
    // site dispatched integer-domain vs simulated. The formats keep all
    // pi_mlp site shapes inside the accumulator bound, and params/x are
    // pre-quantized onto their grids (as the Trainer maintains them), so
    // the forward/dw sites actually take the integer path.
    let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
    let (comp, up) = (FixedFormat::new(8, -2), FixedFormat::new(8, 0));
    let ctrl = ScaleController::fixed(24, comp, up);
    let step_iters = scaled(10).max(3);
    let time_step = |int_domain: bool| {
        let (mut params, mut vels, mut x, y) = pi_mlp_step_fixture();
        let qup = Quantizer::from_format(up);
        for p in &mut params {
            qup.apply_slice(p.data_mut());
        }
        Quantizer::from_format(comp).apply_slice(x.data_mut());
        bench(1, step_iters, || {
            let _ = golden::train_step_opt(
                shape,
                &mut params,
                &mut vels,
                &x,
                &y,
                0.01,
                0.5,
                3.0,
                &ctrl,
                StepOptions { fused: true, int_domain, ..Default::default() },
            );
        })
    };
    let s_sim = time_step(false);
    let s_int = time_step(true);
    table.row(&[
        "int train step (pi_mlp, batch 64, fixed 8.-2 comp / 8.0 up)".into(),
        format!(
            "simulated {:.2}ms | integer {:.2}ms | speedup {:.2}x",
            s_sim.mean * 1e3,
            s_int.mean * 1e3,
            s_sim.mean / s_int.mean.max(1e-12),
        ),
    ]);
}

/// Split-accumulator and unrolled-microkernel A/Bs (ROADMAP 1b/1c).
///
/// * `split gemm` rows: wide-grid deep-reduction shapes whose
///   whole-site worst case overflows [`int_gemm::ACC_BOUND`] — before
///   the split schedule these were forced onto the simulated path, so
///   the honest baseline is the simulated kernel it replaces. The plan
///   is asserted `Split` per row so a silently-Whole (or
///   silently-Simulated) dispatch cannot pose as a split result.
/// * `unrolled int gemm` row: the 4-wide k-unrolled i16 NT microkernel
///   vs the rolled reference loop it replaced (`imm_nt_serial_ref`),
///   on the l0-dw-like 784-deep contraction.
fn split_gemm_section(table: &mut Table) {
    let iters = scaled(40).max(10);
    let mut rng = Pcg32::seeded(47);
    // wide 12-bit grid: |int| ≤ 2047 at step 2^-7. Each product is
    // f32-exact (2047² ≤ 2^24) but the deep reductions below overflow
    // the whole-site bound, so only the split plan can take them.
    let (amax, exp) = (2047u32, -7i32);
    let step = int_gemm::exp2f(exp);
    let mut grid = |len: usize| -> Vec<f32> {
        let mut v: Vec<f32> = (0..len)
            .map(|_| (rng.below(2 * amax + 1) as i32 - amax as i32) as f32 * step)
            .collect();
        v[0] = amax as f32 * step; // pin the packed amax: plan is deterministic
        v
    };
    let epi = QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(16, 8)));
    let speed = |sim: &Stats, alt: &str, s: &Stats| {
        format!(
            "simulated {:.2}ms | {alt} {:.2}ms | speedup {:.2}x",
            sim.mean * 1e3,
            s.mean * 1e3,
            sim.mean / s.mean.max(1e-12),
        )
    };

    // NN (l0 z shape): 784 · 2047² ≫ 2^24
    let (m, kd, n) = (64usize, 784usize, 128usize);
    let a = grid(m * kd);
    let b = grid(kd * n);
    let bias = grid(n);
    let zeros = vec![0.0f32; m * n];
    assert_eq!(
        ops::quant_gemm_plan(&a, &b, kd, Some(&zeros)),
        ops::QuantGemmImpl::Split,
        "split nn"
    );
    let mut dst = zeros;
    let mut time_nn = |int: bool| {
        bench(2, iters, || {
            dst.fill(0.0);
            let _ = ops::matmul_sl_qd_into(&a, &b, Some(&bias), &mut dst, m, kd, n, epi, int);
        })
    };
    let s_sim = time_nn(false);
    let s_split = time_nn(true);
    table.row(&[
        format!("split gemm nn z 64x{kd}x128+bias (wide 12-bit grid)"),
        speed(&s_sim, "split", &s_split),
    ]);

    // NT (l0 dx shape): dy [64,128] @ w [784,128]^T, 128-deep
    let dy = grid(m * n);
    let w = grid(kd * n);
    assert_eq!(ops::quant_gemm_plan(&dy, &w, n, None), ops::QuantGemmImpl::Split, "split nt");
    let mut time_nt = |int: bool| {
        bench(2, iters, || {
            let _ = ops::matmul_nt_sl_qd(&dy, &w, m, n, kd, epi, int);
        })
    };
    let s_sim = time_nt(false);
    let s_split = time_nt(true);
    table.row(&[
        format!("split gemm nt dx 64x{n} @ {kd}x{n}^T (wide 12-bit grid)"),
        speed(&s_sim, "split", &s_split),
    ]);

    // TN (l0 dw shape): x [64,784]^T @ dz [64,128], 64-deep batch
    let xs = grid(m * kd);
    let dz = grid(m * n);
    let zeros = vec![0.0f32; kd * n];
    assert_eq!(
        ops::quant_gemm_plan(&xs, &dz, m, Some(&zeros)),
        ops::QuantGemmImpl::Split,
        "split tn"
    );
    let mut dw = zeros;
    let mut time_tn = |int: bool| {
        bench(2, iters, || {
            dw.fill(0.0);
            let _ = ops::matmul_tn_sl_qd_into(&xs, &dz, &mut dw, m, kd, n, epi, int);
        })
    };
    let s_sim = time_tn(false);
    let s_split = time_tn(true);
    table.row(&[
        format!("split gemm tn dw {m}^T {kd}x{n} (wide 12-bit grid)"),
        speed(&s_sim, "split", &s_split),
    ]);

    // unrolled i16 NT microkernel vs the rolled reference it replaced,
    // on the 784-deep l0-dw contraction (pure integer loops, no
    // dispatch/epilogue — isolates the k-unroll win). Magnitudes stay
    // ≤ 127 so the 784-term i32 accumulation cannot wrap even in the
    // worst case; the kernel's cost is magnitude-independent.
    let mut krng = Pcg32::seeded(48);
    let (ua, ib) = (784usize, 128usize);
    let ai: Vec<i16> = (0..m * ua).map(|_| (krng.below(255) as i32 - 127) as i16).collect();
    let bi: Vec<i16> = (0..ib * ua).map(|_| (krng.below(255) as i32 - 127) as i16).collect();
    let mut out = vec![0i32; m * ib];
    let mut time_kernel = |unrolled: bool| {
        bench(2, iters, || {
            out.fill(0);
            if unrolled {
                int_gemm::imm_nt_serial(&ai, &bi, &mut out, ua, ib);
            } else {
                int_gemm::imm_nt_serial_ref(&ai, &bi, &mut out, ua, ib);
            }
        })
    };
    let s_ref = time_kernel(false);
    let s_unr = time_kernel(true);
    table.row(&[
        format!("unrolled int gemm nt {m}x{ua} @ {ib}x{ua}^T (i16)"),
        format!(
            "rolled {:.2}ms | unrolled {:.2}ms | speedup {:.2}x",
            s_ref.mean * 1e3,
            s_unr.mean * 1e3,
            s_ref.mean / s_unr.mean.max(1e-12),
        ),
    ]);
}

/// Packed-vs-repack A/Bs for the weight-slab cache (ROADMAP 1a/4b).
/// Both paths are bit-identical (tests/int_gemm_parity.rs), so the rows
/// are pure perf A/Bs; every leg's [`int_gemm::pack_calls`] delta is
/// measured (and the cached legs asserted cheaper) so a silently-dead
/// cache cannot masquerade as a win.
fn packed_cache_section(table: &mut Table) {
    let arithmetics: &[(&str, FixedFormat)] =
        &[("fixed 10.3", FixedFormat::new(10, 3)), ("fixed 8.-2", FixedFormat::new(8, -2))];
    let iters = scaled(40).max(10);
    let mut rng = Pcg32::seeded(47);

    // kernel level: the weight operand's pack hoisted out of the call
    for &(label, fmt) in arithmetics {
        let q = Quantizer::from_format(fmt);
        let mut grid = |len: usize| -> Vec<f32> {
            let mut v: Vec<f32> = (0..len).map(|_| rng.normal() * 0.2 * q.maxv).collect();
            q.apply_slice(&mut v);
            v
        };
        let epi = QuantEpilogue::new(q);
        let amax = (fmt.maxv() / fmt.step()) as u64;
        let kd = ((int_gemm::ACC_BOUND / (amax * amax)) as usize).min(784);
        let (m, n) = (64usize, 128usize);
        let a = grid(m * kd);
        let b = grid(kd * n);
        let bias = grid(n);
        let bp = int_gemm::pack(&b).expect("grid weights pack");
        let zeros = vec![0.0f32; m * n];
        assert_eq!(
            ops::quant_gemm_plan_cached(&a, Some(&bp), kd, Some(&zeros)),
            ops::QuantGemmImpl::IntDomain,
            "packed nn {label}"
        );
        let mut dst = zeros;
        // pack-call cadence: the repack leg packs activations AND
        // weights, the cached leg only the activations
        let c0 = int_gemm::pack_calls();
        dst.fill(0.0);
        let _ = ops::matmul_sl_qd_into(&a, &b, Some(&bias), &mut dst, m, kd, n, epi, true);
        let repack_packs = int_gemm::pack_calls() - c0;
        let c0 = int_gemm::pack_calls();
        dst.fill(0.0);
        let _ = ops::matmul_sl_qd_cached_into(
            &a,
            &b,
            Some(&bp),
            Some(&bias),
            &mut dst,
            m,
            kd,
            n,
            epi,
        );
        let cached_packs = int_gemm::pack_calls() - c0;
        assert!(
            cached_packs < repack_packs,
            "packed nn {label}: cached leg must skip the weight pack \
             ({cached_packs} vs {repack_packs})"
        );
        let s_repack = bench(2, iters, || {
            dst.fill(0.0);
            let _ = ops::matmul_sl_qd_into(&a, &b, Some(&bias), &mut dst, m, kd, n, epi, true);
        });
        let s_cached = bench(2, iters, || {
            dst.fill(0.0);
            let _ = ops::matmul_sl_qd_cached_into(
                &a,
                &b,
                Some(&bp),
                Some(&bias),
                &mut dst,
                m,
                kd,
                n,
                epi,
            );
        });
        table.row(&[
            format!("packed gemm nn z 64x{kd}x128+bias ({label})"),
            format!(
                "repack {:.2}ms | cached {:.2}ms | speedup {:.2}x (packs/call {repack_packs}→{cached_packs})",
                s_repack.mean * 1e3,
                s_cached.mean * 1e3,
                s_repack.mean / s_cached.mean.max(1e-12),
            ),
        ]);
    }

    // end-to-end cadence: a persistent Network re-packs each weight
    // layer exactly once per step (sgd_update moves the values, so one
    // rebuild is unavoidable) — the A/B against a fresh-Network-per-step
    // loop shows the cache costs nothing in training, and the pack
    // deltas prove the once-per-update cadence
    let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
    let (comp, up) = (FixedFormat::new(8, -2), FixedFormat::new(8, 0));
    let ctrl = ScaleController::fixed(24, comp, up);
    let step_iters = scaled(10).max(3);
    let opts = StepOptions { fused: true, int_domain: true, ..Default::default() };
    let quantized_state = || {
        let (mut params, vels, mut x, y) = pi_mlp_step_fixture();
        let qup = Quantizer::from_format(up);
        for p in &mut params {
            qup.apply_slice(p.data_mut());
        }
        Quantizer::from_format(comp).apply_slice(x.data_mut());
        (params, vels, x, y)
    };

    let net = Network::from_mlp_shape(shape);
    let (mut params, mut vels, x, y) = quantized_state();
    let _ = net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
    let c0 = int_gemm::pack_calls();
    let builds0 = net.weight_pack_builds();
    let _ = net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
    let cached_step_packs = int_gemm::pack_calls() - c0;
    assert_eq!(
        net.weight_pack_builds() - builds0,
        net.n_compute_layers() as u64,
        "packed train step: exactly one rebuild per weight layer per step"
    );
    let s_cached = bench(1, step_iters, || {
        let _ =
            net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
    });
    let (mut params, mut vels, x, y) = quantized_state();
    let c0 = int_gemm::pack_calls();
    let fresh = Network::from_mlp_shape(shape);
    let _ =
        fresh.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
    let fresh_step_packs = int_gemm::pack_calls() - c0;
    let s_fresh = bench(1, step_iters, || {
        let fresh = Network::from_mlp_shape(shape);
        let _ = fresh.train_step(
            &mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone(),
        );
    });
    table.row(&[
        "packed train step (pi_mlp, batch 64, fixed 8.-2 comp / 8.0 up)".into(),
        format!(
            "fresh-net {:.2}ms | persistent {:.2}ms | speedup {:.2}x (packs/step {fresh_step_packs}→{cached_step_packs}; update forces one rebuild/layer)",
            s_fresh.mean * 1e3,
            s_cached.mean * 1e3,
            s_fresh.mean / s_cached.mean.max(1e-12),
        ),
    ]);

    // serve steady state: frozen weights, forward-only — the persistent
    // (prepacked) network stops packing entirely, while a fresh network
    // per request batch re-packs every weight slab each time
    let (params, _, x, _) = quantized_state();
    let net = Network::from_mlp_shape(shape);
    net.prepack_int_operands(&params, &ctrl);
    let c0 = int_gemm::pack_calls();
    let _ = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    let warm_packs = int_gemm::pack_calls() - c0;
    let c0 = int_gemm::pack_calls();
    let fresh = Network::from_mlp_shape(shape);
    let _ = fresh.eval_logits_opt(&params, &x, &ctrl, &opts);
    let cold_packs = int_gemm::pack_calls() - c0;
    assert!(
        warm_packs < cold_packs,
        "packed eval: the prepacked network must not re-pack weights \
         ({warm_packs} vs {cold_packs})"
    );
    let s_warm = bench(1, iters, || {
        let _ = net.eval_logits_opt(&params, &x, &ctrl, &opts);
    });
    let s_cold = bench(1, iters, || {
        let fresh = Network::from_mlp_shape(shape);
        let _ = fresh.eval_logits_opt(&params, &x, &ctrl, &opts);
    });
    table.row(&[
        "packed eval batch (pi_mlp, batch 64, prepacked worker vs per-batch repack)".into(),
        format!(
            "repack {:.2}ms | prepacked {:.2}ms | speedup {:.2}x (packs/batch {cold_packs}→{warm_packs}; remainder is activations)",
            s_cold.mean * 1e3,
            s_warm.mean * 1e3,
            s_cold.mean / s_warm.mean.max(1e-12),
        ),
    ]);
}

/// Data-parallel train steps: the batch sharded across 1/2/4 workers
/// with central gradient reduction — bit-identical at every worker
/// count (`tests/dp_parity.rs`), so the rows are pure perf A/Bs on the
/// pi_mlp and builtin conv nets. Speedups are printed (they depend on
/// the host's core count); the packed-operand cadence is asserted: the
/// shared weight caches must rebuild exactly once per weight layer per
/// step no matter how many workers ran the forward pass.
fn dp_step_section(table: &mut Table) {
    let (comp, up) = (FixedFormat::new(8, -2), FixedFormat::new(8, 0));
    let qcomp = Quantizer::from_format(comp);
    let qup = Quantizer::from_format(up);
    let step_iters = scaled(10).max(3);

    // pi_mlp, batch 64 — same on-grid fixture as the packed-cache rows,
    // so every fused site is integer-domain eligible
    let shape = MlpShape::for_dataset("digits", 128, 4).expect("digits dims");
    let ctrl = ScaleController::fixed(24, comp, up);
    let mlp_state = || {
        let (mut params, vels, mut x, y) = pi_mlp_step_fixture();
        for p in &mut params {
            qup.apply_slice(p.data_mut());
        }
        qcomp.apply_slice(x.data_mut());
        (params, vels, x, y)
    };
    let net = Network::from_mlp_shape(shape);
    let mut serial_mean = 0.0f64;
    for workers in [1usize, 2, 4] {
        let opts = StepOptions {
            fused: true,
            int_domain: true,
            dp_workers: workers,
            ..Default::default()
        };
        let (mut params, mut vels, x, y) = mlp_state();
        let _ =
            net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
        let builds0 = net.weight_pack_builds();
        let _ =
            net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
        let packs = net.weight_pack_builds() - builds0;
        assert_eq!(
            packs,
            net.n_compute_layers() as u64,
            "dp train step x{workers}: exactly one pack rebuild per weight layer per step"
        );
        let s = bench(1, step_iters, || {
            let _ = net.train_step(
                &mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone(),
            );
        });
        if workers == 1 {
            serial_mean = s.mean;
        }
        table.row(&[
            format!("dp train step x{workers} (pi_mlp, batch 64, fixed 8.-2/8.0)"),
            format!(
                "{:.2}ms | speedup vs x1 {:.2}x (packs/step {packs})",
                s.mean * 1e3,
                serial_mean / s.mean.max(1e-12),
            ),
        ]);
    }

    // builtin conv on digits, batch 16 — conv weight slabs (im2col
    // filter matrices) share the same once-per-update cadence
    let spec = TopologySpec::builtin("conv").expect("builtin conv");
    let (in_shape, n_classes) = lpdnn::data::dataset_shape("digits").expect("digits shape");
    let net = Network::from_topology_shaped(&spec, in_shape, n_classes).expect("conv net");
    let ctrl = ScaleController::fixed(net.n_groups(), comp, up);
    let conv_iters = scaled(5).max(2);
    let batch = 16;
    let conv_state = || {
        let (mut params, vels) = lpdnn::testing::topology_state(&spec, in_shape, n_classes, 31);
        for p in &mut params {
            qup.apply_slice(p.data_mut());
        }
        let mut rng = Pcg32::seeded(29);
        let mut dims = vec![batch];
        dims.extend(in_shape.dims());
        let mut x = Tensor::from_vec(
            &dims,
            (0..batch * in_shape.len()).map(|_| rng.uniform()).collect(),
        );
        qcomp.apply_slice(x.data_mut());
        let labels: Vec<usize> = (0..batch).map(|_| rng.below(10) as usize).collect();
        (params, vels, x, ops::one_hot(&labels, 10))
    };
    let mut serial_mean = 0.0f64;
    for workers in [1usize, 2, 4] {
        let opts = StepOptions {
            fused: true,
            int_domain: true,
            dp_workers: workers,
            ..Default::default()
        };
        let (mut params, mut vels, x, y) = conv_state();
        let _ =
            net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
        let builds0 = net.weight_pack_builds();
        let _ =
            net.train_step(&mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone());
        let packs = net.weight_pack_builds() - builds0;
        assert_eq!(
            packs,
            net.n_compute_layers() as u64,
            "dp conv train step x{workers}: one pack rebuild per weight layer per step"
        );
        let s = bench(1, conv_iters, || {
            let _ = net.train_step(
                &mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl, opts.clone(),
            );
        });
        if workers == 1 {
            serial_mean = s.mean;
        }
        table.row(&[
            format!("dp train step x{workers} (conv digits, batch 16, fixed 8.-2/8.0)"),
            format!(
                "{:.2}ms | speedup vs x1 {:.2}x (packs/step {packs})",
                s.mean * 1e3,
                serial_mean / s.mean.max(1e-12),
            ),
        ]);
    }
}

fn quantizer_section(table: &mut Table) {
    let mut rng = Pcg32::seeded(2);
    let mut xs: Vec<f32> = (0..1 << 22).map(|_| rng.normal()).collect(); // 16 MiB
    let q = Quantizer::from_format(FixedFormat::new(12, 3));
    let s = bench(2, 10, || {
        let _ = q.apply_slice(&mut xs);
    });
    let gbps = (xs.len() * 4) as f64 / s.mean / 1e9;
    table.row(&[
        "host quantizer (apply_slice, 16 MiB f32)".into(),
        format!("{:.2} GB/s ({:.2}ms)", gbps, s.mean * 1e3),
    ]);
}

fn controller_section(table: &mut Table) {
    let mut ctrl = ScaleController::dynamic(
        24,
        FixedFormat::new(10, 3),
        FixedFormat::new(12, 0),
        1e-4,
        64,
    );
    let overflow = Tensor::from_vec(&[24, 3], vec![1.0; 72]);
    let s = bench(10, 1000, || {
        ctrl.observe_matrix(&overflow);
        let _ = ctrl.after_batch(64, 0);
    });
    table.row(&[
        "scale controller observe+tick (24 groups)".into(),
        format!("{:.2}µs", s.mean * 1e6),
    ]);
}

/// PJRT-only micro-benchmarks: the compiled step in isolation and the
/// literal-assembly boundary. Needs artifacts; skipped without.
#[cfg(feature = "pjrt")]
fn pjrt_section(table: &mut Table) {
    use lpdnn::runtime::literal_util::*;
    use lpdnn::runtime::{Engine, Manifest};

    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        table.row(&[
            "pjrt compiled-step micro-benches".into(),
            "skipped (run `make artifacts`)".into(),
        ]);
        return;
    }
    let manifest = Manifest::load(dir).expect("manifest");
    let engine = Engine::cpu().expect("PJRT cpu client");
    let model = manifest.model("pi_mlp").unwrap();
    let exe = engine
        .load_cached(manifest.artifact("pi_mlp", "fixed", "train").unwrap())
        .unwrap();
    let mut rng = Pcg32::seeded(1);
    let params: Vec<Tensor> =
        model.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
    let x = Tensor::from_vec(&[64, 784], (0..64 * 784).map(|_| rng.uniform()).collect());
    let labels: Vec<usize> = (0..64).map(|_| rng.below(10) as usize).collect();
    let y = ops::one_hot(&labels, 10);
    let build_inputs = || {
        let mut inputs = Vec::new();
        for p in &params {
            inputs.push(tensor_to_literal(p).unwrap());
        }
        for p in &params {
            inputs.push(tensor_to_literal(&Tensor::zeros(p.shape())).unwrap());
        }
        inputs.push(tensor_to_literal(&x).unwrap());
        inputs.push(tensor_to_literal(&y).unwrap());
        for v in [0.1f32, 0.5, 3.0, 7.0] {
            inputs.push(scalar(v));
        }
        inputs.push(slice_to_literal(&[0.0; 3], &[3]).unwrap());
        inputs.push(slice_to_literal(&vec![2f32.powi(-6); 24], &[24]).unwrap());
        inputs.push(slice_to_literal(&vec![8.0; 24], &[24]).unwrap());
        inputs
    };
    let inputs = build_inputs();
    let s = bench(3, scaled(30).max(10), || {
        let _ = exe.run(&inputs).unwrap();
    });
    table.row(&["pi_mlp compiled train step (XLA execute only)".into(), fmt_stats(&s)]);

    let s = bench(3, scaled(30).max(10), || {
        let _ = build_inputs();
    });
    table.row(&["pi_mlp input literal assembly (L3→PJRT boundary)".into(), fmt_stats(&s)]);
}

fn main() {
    let mut session = common::setup();
    let mut table = Table::new(&["benchmark", "result"]);

    matmul_section(&mut table);
    fused_gemm_section(&mut table);
    int_gemm_section(&mut table);
    split_gemm_section(&mut table);
    packed_cache_section(&mut table);
    end_to_end_section(&mut session, &mut table);
    native_step_section(&mut table);
    graph_step_section(&mut table);
    conv_step_section(&mut table);
    dp_step_section(&mut table);
    quantizer_section(&mut table);
    controller_section(&mut table);
    #[cfg(feature = "pjrt")]
    pjrt_section(&mut table);

    println!("\n=== performance micro-benchmarks ===");
    table.print();
    println!("(tracked across optimization iterations in EXPERIMENTS.md §Perf)");
    common::persist_table("perf", &table);
}
