//! Performance micro-benchmarks: the numbers EXPERIMENTS.md §Perf tracks.
//!
//! * compiled train-step latency per model/mode (the end-to-end hot path)
//! * compiled eval-step latency
//! * host quantizer throughput (GB/s over f32)
//! * golden train step (host reference point for the compiled step)
//! * literal conversion overhead (the L3↔PJRT boundary)
//! * scale controller overhead per tick

#[path = "common.rs"]
mod common;

use lpdnn::arith::{FixedFormat, Quantizer, RoundMode};
use lpdnn::bench_support::{bench, scaled, Stats, Table};
use lpdnn::config::Arithmetic;
use lpdnn::coordinator::{ScaleController, Trainer};
use lpdnn::golden::{self, MlpShape};
use lpdnn::runtime::literal_util::*;
use lpdnn::tensor::{init::InitSpec, ops, Pcg32, Tensor};

fn fmt_stats(s: &Stats) -> String {
    format!(
        "{:.2}ms ±{:.2} (p50 {:.2}, p90 {:.2}, n={})",
        s.mean * 1e3,
        s.sd * 1e3,
        s.p50 * 1e3,
        s.p90 * 1e3,
        s.n
    )
}

fn main() {
    let (engine, manifest) = common::setup();
    let mut table = Table::new(&["benchmark", "result"]);

    // ------------------------------------------------------------------
    // compiled step latency per model
    // ------------------------------------------------------------------
    for model in ["pi_mlp", "conv", "conv32"] {
        let dataset = match model {
            "pi_mlp" => "digits",
            "conv" => "digits",
            _ => "cifar_like",
        };
        let mut cfg = common::base_cfg(&format!("perf-{model}"), model, dataset);
        cfg.train.steps = scaled(20).max(5);
        cfg.data.n_train = 512;
        cfg.data.n_test = 256;
        cfg.arithmetic = Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 };
        let t0 = std::time::Instant::now();
        let r = Trainer::new(&engine, &manifest, cfg).run().expect("run");
        let total = t0.elapsed().as_secs_f64();
        let per_step = (total
            - 0.0) // compile amortized via engine cache across benches
            / r.steps_run as f64;
        table.row(&[
            format!("{model} end-to-end per train step (incl. eval amortized)"),
            format!("{:.1}ms", per_step * 1e3),
        ]);
    }

    // isolated compiled step (no batcher, no literal rebuild of x/y)
    {
        let model = manifest.model("pi_mlp").unwrap();
        let exe = engine
            .load_cached(manifest.artifact("pi_mlp", "fixed", "train").unwrap())
            .unwrap();
        let mut rng = Pcg32::seeded(1);
        let params: Vec<Tensor> =
            model.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
        let x = Tensor::from_vec(
            &[64, 784],
            (0..64 * 784).map(|_| rng.uniform()).collect(),
        );
        let labels: Vec<usize> = (0..64).map(|_| rng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);
        let build_inputs = || {
            let mut inputs = Vec::new();
            for p in &params {
                inputs.push(tensor_to_literal(p).unwrap());
            }
            for p in &params {
                inputs.push(tensor_to_literal(&Tensor::zeros(p.shape())).unwrap());
            }
            inputs.push(tensor_to_literal(&x).unwrap());
            inputs.push(tensor_to_literal(&y).unwrap());
            for v in [0.1f32, 0.5, 3.0, 7.0] {
                inputs.push(scalar(v));
            }
            inputs.push(slice_to_literal(&[0.0; 3], &[3]).unwrap());
            inputs.push(slice_to_literal(&vec![2f32.powi(-6); 24], &[24]).unwrap());
            inputs.push(slice_to_literal(&vec![8.0; 24], &[24]).unwrap());
            inputs
        };
        let inputs = build_inputs();
        let s = bench(3, scaled(30).max(10), || {
            let _ = exe.run(&inputs).unwrap();
        });
        table.row(&["pi_mlp compiled train step (XLA execute only)".into(), fmt_stats(&s)]);

        let s = bench(3, scaled(30).max(10), || {
            let _ = build_inputs();
        });
        table.row(&["pi_mlp input literal assembly (L3→PJRT boundary)".into(), fmt_stats(&s)]);
    }

    // ------------------------------------------------------------------
    // host quantizer throughput
    // ------------------------------------------------------------------
    {
        let mut rng = Pcg32::seeded(2);
        let mut xs: Vec<f32> = (0..1 << 22).map(|_| rng.normal()).collect(); // 16 MiB
        let q = Quantizer::from_format(FixedFormat::new(12, 3));
        let s = bench(2, 10, || {
            let _ = q.apply_slice(&mut xs);
        });
        let gbps = (xs.len() * 4) as f64 / s.mean / 1e9;
        table.row(&[
            "host quantizer (apply_slice, 16 MiB f32)".into(),
            format!("{:.2} GB/s ({:.2}ms)", gbps, s.mean * 1e3),
        ]);
    }

    // ------------------------------------------------------------------
    // golden host train step (reference for the compiled one)
    // ------------------------------------------------------------------
    {
        let shape = MlpShape::pi_mlp(128, 4);
        let ctrl = ScaleController::fixed(3, FixedFormat::new(12, 3), FixedFormat::new(14, 1));
        let mut rng = Pcg32::seeded(3);
        let mut params = vec![
            InitSpec::GlorotUniform { fan_in: 784, fan_out: 128 }
                .realize(&[4, 784, 128], &mut rng),
            Tensor::zeros(&[4, 128]),
            InitSpec::GlorotUniform { fan_in: 128, fan_out: 128 }
                .realize(&[4, 128, 128], &mut rng),
            Tensor::zeros(&[4, 128]),
            InitSpec::GlorotUniform { fan_in: 128, fan_out: 10 }
                .realize(&[128, 10], &mut rng),
            Tensor::zeros(&[10]),
        ];
        let mut vels: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let x = Tensor::from_vec(&[64, 784], (0..64 * 784).map(|_| rng.uniform()).collect());
        let labels: Vec<usize> = (0..64).map(|_| rng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);
        let s = bench(1, scaled(10).max(3), || {
            let _ = golden::train_step(
                shape, &mut params, &mut vels, &x, &y, 0.01, 0.5, 3.0, &ctrl,
                RoundMode::HalfAway,
            );
        });
        table.row(&["golden host train step (pi_mlp, single thread)".into(), fmt_stats(&s)]);
    }

    // ------------------------------------------------------------------
    // controller overhead
    // ------------------------------------------------------------------
    {
        let mut ctrl = ScaleController::dynamic(
            3,
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
            1e-4,
            64,
        );
        let overflow = Tensor::from_vec(&[24, 3], vec![1.0; 72]);
        let s = bench(10, 1000, || {
            ctrl.observe_matrix(&overflow);
            let _ = ctrl.after_batch(64, 0);
        });
        table.row(&[
            "scale controller observe+tick (24 groups)".into(),
            format!("{:.2}µs", s.mean * 1e6),
        ]);
    }

    println!("\n=== performance micro-benchmarks ===");
    table.print();
    println!("(tracked across optimization iterations in EXPERIMENTS.md §Perf)");
}
