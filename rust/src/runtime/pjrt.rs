//! PJRT backend: load AOT artifacts (HLO text) and execute them.
//!
//! This is the bridge between L3 (this crate) and the compiled L2/L1
//! graphs: a thin, typed wrapper over the `xla` crate's PJRT CPU client,
//! plus the [`PjrtBackend`] adapter that plugs it into the generic
//! [`Backend`](crate::runtime::Backend) trait. Only compiled when the
//! `pjrt` cargo feature is on.
//!
//! * [`Engine`] — one PJRT client per process (creation is expensive).
//! * [`Executable`] — a compiled artifact + its manifest metadata; `run`
//!   takes inputs in manifest order and returns the flattened output
//!   tuple (the L2 graphs are lowered with `return_tuple=True`).
//! * [`PjrtBackend`] — per-run artifact selection + device-side model
//!   state. Parameters/velocities live as PJRT literals: each step's
//!   outputs are fed straight back as the next step's inputs, so model
//!   state never makes a host round-trip on the training path
//!   (EXPERIMENTS.md §Perf).
//!
//! Interchange is HLO *text* (never serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use xla::{HloModuleProto, Literal, PjRtClient, XlaComputation};

use super::literal_util::{
    literal_to_scalar, literal_to_tensor, scalar, slice_to_literal, tensor_to_literal,
};
use super::manifest::{ArtifactInfo, Manifest, ModelInfo};
use super::{Backend, StepOut, StepParams};
use crate::arith::Quantizer;
use crate::config::ExperimentConfig;
use crate::coordinator::ScaleController;
use crate::error::Context;
use crate::tensor::{Pcg32, Tensor};

/// Process-wide PJRT client wrapper with a compile cache: sweeps run tens
/// of experiments over the same handful of artifacts, and XLA compilation
/// costs seconds per artifact.
pub struct Engine {
    client: PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> crate::Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact (uncached).
    pub fn load(&self, info: &ArtifactInfo) -> crate::Result<Executable> {
        let proto = HloModuleProto::from_text_file(
            info.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", info.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", info.key))?;
        Ok(Executable { exe, info: info.clone() })
    }

    /// Load + compile with memoization on the artifact key.
    pub fn load_cached(&self, info: &ArtifactInfo) -> crate::Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(&info.key) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(self.load(info)?);
        self.cache.borrow_mut().insert(info.key.clone(), exe.clone());
        Ok(exe)
    }
}

/// A compiled artifact, executable with manifest-ordered inputs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    info: ArtifactInfo,
}

impl Executable {
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    /// Execute with inputs in manifest order; returns the output tuple
    /// elements in manifest order. Accepts owned or borrowed literals, so
    /// the trainer can feed the previous step's outputs back without
    /// host-side copies.
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        inputs: &[L],
    ) -> crate::Result<Vec<Literal>> {
        crate::ensure!(
            inputs.len() == self.info.inputs.len(),
            "artifact {} expects {} inputs, got {} (order: {:?})",
            self.info.key,
            self.info.inputs.len(),
            inputs.len(),
            self.info.inputs
        );
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.info.key))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching outputs")?
            .to_tuple()
            .context("untupling outputs")?;
        crate::ensure!(
            tuple.len() == self.info.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.info.key,
            tuple.len(),
            self.info.outputs.len()
        );
        Ok(tuple)
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> crate::Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("output '{name}' not in {}", self.info.key))
    }
}

/// Per-run state for the PJRT backend.
struct PjrtRun {
    model: ModelInfo,
    train_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    params: Vec<Literal>,
    vels: Vec<Literal>,
}

/// The compiled-artifact implementation of [`Backend`].
pub struct PjrtBackend {
    engine: Engine,
    manifest: Manifest,
    run: Option<PjrtRun>,
}

impl PjrtBackend {
    /// Engine + manifest from [`Manifest::default_dir`].
    pub fn from_default_manifest() -> crate::Result<PjrtBackend> {
        Self::new(Manifest::load(Manifest::default_dir())?)
    }

    pub fn new(manifest: Manifest) -> crate::Result<PjrtBackend> {
        Ok(PjrtBackend { engine: Engine::cpu()?, manifest, run: None })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run_mut(&mut self) -> crate::Result<&mut PjrtRun> {
        self.run.as_mut().context("PjrtBackend: begin_run was never called")
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports_model(&self, model: &str) -> bool {
        self.manifest.models.contains_key(model)
    }

    fn begin_run(&mut self, cfg: &ExperimentConfig) -> crate::Result<ModelInfo> {
        // explicit topologies are a native-backend feature: the compiled
        // artifacts exist only for the manifest's models, so silently
        // training a different network than configured must be an error
        // — never a fallback. Conv topologies in particular are
        // im2col-lowered by the native graph and have no compiled form.
        if let Some(t) = &cfg.topology {
            let kind = if t.conv.is_empty() { "MLP" } else { "conv" };
            crate::bail!(
                "the pjrt backend runs compiled manifest models only and \
                 cannot realize the explicit {kind} topology '{}' — drop \
                 [topology]/--topology or use --backend native",
                t.name
            );
        }
        let model = self.manifest.model(&cfg.model)?.clone();
        let mode = cfg.arithmetic.mode();
        let train_exe =
            self.engine.load_cached(self.manifest.artifact(&cfg.model, mode, "train")?)?;
        let eval_exe =
            self.engine.load_cached(self.manifest.artifact(&cfg.model, mode, "eval")?)?;
        self.run = Some(PjrtRun {
            model: model.clone(),
            train_exe,
            eval_exe,
            params: Vec::new(),
            vels: Vec::new(),
        });
        Ok(model)
    }

    fn init_state(&mut self, ctrl: &ScaleController, rng: &mut Pcg32) -> crate::Result<()> {
        let run = self.run_mut()?;
        let mut params = Vec::with_capacity(run.model.params.len());
        let mut vels = Vec::with_capacity(run.model.params.len());
        for spec in &run.model.params {
            let mut t = spec.init.realize(&spec.shape, rng);
            // quantize onto the group's storage grid (the device does so
            // on every update; doing it at init keeps step 0 consistent)
            Quantizer::from_format(ctrl.format(spec.group())).apply_slice(t.data_mut());
            params.push(tensor_to_literal(&t)?);
            vels.push(tensor_to_literal(&Tensor::zeros(&spec.shape))?);
        }
        run.params = params;
        run.vels = vels;
        Ok(())
    }

    fn train_step(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        hp: &StepParams,
    ) -> crate::Result<StepOut> {
        let run = self.run_mut()?;
        let model = &run.model;
        let n_p = model.params.len();

        // Per-step inputs (x, y, scalars, scale vectors) are freshly
        // built; parameters/velocities are borrowed from the previous
        // step's outputs — no host round-trip for model state.
        // x arrives in dataset layout; the artifact wants [batch, ...model
        // input shape] — same bytes (e.g. 28×28×1 → 784 for pi_mlp).
        let mut x_shape = vec![model.train_batch];
        x_shape.extend_from_slice(&model.input_shape);
        let mut rates = vec![hp.dropout_hidden; model.n_layers];
        rates[0] = hp.dropout_input;
        let fresh: Vec<Literal> = vec![
            slice_to_literal(x.data(), &x_shape)?,
            tensor_to_literal(y)?,
            scalar(hp.lr),
            scalar(hp.momentum),
            scalar(hp.max_norm),
            scalar((hp.t as u32 % (1 << 24)) as f32), // in-graph dropout seed
            slice_to_literal(&rates, &[model.n_layers])?,
            slice_to_literal(&ctrl.steps_vec(), &[model.n_groups])?,
            slice_to_literal(&ctrl.maxvs_vec(), &[model.n_groups])?,
        ];
        let inputs: Vec<&Literal> =
            run.params.iter().chain(run.vels.iter()).chain(fresh.iter()).collect();

        let mut outputs = run.train_exe.run(&inputs).context("train step")?;

        let loss = literal_to_scalar(&outputs[2 * n_p])?;
        let overflow = literal_to_tensor(&outputs[2 * n_p + 1])?;
        // feed the updated state straight into the next step
        run.vels = outputs.split_off(n_p).into_iter().take(n_p).collect();
        run.params = outputs;
        Ok(StepOut { loss, overflow })
    }

    fn eval_errors(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        n_real: usize,
    ) -> crate::Result<usize> {
        let run = self.run_mut()?;
        let model = &run.model;
        // the compiled eval graph scores the whole fixed-size batch; the
        // trainer rounds the test set up to whole batches so wrap-padding
        // never reaches it
        crate::ensure!(
            n_real == model.eval_batch,
            "pjrt eval expects batch-aligned test sets ({n_real} != {})",
            model.eval_batch
        );
        let mut x_shape = vec![model.eval_batch];
        x_shape.extend_from_slice(&model.input_shape);
        let fresh: Vec<Literal> = vec![
            slice_to_literal(x.data(), &x_shape)?,
            tensor_to_literal(y)?,
            slice_to_literal(&ctrl.steps_vec(), &[model.n_groups])?,
            slice_to_literal(&ctrl.maxvs_vec(), &[model.n_groups])?,
        ];
        let inputs: Vec<&Literal> = run.params.iter().chain(fresh.iter()).collect();
        let out = run.eval_exe.run(&inputs).context("eval step")?;
        Ok(literal_to_scalar(&out[0])?.round() as usize)
    }

    fn params_host(&self) -> crate::Result<Vec<Tensor>> {
        let run = self.run.as_ref().context("PjrtBackend: begin_run was never called")?;
        let mut out = Vec::with_capacity(run.params.len());
        for (lit, spec) in run.params.iter().zip(&run.model.params) {
            let t = literal_to_tensor(lit)?;
            crate::ensure!(t.shape() == &spec.shape[..], "param {} shape drift", spec.name);
            out.push(t);
        }
        Ok(out)
    }
}
