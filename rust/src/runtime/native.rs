//! Native backend: the pure-Rust training engine (default).
//!
//! Promotes the golden model (`crate::golden`) from test-only
//! cross-validator to a first-class [`Backend`]: the same maxout
//! forward/backward, per-signal quantization hooks, momentum updates and
//! overflow statistics as the compiled artifacts, driven by the same
//! `Trainer` loop and scale controller — but with zero external
//! dependencies, no AOT artifacts and no Python anywhere.
//!
//! Topology is **data**: `begin_run` resolves the experiment's
//! [`TopologySpec`] (the explicit `[topology]` table / `--topology`
//! value, or the builtin spec the model name selects), derives the
//! input signal [`Shape`] and class count from the configured dataset
//! ([`crate::data::dataset_shape`]), and assembles a
//! [`Network`] layer graph plus the matching
//! [`ModelInfo`] parameter specs. Depth/width sweeps, non-MNIST MLP
//! workloads *and* the paper's maxout-conv nets (`conv`, `conv32`,
//! `pi_conv`, or any `--topology c...` spec — im2col-lowered onto the
//! fused GEMM epilogues) are therefore config changes — see DESIGN.md
//! §Layer graph and §Conv lowering.
//!
//! Model state lives as host [`Tensor`]s; the hot contractions run on
//! the blocked/parallel kernels in [`crate::tensor::ops`], with the
//! Z/DW/DX re-quantizations fused into the GEMM epilogues by default
//! (`LPDNN_FUSED=0` selects the bit-identical two-pass path — see
//! DESIGN.md §Fused quantized GEMM).
//!
//! Differences from the compiled path (documented, not hidden):
//!
//! * Dropout uses standard host-side inverted dropout seeded from the
//!   experiment seed and step index ([`Dropout`]); the compiled
//!   graphs use an in-graph hash PRNG. Both are deterministic per run;
//!   masks differ bit-wise between backends.
//! * Conv weights are stored as the im2col-lowered
//!   `[k, ksize²·C_in, C_out]` slabs, not L2's HWIO tensors — same
//!   math, different layout, so conv state is not byte-interchangeable
//!   with the compiled artifacts (the MLPs are).
//!
//! With dropout off, one native step is verified to agree with
//! [`crate::golden::train_step`] exactly (`tests/native_backend.rs`), which is
//! itself cross-validated against the compiled artifact under `pjrt`.

use super::manifest::ModelInfo;
use super::{Backend, StepOut, StepParams};
use crate::arith::{Quantizer, RoundMode};
use crate::config::{Arithmetic, ExperimentConfig, TopologySpec};
use crate::coordinator::ScaleController;
use crate::error::Context;
use crate::golden::{Dropout, Network, Params, StepOptions};
use crate::tensor::{ops, Pcg32, Shape, Tensor};

/// Per-run state for the native backend.
struct NativeRun {
    model: ModelInfo,
    /// The layer graph realized from the run's topology + dataset dims.
    /// Built once per run, so per-layer state amortizes across steps:
    /// conv im2col scratch allocates on the first step, and the
    /// integer-domain packed-weight caches persist until an update or
    /// scale move invalidates them (`Network::weight_pack_builds`).
    net: Network,
    /// Simulate float16 via binary16 round-trips at every hook.
    half: bool,
    /// Experiment seed (dropout masks derive from it + the step index).
    seed: u64,
    params: Params,
    vels: Params,
}

/// The self-contained pure-Rust implementation of [`Backend`].
#[derive(Default)]
pub struct NativeBackend {
    run: Option<NativeRun>,
    /// Data-parallel workers per train step. `None` defers to
    /// `LPDNN_DP_WORKERS` at step time (unset = serial). Any value
    /// produces bit-identical training (`tests/dp_parity.rs`).
    dp_workers: Option<usize>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }

    /// Pin the data-parallel worker count (overrides
    /// `LPDNN_DP_WORKERS`); purely a wall-clock knob, never a bits one.
    pub fn with_dp_workers(mut self, n: usize) -> NativeBackend {
        self.dp_workers = Some(n.max(1));
        self
    }

    fn run_mut(&mut self) -> crate::Result<&mut NativeRun> {
        self.run.as_mut().context("NativeBackend: begin_run was never called")
    }

    /// Reinterpret a dataset-layout batch `[n, ...example]` as the
    /// network's input `[n, ...in_shape.dims()]` (same bytes: 28×28×1
    /// flattens to 784 for the MLPs, stays NHWC for the conv nets).
    fn shape_input(x: &Tensor, in_shape: Shape) -> crate::Result<Tensor> {
        let n = x.shape()[0];
        let mut dims = vec![n];
        dims.extend(in_shape.dims());
        crate::ensure!(
            x.len() == n * in_shape.len(),
            "input batch {:?} does not reshape to [{n}, {in_shape}]",
            x.shape()
        );
        Ok(x.clone().reshape(&dims))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_model(&self, model: &str) -> bool {
        // name-based gating for the builtin specs (MLPs and conv nets
        // alike) only; configs with an explicit topology bypass this
        // and are resolved by begin_run
        TopologySpec::builtin(model).is_some()
    }

    fn begin_run(&mut self, cfg: &ExperimentConfig) -> crate::Result<ModelInfo> {
        let spec = match &cfg.topology {
            Some(t) => t.clone(),
            None => TopologySpec::builtin(&cfg.model).with_context(|| {
                format!(
                    "model '{}' is not a builtin topology (pi_mlp, pi_mlp_wide, conv, \
                     conv32, pi_conv) — pass an explicit topology \
                     (--topology / [topology]) or a manifest model on the pjrt backend",
                    cfg.model
                )
            })?,
        };
        spec.validate()?;
        // the input signal shape and class count come from the data
        // source, so the same topology composes with any dataset whose
        // shape fits: MLP topologies consume the flattened view (e.g.
        // cifar_like as 3072-d), conv topologies the spatial H×W×C one
        let (data_shape, n_classes) = crate::data::dataset_shape(&cfg.data.dataset)?;
        let in_shape = if spec.conv.is_empty() {
            data_shape.flattened()
        } else {
            data_shape
        };
        let model = ModelInfo::from_topology_shaped(&spec, &in_shape, n_classes)?;
        let net = Network::from_topology_shaped(&spec, in_shape, n_classes)?;
        self.run = Some(NativeRun {
            model: model.clone(),
            net,
            half: matches!(cfg.arithmetic, Arithmetic::Half),
            seed: cfg.train.seed,
            params: Vec::new(),
            vels: Vec::new(),
        });
        Ok(model)
    }

    fn init_state(&mut self, ctrl: &ScaleController, rng: &mut Pcg32) -> crate::Result<()> {
        let run = self.run_mut()?;
        let mut params = Vec::with_capacity(run.model.params.len());
        let mut vels = Vec::with_capacity(run.model.params.len());
        for spec in &run.model.params {
            let mut t = spec.init.realize(&spec.shape, rng);
            // same init-time storage quantization as the PJRT path
            Quantizer::from_format(ctrl.format(spec.group())).apply_slice(t.data_mut());
            vels.push(Tensor::zeros(&spec.shape));
            params.push(t);
        }
        run.params = params;
        run.vels = vels;
        Ok(())
    }

    fn train_step(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        hp: &StepParams,
    ) -> crate::Result<StepOut> {
        let dp_workers =
            self.dp_workers.unwrap_or_else(crate::golden::dp_workers_default);
        let run = self.run_mut()?;
        let x = Self::shape_input(x, run.net.in_shape())?;
        let dropout = if hp.dropout_input > 0.0 || hp.dropout_hidden > 0.0 {
            Some(Dropout {
                input_rate: hp.dropout_input,
                hidden_rate: hp.dropout_hidden,
                // independent mask stream per (experiment seed, step)
                rng: Pcg32::seeded(run.seed ^ 0xD80F_0A57).fork(hp.t as u64),
            })
        } else {
            None
        };
        let out = run.net.train_step(
            &mut run.params,
            &mut run.vels,
            &x,
            y,
            hp.lr,
            hp.momentum,
            hp.max_norm,
            ctrl,
            // defaults: canonical half-away rounding, fused Z/DW/DX
            // epilogues unless LPDNN_FUSED=0, integer-domain GEMMs only
            // when LPDNN_INT_GEMM=1 (same bits every way)
            StepOptions { half: run.half, dropout, dp_workers, ..Default::default() },
        );
        Ok(StepOut { loss: out.loss, overflow: out.overflow })
    }

    fn eval_errors(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        n_real: usize,
    ) -> crate::Result<usize> {
        let run = self.run_mut()?;
        let x = Self::shape_input(x, run.net.in_shape())?;
        let logits = run.net.eval_logits(&run.params, &x, ctrl, RoundMode::HalfAway, run.half);
        let preds = ops::argmax_rows(&logits);
        let truth = ops::argmax_rows(y);
        crate::ensure!(n_real <= preds.len(), "n_real {n_real} > batch {}", preds.len());
        Ok(preds
            .iter()
            .zip(&truth)
            .take(n_real)
            .filter(|(p, t)| p != t)
            .count())
    }

    fn params_host(&self) -> crate::Result<Vec<Tensor>> {
        let run = self.run.as_ref().context("NativeBackend: begin_run was never called")?;
        Ok(run.params.clone())
    }

    fn load_params(&mut self, params: Vec<Tensor>) -> crate::Result<()> {
        let run = self.run_mut()?;
        crate::ensure!(
            params.len() == run.model.params.len(),
            "load_params: {} tensors for a model with {} parameters",
            params.len(),
            run.model.params.len()
        );
        for (t, spec) in params.iter().zip(&run.model.params) {
            crate::ensure!(
                t.shape() == spec.shape.as_slice(),
                "load_params: parameter '{}' has shape {:?}, model wants {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
        run.vels = run.model.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        run.params = params;
        Ok(())
    }

    fn int_gemm_sites(&self) -> std::collections::BTreeMap<String, ops::GemmSiteCounts> {
        self.run.as_ref().map(|r| r.net.int_gemm_sites()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::FixedFormat;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::default()
    }

    #[test]
    fn supports_the_builtin_conv_models_and_rejects_unknowns() {
        let be = NativeBackend::new();
        assert!(be.supports_model("pi_mlp") && be.supports_model("pi_mlp_wide"));
        assert!(be.supports_model("conv") && be.supports_model("conv32"));
        assert!(be.supports_model("pi_conv"));
        assert!(!be.supports_model("resnet"));
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.model = "resnet".into();
        let err = be.begin_run(&c).unwrap_err();
        assert!(format!("{err:#}").contains("not a builtin topology"), "{err:#}");
    }

    #[test]
    fn conv_model_runs_end_to_end_on_the_spatial_dataset() {
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.model = "pi_conv".into();
        c.data.dataset = "cifar_like".into();
        let model = be.begin_run(&c).unwrap();
        assert_eq!(model.n_layers, 4);
        assert_eq!(model.n_groups, 32);
        assert_eq!(model.input_shape, vec![32, 32, 3]);
        let ctrl =
            ScaleController::fixed(model.n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(9);
        be.init_state(&ctrl, &mut rng).unwrap();
        let n = 4;
        let x = Tensor::from_vec(
            &[n, 32, 32, 3],
            (0..n * 3072).map(|_| rng.normal()).collect(),
        );
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);
        let hp = StepParams {
            lr: 0.05,
            momentum: 0.5,
            max_norm: 0.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            t: 0,
        };
        let out = be.train_step(&ctrl, &x, &y, &hp).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[32, 3]);
        let errs = be.eval_errors(&ctrl, &x, &y, n).unwrap();
        assert!(errs <= n);
    }

    #[test]
    fn conv_stages_reject_the_flat_dataset_at_begin_run() {
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.topology = Some(TopologySpec::builtin("pi_conv").unwrap());
        c.model = "pi_conv".into();
        c.data.dataset = "clusters".into();
        let err = be.begin_run(&c).unwrap_err();
        assert!(format!("{err:#}").contains("spatial"), "{err:#}");
    }

    #[test]
    fn init_quantizes_onto_storage_grid() {
        let mut be = NativeBackend::new();
        let model = be.begin_run(&cfg()).unwrap();
        let up = FixedFormat::new(12, 0);
        let ctrl = ScaleController::fixed(model.n_groups, FixedFormat::new(10, 3), up);
        let mut rng = Pcg32::seeded(3);
        be.init_state(&ctrl, &mut rng).unwrap();
        for p in be.params_host().unwrap() {
            for &v in p.data() {
                let k = v / up.step();
                assert!((k - k.round()).abs() < 1e-3, "off grid: {v}");
            }
        }
    }

    #[test]
    fn explicit_topology_overrides_the_model_and_follows_the_dataset() {
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.topology = Some(TopologySpec::mlp(vec![24, 16, 8], 2));
        c.model = c.topology.as_ref().unwrap().name.clone();
        c.data.dataset = "cifar_like".into(); // 3072-d input, 10 classes
        let model = be.begin_run(&c).unwrap();
        assert_eq!(model.n_layers, 4);
        assert_eq!(model.n_groups, 32);
        assert_eq!(model.input_shape, vec![3072]);
        assert_eq!(model.params[0].shape, vec![2, 3072, 24]);
        let ctrl =
            ScaleController::fixed(model.n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(5);
        be.init_state(&ctrl, &mut rng).unwrap();
        // one step end to end on the dataset-shaped input
        let n = model.train_batch;
        let x = Tensor::from_vec(
            &[n, 32, 32, 3],
            (0..n * 3072).map(|_| rng.uniform()).collect(),
        );
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);
        let hp = StepParams {
            lr: 0.1,
            momentum: 0.5,
            max_norm: 0.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            t: 0,
        };
        let out = be.train_step(&ctrl, &x, &y, &hp).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[32, 3]);
    }

    #[test]
    fn load_params_replaces_state_and_validates_shapes() {
        let mut be = NativeBackend::new();
        let model = be.begin_run(&cfg()).unwrap();
        let ctrl =
            ScaleController::fixed(model.n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(11);
        be.init_state(&ctrl, &mut rng).unwrap();
        let mut params = be.params_host().unwrap();
        params[0].data_mut()[0] = 0.25;
        be.load_params(params.clone()).unwrap();
        assert_eq!(be.params_host().unwrap()[0].data()[0], 0.25);
        // wrong count
        let err = be.load_params(params[1..].to_vec()).unwrap_err();
        assert!(format!("{err:#}").contains("tensors for a model"), "{err:#}");
        // wrong shape
        let mut bad = params;
        bad[0] = Tensor::zeros(&[1, 2, 3]);
        let err = be.load_params(bad).unwrap_err();
        assert!(format!("{err:#}").contains("model wants"), "{err:#}");
    }

    #[test]
    fn methods_before_begin_run_fail_cleanly() {
        let mut be = NativeBackend::new();
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(1);
        assert!(be.init_state(&ctrl, &mut rng).is_err());
        assert!(be.params_host().is_err());
    }
}
