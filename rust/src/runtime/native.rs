//! Native backend: the pure-Rust training engine (default).
//!
//! Promotes the golden model (`crate::golden`) from test-only
//! cross-validator to a first-class [`Backend`]: the same maxout
//! forward/backward, per-signal quantization hooks, momentum updates and
//! overflow statistics as the compiled artifacts, driven by the same
//! `Trainer` loop and scale controller — but with zero external
//! dependencies, no AOT artifacts and no Python anywhere.
//!
//! Topology is **data**: `begin_run` resolves the experiment's
//! [`TopologySpec`] (the explicit `[topology]` table / `--topology`
//! value, or the builtin spec the model name selects), derives the
//! input/output dimensions from the configured dataset
//! ([`crate::data::dataset_dims`]), and assembles a
//! [`Network`] layer graph plus the matching
//! [`ModelInfo`] parameter specs. Depth/width sweeps and non-MNIST MLP
//! workloads are therefore config changes — see DESIGN.md §Layer graph.
//!
//! Model state lives as host [`Tensor`]s; the hot contractions run on
//! the blocked/parallel kernels in [`crate::tensor::ops`], with the
//! Z/DW/DX re-quantizations fused into the GEMM epilogues by default
//! (`LPDNN_FUSED=0` selects the bit-identical two-pass path — see
//! DESIGN.md §Fused quantized GEMM).
//!
//! Differences from the compiled path (documented, not hidden):
//!
//! * Dropout uses standard host-side inverted dropout seeded from the
//!   experiment seed and step index ([`Dropout`]); the compiled
//!   graphs use an in-graph hash PRNG. Both are deterministic per run;
//!   masks differ bit-wise between backends.
//! * Only maxout MLPs run natively — the conv nets exist only as
//!   compiled graphs. `begin_run` rejects them with a clear error;
//!   sweeps skip them via [`Backend::supports_model`].
//!
//! With dropout off, one native step is verified to agree with
//! [`crate::golden::train_step`] exactly (`tests/native_backend.rs`), which is
//! itself cross-validated against the compiled artifact under `pjrt`.

use super::manifest::ModelInfo;
use super::{Backend, StepOut, StepParams};
use crate::arith::{Quantizer, RoundMode};
use crate::config::{Arithmetic, ExperimentConfig, TopologySpec};
use crate::coordinator::ScaleController;
use crate::error::Context;
use crate::golden::{Dropout, Network, Params, StepOptions};
use crate::tensor::{ops, Pcg32, Tensor};

/// Per-run state for the native backend.
struct NativeRun {
    model: ModelInfo,
    /// The layer graph realized from the run's topology + dataset dims.
    net: Network,
    /// Simulate float16 via binary16 round-trips at every hook.
    half: bool,
    /// Experiment seed (dropout masks derive from it + the step index).
    seed: u64,
    params: Params,
    vels: Params,
}

/// The self-contained pure-Rust implementation of [`Backend`].
#[derive(Default)]
pub struct NativeBackend {
    run: Option<NativeRun>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { run: None }
    }

    fn run_mut(&mut self) -> crate::Result<&mut NativeRun> {
        self.run.as_mut().context("NativeBackend: begin_run was never called")
    }

    /// Reinterpret a dataset-layout batch `[n, ...example]` as the model's
    /// flat input `[n, d_in]` (same bytes, e.g. 28×28×1 → 784).
    fn flatten_input(x: &Tensor, d_in: usize) -> crate::Result<Tensor> {
        let n = x.shape()[0];
        crate::ensure!(
            x.len() == n * d_in,
            "input batch {:?} does not flatten to [{n}, {d_in}]",
            x.shape()
        );
        Ok(x.clone().reshape(&[n, d_in]))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_model(&self, model: &str) -> bool {
        // name-based gating for the builtin specs only; configs with an
        // explicit topology bypass this and are resolved by begin_run
        TopologySpec::builtin(model).is_some()
    }

    fn begin_run(&mut self, cfg: &ExperimentConfig) -> crate::Result<ModelInfo> {
        let spec = match &cfg.topology {
            Some(t) => t.clone(),
            None => TopologySpec::builtin(&cfg.model).with_context(|| {
                format!(
                    "the native backend implements the maxout MLPs only; model '{}' \
                     needs compiled artifacts (build with --features pjrt and use \
                     the pjrt backend) — or pass an explicit MLP topology \
                     (--topology / [topology])",
                    cfg.model
                )
            })?,
        };
        spec.validate()?;
        // input/output dimensions come from the data source, so the same
        // topology composes with any dataset
        let (d_in, n_classes) = crate::data::dataset_dims(&cfg.data.dataset)?;
        let model = ModelInfo::from_topology(&spec, d_in, n_classes);
        let net = Network::from_topology(&spec, d_in, n_classes);
        self.run = Some(NativeRun {
            model: model.clone(),
            net,
            half: matches!(cfg.arithmetic, Arithmetic::Half),
            seed: cfg.train.seed,
            params: Vec::new(),
            vels: Vec::new(),
        });
        Ok(model)
    }

    fn init_state(&mut self, ctrl: &ScaleController, rng: &mut Pcg32) -> crate::Result<()> {
        let run = self.run_mut()?;
        let mut params = Vec::with_capacity(run.model.params.len());
        let mut vels = Vec::with_capacity(run.model.params.len());
        for spec in &run.model.params {
            let mut t = spec.init.realize(&spec.shape, rng);
            // same init-time storage quantization as the PJRT path
            Quantizer::from_format(ctrl.format(spec.group())).apply_slice(t.data_mut());
            vels.push(Tensor::zeros(&spec.shape));
            params.push(t);
        }
        run.params = params;
        run.vels = vels;
        Ok(())
    }

    fn train_step(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        hp: &StepParams,
    ) -> crate::Result<StepOut> {
        let run = self.run_mut()?;
        let x = Self::flatten_input(x, run.net.d_in())?;
        let dropout = if hp.dropout_input > 0.0 || hp.dropout_hidden > 0.0 {
            Some(Dropout {
                input_rate: hp.dropout_input,
                hidden_rate: hp.dropout_hidden,
                // independent mask stream per (experiment seed, step)
                rng: Pcg32::seeded(run.seed ^ 0xD80F_0A57).fork(hp.t as u64),
            })
        } else {
            None
        };
        let out = run.net.train_step(
            &mut run.params,
            &mut run.vels,
            &x,
            y,
            hp.lr,
            hp.momentum,
            hp.max_norm,
            ctrl,
            // defaults: canonical half-away rounding, fused Z/DW/DX
            // epilogues unless LPDNN_FUSED=0 (same bits either way)
            StepOptions { half: run.half, dropout, ..Default::default() },
        );
        Ok(StepOut { loss: out.loss, overflow: out.overflow })
    }

    fn eval_errors(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        n_real: usize,
    ) -> crate::Result<usize> {
        let run = self.run_mut()?;
        let x = Self::flatten_input(x, run.net.d_in())?;
        let logits = run.net.eval_logits(&run.params, &x, ctrl, RoundMode::HalfAway, run.half);
        let preds = ops::argmax_rows(&logits);
        let truth = ops::argmax_rows(y);
        crate::ensure!(n_real <= preds.len(), "n_real {n_real} > batch {}", preds.len());
        Ok(preds
            .iter()
            .zip(&truth)
            .take(n_real)
            .filter(|(p, t)| p != t)
            .count())
    }

    fn params_host(&self) -> crate::Result<Vec<Tensor>> {
        let run = self.run.as_ref().context("NativeBackend: begin_run was never called")?;
        Ok(run.params.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::FixedFormat;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::default()
    }

    #[test]
    fn begin_run_rejects_conv_models() {
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.model = "conv".into();
        c.data.dataset = "digits".into();
        let err = be.begin_run(&c).unwrap_err();
        assert!(format!("{err:#}").contains("native backend"));
        assert!(!be.supports_model("conv32"));
        assert!(be.supports_model("pi_mlp") && be.supports_model("pi_mlp_wide"));
    }

    #[test]
    fn init_quantizes_onto_storage_grid() {
        let mut be = NativeBackend::new();
        let model = be.begin_run(&cfg()).unwrap();
        let up = FixedFormat::new(12, 0);
        let ctrl = ScaleController::fixed(model.n_groups, FixedFormat::new(10, 3), up);
        let mut rng = Pcg32::seeded(3);
        be.init_state(&ctrl, &mut rng).unwrap();
        for p in be.params_host().unwrap() {
            for &v in p.data() {
                let k = v / up.step();
                assert!((k - k.round()).abs() < 1e-3, "off grid: {v}");
            }
        }
    }

    #[test]
    fn explicit_topology_overrides_the_model_and_follows_the_dataset() {
        let mut be = NativeBackend::new();
        let mut c = cfg();
        c.topology = Some(TopologySpec::mlp(vec![24, 16, 8], 2));
        c.model = c.topology.as_ref().unwrap().name.clone();
        c.data.dataset = "cifar_like".into(); // 3072-d input, 10 classes
        let model = be.begin_run(&c).unwrap();
        assert_eq!(model.n_layers, 4);
        assert_eq!(model.n_groups, 32);
        assert_eq!(model.input_shape, vec![3072]);
        assert_eq!(model.params[0].shape, vec![2, 3072, 24]);
        let ctrl =
            ScaleController::fixed(model.n_groups, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(5);
        be.init_state(&ctrl, &mut rng).unwrap();
        // one step end to end on the dataset-shaped input
        let n = model.train_batch;
        let x = Tensor::from_vec(
            &[n, 32, 32, 3],
            (0..n * 3072).map(|_| rng.uniform()).collect(),
        );
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
        let y = ops::one_hot(&labels, 10);
        let hp = StepParams {
            lr: 0.1,
            momentum: 0.5,
            max_norm: 0.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            t: 0,
        };
        let out = be.train_step(&ctrl, &x, &y, &hp).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[32, 3]);
    }

    #[test]
    fn methods_before_begin_run_fail_cleanly() {
        let mut be = NativeBackend::new();
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut rng = Pcg32::seeded(1);
        assert!(be.init_state(&ctrl, &mut rng).is_err());
        assert!(be.params_host().is_err());
    }
}
