//! Host [`Tensor`] ↔ XLA [`Literal`] conversion helpers.

use xla::Literal;

use crate::tensor::Tensor;

/// Convert a host tensor to an f32 literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> crate::Result<Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(t.data()).reshape(&dims)?)
}

/// Convert a flat f32 slice + shape to a literal.
pub fn slice_to_literal(data: &[f32], shape: &[usize]) -> crate::Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Scalar f32 literal.
pub fn scalar(v: f32) -> Literal {
    Literal::from(v)
}

/// Convert a literal back to a host tensor (f32 only).
pub fn literal_to_tensor(l: &Literal) -> crate::Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Extract the f32 scalar held by a literal.
pub fn literal_to_scalar(l: &Literal) -> crate::Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.shape(), t.shape());
        assert_eq!(back.data(), t.data());
    }

    #[test]
    fn scalar_roundtrip() {
        let l = scalar(3.25);
        assert_eq!(literal_to_scalar(&l).unwrap(), 3.25);
    }
}
