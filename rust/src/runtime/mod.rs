//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the bridge between L3 (this crate) and the compiled L2/L1
//! graphs: a thin, typed wrapper over the `xla` crate's PJRT CPU client.
//!
//! * [`Engine`] — one PJRT client per process (creation is expensive).
//! * [`Executable`] — a compiled artifact + its manifest metadata; `run`
//!   takes inputs in manifest order and returns the flattened output
//!   tuple (the L2 graphs are lowered with `return_tuple=True`).
//! * [`manifest`] — the typed `manifest.json` view.
//! * [`literal_util`] — host tensor ↔ literal conversion.
//!
//! Interchange is HLO *text* (never serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod literal_util;
pub mod manifest;

pub use manifest::{ArtifactInfo, Manifest, ModelInfo, ParamSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Context;
use xla::{HloModuleProto, Literal, PjRtClient, XlaComputation};

/// Process-wide PJRT client wrapper with a compile cache: sweeps run tens
/// of experiments over the same handful of artifacts, and XLA compilation
/// costs seconds per artifact.
pub struct Engine {
    client: PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> crate::Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact (uncached).
    pub fn load(&self, info: &ArtifactInfo) -> crate::Result<Executable> {
        let proto = HloModuleProto::from_text_file(
            info.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", info.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", info.key))?;
        Ok(Executable { exe, info: info.clone() })
    }

    /// Load + compile with memoization on the artifact key.
    pub fn load_cached(&self, info: &ArtifactInfo) -> crate::Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(&info.key) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(self.load(info)?);
        self.cache.borrow_mut().insert(info.key.clone(), exe.clone());
        Ok(exe)
    }
}

/// A compiled artifact, executable with manifest-ordered inputs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    info: ArtifactInfo,
}

impl Executable {
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    /// Execute with inputs in manifest order; returns the output tuple
    /// elements in manifest order. Accepts owned or borrowed literals, so
    /// the trainer can feed the previous step's outputs back without
    /// host-side copies.
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        inputs: &[L],
    ) -> crate::Result<Vec<Literal>> {
        anyhow::ensure!(
            inputs.len() == self.info.inputs.len(),
            "artifact {} expects {} inputs, got {} (order: {:?})",
            self.info.key,
            self.info.inputs.len(),
            inputs.len(),
            self.info.inputs
        );
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.info.key))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching outputs")?
            .to_tuple()
            .context("untupling outputs")?;
        anyhow::ensure!(
            tuple.len() == self.info.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.info.key,
            tuple.len(),
            self.info.outputs.len()
        );
        Ok(tuple)
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> crate::Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("output '{name}' not in {}", self.info.key))
    }
}
