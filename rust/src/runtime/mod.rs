//! Execution runtime: the pluggable [`Backend`] trait, the [`BackendSpec`]
//! description that builds backends, and the two implementations.
//!
//! The coordinator ([`crate::coordinator::Session`]) is backend-agnostic:
//! it owns dataset synthesis, schedules, the scale controller and the
//! minibatch loop, and delegates every numeric step to a [`Backend`]:
//!
//! * [`NativeBackend`] (`native`, the default) — the pure-Rust golden
//!   model promoted to a first-class training engine. Full maxout
//!   forward/backward with every per-signal quantization hook, momentum
//!   updates, overflow statistics, float16 simulation and host-side
//!   dropout, running on the blocked/parallel matmul kernels. Needs no
//!   artifacts, no Python, no external crates.
//! * `PjrtBackend` (`pjrt`, behind the `pjrt` cargo feature) — loads
//!   AOT artifacts (HLO text) and executes them on the `xla` crate's
//!   PJRT CPU client. Model state lives device-side as literals; each
//!   step's outputs feed the next step's inputs without host round-trips.
//!
//! Both backends initialize from the same [`manifest::ModelInfo`] specs
//! (manifest-loaded or [`manifest::ModelInfo::builtin`]), quantize initial
//! parameters onto the same storage grids, and report the same
//! `[n_groups, 3]` overflow matrix to the scale controller — so sweep
//! results are comparable across backends (DESIGN.md §Backends,
//! EXPERIMENTS.md §Experiment index).

pub mod manifest;
pub mod native;
pub mod spec;

#[cfg(feature = "pjrt")]
pub mod literal_util;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{ArtifactInfo, Manifest, ModelInfo, ParamSpec};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, Executable, PjrtBackend};
pub use spec::BackendSpec;

use crate::config::ExperimentConfig;
use crate::coordinator::ScaleController;
use crate::tensor::ops::GemmSiteCounts;
use crate::tensor::{Pcg32, Tensor};

/// Per-step hyperparameters the trainer hands a backend (the schedules
/// live in the trainer; backends only see the step's resolved values,
/// plus `t` for dropout-mask seeding).
#[derive(Clone, Copy, Debug)]
pub struct StepParams {
    pub lr: f32,
    pub momentum: f32,
    pub max_norm: f32,
    /// Input-layer dropout rate (0 = off).
    pub dropout_input: f32,
    /// Hidden-layer dropout rate (0 = off).
    pub dropout_hidden: f32,
    /// Step index within the run (dropout seeding + diagnostics).
    pub t: usize,
}

/// One train step's observable outputs.
#[derive(Debug)]
pub struct StepOut {
    pub loss: f32,
    /// `[n_groups, 3]` overflow matrix (n_over, n_half, n_total columns).
    pub overflow: Tensor,
}

/// A training execution engine. One backend instance serves many runs
/// sequentially (sweeps reuse compile caches across runs); `begin_run`
/// resets the per-run state.
pub trait Backend {
    /// Short identifier ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Can this backend run the *named* builtin/manifest model?
    /// ([`NativeBackend`] runs every builtin topology — the maxout MLPs
    /// and the conv nets, im2col-lowered; the pjrt backend whatever its
    /// manifest declares.) Name-based gating only: a config carrying an
    /// explicit [`TopologySpec`](crate::config::TopologySpec) is always
    /// runnable on the native backend regardless of its model label —
    /// `begin_run` is the authoritative check.
    fn supports_model(&self, model: &str) -> bool;

    /// Resolve model metadata and prepare executables for this config.
    /// Must be called before any other stateful method.
    fn begin_run(&mut self, cfg: &ExperimentConfig) -> crate::Result<ModelInfo>;

    /// (Re)initialize parameters and velocities from the model's init
    /// specs, quantized onto each group's storage grid under `ctrl`.
    fn init_state(&mut self, ctrl: &ScaleController, rng: &mut Pcg32) -> crate::Result<()>;

    /// One SGD step on minibatch `(x, y)`; `x` arrives in dataset layout
    /// `[batch, ...example_shape]` and is reinterpreted per the model's
    /// input shape. Mutates the backend-held state.
    fn train_step(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        hp: &StepParams,
    ) -> crate::Result<StepOut>;

    /// Number of misclassified examples among the first `n_real` of the
    /// eval batch `(x, y)` (the tail may be wrap-padding).
    fn eval_errors(
        &mut self,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        n_real: usize,
    ) -> crate::Result<usize>;

    /// Current parameters as host tensors in manifest order (testing and
    /// inspection; the PJRT backend fetches from the device).
    fn params_host(&self) -> crate::Result<Vec<Tensor>>;

    /// Replace the run's parameters with host tensors in manifest order
    /// (checkpoint restore). Values are adopted verbatim — they are
    /// expected to already sit on their storage grids — and optimizer
    /// velocities reset to zero. Backends that keep state device-side
    /// may not support importing host tensors; the default refuses.
    fn load_params(&mut self, params: Vec<Tensor>) -> crate::Result<()> {
        let _ = params;
        crate::bail!("backend '{}' does not support loading host parameters", self.name())
    }

    /// Per-site GEMM lowering-outcome counters of the current run,
    /// keyed `"<layer>.<site>"` — the report's `int_gemm_sites`
    /// section. Backends without a layer graph (or before `begin_run`)
    /// report nothing.
    fn int_gemm_sites(&self) -> std::collections::BTreeMap<String, GemmSiteCounts> {
        std::collections::BTreeMap::new()
    }
}
