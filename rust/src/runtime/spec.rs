//! [`BackendSpec`]: a cloneable, thread-safe *description* of how to
//! build a backend.
//!
//! A [`Backend`](super::Backend) instance is stateful and deliberately
//! not `Send` (the PJRT backend holds device buffers and an `Rc`-based
//! compile cache), so it cannot be handed across threads. The spec is
//! the opposite: a plain value (`BackendKind` + artifact location) that
//! IS `Send + Sync + Clone`, so a parallel sweep can ship one spec to
//! every worker and let each worker construct its own engine
//! ([`crate::coordinator::Session`] does exactly that).
//!
//! This replaces the old free function `create_backend(kind)`: the kind
//! alone was not enough to describe a backend once artifact directories
//! entered the picture, and a bare `BackendKind` could not grow new
//! fields without breaking every call site.

use std::path::PathBuf;

use super::{Backend, NativeBackend};
use crate::config::BackendKind;

/// How to build a [`Backend`]. Cheap to clone, safe to send across
/// threads; each [`create`](BackendSpec::create) call returns a fresh,
/// independent engine.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    kind: BackendKind,
    /// Artifacts directory for the PJRT backend. `None` means
    /// [`Manifest::default_dir`](super::Manifest::default_dir)
    /// (`$LPDNN_ARTIFACTS` or `<crate root>/artifacts`).
    artifacts_dir: Option<PathBuf>,
    /// Data-parallel worker count for the native backend's train steps
    /// (`--dp-workers`). `None` defers to `LPDNN_DP_WORKERS` (unset =
    /// 1); bit-identical at any value, so this is purely a wall-clock
    /// knob. The PJRT backend ignores it.
    dp_workers: Option<usize>,
}

impl BackendSpec {
    /// Spec for `kind` with default artifact resolution.
    pub fn new(kind: BackendKind) -> BackendSpec {
        BackendSpec { kind, artifacts_dir: None, dp_workers: None }
    }

    /// The self-contained pure-Rust backend (no artifacts needed).
    pub fn native() -> BackendSpec {
        BackendSpec::new(BackendKind::Native)
    }

    /// Spec for the backend named by `LPDNN_BACKEND` (unset = native).
    pub fn from_env() -> crate::Result<BackendSpec> {
        Ok(BackendSpec::new(BackendKind::from_env()?))
    }

    /// Override the artifacts directory (PJRT backend only; the native
    /// backend ignores it).
    pub fn with_artifacts_dir(mut self, dir: impl Into<PathBuf>) -> BackendSpec {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Pin the native backend's data-parallel worker count (overrides
    /// `LPDNN_DP_WORKERS`). Training bits are identical at any value.
    pub fn with_dp_workers(mut self, n: usize) -> BackendSpec {
        self.dp_workers = Some(n.max(1));
        self
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// Short backend name ("native" / "pjrt") without constructing one.
    pub fn label(&self) -> &'static str {
        self.kind.label()
    }

    /// Construct a fresh backend from this description. The PJRT
    /// backend is only available when the crate is built with
    /// `--features pjrt`.
    pub fn create(&self) -> crate::Result<Box<dyn Backend>> {
        match self.kind {
            BackendKind::Native => {
                let mut be = NativeBackend::new();
                if let Some(n) = self.dp_workers {
                    be = be.with_dp_workers(n);
                }
                Ok(Box::new(be))
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                let dir = self
                    .artifacts_dir
                    .clone()
                    .unwrap_or_else(super::Manifest::default_dir);
                let manifest = super::Manifest::load(dir)?;
                Ok(Box::new(super::PjrtBackend::new(manifest)?))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => crate::bail!(
                "this build has no PJRT support — rebuild with `--features pjrt` \
                 (and provide the xla crate, see rust/Cargo.toml) or use the \
                 native backend"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The whole point of the spec: sweep workers can share and ship it.
    fn assert_send_sync<T: Send + Sync + Clone>() {}

    #[test]
    fn spec_is_send_sync_clone() {
        assert_send_sync::<BackendSpec>();
    }

    #[test]
    fn native_spec_creates_native_backend() {
        let spec = BackendSpec::native();
        assert_eq!(spec.kind(), BackendKind::Native);
        assert_eq!(spec.label(), "native");
        let backend = spec.create().unwrap();
        assert_eq!(backend.name(), "native");
        // every create() call is an independent engine
        let again = spec.create().unwrap();
        assert_eq!(again.name(), "native");
    }

    #[test]
    fn dp_workers_override_is_recorded_and_floored() {
        let spec = BackendSpec::native().with_dp_workers(4);
        assert_eq!(spec.dp_workers, Some(4));
        // zero is nonsense; the builder floors it to serial
        assert_eq!(BackendSpec::native().with_dp_workers(0).dp_workers, Some(1));
        assert_eq!(BackendSpec::native().dp_workers, None);
    }

    #[test]
    fn artifacts_dir_override_is_recorded() {
        let spec = BackendSpec::new(BackendKind::Pjrt).with_artifacts_dir("/tmp/arts");
        assert_eq!(spec.kind(), BackendKind::Pjrt);
        assert_eq!(spec.artifacts_dir.as_deref(), Some(std::path::Path::new("/tmp/arts")));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_cleanly_without_feature() {
        let err = BackendSpec::new(BackendKind::Pjrt).create().unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }
}
