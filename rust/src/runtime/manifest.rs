//! Typed view of `artifacts/manifest.json` (written by `compile/aot.py`)
//! plus the built-in topologies the native backend runs without any
//! artifacts at all.
//!
//! For the PJRT path the manifest is the single source of truth for
//! everything the rust side must know about the compiled graphs: model
//! topologies, parameter specs (shape + init + group), scaling-factor
//! group tables, and the exact input/output orderings of each artifact.
//! [`ModelInfo::builtin`] mirrors the maxout-MLP entries of that manifest
//! so the self-contained [`crate::runtime::NativeBackend`] can construct
//! identical state on a machine that has never run `make artifacts`
//! (DESIGN.md §Backends).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::Context;

use crate::config::json;
use crate::config::TopologySpec;
use crate::tensor::init::InitSpec;
use crate::tensor::Shape;

/// Signal kinds — must match python `compile/formats.py` exactly.
pub const KIND_NAMES: [&str; 8] = ["w", "b", "z", "h", "dw", "db", "dz", "dh"];
pub const N_KINDS: usize = 8;
pub const KIND_W: usize = 0;
pub const KIND_B: usize = 1;
pub const KIND_Z: usize = 2;
pub const KIND_H: usize = 3;
pub const KIND_DW: usize = 4;
pub const KIND_DB: usize = 5;
pub const KIND_DZ: usize = 6;
pub const KIND_DH: usize = 7;

/// Kinds stored at the parameter-update bit-width (paper section 6).
pub const UPDATE_KINDS: [usize; 2] = [KIND_W, KIND_B];

/// Flat scaling-factor group index (must match formats.group_index).
pub fn group_index(layer: usize, kind: usize) -> usize {
    layer * N_KINDS + kind
}

/// One parameter tensor's metadata.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub layer: usize,
    /// "w" or "b".
    pub kind: String,
    pub init: InitSpec,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The scaling-factor group this parameter is stored under.
    pub fn group(&self) -> usize {
        group_index(self.layer, if self.kind == "w" { KIND_W } else { KIND_B })
    }
}

/// One model's metadata.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub n_layers: usize,
    pub n_groups: usize,
    pub group_names: Vec<String>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub n_classes: usize,
    pub params: Vec<ParamSpec>,
}

impl ModelInfo {
    /// Realize a [`TopologySpec`] against a data source's signal
    /// [`Shape`]: parameter specs in manifest order
    /// (`w0 b0 w1 b1 ... wH bH`, conv stages first), layer-major group
    /// tables, Glorot init for weights — the same conventions
    /// `python/compile/model.py` uses, generalized to any topology. Conv
    /// stage weights are the im2col-lowered `[k, ksize²·C_in, C_out]`
    /// slabs (fan-in/fan-out matching L2's HWIO Glorot). The graph
    /// executor ([`crate::golden::Network`]) builds its layers from the
    /// same spec, so state order and group indexing agree by
    /// construction. Errors are topology/dataset mismatches (conv over a
    /// flat source, over-pooling).
    pub fn from_topology_shaped(
        spec: &TopologySpec,
        in_shape: &Shape,
        n_classes: usize,
    ) -> crate::Result<ModelInfo> {
        // same hard invariant as Network::from_topology_shaped
        assert!(
            !(spec.conv.is_empty() && spec.hidden.is_empty()),
            "topology needs >= 1 conv stage or hidden layer"
        );
        let n_layers = spec.n_layers();
        let w = |l: usize, shape: Vec<usize>, fan_in: usize, fan_out: usize| ParamSpec {
            name: format!("l{l}.w"),
            shape,
            layer: l,
            kind: "w".into(),
            init: InitSpec::GlorotUniform { fan_in, fan_out },
        };
        let b = |l: usize, shape: Vec<usize>| ParamSpec {
            name: format!("l{l}.b"),
            shape,
            layer: l,
            kind: "b".into(),
            init: InitSpec::Zeros,
        };
        let mut params = Vec::with_capacity(2 * n_layers);
        let mut shape = *in_shape;
        let mut l = 0;
        for cs in &spec.conv {
            let Shape::Spatial { c, .. } = shape else {
                crate::bail!(
                    "topology '{}': conv stage l{l} needs a spatial input, got {shape} \
                     (conv topologies require an image dataset)",
                    spec.name
                );
            };
            let plen = cs.ksize * cs.ksize * c;
            // L2's HWIO Glorot fans: in = ks²·C_in, out = ks²·C_out
            params.push(w(
                l,
                vec![spec.k, plen, cs.channels],
                plen,
                cs.ksize * cs.ksize * cs.channels,
            ));
            params.push(b(l, vec![spec.k, cs.channels]));
            shape = cs.out_shape(&shape).map_err(|e| {
                crate::err!("topology '{}' does not fit input {in_shape}: {e}", spec.name)
            })?;
            l += 1;
        }
        let mut prev = shape.len();
        for &units in &spec.hidden {
            params.push(w(l, vec![spec.k, prev, units], prev, units));
            params.push(b(l, vec![spec.k, units]));
            prev = units;
            l += 1;
        }
        params.push(w(l, vec![prev, n_classes], prev, n_classes));
        params.push(b(l, vec![n_classes]));

        let mut group_names = Vec::with_capacity(n_layers * N_KINDS);
        for layer in 0..n_layers {
            for kind in KIND_NAMES {
                group_names.push(format!("l{layer}.{kind}"));
            }
        }
        Ok(ModelInfo {
            name: spec.name.clone(),
            input_shape: in_shape.dims(),
            n_layers,
            n_groups: n_layers * N_KINDS,
            group_names,
            train_batch: spec.train_batch,
            eval_batch: spec.eval_batch,
            n_classes,
            params,
        })
    }

    /// Realize an MLP topology against a flat input width (the legacy
    /// entry point; conv stages need
    /// [`ModelInfo::from_topology_shaped`]).
    pub fn from_topology(spec: &TopologySpec, d_in: usize, n_classes: usize) -> ModelInfo {
        assert!(
            spec.conv.is_empty(),
            "topology '{}' has conv stages: realize it with from_topology_shaped",
            spec.name
        );
        ModelInfo::from_topology_shaped(spec, &Shape::Flat(d_in), n_classes)
            .expect("MLP topologies realize against any flat input")
    }

    /// Built-in topologies for the native backend — the same models
    /// `python/compile/model.py` declares, so manifest order, group
    /// indexing and init specs line up with the compiled artifacts
    /// (which pin the datasets' dimensions: 784/10 for the MLPs,
    /// 28×28×1 for `conv`, 32×32×3 for `conv32`/`pi_conv`). Note the
    /// conv weight *layout* differs deliberately: the manifest stores
    /// L2's HWIO `[ks, ks, C_in, k·C_out]`, the native graph the
    /// im2col-lowered `[k, ks²·C_in, C_out]` slab. Dataset-aware
    /// callers should prefer [`ModelInfo::from_topology_shaped`] with
    /// [`crate::data::dataset_shape`].
    pub fn builtin(name: &str) -> Option<ModelInfo> {
        let spec = TopologySpec::builtin(name)?;
        let in_shape = match name {
            "conv" => Shape::Spatial { h: 28, w: 28, c: 1 },
            "conv32" | "pi_conv" => Shape::Spatial { h: 32, w: 32, c: 3 },
            _ => Shape::Flat(784),
        };
        Some(
            ModelInfo::from_topology_shaped(&spec, &in_shape, 10)
                .expect("builtin topologies realize against their pinned dims"),
        )
    }
}

/// One compiled artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub key: String,
    pub file: PathBuf,
    pub model: String,
    /// "fixed" | "half"
    pub mode: String,
    /// "train" | "eval"
    pub graph: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let doc = json::parse(&text).context("parsing manifest.json")?;

        let version = doc.get("version")?.as_i64()?;
        crate::ensure!(version == 1, "unsupported manifest version {version}");

        let mut models = BTreeMap::new();
        for (name, m) in doc.get("models")?.as_object()? {
            let mut params = Vec::new();
            for p in m.get("params")?.as_array()? {
                let init = match p.get("init")?.as_str()? {
                    "zeros" => InitSpec::Zeros,
                    "glorot_uniform" => InitSpec::GlorotUniform {
                        fan_in: p.get("fan_in")?.as_usize()?,
                        fan_out: p.get("fan_out")?.as_usize()?,
                    },
                    other => crate::bail!("unknown init '{other}'"),
                };
                params.push(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_usize_vec()?,
                    layer: p.get("layer")?.as_usize()?,
                    kind: p.get("kind")?.as_str()?.to_string(),
                    init,
                });
            }
            let info = ModelInfo {
                name: name.clone(),
                input_shape: m.get("input_shape")?.as_usize_vec()?,
                n_layers: m.get("n_layers")?.as_usize()?,
                n_groups: m.get("n_groups")?.as_usize()?,
                group_names: m.get("group_names")?.as_str_vec()?,
                train_batch: m.get("train_batch")?.as_usize()?,
                eval_batch: m.get("eval_batch")?.as_usize()?,
                n_classes: m.get("n_classes")?.as_usize()?,
                params,
            };
            crate::ensure!(
                info.n_groups == info.n_layers * N_KINDS,
                "group table mismatch for model {name}"
            );
            crate::ensure!(
                info.group_names.len() == info.n_groups,
                "group names mismatch for model {name}"
            );
            models.insert(name.clone(), info);
        }

        let mut artifacts = BTreeMap::new();
        for (key, a) in doc.get("artifacts")?.as_object()? {
            let info = ArtifactInfo {
                key: key.clone(),
                file: dir.join(a.get("file")?.as_str()?),
                model: a.get("model")?.as_str()?.to_string(),
                mode: a.get("mode")?.as_str()?.to_string(),
                graph: a.get("graph")?.as_str()?.to_string(),
                inputs: a.get("inputs")?.as_str_vec()?,
                outputs: a.get("outputs")?.as_str_vec()?,
            };
            crate::ensure!(
                models.contains_key(&info.model),
                "artifact {key} references unknown model {}",
                info.model
            );
            crate::ensure!(info.file.exists(), "artifact file missing: {:?}", info.file);
            artifacts.insert(key.clone(), info);
        }

        Ok(Manifest { dir, models, artifacts })
    }

    /// Locate the default artifacts directory (`$LPDNN_ARTIFACTS` or
    /// `<crate root>/artifacts`).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("LPDNN_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn model(&self, name: &str) -> crate::Result<&ModelInfo> {
        self.models.get(name).with_context(|| format!("model '{name}' not in manifest"))
    }

    /// Artifact for (model, mode, graph), e.g. ("pi_mlp", "fixed", "train").
    pub fn artifact(&self, model: &str, mode: &str, graph: &str) -> crate::Result<&ArtifactInfo> {
        let key = format!("{model}_{mode}_{graph}");
        self.artifacts.get(&key).with_context(|| format!("artifact '{key}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_built_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        let pi = m.model("pi_mlp").unwrap();
        assert_eq!(pi.n_layers, 3);
        assert_eq!(pi.n_groups, 24);
        assert_eq!(pi.input_shape, vec![784]);
        assert_eq!(pi.params.len(), 6);
        assert_eq!(pi.params[0].name, "l0.w");
        assert!(matches!(pi.params[0].init, InitSpec::GlorotUniform { fan_in: 784, .. }));
        assert_eq!(pi.params[0].group(), 0);
        assert_eq!(pi.params[1].group(), 1); // l0.b → group 1

        let art = m.artifact("pi_mlp", "fixed", "train").unwrap();
        assert_eq!(art.inputs.len(), 12 + 9);
        assert_eq!(art.outputs.last().unwrap(), "overflow");
        assert!(art.file.exists());
    }

    #[test]
    fn topology_realization_generalizes_the_builtin() {
        use crate::config::TopologySpec;
        // the builtin must be exactly pi_mlp realized at the MNIST dims
        let from_spec =
            ModelInfo::from_topology(&TopologySpec::builtin("pi_mlp").unwrap(), 784, 10);
        let builtin = ModelInfo::builtin("pi_mlp").unwrap();
        assert_eq!(from_spec.params.len(), builtin.params.len());
        for (a, b) in from_spec.params.iter().zip(&builtin.params) {
            assert_eq!((a.name.clone(), a.shape.clone()), (b.name.clone(), b.shape.clone()));
        }
        assert_eq!(from_spec.group_names, builtin.group_names);

        // a non-square depth-3 topology against a non-MNIST data source
        let spec = TopologySpec::mlp(vec![64, 32, 16], 2);
        let m = ModelInfo::from_topology(&spec, 3072, 10);
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.n_groups, 32);
        assert_eq!(m.params.len(), 8);
        assert_eq!(m.params[0].shape, vec![2, 3072, 64]); // l0.w
        assert_eq!(m.params[2].shape, vec![2, 64, 32]); // l1.w
        assert_eq!(m.params[4].shape, vec![2, 32, 16]); // l2.w
        assert_eq!(m.params[6].shape, vec![16, 10]); // head w
        assert_eq!(m.params[7].shape, vec![10]); // head b
        assert_eq!(m.params[6].group(), group_index(3, KIND_W));
        assert_eq!(m.group_names[31], "l3.dh");
        assert_eq!(m.input_shape, vec![3072]);
    }

    #[test]
    fn conv_topology_realizes_im2col_slabs_against_the_shape() {
        use crate::config::TopologySpec;
        let spec = TopologySpec::builtin("pi_conv").unwrap();
        let m = ModelInfo::from_topology_shaped(
            &spec,
            &Shape::Spatial { h: 32, w: 32, c: 3 },
            10,
        )
        .unwrap();
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.n_groups, 32);
        assert_eq!(m.input_shape, vec![32, 32, 3]);
        assert_eq!(m.params.len(), 8);
        // stage 0: 5x5 over 3 channels -> [k, 75, 16]
        assert_eq!(m.params[0].shape, vec![2, 75, 16]);
        assert!(matches!(
            m.params[0].init,
            InitSpec::GlorotUniform { fan_in: 75, fan_out: 400 }
        ));
        assert_eq!(m.params[1].shape, vec![2, 16]);
        // stage 2 runs at 8x8 over 16 channels -> [k, 400, 24]
        assert_eq!(m.params[4].shape, vec![2, 400, 24]);
        // head consumes the flattened 4x4x24 = 384 map
        assert_eq!(m.params[6].shape, vec![384, 10]);
        assert_eq!(m.params[6].group(), group_index(3, KIND_W));
        // the builtin pins exactly these dims
        let b = ModelInfo::builtin("pi_conv").unwrap();
        assert_eq!(b.params[6].shape, vec![384, 10]);
        let b = ModelInfo::builtin("conv").unwrap();
        assert_eq!(b.input_shape, vec![28, 28, 1]);
        // 28 -> 14 -> 7 -> 3 (VALID pool floors), 3*3*16 = 144
        assert_eq!(b.params[6].shape, vec![144, 10]);
        // conv over a flat source is a clear error
        let err =
            ModelInfo::from_topology_shaped(&spec, &Shape::Flat(3072), 10).unwrap_err();
        assert!(format!("{err:#}").contains("spatial"), "{err:#}");
    }

    #[test]
    fn group_indexing_matches_python() {
        assert_eq!(group_index(0, KIND_W), 0);
        assert_eq!(group_index(1, KIND_DZ), 14);
        assert_eq!(group_index(2, KIND_DH), 23);
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
