//! Configuration substrate: JSON + TOML-subset parsing (from scratch — the
//! offline environment has no serde) and the typed experiment schema.

pub mod experiment;
pub mod json;
pub mod toml;
pub mod topology;

pub use experiment::{Arithmetic, BackendKind, DataConfig, ExperimentConfig, TrainConfig};
pub use json::{Json, JsonError};
pub use topology::{ConvStageSpec, TopologySpec};
