//! Typed experiment configuration: what one training run looks like.
//!
//! An experiment is (model, dataset, arithmetic, schedule). The sweep
//! benches construct these programmatically; the CLI reads them from a
//! TOML-subset file (`lpdnn train --config run.toml`). All schedule
//! parameters mirror the paper's procedure (section 8.1: linearly decaying
//! learning rate, linearly saturating momentum, dropout, max-norm).

use crate::bail;
use crate::error::Context;

use super::json::Json;
use super::toml;
use super::topology::TopologySpec;
use crate::arith::FixedFormat;

/// Which execution backend runs the experiment (DESIGN.md §Backends).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust engine (default): self-contained, no artifacts needed.
    #[default]
    Native,
    /// Compiled AOT artifacts on the PJRT CPU client (`pjrt` feature).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> crate::Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend '{other}' (native|pjrt)"),
        }
    }

    /// Backend named by the `LPDNN_BACKEND` env var (benches + examples);
    /// unset means [`BackendKind::Native`], anything unrecognized is an
    /// error rather than a silent fallback.
    pub fn from_env() -> crate::Result<BackendKind> {
        match std::env::var("LPDNN_BACKEND") {
            Ok(s) => Self::parse(&s),
            Err(_) => Ok(BackendKind::Native),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which arithmetic the run trains under (paper sections 3–5).
#[derive(Clone, Debug, PartialEq)]
pub enum Arithmetic {
    /// Single precision floating point — the reference (step = 0 sentinel).
    Float32,
    /// Half precision floating point simulation (f16 round-trip artifact).
    Half,
    /// Fixed point: ONE global scaling factor for every group.
    Fixed {
        /// Computation bit-width (paper "Comp.", sign included).
        bits_comp: i32,
        /// Parameter update bit-width (paper "Up.", sign included).
        bits_up: i32,
        /// Radix point position (integer bits). Paper Figure 1 sweeps
        /// this; 5 is the optimum the paper reports.
        int_bits: i32,
    },
    /// Dynamic fixed point: per-group scaling factors updated online
    /// (paper section 5).
    Dynamic {
        bits_comp: i32,
        bits_up: i32,
        /// Maximum overflow rate (paper: 1e-4 = 0.01%).
        max_overflow_rate: f64,
        /// Update the scaling factors every this many examples
        /// (paper: 10 000).
        update_every_examples: usize,
        /// Initial integer-bit count for every group before warmup.
        init_int_bits: i32,
        /// Steps of high-precision warmup used to find initial scaling
        /// factors (paper 9.3: "we find the initial scaling factors by
        /// training with a higher precision format"); parameters are
        /// re-initialized afterwards.
        warmup_steps: usize,
    },
}

impl Arithmetic {
    /// Human-readable name matching the paper's Table 3 rows.
    pub fn label(&self) -> String {
        match self {
            Arithmetic::Float32 => "float32".into(),
            Arithmetic::Half => "float16".into(),
            Arithmetic::Fixed { bits_comp, bits_up, int_bits } => {
                format!("fixed({bits_comp}/{bits_up}@{int_bits})")
            }
            Arithmetic::Dynamic { bits_comp, bits_up, .. } => {
                format!("dynamic({bits_comp}/{bits_up})")
            }
        }
    }

    /// Which compiled artifact mode this arithmetic runs on.
    pub fn mode(&self) -> &'static str {
        match self {
            Arithmetic::Half => "half",
            _ => "fixed", // float32 uses the fixed artifact with step=0
        }
    }

    /// The initial per-kind formats `(comp_fmt, up_fmt)` for this
    /// arithmetic (None ⇒ float32 passthrough for both).
    pub fn initial_formats(&self) -> (FixedFormat, FixedFormat) {
        match *self {
            Arithmetic::Float32 | Arithmetic::Half => {
                (FixedFormat::FLOAT32, FixedFormat::FLOAT32)
            }
            Arithmetic::Fixed { bits_comp, bits_up, int_bits } => {
                (FixedFormat::new(bits_comp, int_bits), FixedFormat::new(bits_up, int_bits))
            }
            Arithmetic::Dynamic { bits_comp, bits_up, init_int_bits, .. } => (
                FixedFormat::new(bits_comp, init_int_bits),
                FixedFormat::new(bits_up, init_int_bits),
            ),
        }
    }
}

/// Training schedule (paper section 8.1 procedure, budget-scaled).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Total SGD steps.
    pub steps: usize,
    /// Learning rate: linear decay from `lr_start` to `lr_end`.
    pub lr_start: f32,
    pub lr_end: f32,
    /// Momentum: linear saturation from `mom_start` to `mom_end`.
    pub mom_start: f32,
    pub mom_end: f32,
    /// Max-norm constraint on incoming weight vectors (0 disables).
    pub max_norm: f32,
    /// Dropout rate on the input layer (paper uses 0.2 on PI MNIST).
    pub dropout_input: f32,
    /// Dropout rate on hidden layers (paper uses 0.5).
    pub dropout_hidden: f32,
    /// Master seed: datasets, init and in-graph dropout all derive from it.
    pub seed: u64,
    /// Evaluate on the test set every N steps (0 = only at the end).
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 400,
            lr_start: 0.15,
            lr_end: 0.01,
            mom_start: 0.5,
            mom_end: 0.7,
            max_norm: 3.0,
            dropout_input: 0.0,
            dropout_hidden: 0.0,
            seed: 1234,
            eval_every: 0,
        }
    }
}

impl TrainConfig {
    /// Linearly decaying learning rate at step `t` (paper 8.1).
    pub fn lr_at(&self, t: usize) -> f32 {
        schedule_linear(self.lr_start, self.lr_end, t, self.steps)
    }

    /// Linearly saturating momentum at step `t` (paper 8.1).
    pub fn momentum_at(&self, t: usize) -> f32 {
        schedule_linear(self.mom_start, self.mom_end, t, self.steps)
    }
}

fn schedule_linear(start: f32, end: f32, t: usize, total: usize) -> f32 {
    if total <= 1 {
        return end;
    }
    let frac = (t.min(total - 1)) as f32 / (total - 1) as f32;
    start + (end - start) * frac
}

/// Dataset choice + size (synthetic substitutes; DESIGN.md §Substitutions).
#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    /// "digits" | "clusters" | "cifar_like" | "svhn_like"
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig { dataset: "digits".into(), n_train: 4096, n_test: 1024 }
    }
}

/// A full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    /// "pi_mlp" | "pi_mlp_wide" | "conv" | "conv32" | "pi_conv" — all
    /// built-in topologies on the native backend (realized against the
    /// dataset's shape); for pjrt the name must exist in the manifest
    /// (`pi_conv` is native-only). When `topology` is set it overrides
    /// the model and this field is just the run's model label.
    pub model: String,
    /// Which execution backend to run on (`[experiment] backend = ...`).
    pub backend: BackendKind,
    /// Explicit maxout-MLP topology (`[topology]` table / `--topology`):
    /// the native backend realizes it against the dataset's dimensions.
    /// `None` means the model name selects a built-in topology.
    pub topology: Option<TopologySpec>,
    pub arithmetic: Arithmetic,
    pub train: TrainConfig,
    pub data: DataConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            model: "pi_mlp".into(),
            backend: BackendKind::default(),
            topology: None,
            arithmetic: Arithmetic::Float32,
            train: TrainConfig::default(),
            data: DataConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a TOML-subset document.
    pub fn from_toml_str(src: &str) -> crate::Result<Self> {
        let doc = toml::parse(src).context("parsing experiment config")?;
        Self::from_json(&doc)
    }

    /// Build from the dynamic config tree (TOML or JSON file).
    pub fn from_json(doc: &Json) -> crate::Result<Self> {
        let mut cfg = ExperimentConfig::default();

        if let Some(exp) = doc.opt("experiment") {
            if let Some(v) = exp.opt("name") {
                cfg.name = v.as_str()?.to_string();
            }
            if let Some(v) = exp.opt("model") {
                cfg.model = v.as_str()?.to_string();
            }
            if let Some(v) = exp.opt("dataset") {
                cfg.data.dataset = v.as_str()?.to_string();
            }
            if let Some(v) = exp.opt("backend") {
                cfg.backend = BackendKind::parse(v.as_str()?)?;
            }
        }
        if let Some(t) = doc.opt("topology") {
            let spec = TopologySpec::from_json(t)?;
            // a custom topology names the model unless the config already did
            if doc.opt("experiment").and_then(|e| e.opt("model")).is_none() {
                cfg.model = spec.name.clone();
            }
            cfg.topology = Some(spec);
        }
        if let Some(d) = doc.opt("data") {
            if let Some(v) = d.opt("n_train") {
                cfg.data.n_train = v.as_usize()?;
            }
            if let Some(v) = d.opt("n_test") {
                cfg.data.n_test = v.as_usize()?;
            }
            if let Some(v) = d.opt("dataset") {
                cfg.data.dataset = v.as_str()?.to_string();
            }
        }
        if let Some(a) = doc.opt("arithmetic") {
            let kind = a.opt("kind").map(|v| v.as_str()).transpose()?.unwrap_or("float32");
            let geti = |key: &str, default: i32| -> crate::Result<i32> {
                Ok(a.opt(key).map(|v| v.as_i64()).transpose()?.map(|x| x as i32).unwrap_or(default))
            };
            cfg.arithmetic = match kind {
                "float32" => Arithmetic::Float32,
                "half" | "float16" => Arithmetic::Half,
                "fixed" => Arithmetic::Fixed {
                    bits_comp: geti("bits_comp", 20)?,
                    bits_up: geti("bits_up", 20)?,
                    int_bits: geti("int_bits", 5)?,
                },
                "dynamic" => Arithmetic::Dynamic {
                    bits_comp: geti("bits_comp", 10)?,
                    bits_up: geti("bits_up", 12)?,
                    max_overflow_rate: a
                        .opt("max_overflow_rate")
                        .map(|v| v.as_f64())
                        .transpose()?
                        .unwrap_or(1e-4),
                    update_every_examples: a
                        .opt("update_every_examples")
                        .map(|v| v.as_usize())
                        .transpose()?
                        .unwrap_or(10_000),
                    init_int_bits: geti("init_int_bits", 3)?,
                    warmup_steps: a
                        .opt("warmup_steps")
                        .map(|v| v.as_usize())
                        .transpose()?
                        .unwrap_or(0),
                },
                other => bail!("unknown arithmetic kind '{other}'"),
            };
        }
        if let Some(t) = doc.opt("train") {
            let mut tc = cfg.train.clone();
            macro_rules! grab {
                ($field:ident, $conv:ident) => {
                    if let Some(v) = t.opt(stringify!($field)) {
                        tc.$field = v.as_f64()? as _;
                    }
                    let _ = stringify!($conv);
                };
            }
            grab!(lr_start, f32);
            grab!(lr_end, f32);
            grab!(mom_start, f32);
            grab!(mom_end, f32);
            grab!(max_norm, f32);
            grab!(dropout_input, f32);
            grab!(dropout_hidden, f32);
            if let Some(v) = t.opt("steps") {
                tc.steps = v.as_usize()?;
            }
            if let Some(v) = t.opt("seed") {
                tc.seed = v.as_i64()? as u64;
            }
            if let Some(v) = t.opt("eval_every") {
                tc.eval_every = v.as_usize()?;
            }
            cfg.train = tc;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the configuration before spending a training run on it.
    pub fn validate(&self) -> crate::Result<()> {
        // one source of truth for dataset existence AND geometry: conv
        // stages can only consume spatial (image) datasets
        let (data_shape, _) = crate::data::dataset_shape(&self.data.dataset)?;
        let spatial_dataset = matches!(data_shape, crate::tensor::Shape::Spatial { .. });
        if let Some(t) = &self.topology {
            // an explicit topology replaces the model whitelist: the MLP
            // graph consumes any dataset flattened to its example length,
            // and conv stages consume any spatial (image) dataset
            t.validate()?;
            if !t.conv.is_empty() && !spatial_dataset {
                bail!(
                    "topology '{}' has conv stages and needs a spatial dataset; \
                     '{}' is flat",
                    t.name,
                    self.data.dataset
                );
            }
        } else {
            if !["pi_mlp", "pi_mlp_wide", "conv", "conv32", "pi_conv"]
                .contains(&self.model.as_str())
            {
                bail!("unknown model '{}'", self.model);
            }
            let input_ok = match self.model.as_str() {
                "pi_mlp" | "pi_mlp_wide" => {
                    ["digits", "clusters"].contains(&self.data.dataset.as_str())
                }
                "conv" => self.data.dataset == "digits",
                "conv32" => ["cifar_like", "svhn_like"].contains(&self.data.dataset.as_str()),
                // the native-first conv net realizes against any image set
                "pi_conv" => spatial_dataset,
                _ => unreachable!(),
            };
            if !input_ok {
                bail!("model '{}' cannot consume dataset '{}'", self.model, self.data.dataset);
            }
        }
        if self.train.steps == 0 {
            bail!("train.steps must be > 0");
        }
        match self.arithmetic {
            Arithmetic::Fixed { bits_comp, bits_up, .. }
            | Arithmetic::Dynamic { bits_comp, bits_up, .. } => {
                for (name, b) in [("bits_comp", bits_comp), ("bits_up", bits_up)] {
                    if !(2..=31).contains(&b) {
                        bail!("{name}={b} out of range [2, 31]");
                    }
                }
            }
            _ => {}
        }
        if let Arithmetic::Dynamic { max_overflow_rate, update_every_examples, .. } =
            self.arithmetic
        {
            if !(0.0..1.0).contains(&max_overflow_rate) {
                bail!("max_overflow_rate must be in [0, 1)");
            }
            if update_every_examples == 0 {
                bail!("update_every_examples must be > 0");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_toml() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
[experiment]
name = "tbl3-dynamic"
model = "pi_mlp"
dataset = "digits"
[arithmetic]
kind = "dynamic"
bits_comp = 10
bits_up = 12
max_overflow_rate = 1e-4
update_every_examples = 10000
init_int_bits = 3
warmup_steps = 50
[train]
steps = 300
lr_start = 0.2
dropout_input = 0.2
dropout_hidden = 0.5
seed = 42
[data]
n_train = 2048
n_test = 512
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "tbl3-dynamic");
        assert_eq!(
            cfg.arithmetic,
            Arithmetic::Dynamic {
                bits_comp: 10,
                bits_up: 12,
                max_overflow_rate: 1e-4,
                update_every_examples: 10_000,
                init_int_bits: 3,
                warmup_steps: 50,
            }
        );
        assert_eq!(cfg.train.steps, 300);
        assert_eq!(cfg.train.seed, 42);
        assert_eq!(cfg.data.n_train, 2048);
    }

    #[test]
    fn parses_topology_table() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
[topology]
hidden = [32, 32, 32]
k = 2
[train]
steps = 10
"#,
        )
        .unwrap();
        let t = cfg.topology.as_ref().unwrap();
        assert_eq!(t.hidden, vec![32, 32, 32]);
        assert_eq!(t.k, 2);
        // the topology names the model when the config doesn't
        assert_eq!(cfg.model, t.name);
        // a degenerate topology is rejected at parse time
        assert!(ExperimentConfig::from_toml_str("[topology]\nhidden = []\n").is_err());
    }

    #[test]
    fn topology_composes_with_any_dataset() {
        let mut cfg = ExperimentConfig::default();
        cfg.topology = Some(crate::config::TopologySpec::mlp(vec![16, 16], 2));
        for ds in ["digits", "clusters", "cifar_like", "svhn_like"] {
            cfg.data.dataset = ds.into();
            cfg.validate().unwrap_or_else(|e| panic!("{ds}: {e:#}"));
        }
        cfg.data.dataset = "imagenet".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn conv_topologies_need_spatial_datasets() {
        let mut cfg = ExperimentConfig::default();
        cfg.topology = Some(crate::config::TopologySpec::parse_cli("c8k3p2/16x1@k2").unwrap());
        for ds in ["digits", "cifar_like", "svhn_like"] {
            cfg.data.dataset = ds.into();
            cfg.validate().unwrap_or_else(|e| panic!("{ds}: {e:#}"));
        }
        cfg.data.dataset = "clusters".into();
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("spatial"), "{err:#}");

        // the builtin conv model names follow the same matrix
        let mut cfg = ExperimentConfig::default();
        cfg.model = "pi_conv".into();
        for ds in ["digits", "cifar_like", "svhn_like"] {
            cfg.data.dataset = ds.into();
            cfg.validate().unwrap_or_else(|e| panic!("{ds}: {e:#}"));
        }
        cfg.data.dataset = "clusters".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parses_conv_topology_table() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
[topology]
k = 2
hidden = [32]

[[topology.conv]]
channels = 8
ksize = 3

[train]
steps = 5

[experiment]
dataset = "cifar_like"
"#,
        )
        .unwrap();
        let t = cfg.topology.as_ref().unwrap();
        assert_eq!(t.conv.len(), 1);
        assert_eq!((t.conv[0].channels, t.conv[0].ksize, t.conv[0].pool), (8, 3, 2));
        assert_eq!(t.hidden, vec![32]);
        // the derived conv name labels the model
        assert_eq!(cfg.model, t.name);
        // the same table over the flat dataset is rejected
        assert!(ExperimentConfig::from_toml_str(
            "[[topology.conv]]\nchannels = 8\n[experiment]\ndataset = \"clusters\"\n",
        )
        .is_err());
    }

    #[test]
    fn schedules_are_linear_and_clamped() {
        let tc = TrainConfig { steps: 101, lr_start: 1.0, lr_end: 0.0, ..Default::default() };
        assert_eq!(tc.lr_at(0), 1.0);
        assert!((tc.lr_at(50) - 0.5).abs() < 1e-6);
        assert_eq!(tc.lr_at(100), 0.0);
        assert_eq!(tc.lr_at(1000), 0.0); // clamped past the end
        let m = TrainConfig { steps: 3, mom_start: 0.5, mom_end: 0.7, ..Default::default() };
        assert_eq!(m.momentum_at(0), 0.5);
        assert!((m.momentum_at(2) - 0.7).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = ExperimentConfig::default();
        c.model = "resnet".into();
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.data.dataset = "cifar_like".into(); // pi_mlp can't consume 32x32x3
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.arithmetic = Arithmetic::Fixed { bits_comp: 1, bits_up: 20, int_bits: 5 };
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.arithmetic = Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 2.0,
            update_every_examples: 1000,
            init_int_bits: 0,
            warmup_steps: 0,
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn backend_kind_parses_and_defaults_native() {
        assert_eq!(ExperimentConfig::default().backend, BackendKind::Native);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        let cfg = ExperimentConfig::from_toml_str(
            "[experiment]\nname = \"b\"\nbackend = \"pjrt\"\n",
        )
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        assert_eq!(cfg.backend.label(), "pjrt");
    }

    #[test]
    fn arithmetic_labels_and_modes() {
        assert_eq!(Arithmetic::Float32.label(), "float32");
        assert_eq!(Arithmetic::Half.mode(), "half");
        assert_eq!(
            Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 }.mode(),
            "fixed"
        );
    }

    #[test]
    fn initial_formats_follow_arithmetic() {
        let (c, u) = Arithmetic::Float32.initial_formats();
        assert!(c.is_float32() && u.is_float32());
        let (c, u) = Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 1e-4,
            update_every_examples: 10_000,
            init_int_bits: 3,
            warmup_steps: 0,
        }
        .initial_formats();
        assert_eq!((c.total_bits, c.int_bits), (10, 3));
        assert_eq!((u.total_bits, u.int_bits), (12, 3));
    }
}
