//! From-scratch JSON parser + writer (no serde in the offline environment).
//!
//! Used for `artifacts/manifest.json` (read) and metrics/result files
//! (write). Full JSON: objects, arrays, strings with escapes (incl.
//! \uXXXX + surrogate pairs), numbers, bools, null. Not streaming — the
//! manifest is a few tens of KiB.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a BTreeMap: deterministic iteration
/// order makes written files diff-stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, path: String },
    Missing(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors (ergonomics for manifest reading)
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Object(m) => m.get(key).ok_or_else(|| JsonError::Missing(key.to_string())),
            _ => Err(JsonError::Type { expected: "object", path: key.to_string() }),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Type { expected: "number", path: String::new() }),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type { expected: "string", path: String::new() }),
        }
    }

    pub fn as_array(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Array(v) => Ok(v),
            _ => Err(JsonError::Type { expected: "array", path: String::new() }),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Object(m) => Ok(m),
            _ => Err(JsonError::Type { expected: "object", path: String::new() }),
        }
    }

    /// Array of numbers → Vec<usize> (shape fields).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Array of strings → Vec<String>.
    pub fn as_str_vec(&self) -> Result<Vec<String>, JsonError> {
        self.as_array()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }

    // ------------------------------------------------------------------
    // Writing
    // ------------------------------------------------------------------

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_number(out, *x),
            Json::Str(s) => write_string(out, s),
            Json::Array(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ----------------------------------------------------------------------
// Parsing
// ----------------------------------------------------------------------

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy raw bytes of the sequence
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"héllo😀\"").unwrap(), Json::Str("héllo😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"\\x\"", "[] []"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn writer_roundtrips_manifest_like_docs() {
        let src = r#"{"artifacts":{"k":{"bytes":212412,"file":"a.hlo.txt"}},"models":{"pi_mlp":{"n_groups":24,"names":["l0.w","l0.b"],"neg":-1.5}},"version":1}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
        // pretty form parses back too
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_range(0, 3) } else { g.usize_range(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f32_range(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_range(0, 10))
                    .map(|_| *g.choose(&['a', '"', '\\', 'é', '\n', '😀', 'z']))
                    .collect(),
            ),
            4 => Json::Array((0..g.usize_range(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Object(
                (0..g.usize_range(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn property_roundtrip() {
        forall("json roundtrip", |g: &mut Gen| {
            let v = gen_json(g, 3);
            let s = v.to_string();
            let back = parse(&s).unwrap_or_else(|e| panic!("reparse failed: {e}\n{s}"));
            assert_eq!(back, v, "doc: {s}");
        });
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"shape": [4, 784, 128], "names": ["a", "b"]}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().as_usize_vec().unwrap(), vec![4, 784, 128]);
        assert_eq!(v.get("names").unwrap().as_str_vec().unwrap(), vec!["a", "b"]);
        assert!(matches!(v.get("nope"), Err(JsonError::Missing(_))));
    }
}
