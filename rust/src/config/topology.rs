//! [`TopologySpec`]: model topology as *data* instead of code.
//!
//! The paper trains several maxout topologies — PI-MLPs of varying
//! depth/width on MNIST plus deeper nets for CIFAR-10/SVHN — and the
//! precision effects it studies are depth-dependent. A `TopologySpec`
//! describes one maxout-MLP topology (hidden widths + pieces-per-unit)
//! without pinning the input/output dimensions: those are derived from
//! the dataset when the spec is *realized* into a
//! [`ModelInfo`](crate::runtime::ModelInfo) and a
//! [`Network`](crate::golden::Network), so the same spec composes with
//! any data source.
//!
//! Specs come from three places, all producing the same type:
//!
//! * the built-in names (`pi_mlp`, `pi_mlp_wide`) that mirror the
//!   compiled manifest's models ([`TopologySpec::builtin`]),
//! * a `[topology]` table in the experiment TOML/JSON config
//!   ([`TopologySpec::from_json`], round-tripped by
//!   [`TopologySpec::to_json`]),
//! * the CLI's `--topology` flag ([`TopologySpec::parse_cli`]):
//!   a builtin name, `WIDTHxDEPTH` (e.g. `128x3`), or a comma list of
//!   widths (e.g. `256,128`), optionally suffixed `@kN` to set the
//!   maxout piece count (e.g. `128x3@k2`).

use crate::bail;

use super::json::Json;

/// One maxout-MLP topology: hidden layer widths + maxout pieces. The
/// input/output dimensions are *not* part of the spec — they come from
/// the dataset at realization time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopologySpec {
    /// Model name used in configs, reports and manifest lookups.
    pub name: String,
    /// Hidden maxout layer widths, input side first (e.g. `[128, 128]`).
    pub hidden: Vec<usize>,
    /// Maxout pieces per hidden unit (paper: 4 on PI MNIST).
    pub k: usize,
    /// Training minibatch size.
    pub train_batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
}

impl TopologySpec {
    /// A custom maxout MLP with the default batch sizes and a derived
    /// name (`mlp-<w1>x<w2>...-k<k>`).
    pub fn mlp(hidden: Vec<usize>, k: usize) -> TopologySpec {
        let widths: Vec<String> = hidden.iter().map(|u| u.to_string()).collect();
        TopologySpec {
            name: format!("mlp-{}-k{k}", widths.join("x")),
            hidden,
            k,
            train_batch: 64,
            eval_batch: 256,
        }
    }

    /// The built-in topologies — the same maxout MLPs
    /// `python/compile/model.py` declares, so graph-built state lines up
    /// with the compiled artifacts. `None` for unknown names (the conv
    /// nets exist only as compiled graphs and have no spec).
    pub fn builtin(name: &str) -> Option<TopologySpec> {
        let units = match name {
            "pi_mlp" => 128,
            // paper 9.2/9.3 width ablation: double the hidden units
            "pi_mlp_wide" => 256,
            _ => return None,
        };
        Some(TopologySpec {
            name: name.to_string(),
            hidden: vec![units, units],
            k: 4,
            train_batch: 64,
            eval_batch: 256,
        })
    }

    /// Parse the CLI `--topology` value: a builtin name, `WIDTHxDEPTH`
    /// (`128x3`), or comma-separated widths (`256,128`), optionally
    /// suffixed `@kN` (`128x3@k2`).
    pub fn parse_cli(s: &str) -> crate::Result<TopologySpec> {
        if let Some(t) = TopologySpec::builtin(s) {
            return Ok(t);
        }
        let (body, k) = match s.split_once('@') {
            Some((body, ksuf)) => {
                let Some(kstr) = ksuf.strip_prefix('k') else {
                    bail!("--topology '{s}': expected '@k<N>' suffix, got '@{ksuf}'");
                };
                let k: usize = kstr
                    .parse()
                    .map_err(|e| crate::err!("--topology '{s}': bad k '{kstr}': {e}"))?;
                (body, k)
            }
            None => (s, 4),
        };
        let parse_width = |w: &str| -> crate::Result<usize> {
            w.parse().map_err(|e| crate::err!("--topology '{s}': bad width '{w}': {e}"))
        };
        let hidden: Vec<usize> = if let Some((w, d)) = body.split_once('x') {
            let w = parse_width(w)?;
            let d: usize = d
                .parse()
                .map_err(|e| crate::err!("--topology '{s}': bad depth '{d}': {e}"))?;
            crate::ensure!(d >= 1, "--topology '{s}': depth must be >= 1");
            vec![w; d]
        } else {
            body.split(',')
                .map(|w| parse_width(w.trim()))
                .collect::<crate::Result<Vec<usize>>>()?
        };
        let spec = TopologySpec::mlp(hidden, k);
        spec.validate()?;
        Ok(spec)
    }

    /// Build from a config tree's `[topology]` table (TOML or JSON).
    pub fn from_json(doc: &Json) -> crate::Result<TopologySpec> {
        let hidden = doc
            .opt("hidden")
            .map(|v| v.as_usize_vec())
            .transpose()?
            .unwrap_or_else(|| vec![128, 128]);
        let k = doc.opt("k").map(|v| v.as_usize()).transpose()?.unwrap_or(4);
        let mut spec = TopologySpec::mlp(hidden, k);
        if let Some(v) = doc.opt("name") {
            spec.name = v.as_str()?.to_string();
        }
        if let Some(v) = doc.opt("train_batch") {
            spec.train_batch = v.as_usize()?;
        }
        if let Some(v) = doc.opt("eval_batch") {
            spec.eval_batch = v.as_usize()?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the dynamic config tree; `from_json` of the result
    /// reproduces the spec exactly (round-trip tested).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert(
            "hidden".to_string(),
            Json::Array(self.hidden.iter().map(|&u| Json::Num(u as f64)).collect()),
        );
        m.insert("k".to_string(), Json::Num(self.k as f64));
        m.insert("train_batch".to_string(), Json::Num(self.train_batch as f64));
        m.insert("eval_batch".to_string(), Json::Num(self.eval_batch as f64));
        Json::Object(m)
    }

    /// Number of compute layers (hidden maxout layers + softmax head) —
    /// the graph's scaling-group row count.
    pub fn n_layers(&self) -> usize {
        self.hidden.len() + 1
    }

    /// Sanity-check before spending a training run on it.
    pub fn validate(&self) -> crate::Result<()> {
        if self.hidden.is_empty() {
            bail!("topology '{}' has no hidden layers", self.name);
        }
        if self.hidden.len() > 16 {
            bail!("topology '{}': {} hidden layers (max 16)", self.name, self.hidden.len());
        }
        for &u in &self.hidden {
            if !(1..=8192).contains(&u) {
                bail!("topology '{}': hidden width {u} out of range [1, 8192]", self.name);
            }
        }
        if !(1..=8).contains(&self.k) {
            bail!("topology '{}': k={} out of range [1, 8]", self.name, self.k);
        }
        if self.train_batch == 0 || self.eval_batch == 0 {
            bail!("topology '{}': batch sizes must be > 0", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_mirror_the_manifest_models() {
        let pi = TopologySpec::builtin("pi_mlp").unwrap();
        assert_eq!(pi.hidden, vec![128, 128]);
        assert_eq!(pi.k, 4);
        assert_eq!((pi.train_batch, pi.eval_batch), (64, 256));
        assert_eq!(pi.n_layers(), 3);
        let wide = TopologySpec::builtin("pi_mlp_wide").unwrap();
        assert_eq!(wide.hidden, vec![256, 256]);
        assert!(TopologySpec::builtin("conv").is_none());
    }

    #[test]
    fn cli_forms_parse() {
        assert_eq!(TopologySpec::parse_cli("pi_mlp").unwrap().hidden, vec![128, 128]);
        let t = TopologySpec::parse_cli("128x3").unwrap();
        assert_eq!(t.hidden, vec![128, 128, 128]);
        assert_eq!(t.k, 4);
        assert_eq!(t.name, "mlp-128x128x128-k4");
        let t = TopologySpec::parse_cli("256,128").unwrap();
        assert_eq!(t.hidden, vec![256, 128]);
        let t = TopologySpec::parse_cli("64x4@k2").unwrap();
        assert_eq!(t.hidden, vec![64; 4]);
        assert_eq!(t.k, 2);
        for bad in ["", "x3", "128x0", "128@q2", "128@k", "0x3", "128,many"] {
            assert!(TopologySpec::parse_cli(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        for spec in [
            TopologySpec::builtin("pi_mlp").unwrap(),
            TopologySpec::mlp(vec![64, 32, 16], 2),
            TopologySpec {
                name: "custom".into(),
                hidden: vec![48; 3],
                k: 3,
                train_batch: 32,
                eval_batch: 128,
            },
        ] {
            let back = TopologySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn toml_table_round_trips_through_the_parser() {
        let doc = crate::config::toml::parse(
            "[topology]\nname = \"deep\"\nhidden = [32, 32, 32]\nk = 2\n",
        )
        .unwrap();
        let spec = TopologySpec::from_json(doc.get("topology").unwrap()).unwrap();
        assert_eq!(spec.name, "deep");
        assert_eq!(spec.hidden, vec![32, 32, 32]);
        assert_eq!(spec.k, 2);
        // defaults fill in, and the JSON form reproduces the spec
        assert_eq!((spec.train_batch, spec.eval_batch), (64, 256));
        assert_eq!(TopologySpec::from_json(&spec.to_json()).unwrap(), spec);
    }

    #[test]
    fn validation_rejects_degenerate_topologies() {
        assert!(TopologySpec::mlp(vec![], 4).validate().is_err());
        assert!(TopologySpec::mlp(vec![128], 0).validate().is_err());
        assert!(TopologySpec::mlp(vec![128], 9).validate().is_err());
        assert!(TopologySpec::mlp(vec![0], 4).validate().is_err());
        assert!(TopologySpec::mlp(vec![16; 17], 4).validate().is_err());
        let mut t = TopologySpec::mlp(vec![16], 2);
        t.train_batch = 0;
        assert!(t.validate().is_err());
    }
}
