//! [`TopologySpec`]: model topology as *data* instead of code.
//!
//! The paper trains several maxout topologies — PI-MLPs of varying
//! depth/width on MNIST plus maxout *convolutional* networks for
//! MNIST/CIFAR-10/SVHN — and the precision effects it studies are
//! topology-dependent. A `TopologySpec` describes one maxout network
//! (conv stages + hidden dense widths + pieces-per-unit) without
//! pinning the input/output dimensions: those are derived from the
//! dataset's signal [`Shape`] when the spec is *realized* into a
//! [`ModelInfo`](crate::runtime::ModelInfo) and a
//! [`Network`](crate::golden::Network), so the same spec composes with
//! any data source whose shape fits.
//!
//! Specs come from three places, all producing the same type:
//!
//! * the built-in names (`pi_mlp`, `pi_mlp_wide`, `conv`, `conv32`,
//!   `pi_conv`) that mirror `python/compile/model.py`'s model zoo
//!   ([`TopologySpec::builtin`]),
//! * a `[topology]` table in the experiment TOML/JSON config, with conv
//!   stages as a `[[topology.conv]]` array of tables
//!   ([`TopologySpec::from_json`], round-tripped by
//!   [`TopologySpec::to_json`]),
//! * the CLI's `--topology` flag ([`TopologySpec::parse_cli`]):
//!   a builtin name, `WIDTHxDEPTH` (e.g. `128x3`), a comma list of
//!   widths (e.g. `256,128`), or a conv grammar — comma-separated
//!   `c<CH>[k<KSIZE>][p<POOL>]` stages, optionally followed by
//!   `/<dense part>` (e.g. `c32k5p2,c64k5p2/128x2`) — all optionally
//!   suffixed `@kN` to set the maxout piece count (e.g. `128x3@k2`,
//!   `c32k5p2,c64k5p2/128x2@k2`).

use crate::bail;
use crate::tensor::Shape;

use super::json::Json;

/// One maxout-conv stage: SAME-padded stride-1 conv (`ksize` odd) with
/// `channels` output maps per maxout filter, then a non-overlapping
/// `pool`×`pool` spatial max pool (VALID: trailing rows that don't fill
/// a window are dropped). The stage owns one scaling-group row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvStageSpec {
    /// Output channels (per maxout filter).
    pub channels: usize,
    /// Square kernel side; must be odd for SAME padding.
    pub ksize: usize,
    /// Pool window = stride (1 disables pooling).
    pub pool: usize,
}

impl ConvStageSpec {
    /// The stage's output signal shape, or a config error when the
    /// input is flat, the kernel is even (no SAME padding), the pool is
    /// degenerate, or the pool eats the whole map. This enforces the
    /// same rules as the graph's `MaxoutConv2d`/`MaxPool2d` shape
    /// contract, so `ModelInfo` realization and `Network` construction
    /// accept exactly the same specs.
    pub fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        let Shape::Spatial { h, w, .. } = *in_shape else {
            bail!(
                "conv stage {} needs a spatial input, got {in_shape} (conv \
                 topologies require an image dataset)",
                self.label()
            );
        };
        crate::ensure!(
            self.ksize % 2 == 1,
            "conv stage {}: SAME padding needs an odd kernel size",
            self.label()
        );
        crate::ensure!(self.pool >= 1, "conv stage {}: pool must be >= 1", self.label());
        let (ph, pw) = (h / self.pool, w / self.pool);
        crate::ensure!(
            ph >= 1 && pw >= 1,
            "conv stage {} pools a {h}x{w} map below one pixel",
            self.label()
        );
        Ok(Shape::Spatial { h: ph, w: pw, c: self.channels })
    }

    /// The stage in `--topology` grammar (`c<CH>k<KSIZE>p<POOL>`).
    fn label(&self) -> String {
        format!("c{}k{}p{}", self.channels, self.ksize, self.pool)
    }
}

/// One maxout topology: conv stages (input side), hidden dense widths,
/// and maxout pieces. The input/output dimensions are *not* part of the
/// spec — they come from the dataset at realization time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopologySpec {
    /// Model name used in configs, reports and manifest lookups.
    pub name: String,
    /// Maxout-conv stages, input side first; empty for a pure MLP.
    pub conv: Vec<ConvStageSpec>,
    /// Hidden maxout dense widths after the conv stages (e.g.
    /// `[128, 128]`); may be empty when conv stages exist.
    pub hidden: Vec<usize>,
    /// Maxout pieces per hidden unit (paper: 4 on PI MNIST, 2 on conv).
    pub k: usize,
    /// Training minibatch size.
    pub train_batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
}

impl TopologySpec {
    /// A custom maxout MLP with the default batch sizes and a derived
    /// name (`mlp-<w1>x<w2>...-k<k>`).
    pub fn mlp(hidden: Vec<usize>, k: usize) -> TopologySpec {
        let widths: Vec<String> = hidden.iter().map(|u| u.to_string()).collect();
        TopologySpec {
            name: format!("mlp-{}-k{k}", widths.join("x")),
            conv: Vec::new(),
            hidden,
            k,
            train_batch: 64,
            eval_batch: 256,
        }
    }

    /// A custom maxout conv net (conv stages, then dense widths) with
    /// the default batch sizes and a derived name.
    pub fn conv_net(conv: Vec<ConvStageSpec>, hidden: Vec<usize>, k: usize) -> TopologySpec {
        let stages: Vec<String> = conv.iter().map(|c| c.label()).collect();
        let widths: Vec<String> = hidden.iter().map(|u| u.to_string()).collect();
        let dense = if widths.is_empty() {
            String::new()
        } else {
            format!("-{}", widths.join("x"))
        };
        TopologySpec {
            name: format!("conv-{}{dense}-k{k}", stages.join("+")),
            conv,
            hidden,
            k,
            train_batch: 64,
            eval_batch: 256,
        }
    }

    /// The built-in topologies — the same maxout models
    /// `python/compile/model.py` declares, so graph-built state lines up
    /// with the compiled artifacts: the PI MLPs, the 28×28 `conv` net,
    /// and the 32×32 `conv32` net (aliased `pi_conv`, the native-first
    /// name). `None` for unknown names.
    pub fn builtin(name: &str) -> Option<TopologySpec> {
        let stage = |channels| ConvStageSpec { channels, ksize: 5, pool: 2 };
        let (conv, hidden, k) = match name {
            "pi_mlp" => (vec![], vec![128, 128], 4),
            // paper 9.2/9.3 width ablation: double the hidden units
            "pi_mlp_wide" => (vec![], vec![256, 256], 4),
            // paper 8.1 conv model (28x28x1 datasets)
            "conv" => (vec![stage(8), stage(16), stage(16)], vec![], 2),
            // paper 8.2/8.3 conv model (32x32x3 datasets)
            "conv32" | "pi_conv" => (vec![stage(16), stage(16), stage(24)], vec![], 2),
            _ => return None,
        };
        Some(TopologySpec {
            name: name.to_string(),
            conv,
            hidden,
            k,
            train_batch: 64,
            eval_batch: 256,
        })
    }

    /// Parse one conv-stage token: `c<CH>`, optionally `k<KSIZE>`
    /// (default 5), optionally `p<POOL>` (default 2).
    fn parse_conv_token(s: &str, tok: &str) -> crate::Result<ConvStageSpec> {
        let split_digits = |t: &str| -> (String, String) {
            let i = t.find(|c: char| !c.is_ascii_digit()).unwrap_or(t.len());
            (t[..i].to_string(), t[i..].to_string())
        };
        let Some(rest) = tok.strip_prefix('c') else {
            bail!("--topology '{s}': conv stage '{tok}' must start with 'c'");
        };
        let (ch, mut rest) = split_digits(rest);
        let channels: usize = ch
            .parse()
            .map_err(|e| crate::err!("--topology '{s}': bad channels in '{tok}': {e}"))?;
        let mut ksize = 5usize;
        let mut pool = 2usize;
        if let Some(r) = rest.strip_prefix('k') {
            let (n, r2) = split_digits(r);
            ksize = n
                .parse()
                .map_err(|e| crate::err!("--topology '{s}': bad ksize in '{tok}': {e}"))?;
            rest = r2;
        }
        if let Some(r) = rest.strip_prefix('p') {
            let (n, r2) = split_digits(r);
            pool = n
                .parse()
                .map_err(|e| crate::err!("--topology '{s}': bad pool in '{tok}': {e}"))?;
            rest = r2;
        }
        crate::ensure!(
            rest.is_empty(),
            "--topology '{s}': trailing '{rest}' in conv stage '{tok}' \
             (grammar: c<CH>[k<KSIZE>][p<POOL>])"
        );
        Ok(ConvStageSpec { channels, ksize, pool })
    }

    /// Parse the CLI `--topology` value: a builtin name, `WIDTHxDEPTH`
    /// (`128x3`), comma-separated widths (`256,128`), or conv stages
    /// `c<CH>[k<KSIZE>][p<POOL>],...` optionally followed by
    /// `/<dense part>` (`c32k5p2,c64k5p2/128x2`) — all optionally
    /// suffixed `@kN` (`128x3@k2`).
    pub fn parse_cli(s: &str) -> crate::Result<TopologySpec> {
        if let Some(t) = TopologySpec::builtin(s) {
            return Ok(t);
        }
        let (body, k) = match s.split_once('@') {
            Some((body, ksuf)) => {
                let Some(kstr) = ksuf.strip_prefix('k') else {
                    bail!("--topology '{s}': expected '@k<N>' suffix, got '@{ksuf}'");
                };
                let k: usize = kstr
                    .parse()
                    .map_err(|e| crate::err!("--topology '{s}': bad k '{kstr}': {e}"))?;
                (body, k)
            }
            None => (s, 4),
        };
        let parse_width = |w: &str| -> crate::Result<usize> {
            w.parse().map_err(|e| crate::err!("--topology '{s}': bad width '{w}': {e}"))
        };
        let parse_dense = |body: &str| -> crate::Result<Vec<usize>> {
            if let Some((w, d)) = body.split_once('x') {
                let w = parse_width(w)?;
                let d: usize = d
                    .parse()
                    .map_err(|e| crate::err!("--topology '{s}': bad depth '{d}': {e}"))?;
                crate::ensure!(d >= 1, "--topology '{s}': depth must be >= 1");
                Ok(vec![w; d])
            } else {
                body.split(',')
                    .map(|w| parse_width(w.trim()))
                    .collect::<crate::Result<Vec<usize>>>()
            }
        };
        let looks_conv =
            |t: &str| t.len() >= 2 && t.starts_with('c') && t.as_bytes()[1].is_ascii_digit();
        let spec = match body.split_once('/') {
            Some((conv_part, dense_part)) => {
                let conv = conv_part
                    .split(',')
                    .map(|t| Self::parse_conv_token(s, t.trim()))
                    .collect::<crate::Result<Vec<ConvStageSpec>>>()?;
                let hidden = if dense_part.is_empty() {
                    Vec::new()
                } else {
                    parse_dense(dense_part)?
                };
                TopologySpec::conv_net(conv, hidden, k)
            }
            None if body.split(',').all(|t| looks_conv(t.trim())) && !body.is_empty() => {
                let conv = body
                    .split(',')
                    .map(|t| Self::parse_conv_token(s, t.trim()))
                    .collect::<crate::Result<Vec<ConvStageSpec>>>()?;
                TopologySpec::conv_net(conv, Vec::new(), k)
            }
            None => TopologySpec::mlp(parse_dense(body)?, k),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Build from a config tree's `[topology]` table (TOML or JSON);
    /// conv stages come from a `[[topology.conv]]` array of tables
    /// (`channels` required, `ksize`/`pool` defaulting to 5/2).
    pub fn from_json(doc: &Json) -> crate::Result<TopologySpec> {
        let conv = match doc.opt("conv") {
            Some(v) => v
                .as_array()?
                .iter()
                .map(|t| {
                    Ok(ConvStageSpec {
                        channels: t.get("channels")?.as_usize()?,
                        ksize: t.opt("ksize").map(|v| v.as_usize()).transpose()?.unwrap_or(5),
                        pool: t.opt("pool").map(|v| v.as_usize()).transpose()?.unwrap_or(2),
                    })
                })
                .collect::<crate::Result<Vec<ConvStageSpec>>>()?,
            None => Vec::new(),
        };
        let hidden = doc
            .opt("hidden")
            .map(|v| v.as_usize_vec())
            .transpose()?
            // a pure-MLP table defaults to the pi_mlp widths; a conv
            // table defaults to conv-stages-then-head
            .unwrap_or_else(|| {
                if conv.is_empty() {
                    vec![128, 128]
                } else {
                    Vec::new()
                }
            });
        let k = doc.opt("k").map(|v| v.as_usize()).transpose()?.unwrap_or(4);
        let mut spec = if conv.is_empty() {
            TopologySpec::mlp(hidden, k)
        } else {
            TopologySpec::conv_net(conv, hidden, k)
        };
        if let Some(v) = doc.opt("name") {
            spec.name = v.as_str()?.to_string();
        }
        if let Some(v) = doc.opt("train_batch") {
            spec.train_batch = v.as_usize()?;
        }
        if let Some(v) = doc.opt("eval_batch") {
            spec.eval_batch = v.as_usize()?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the dynamic config tree; `from_json` of the result
    /// reproduces the spec exactly (round-trip tested).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        if !self.conv.is_empty() {
            let stages: Vec<Json> = self
                .conv
                .iter()
                .map(|c| {
                    let mut s = std::collections::BTreeMap::new();
                    s.insert("channels".to_string(), Json::Num(c.channels as f64));
                    s.insert("ksize".to_string(), Json::Num(c.ksize as f64));
                    s.insert("pool".to_string(), Json::Num(c.pool as f64));
                    Json::Object(s)
                })
                .collect();
            m.insert("conv".to_string(), Json::Array(stages));
        }
        m.insert(
            "hidden".to_string(),
            Json::Array(self.hidden.iter().map(|&u| Json::Num(u as f64)).collect()),
        );
        m.insert("k".to_string(), Json::Num(self.k as f64));
        m.insert("train_batch".to_string(), Json::Num(self.train_batch as f64));
        m.insert("eval_batch".to_string(), Json::Num(self.eval_batch as f64));
        Json::Object(m)
    }

    /// Number of compute stages (conv stages + hidden maxout layers +
    /// softmax head) — the graph's scaling-group row count.
    pub fn n_layers(&self) -> usize {
        self.conv.len() + self.hidden.len() + 1
    }

    /// Sanity-check before spending a training run on it.
    pub fn validate(&self) -> crate::Result<()> {
        if self.conv.is_empty() && self.hidden.is_empty() {
            bail!("topology '{}' has no conv stages and no hidden layers", self.name);
        }
        if self.hidden.len() > 16 {
            bail!("topology '{}': {} hidden layers (max 16)", self.name, self.hidden.len());
        }
        if self.conv.len() > 8 {
            bail!("topology '{}': {} conv stages (max 8)", self.name, self.conv.len());
        }
        for &u in &self.hidden {
            if !(1..=8192).contains(&u) {
                bail!("topology '{}': hidden width {u} out of range [1, 8192]", self.name);
            }
        }
        for c in &self.conv {
            if !(1..=1024).contains(&c.channels) {
                bail!(
                    "topology '{}': conv channels {} out of range [1, 1024]",
                    self.name,
                    c.channels
                );
            }
            if c.ksize % 2 == 0 || !(1..=15).contains(&c.ksize) {
                bail!(
                    "topology '{}': conv ksize {} must be odd and in [1, 15] (SAME padding)",
                    self.name,
                    c.ksize
                );
            }
            if !(1..=8).contains(&c.pool) {
                bail!("topology '{}': pool {} out of range [1, 8]", self.name, c.pool);
            }
        }
        if !(1..=8).contains(&self.k) {
            bail!("topology '{}': k={} out of range [1, 8]", self.name, self.k);
        }
        if self.train_batch == 0 || self.eval_batch == 0 {
            bail!("topology '{}': batch sizes must be > 0", self.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_mirror_the_manifest_models() {
        let pi = TopologySpec::builtin("pi_mlp").unwrap();
        assert!(pi.conv.is_empty());
        assert_eq!(pi.hidden, vec![128, 128]);
        assert_eq!(pi.k, 4);
        assert_eq!((pi.train_batch, pi.eval_batch), (64, 256));
        assert_eq!(pi.n_layers(), 3);
        let wide = TopologySpec::builtin("pi_mlp_wide").unwrap();
        assert_eq!(wide.hidden, vec![256, 256]);
        // the conv zoo mirrors python/compile/model.py's conv/conv32
        let c = TopologySpec::builtin("conv").unwrap();
        assert_eq!(
            c.conv.iter().map(|s| s.channels).collect::<Vec<_>>(),
            vec![8, 16, 16]
        );
        assert!(c.hidden.is_empty());
        assert_eq!((c.k, c.n_layers()), (2, 4));
        let pc = TopologySpec::builtin("pi_conv").unwrap();
        assert_eq!(
            pc.conv.iter().map(|s| s.channels).collect::<Vec<_>>(),
            vec![16, 16, 24]
        );
        assert_eq!(pc.conv[0], ConvStageSpec { channels: 16, ksize: 5, pool: 2 });
        let c32 = TopologySpec::builtin("conv32").unwrap();
        assert_eq!(c32.conv, pc.conv);
        assert!(TopologySpec::builtin("resnet").is_none());
    }

    #[test]
    fn cli_forms_parse() {
        assert_eq!(TopologySpec::parse_cli("pi_mlp").unwrap().hidden, vec![128, 128]);
        let t = TopologySpec::parse_cli("128x3").unwrap();
        assert_eq!(t.hidden, vec![128, 128, 128]);
        assert_eq!(t.k, 4);
        assert_eq!(t.name, "mlp-128x128x128-k4");
        let t = TopologySpec::parse_cli("256,128").unwrap();
        assert_eq!(t.hidden, vec![256, 128]);
        let t = TopologySpec::parse_cli("64x4@k2").unwrap();
        assert_eq!(t.hidden, vec![64; 4]);
        assert_eq!(t.k, 2);
        for bad in ["", "x3", "128x0", "128@q2", "128@k", "0x3", "128,many"] {
            assert!(TopologySpec::parse_cli(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn cli_conv_forms_parse() {
        // the full grammar: conv stages / dense part @ maxout pieces
        let t = TopologySpec::parse_cli("c32k5p2,c64k5p2/128x2@k2").unwrap();
        assert_eq!(
            t.conv,
            vec![
                ConvStageSpec { channels: 32, ksize: 5, pool: 2 },
                ConvStageSpec { channels: 64, ksize: 5, pool: 2 },
            ]
        );
        assert_eq!(t.hidden, vec![128, 128]);
        assert_eq!(t.k, 2);
        assert_eq!(t.n_layers(), 5);
        // conv-only (no dense part), with ksize/pool defaults
        let t = TopologySpec::parse_cli("c8,c16p1").unwrap();
        assert_eq!(
            t.conv,
            vec![
                ConvStageSpec { channels: 8, ksize: 5, pool: 2 },
                ConvStageSpec { channels: 16, ksize: 5, pool: 1 },
            ]
        );
        assert!(t.hidden.is_empty());
        // comma dense part after the slash
        let t = TopologySpec::parse_cli("c8k3p2/64,32").unwrap();
        assert_eq!(t.hidden, vec![64, 32]);
        // a trailing slash is conv-only (empty dense part)
        assert!(TopologySpec::parse_cli("c8/").unwrap().hidden.is_empty());
        for bad in [
            "c/128",    // missing channels
            "c8q3/128", // bad stage suffix
            "c8k4/128", // even ksize (SAME padding needs odd)
            "c8p9/128", // pool out of range
        ] {
            assert!(TopologySpec::parse_cli(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        for spec in [
            TopologySpec::builtin("pi_mlp").unwrap(),
            TopologySpec::builtin("pi_conv").unwrap(),
            TopologySpec::mlp(vec![64, 32, 16], 2),
            TopologySpec::conv_net(
                vec![ConvStageSpec { channels: 8, ksize: 3, pool: 2 }],
                vec![32],
                2,
            ),
            TopologySpec {
                name: "custom".into(),
                conv: Vec::new(),
                hidden: vec![48; 3],
                k: 3,
                train_batch: 32,
                eval_batch: 128,
            },
        ] {
            let back = TopologySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn toml_table_round_trips_through_the_parser() {
        let doc = crate::config::toml::parse(
            "[topology]\nname = \"deep\"\nhidden = [32, 32, 32]\nk = 2\n",
        )
        .unwrap();
        let spec = TopologySpec::from_json(doc.get("topology").unwrap()).unwrap();
        assert_eq!(spec.name, "deep");
        assert_eq!(spec.hidden, vec![32, 32, 32]);
        assert_eq!(spec.k, 2);
        // defaults fill in, and the JSON form reproduces the spec
        assert_eq!((spec.train_batch, spec.eval_batch), (64, 256));
        assert_eq!(TopologySpec::from_json(&spec.to_json()).unwrap(), spec);
    }

    #[test]
    fn toml_conv_array_of_tables_round_trips() {
        let doc = crate::config::toml::parse(
            "[topology]\nk = 2\nhidden = [32]\n\n\
             [[topology.conv]]\nchannels = 8\nksize = 3\n\n\
             [[topology.conv]]\nchannels = 16\npool = 1\n",
        )
        .unwrap();
        let spec = TopologySpec::from_json(doc.get("topology").unwrap()).unwrap();
        assert_eq!(
            spec.conv,
            vec![
                ConvStageSpec { channels: 8, ksize: 3, pool: 2 },
                ConvStageSpec { channels: 16, ksize: 5, pool: 1 },
            ]
        );
        assert_eq!(spec.hidden, vec![32]);
        assert_eq!(spec.n_layers(), 4);
        assert_eq!(TopologySpec::from_json(&spec.to_json()).unwrap(), spec);
        // a conv table without hidden widths defaults to conv-then-head
        let doc = crate::config::toml::parse("[[topology.conv]]\nchannels = 8\n").unwrap();
        let spec = TopologySpec::from_json(doc.get("topology").unwrap()).unwrap();
        assert!(spec.hidden.is_empty());
        assert_eq!(spec.conv.len(), 1);
    }

    #[test]
    fn validation_rejects_degenerate_topologies() {
        assert!(TopologySpec::mlp(vec![], 4).validate().is_err());
        assert!(TopologySpec::mlp(vec![128], 0).validate().is_err());
        assert!(TopologySpec::mlp(vec![128], 9).validate().is_err());
        assert!(TopologySpec::mlp(vec![0], 4).validate().is_err());
        assert!(TopologySpec::mlp(vec![16; 17], 4).validate().is_err());
        let mut t = TopologySpec::mlp(vec![16], 2);
        t.train_batch = 0;
        assert!(t.validate().is_err());
        // conv-only is valid; degenerate conv stages are not
        let stage = |channels, ksize, pool| ConvStageSpec { channels, ksize, pool };
        assert!(TopologySpec::conv_net(vec![stage(8, 3, 2)], vec![], 2).validate().is_ok());
        assert!(TopologySpec::conv_net(vec![stage(0, 3, 2)], vec![], 2).validate().is_err());
        assert!(TopologySpec::conv_net(vec![stage(8, 4, 2)], vec![], 2).validate().is_err());
        assert!(TopologySpec::conv_net(vec![stage(8, 3, 0)], vec![], 2).validate().is_err());
        assert!(TopologySpec::conv_net(vec![stage(8, 3, 2); 9], vec![], 2)
            .validate()
            .is_err());
    }

    #[test]
    fn conv_stage_out_shape_follows_same_conv_plus_pool() {
        let s = ConvStageSpec { channels: 16, ksize: 5, pool: 2 };
        let out = s.out_shape(&Shape::Spatial { h: 28, w: 28, c: 1 }).unwrap();
        assert_eq!(out, Shape::Spatial { h: 14, w: 14, c: 16 });
        // VALID pooling floors odd extents, like L2's reduce_window
        let out = s.out_shape(&Shape::Spatial { h: 7, w: 7, c: 16 }).unwrap();
        assert_eq!(out, Shape::Spatial { h: 3, w: 3, c: 16 });
        assert!(s.out_shape(&Shape::Flat(784)).is_err());
        let deep = ConvStageSpec { channels: 4, ksize: 3, pool: 8 };
        assert!(deep.out_shape(&Shape::Spatial { h: 4, w: 4, c: 1 }).is_err());
    }
}
