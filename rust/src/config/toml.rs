//! From-scratch TOML-subset parser for experiment config files.
//!
//! Supports the subset our configs use: `[section]` / `[a.b]` tables,
//! `[[a.b]]` arrays of tables (each header appends a fresh table;
//! subsequent keys land in it — how conv stages are declared), `key =
//! value` with strings, integers, floats, booleans and flat arrays,
//! plus `#` comments. Parses into the crate's [`Json`] value type so the
//! rest of the config layer has a single dynamic representation.
//!
//! ```toml
//! [experiment]
//! name = "fig2-dynamic"        # identifies the run
//! [arithmetic]
//! kind = "dynamic"
//! bits_comp = 10
//! max_overflow_rate = 1e-4
//! [[topology.conv]]
//! channels = 32
//! [[topology.conv]]
//! channels = 64
//! ```

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError { line, msg: msg.into() }
}

/// Parse a TOML-subset document into a JSON object tree.
pub fn parse(input: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line_no = lineno + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest
                .strip_suffix("]]")
                .ok_or_else(|| err(line_no, "unterminated array-of-tables header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line_no, "empty array-of-tables name"));
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(err(line_no, "empty section path component"));
            }
            // append a fresh table; keys below the header land in it
            // (insert_path descends into the last element of an array)
            push_array_table(&mut root, &section, line_no)?;
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(line_no, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line_no, "empty section name"));
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(err(line_no, "empty section path component"));
            }
            // materialize the table so empty sections still exist
            insert_path(&mut root, &section, None, line_no)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(line_no, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(line_no, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), line_no)?;
        let mut path = section.clone();
        path.push(key.to_string());
        insert_path(&mut root, &path, Some(value), line_no)?;
    }
    Ok(Json::Object(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn insert_path(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    value: Option<Json>,
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for (i, part) in path.iter().enumerate() {
        let last = i == path.len() - 1;
        if last {
            match value {
                Some(ref v) => {
                    if cur.contains_key(part) && !matches!(cur.get(part), Some(Json::Object(m)) if m.is_empty())
                    {
                        return Err(err(line, format!("duplicate key '{part}'")));
                    }
                    cur.insert(part.clone(), v.clone());
                }
                None => {
                    let entry = cur
                        .entry(part.clone())
                        .or_insert_with(|| Json::Object(BTreeMap::new()));
                    // a plain [..] header must name a table: catching the
                    // single-bracket typo for an existing [[..]] array
                    // here stops its keys silently merging into the last
                    // array element (a different topology than declared)
                    if !matches!(entry, Json::Object(_)) {
                        return Err(err(
                            line,
                            format!("'{part}' is not a table (use [[{part}]] to append)"),
                        ));
                    }
                }
            }
            return Ok(());
        }
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Object(BTreeMap::new()));
        match entry {
            Json::Object(m) => cur = m,
            // descend into the array-of-tables element under construction
            Json::Array(a) => match a.last_mut() {
                Some(Json::Object(m)) => cur = m,
                _ => return Err(err(line, format!("'{part}' is not a table"))),
            },
            _ => return Err(err(line, format!("'{part}' is not a table"))),
        }
    }
    Ok(())
}

/// `[[path]]`: append a fresh table to the array at `path` (creating the
/// array on first use), so subsequent keys land in the new element.
fn push_array_table(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for part in &path[..path.len() - 1] {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Object(BTreeMap::new()));
        match entry {
            Json::Object(m) => cur = m,
            Json::Array(a) => match a.last_mut() {
                Some(Json::Object(m)) => cur = m,
                _ => return Err(err(line, format!("'{part}' is not a table"))),
            },
            _ => return Err(err(line, format!("'{part}' is not a table"))),
        }
    }
    let name = &path[path.len() - 1];
    let entry = cur
        .entry(name.clone())
        .or_insert_with(|| Json::Array(Vec::new()));
    match entry {
        // only arrays built from [[..]] headers qualify — appending to a
        // plain value array would defer the failure to a confusing
        // downstream field-access error
        Json::Array(a) if a.iter().all(|e| matches!(e, Json::Object(_))) => {
            a.push(Json::Object(BTreeMap::new()));
            Ok(())
        }
        _ => Err(err(line, format!("'{name}' is not an array of tables"))),
    }
}

fn parse_value(s: &str, line: usize) -> Result<Json, TomlError> {
    if s.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(err(line, format!("bad escape {other:?}"))),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Array(vec![]));
        }
        let items: Result<Vec<Json>, TomlError> = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(Json::Array(items?));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(line, format!("cannot parse value '{s}'")))
}

/// Split on commas not inside strings (arrays are flat in our subset).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_experiment_config() {
        let src = r#"
# paper fig 2, dynamic fixed point point
[experiment]
name = "fig2-dynamic-10"
model = "pi_mlp"
dataset = "digits"

[arithmetic]
kind = "dynamic"
bits_comp = 10
bits_up = 31
max_overflow_rate = 1e-4

[train]
steps = 400
lr_start = 0.15
dropout = [0.2, 0.5, 0.5]
verbose = true
"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get("experiment").unwrap().get("name").unwrap().as_str().unwrap(),
            "fig2-dynamic-10"
        );
        assert_eq!(
            v.get("arithmetic").unwrap().get("bits_comp").unwrap().as_usize().unwrap(),
            10
        );
        assert_eq!(
            v.get("arithmetic").unwrap().get("max_overflow_rate").unwrap().as_f64().unwrap(),
            1e-4
        );
        let dropout = v.get("train").unwrap().get("dropout").unwrap().as_array().unwrap();
        assert_eq!(dropout.len(), 3);
        assert_eq!(v.get("train").unwrap().get("verbose").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn nested_sections() {
        let v = parse("[a.b]\nc = 1\n[a.d]\ne = \"x\"").unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("a").unwrap().get("d").unwrap().get("e").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn comments_and_hashes_in_strings() {
        let v = parse("k = \"a#b\" # trailing\n").unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn array_of_tables_appends_elements() {
        let v = parse(
            "[topology]\nk = 2\n\
             [[topology.conv]]\nchannels = 8\nksize = 3\n\
             [[topology.conv]]\nchannels = 16\n\
             [train]\nsteps = 5\n",
        )
        .unwrap();
        let topo = v.get("topology").unwrap();
        assert_eq!(topo.get("k").unwrap().as_usize().unwrap(), 2);
        let conv = topo.get("conv").unwrap().as_array().unwrap();
        assert_eq!(conv.len(), 2);
        assert_eq!(conv[0].get("channels").unwrap().as_usize().unwrap(), 8);
        assert_eq!(conv[0].get("ksize").unwrap().as_usize().unwrap(), 3);
        assert_eq!(conv[1].get("channels").unwrap().as_usize().unwrap(), 16);
        assert!(conv[1].get("ksize").is_err());
        // a later plain section leaves the array alone
        assert_eq!(v.get("train").unwrap().get("steps").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn array_of_tables_before_parent_section() {
        // header order doesn't matter: the parent table materializes
        let v = parse("[[topology.conv]]\nchannels = 4\n[topology]\nk = 2\n").unwrap();
        let topo = v.get("topology").unwrap();
        assert_eq!(topo.get("conv").unwrap().as_array().unwrap().len(), 1);
        assert_eq!(topo.get("k").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn array_of_tables_conflicts_rejected() {
        // a key and an array of tables cannot share a name
        assert!(parse("[a]\nb = 1\n[[a.b]]\nc = 2\n").is_err());
        // ... and neither can a plain value array
        assert!(parse("[a]\nb = [1, 2]\n[[a.b]]\nc = 2\n").is_err());
        assert!(parse("[[a]]\nk = 1\n[a.b]\n").is_ok()); // sub-table of the last element
        assert!(parse("[[unclosed]\nk = 1").is_err());
        // the single-bracket typo for an existing array of tables must
        // error, not silently merge keys into the last element
        let err = parse("[[t.conv]]\nchannels = 32\n[t.conv]\nchannels = 64\n").unwrap_err();
        assert!(err.msg.contains("[[conv]]"), "{err}");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["[unclosed\nk=1", "novalue =", "= 1", "k = [1,", "k = \"open", "[a..b]\n"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn empty_section_materializes() {
        let v = parse("[empty]\n").unwrap();
        assert!(v.get("empty").unwrap().as_object().unwrap().is_empty());
    }
}
