//! From-scratch TOML-subset parser for experiment config files.
//!
//! Supports the subset our configs use: `[section]` / `[a.b]` tables,
//! `key = value` with strings, integers, floats, booleans and flat arrays,
//! plus `#` comments. Parses into the crate's [`Json`] value type so the
//! rest of the config layer has a single dynamic representation.
//!
//! ```toml
//! [experiment]
//! name = "fig2-dynamic"        # identifies the run
//! [arithmetic]
//! kind = "dynamic"
//! bits_comp = 10
//! max_overflow_rate = 1e-4
//! ```

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError { line, msg: msg.into() }
}

/// Parse a TOML-subset document into a JSON object tree.
pub fn parse(input: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line_no = lineno + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(line_no, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line_no, "empty section name"));
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(err(line_no, "empty section path component"));
            }
            // materialize the table so empty sections still exist
            insert_path(&mut root, &section, None, line_no)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(line_no, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(line_no, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), line_no)?;
        let mut path = section.clone();
        path.push(key.to_string());
        insert_path(&mut root, &path, Some(value), line_no)?;
    }
    Ok(Json::Object(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn insert_path(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    value: Option<Json>,
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for (i, part) in path.iter().enumerate() {
        let last = i == path.len() - 1;
        if last {
            match value {
                Some(ref v) => {
                    if cur.contains_key(part) && !matches!(cur.get(part), Some(Json::Object(m)) if m.is_empty())
                    {
                        return Err(err(line, format!("duplicate key '{part}'")));
                    }
                    cur.insert(part.clone(), v.clone());
                }
                None => {
                    cur.entry(part.clone()).or_insert_with(|| Json::Object(BTreeMap::new()));
                }
            }
            return Ok(());
        }
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Object(BTreeMap::new()));
        match entry {
            Json::Object(m) => cur = m,
            _ => return Err(err(line, format!("'{part}' is not a table"))),
        }
    }
    Ok(())
}

fn parse_value(s: &str, line: usize) -> Result<Json, TomlError> {
    if s.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(err(line, format!("bad escape {other:?}"))),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Array(vec![]));
        }
        let items: Result<Vec<Json>, TomlError> = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(Json::Array(items?));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(line, format!("cannot parse value '{s}'")))
}

/// Split on commas not inside strings (arrays are flat in our subset).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_experiment_config() {
        let src = r#"
# paper fig 2, dynamic fixed point point
[experiment]
name = "fig2-dynamic-10"
model = "pi_mlp"
dataset = "digits"

[arithmetic]
kind = "dynamic"
bits_comp = 10
bits_up = 31
max_overflow_rate = 1e-4

[train]
steps = 400
lr_start = 0.15
dropout = [0.2, 0.5, 0.5]
verbose = true
"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get("experiment").unwrap().get("name").unwrap().as_str().unwrap(),
            "fig2-dynamic-10"
        );
        assert_eq!(
            v.get("arithmetic").unwrap().get("bits_comp").unwrap().as_usize().unwrap(),
            10
        );
        assert_eq!(
            v.get("arithmetic").unwrap().get("max_overflow_rate").unwrap().as_f64().unwrap(),
            1e-4
        );
        let dropout = v.get("train").unwrap().get("dropout").unwrap().as_array().unwrap();
        assert_eq!(dropout.len(), 3);
        assert_eq!(v.get("train").unwrap().get("verbose").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn nested_sections() {
        let v = parse("[a.b]\nc = 1\n[a.d]\ne = \"x\"").unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("a").unwrap().get("d").unwrap().get("e").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn comments_and_hashes_in_strings() {
        let v = parse("k = \"a#b\" # trailing\n").unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["[unclosed\nk=1", "novalue =", "= 1", "k = [1,", "k = \"open", "[a..b]\n"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn empty_section_materializes() {
        let v = parse("[empty]\n").unwrap();
        assert!(v.get("empty").unwrap().as_object().unwrap().is_empty());
    }
}
