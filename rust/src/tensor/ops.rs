//! The linear algebra the maxout networks need, tested against naive loops.
//!
//! Shapes follow the L2 model exactly (python/compile/model.py):
//! activations `[B, I]`, maxout weights `[k, I, U]`, biases `[k, U]`,
//! softmax weights `[I, C]`.

use super::Tensor;

/// `c[B,U] = a[B,I] @ b[I,U]` (row-major, cache-friendly ikj loop order).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let (ib, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ia, ib, "matmul inner dims: {:?} @ {:?}", a.shape(), b.shape());
    let mut out = vec![0.0f32; ba * ub];
    let ad = a.data();
    let bd = b.data();
    for i in 0..ba {
        for kk in 0..ia {
            let aik = ad[i * ia + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[kk * ub..(kk + 1) * ub];
            let orow = &mut out[i * ub..(i + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    Tensor::from_vec(&[ba, ub], out)
}

/// `c[B,I] = a[B,U] @ b[I,U]^T` (backprop through a dense layer).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ua) = (a.shape()[0], a.shape()[1]);
    let (ib, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ua, ub, "matmul_nt inner dims");
    let mut out = vec![0.0f32; ba * ib];
    let ad = a.data();
    let bd = b.data();
    for i in 0..ba {
        let arow = &ad[i * ua..(i + 1) * ua];
        for j in 0..ib {
            let brow = &bd[j * ub..(j + 1) * ub];
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            out[i * ib + j] = acc;
        }
    }
    Tensor::from_vec(&[ba, ib], out)
}

/// `c[I,U] = a[B,I]^T @ b[B,U]` (weight gradient of a dense layer).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let (bb, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ba, bb, "matmul_tn batch dims");
    let mut out = vec![0.0f32; ia * ub];
    let ad = a.data();
    let bd = b.data();
    for n in 0..ba {
        let arow = &ad[n * ia..(n + 1) * ia];
        let brow = &bd[n * ub..(n + 1) * ub];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * ub..(i + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor::from_vec(&[ia, ub], out)
}

/// Row-wise log-softmax of a `[B, C]` tensor (numerically stabilized).
pub fn log_softmax(x: &Tensor) -> Tensor {
    let (b, c) = (x.shape()[0], x.shape()[1]);
    let mut out = x.data().to_vec();
    for row in out.chunks_mut(c) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| ((v - m) as f64).exp()).sum::<f64>().ln() as f32 + m;
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
    Tensor::from_vec(&[b, c], out)
}

/// Row-wise argmax of a `[B, C]` tensor.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let c = x.shape()[1];
    x.data()
        .chunks(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Sum over axis 0 of a `[B, C]` tensor → `[C]`.
pub fn sum_rows(x: &Tensor) -> Tensor {
    let (b, c) = (x.shape()[0], x.shape()[1]);
    let mut out = vec![0.0f32; c];
    for n in 0..b {
        for j in 0..c {
            out[j] += x.at2(n, j);
        }
    }
    Tensor::from_vec(&[c], out)
}

/// One-hot encode labels into `[B, n_classes]`.
pub fn one_hot(labels: &[usize], n_classes: usize) -> Tensor {
    let mut out = vec![0.0f32; labels.len() * n_classes];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < n_classes, "label {l} out of range");
        out[i * n_classes + l] = 1.0;
    }
    Tensor::from_vec(&[labels.len(), n_classes], out)
}

/// Scale columns of a weight tensor so each incoming vector has norm ≤ c
/// (max-norm constraint, paper section 8.1). Fan-in axes: all but the last
/// for 2-D `[I, U]`; axis 1 for maxout `[k, I, U]`. `c ≤ 0` disables.
pub fn max_norm_inplace(w: &mut Tensor, c: f32) {
    if c <= 0.0 {
        return;
    }
    match w.shape().len() {
        2 => {
            let (i_dim, u_dim) = (w.shape()[0], w.shape()[1]);
            for u in 0..u_dim {
                let mut ss = 0.0f64;
                for i in 0..i_dim {
                    let v = w.data()[i * u_dim + u] as f64;
                    ss += v * v;
                }
                let norm = ss.sqrt() as f32;
                if norm > c {
                    let s = c / norm.max(1e-7);
                    for i in 0..i_dim {
                        w.data_mut()[i * u_dim + u] *= s;
                    }
                }
            }
        }
        3 => {
            let (k, i_dim, u_dim) = (w.shape()[0], w.shape()[1], w.shape()[2]);
            for kk in 0..k {
                for u in 0..u_dim {
                    let mut ss = 0.0f64;
                    for i in 0..i_dim {
                        let v = w.data()[(kk * i_dim + i) * u_dim + u] as f64;
                        ss += v * v;
                    }
                    let norm = ss.sqrt() as f32;
                    if norm > c {
                        let s = c / norm.max(1e-7);
                        for i in 0..i_dim {
                            w.data_mut()[(kk * i_dim + i) * u_dim + u] *= s;
                        }
                    }
                }
            }
        }
        d => panic!("max_norm: unsupported rank {d}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    fn rand_tensor(g: &mut Gen, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| g.f32_range(-2.0, 2.0)).collect())
    }

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                out.data_mut()[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        forall("matmul", |g: &mut Gen| {
            let (m, k, n) =
                (g.usize_range(1, 8), g.usize_range(1, 8), g.usize_range(1, 8));
            let a = rand_tensor(g, &[m, k]);
            let b = rand_tensor(g, &[k, n]);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            for (x, y) in fast.data().iter().zip(slow.data()) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn matmul_nt_tn_match_transpose_identities() {
        forall("nt/tn", |g: &mut Gen| {
            let (b, i, u) =
                (g.usize_range(1, 6), g.usize_range(1, 6), g.usize_range(1, 6));
            let a = rand_tensor(g, &[b, u]);
            let w = rand_tensor(g, &[i, u]);
            // a @ w^T via explicit transpose
            let mut wt = Tensor::zeros(&[u, i]);
            for x in 0..i {
                for y in 0..u {
                    wt.data_mut()[y * i + x] = w.at2(x, y);
                }
            }
            let want = naive_matmul(&a, &wt);
            let got = matmul_nt(&a, &w);
            for (x, y) in got.data().iter().zip(want.data()) {
                assert!((x - y).abs() < 1e-4);
            }

            let xs = rand_tensor(g, &[b, i]);
            let ys = rand_tensor(g, &[b, u]);
            let mut xt = Tensor::zeros(&[i, b]);
            for r in 0..b {
                for cidx in 0..i {
                    xt.data_mut()[cidx * b + r] = xs.at2(r, cidx);
                }
            }
            let want2 = naive_matmul(&xt, &ys);
            let got2 = matmul_tn(&xs, &ys);
            for (x, y) in got2.data().iter().zip(want2.data()) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn log_softmax_rows_sum_to_one() {
        forall("log_softmax", |g: &mut Gen| {
            let (b, c) = (g.usize_range(1, 5), g.usize_range(2, 10));
            let x = rand_tensor(g, &[b, c]);
            let ls = log_softmax(&x);
            for row in ls.data().chunks(c) {
                let s: f64 = row.iter().map(|v| (*v as f64).exp()).sum();
                assert!((s - 1.0).abs() < 1e-5, "sum={s}");
                assert!(row.iter().all(|v| *v <= 1e-6));
            }
        });
    }

    #[test]
    fn log_softmax_shift_invariant() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let y = Tensor::from_vec(&[1, 3], vec![101.0, 102.0, 103.0]);
        let a = log_softmax(&x);
        let b = log_softmax(&y);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_and_one_hot() {
        let x = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 0.5, 0.2, 0.4]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
        let oh = one_hot(&[1, 0], 3);
        assert_eq!(oh.data(), &[0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn sum_rows_matches_loop() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(sum_rows(&x).data(), &[5.0, 7.0, 9.0]);
    }

    #[test]
    fn max_norm_caps_column_norms() {
        forall("max_norm", |g: &mut Gen| {
            let (k, i, u) =
                (g.usize_range(1, 3), g.usize_range(1, 6), g.usize_range(1, 6));
            let mut w = rand_tensor(g, &[k, i, u]);
            w.map_inplace(|x| x * 10.0);
            max_norm_inplace(&mut w, 1.5);
            for kk in 0..k {
                for uu in 0..u {
                    let mut ss = 0.0f32;
                    for ii in 0..i {
                        let v = w.at3(kk, ii, uu);
                        ss += v * v;
                    }
                    assert!(ss.sqrt() <= 1.5 + 1e-4);
                }
            }
        });
    }

    #[test]
    fn max_norm_disabled_when_c_nonpositive() {
        let mut w = Tensor::from_vec(&[2, 2], vec![10., 10., 10., 10.]);
        let orig = w.clone();
        max_norm_inplace(&mut w, 0.0);
        assert_eq!(w, orig);
    }

    #[test]
    fn max_norm_leaves_small_columns_untouched() {
        let mut w = Tensor::from_vec(&[2, 1], vec![0.3, 0.4]); // norm 0.5
        max_norm_inplace(&mut w, 1.0);
        assert_eq!(w.data(), &[0.3, 0.4]);
    }
}
