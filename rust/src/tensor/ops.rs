//! The linear algebra the maxout networks need, tested against naive loops.
//!
//! Shapes follow the L2 model exactly (python/compile/model.py):
//! activations `[B, I]`, maxout weights `[k, I, U]`, biases `[k, U]`,
//! softmax weights `[I, C]`.
//!
//! The three matmul flavours (`NN`, `NT`, `TN`) run a blocked serial
//! kernel for small problems and split output rows across OS threads
//! (`std::thread::scope` — no external thread-pool crate offline) once a
//! problem crosses [`par_matmul_threshold`] FLOPs. Each output element is
//! written by exactly one thread and every per-element accumulation runs
//! in the same k-order as the seed's naive loops, so results are
//! bit-identical at any thread count (see the determinism tests below,
//! and EXPERIMENTS.md §Perf for the measured speedups).
//!
//! Slice-level entry points (`matmul_sl` & co.) exist so the golden model
//! can contract per-filter sub-blocks of the `[k, I, U]` maxout weight
//! tensors without materializing copies.
//!
//! Every flavour also has a fused quantize-aware variant (`matmul_sl_q`
//! & co.): the [`QuantEpilogue`] — optional bias add, rounding, clipping
//! and `QuantStats` counting — runs over each output tile right after
//! the tile's accumulation finishes, while it is still cache-hot,
//! instead of as a second whole-tensor sweep. Per-tile stats are merged
//! deterministically in tile order (u64 counter addition, so totals are
//! order-insensitive anyway), and stochastic rounding samples come from
//! the epilogue's counter-based [`crate::arith::ElemRng`], keyed on each
//! element's flat index. Both together make the fused kernels
//! **bit-identical** to the two-pass path (plain kernel +
//! `QuantEpilogue::run` sweep) at any thread count — enforced by
//! `tests/fused_parity.rs` and DESIGN.md §Fused quantized GEMM.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::int_gemm::{self, Packed};
use super::Tensor;
use crate::arith::{QuantEpilogue, QuantStats};

/// FLOP count (2·m·k·n) above which a matmul goes parallel. Override with
/// `LPDNN_PAR_MATMUL` (a FLOP count; `0` forces everything parallel,
/// a huge value forces serial).
pub fn par_matmul_threshold() -> usize {
    static THRESHOLD: OnceLock<usize> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        std::env::var("LPDNN_PAR_MATMUL")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1 << 20)
    })
}

/// Worker-thread cap: `LPDNN_THREADS` or the machine's parallelism.
pub fn max_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("LPDNN_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Threads to use for a matmul of `flops` total work over `rows` output
/// rows (1 = stay serial).
fn plan_threads(flops: usize, rows: usize) -> usize {
    if flops < par_matmul_threshold() {
        1
    } else {
        max_threads().min(rows).max(1)
    }
}

/// The env-driven auto plan with an additional caller-side cap, for
/// callers that are themselves one of several concurrent workers (the
/// data-parallel training shards): `cap = 0` keeps the exact auto plan,
/// otherwise the plan is clamped to `cap` so N workers × their GEMM
/// threads stay inside the machine. Threading splits output rows only,
/// so any cap is a pure perf choice — results are bit-identical at
/// every thread count.
pub fn plan_threads_capped(flops: usize, rows: usize, cap: usize) -> usize {
    let t = plan_threads(flops, rows);
    if cap == 0 {
        t
    } else {
        t.min(cap).max(1)
    }
}

/// K-dimension block size for the serial kernels: one `[KC, n]` panel of
/// `b` stays resident in L1/L2 while all rows stream over it.
const KC: usize = 128;

/// Serial blocked kernel: `out[m,n] += a[m,kd] @ b[kd,n]` where
/// `m = out.len() / n`. Per-row accumulation order is ascending k —
/// identical to the naive ikj loops, so blocking never changes results.
fn mm_nn_serial(a: &[f32], b: &[f32], out: &mut [f32], kd: usize, n: usize) {
    if n == 0 || kd == 0 {
        return;
    }
    let m = out.len() / n;
    let mut kb = 0;
    while kb < kd {
        let kend = (kb + KC).min(kd);
        for i in 0..m {
            let arow = &a[i * kd..(i + 1) * kd];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
        kb = kend;
    }
}

/// Serial kernel: `out[m,ib] = a[m,ua] @ b[ib,ua]^T` (dot products), with
/// `m = out.len() / ib`.
fn mm_nt_serial(a: &[f32], b: &[f32], out: &mut [f32], ua: usize, ib: usize) {
    if ib == 0 {
        return;
    }
    let m = out.len() / ib;
    for i in 0..m {
        let arow = &a[i * ua..(i + 1) * ua];
        let orow = &mut out[i * ib..(i + 1) * ib];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * ua..(j + 1) * ua];
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
}

/// Serial kernel for a row-slab of the TN product: accumulates
/// `out[ii,u] += a[nrow, i0+ii] * b[nrow, u]` over all `ba` batch rows,
/// for `ii in 0..out.len()/ub`. Batch accumulation is ascending — same
/// order as the seed's loops.
fn mm_tn_serial(a: &[f32], b: &[f32], out: &mut [f32], ba: usize, ia: usize, ub: usize, i0: usize) {
    if ub == 0 {
        return;
    }
    let icount = out.len() / ub;
    for nrow in 0..ba {
        let arow = &a[nrow * ia..(nrow + 1) * ia];
        let brow = &b[nrow * ub..(nrow + 1) * ub];
        for ii in 0..icount {
            let av = arow[i0 + ii];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[ii * ub..(ii + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `[m,n] = a[m,kd] @ b[kd,n]` over flat slices, with an explicit thread
/// count (the public wrappers pick it via [`par_matmul_threshold`]).
pub fn matmul_sl_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    kd: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * kd, "matmul a size");
    assert_eq!(b.len(), kd * n, "matmul b size");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || kd == 0 {
        return out;
    }
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        mm_nn_serial(a, b, &mut out, kd, n);
        return out;
    }
    let rows_per = m.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / n;
            let asub = &a[i0 * kd..(i0 + rows) * kd];
            s.spawn(move || mm_nn_serial(asub, b, ochunk, kd, n));
        }
    });
    out
}

/// `[m,kd] @ [kd,n]` over flat slices, auto-threaded.
pub fn matmul_sl(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize) -> Vec<f32> {
    matmul_sl_threads(a, b, m, kd, n, plan_threads(2 * m * kd * n, m))
}

/// `dst = a[m,ua] @ b[ib,ua]^T` over flat slices with an explicit
/// thread count — the one NT row-partitioning implementation every
/// plain-NT entry point shares (the bit-identity invariant depends on
/// the alloc and `_into` forms chunking rows identically). Assigns
/// `dst` (the serial NT kernel writes dot products).
pub fn matmul_nt_sl_into_threads(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    m: usize,
    ua: usize,
    ib: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * ua, "matmul_nt a size");
    assert_eq!(b.len(), ib * ua, "matmul_nt b size");
    assert_eq!(dst.len(), m * ib, "matmul_nt dst size");
    if m == 0 || ib == 0 {
        return;
    }
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        mm_nt_serial(a, b, dst, ua, ib);
        return;
    }
    let rows_per = m.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, ochunk) in dst.chunks_mut(rows_per * ib).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / ib;
            let asub = &a[i0 * ua..(i0 + rows) * ua];
            s.spawn(move || mm_nt_serial(asub, b, ochunk, ua, ib));
        }
    });
}

/// `[m,ib] = a[m,ua] @ b[ib,ua]^T` over flat slices with explicit threads.
pub fn matmul_nt_sl_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    threads: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * ib];
    matmul_nt_sl_into_threads(a, b, &mut out, m, ua, ib, threads);
    out
}

/// `[m,ua] @ [ib,ua]^T` over flat slices, auto-threaded.
pub fn matmul_nt_sl(a: &[f32], b: &[f32], m: usize, ua: usize, ib: usize) -> Vec<f32> {
    matmul_nt_sl_threads(a, b, m, ua, ib, plan_threads(2 * m * ua * ib, m))
}

/// `dst = a[m,ua] @ b[ib,ua]^T` over flat slices, auto-threaded — the
/// allocation-free form of [`matmul_nt_sl`] (assigns `dst`, same bits).
/// Hot-loop callers with a reusable buffer (the conv dx path) use this
/// to avoid a fresh `Vec` per call.
pub fn matmul_nt_sl_into(a: &[f32], b: &[f32], dst: &mut [f32], m: usize, ua: usize, ib: usize) {
    matmul_nt_sl_into_threads(a, b, dst, m, ua, ib, plan_threads(2 * m * ua * ib, m));
}

/// `[ia,ub] = a[ba,ia]^T @ b[ba,ub]` over flat slices with explicit
/// threads (split over the `ia` output rows).
pub fn matmul_tn_sl_threads(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), ba * ia, "matmul_tn a size");
    assert_eq!(b.len(), ba * ub, "matmul_tn b size");
    let mut out = vec![0.0f32; ia * ub];
    if ia == 0 || ub == 0 || ba == 0 {
        return out;
    }
    let nt = threads.min(ia).max(1);
    if nt <= 1 {
        mm_tn_serial(a, b, &mut out, ba, ia, ub, 0);
        return out;
    }
    let rows_per = ia.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, ochunk) in out.chunks_mut(rows_per * ub).enumerate() {
            let i0 = ci * rows_per;
            s.spawn(move || mm_tn_serial(a, b, ochunk, ba, ia, ub, i0));
        }
    });
    out
}

/// `[ba,ia]^T @ [ba,ub]` over flat slices, auto-threaded.
pub fn matmul_tn_sl(a: &[f32], b: &[f32], ba: usize, ia: usize, ub: usize) -> Vec<f32> {
    matmul_tn_sl_threads(a, b, ba, ia, ub, plan_threads(2 * ba * ia * ub, ia))
}

// ---------------------------------------------------------------------------
// Fused quantize-aware GEMM kernels
// ---------------------------------------------------------------------------

/// Run the fused epilogue over one output tile of `rows × n` elements
/// starting at flat element `offset` of the logical output: add the bias
/// row (if any), then quantize in place with stats. Bit-identical to
/// doing the same two steps in separate whole-tensor passes. (Thin alias
/// over [`QuantEpilogue::run_biased`], the shared implementation.)
fn fused_epilogue(
    chunk: &mut [f32],
    n: usize,
    bias: Option<&[f32]>,
    epi: QuantEpilogue,
    offset: u64,
) -> QuantStats {
    epi.run_biased(chunk, n, bias, offset)
}

/// Fused `dst += a[m,kd] @ b[kd,n]`, then bias add + quantization in the
/// block epilogue, with an explicit thread count. `dst` is accumulated
/// onto (pass zeros for a plain product) and holds the *quantized*
/// output on return; the returned [`QuantStats`] are the site's overflow
/// counters, merged over tiles in tile order.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_q_into_threads(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    assert_eq!(a.len(), m * kd, "matmul_q a size");
    assert_eq!(b.len(), kd * n, "matmul_q b size");
    assert_eq!(dst.len(), m * n, "matmul_q dst size");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "matmul_q bias size");
    }
    if m == 0 || n == 0 {
        return QuantStats::default();
    }
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        mm_nn_serial(a, b, dst, kd, n);
        return fused_epilogue(dst, n, bias, epi, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * n).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / n;
            let asub = &a[i0 * kd..(i0 + rows) * kd];
            tiles.push(s.spawn(move || {
                mm_nn_serial(asub, b, ochunk, kd, n);
                fused_epilogue(ochunk, n, bias, epi, (i0 * n) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("fused matmul worker"));
        }
    });
    stats
}

/// [`matmul_sl_q_into_threads`] with the auto thread plan.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_q_into(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) -> QuantStats {
    matmul_sl_q_into_threads(a, b, bias, dst, m, kd, n, epi, plan_threads(2 * m * kd * n, m))
}

/// Allocating form of the fused NN kernel with explicit threads.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_q_threads(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * n];
    let st = matmul_sl_q_into_threads(a, b, bias, &mut out, m, kd, n, epi, threads);
    (out, st)
}

/// Fused quantized `[m,kd] @ [kd,n]` (+ optional bias row), auto-threaded.
pub fn matmul_sl_q(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    matmul_sl_q_threads(a, b, bias, m, kd, n, epi, plan_threads(2 * m * kd * n, m))
}

/// Fused `dst = a[m,ua] @ b[ib,ua]^T` + quantization epilogue with an
/// explicit thread count. Unlike the NN/TN flavours this *assigns* `dst`
/// (the serial NT kernel writes dot products, it does not accumulate).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_sl_q_into_threads(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    assert_eq!(a.len(), m * ua, "matmul_nt_q a size");
    assert_eq!(b.len(), ib * ua, "matmul_nt_q b size");
    assert_eq!(dst.len(), m * ib, "matmul_nt_q dst size");
    if m == 0 || ib == 0 {
        return QuantStats::default();
    }
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        mm_nt_serial(a, b, dst, ua, ib);
        return fused_epilogue(dst, ib, None, epi, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * ib).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / ib;
            let asub = &a[i0 * ua..(i0 + rows) * ua];
            tiles.push(s.spawn(move || {
                mm_nt_serial(asub, b, ochunk, ua, ib);
                fused_epilogue(ochunk, ib, None, epi, (i0 * ib) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("fused matmul_nt worker"));
        }
    });
    stats
}

/// Allocating form of the fused NT kernel with explicit threads.
pub fn matmul_nt_sl_q_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * ib];
    let st = matmul_nt_sl_q_into_threads(a, b, &mut out, m, ua, ib, epi, threads);
    (out, st)
}

/// Fused quantized `[m,ua] @ [ib,ua]^T`, auto-threaded.
pub fn matmul_nt_sl_q(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    matmul_nt_sl_q_threads(a, b, m, ua, ib, epi, plan_threads(2 * m * ua * ib, m))
}

/// Fused `dst += a[ba,ia]^T @ b[ba,ub]` + quantization epilogue with an
/// explicit thread count. `dst` is accumulated onto (pass zeros for a
/// plain product) and holds the quantized output on return.
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_sl_q_into_threads(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    assert_eq!(a.len(), ba * ia, "matmul_tn_q a size");
    assert_eq!(b.len(), ba * ub, "matmul_tn_q b size");
    assert_eq!(dst.len(), ia * ub, "matmul_tn_q dst size");
    if ia == 0 || ub == 0 {
        return QuantStats::default();
    }
    let nt = threads.min(ia).max(1);
    if nt <= 1 {
        mm_tn_serial(a, b, dst, ba, ia, ub, 0);
        return fused_epilogue(dst, ub, None, epi, 0);
    }
    let rows_per = ia.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * ub).enumerate() {
            let i0 = ci * rows_per;
            tiles.push(s.spawn(move || {
                mm_tn_serial(a, b, ochunk, ba, ia, ub, i0);
                fused_epilogue(ochunk, ub, None, epi, (i0 * ub) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("fused matmul_tn worker"));
        }
    });
    stats
}

/// [`matmul_tn_sl_q_into_threads`] with the auto thread plan.
pub fn matmul_tn_sl_q_into(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
) -> QuantStats {
    matmul_tn_sl_q_into_threads(a, b, dst, ba, ia, ub, epi, plan_threads(2 * ba * ia * ub, ia))
}

/// Allocating form of the fused TN kernel with explicit threads.
pub fn matmul_tn_sl_q_threads(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; ia * ub];
    let st = matmul_tn_sl_q_into_threads(a, b, &mut out, ba, ia, ub, epi, threads);
    (out, st)
}

/// Fused quantized `[ba,ia]^T @ [ba,ub]`, auto-threaded.
pub fn matmul_tn_sl_q(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    matmul_tn_sl_q_threads(a, b, ba, ia, ub, epi, plan_threads(2 * ba * ia * ub, ia))
}

// ---------------------------------------------------------------------------
// QuantGemmImpl dispatch: simulated-f32 vs integer-domain per site
// ---------------------------------------------------------------------------

/// Which lowering a fused quantized GEMM site runs with.
///
/// `Simulated` is the reference: f32 multiplies + [`QuantEpilogue`].
/// `IntDomain` packs both operands to i8/i16 on a common power-of-two
/// grid ([`int_gemm::pack`]), multiplies in the integer domain with i32
/// accumulators and converts back exactly. `Split` is the integer path
/// for deep/wide sites whose *whole-reduction* worst case exceeds
/// [`int_gemm::ACC_BOUND`] while individual products still fit: the
/// k-reduction runs in exact-i32 segments folded into i64 totals under
/// a per-output headroom guard (see `int_gemm`'s module docs). Both
/// integer lowerings are bit-identical to `Simulated` whenever selected
/// — `tests/int_gemm_parity.rs` enforces it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantGemmImpl {
    /// f32 multiplies, quantization simulated by the fused epilogue.
    Simulated,
    /// i8/i16 × i8/i16 → i32 MACs, exact conversion back to f32.
    IntDomain,
    /// Segmented i32 MACs with i64 carry for deep/wide reductions.
    Split,
}

/// Why a site (with the integer domain enabled) fell back to the
/// simulated kernel. Ordered by check order in the planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SimReason {
    /// The accumulated destination held non-`+0.0` bits.
    DirtyDst,
    /// An operand did not pack onto a common power-of-two i16 grid.
    Unpackable,
    /// The product exponent left the exact-conversion window.
    ExpWindow,
    /// Individual products exceed `ACC_BOUND` — not even [`Split`]
    /// can reproduce the simulated kernel's rounding.
    AccBound,
}

/// Which integer lowering a planned (non-Simulated) site rides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum IntKind {
    Whole,
    Split,
}

/// Per-site lowering-outcome counters ([`QuantGemmImpl`] plus the
/// rejection reason for simulated fallbacks). Fields are atomics so the
/// layer graph can own one tally per GEMM site while data-parallel
/// workers record concurrently; totals are sums of per-call increments
/// and therefore deterministic at any worker count.
#[derive(Debug, Default)]
pub struct GemmSiteTally {
    int: AtomicU64,
    split: AtomicU64,
    disabled: AtomicU64,
    dirty_dst: AtomicU64,
    unpackable: AtomicU64,
    exp_window: AtomicU64,
    acc_bound: AtomicU64,
}

impl GemmSiteTally {
    pub fn new() -> GemmSiteTally {
        GemmSiteTally::default()
    }

    fn record_kind(&self, kind: IntKind) {
        match kind {
            IntKind::Whole => &self.int,
            IntKind::Split => &self.split,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    fn record_sim(&self, why: SimReason) {
        match why {
            SimReason::DirtyDst => &self.dirty_dst,
            SimReason::Unpackable => &self.unpackable,
            SimReason::ExpWindow => &self.exp_window,
            SimReason::AccBound => &self.acc_bound,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    fn record_disabled(&self) {
        self.disabled.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the counters (relaxed loads — callers read between
    /// steps, not mid-GEMM).
    pub fn counts(&self) -> GemmSiteCounts {
        GemmSiteCounts {
            int: self.int.load(Ordering::Relaxed),
            split: self.split.load(Ordering::Relaxed),
            disabled: self.disabled.load(Ordering::Relaxed),
            dirty_dst: self.dirty_dst.load(Ordering::Relaxed),
            unpackable: self.unpackable.load(Ordering::Relaxed),
            exp_window: self.exp_window.load(Ordering::Relaxed),
            acc_bound: self.acc_bound.load(Ordering::Relaxed),
        }
    }
}

/// A plain snapshot of a [`GemmSiteTally`]: how many dispatches of one
/// GEMM site rode each lowering, with simulated fallbacks broken down
/// by rejection reason. Surfaced as the `int_gemm_sites` section of
/// `RunReport` and the `int_gemm_dispatch` row of serve reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GemmSiteCounts {
    /// Whole-reduction integer dispatches ([`QuantGemmImpl::IntDomain`]).
    pub int: u64,
    /// Split-accumulator integer dispatches ([`QuantGemmImpl::Split`]).
    pub split: u64,
    /// Calls made with the integer domain disabled for the step.
    pub disabled: u64,
    /// Simulated: the accumulated destination held non-`+0.0` bits.
    pub dirty_dst: u64,
    /// Simulated: an operand did not pack to an i16 grid.
    pub unpackable: u64,
    /// Simulated: product exponent outside the exact window.
    pub exp_window: u64,
    /// Simulated: individual products exceed the f32-exact bound.
    pub acc_bound: u64,
}

impl GemmSiteCounts {
    /// Total simulated-path dispatches (every non-integer outcome).
    pub fn simulated(&self) -> u64 {
        self.disabled + self.dirty_dst + self.unpackable + self.exp_window + self.acc_bound
    }

    /// Total dispatches recorded.
    pub fn total(&self) -> u64 {
        self.int + self.split + self.simulated()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Field-wise accumulate (for merging worker-local snapshots).
    pub fn merge(&mut self, o: &GemmSiteCounts) {
        self.int += o.int;
        self.split += o.split;
        self.disabled += o.disabled;
        self.dirty_dst += o.dirty_dst;
        self.unpackable += o.unpackable;
        self.exp_window += o.exp_window;
        self.acc_bound += o.acc_bound;
    }
}

/// Decide which integer lowering a pair of packs supports at depth
/// `inner`: the whole-reduction bound picks [`IntKind::Whole`], a
/// too-deep reduction whose individual products still fit picks
/// [`IntKind::Split`] ([`int_gemm::seg_len`]), and anything else is a
/// reasoned rejection. Shared by the per-call and cached-b planners so
/// the two can never diverge.
fn packed_kind(ap: &Packed, bp: &Packed, inner: usize) -> Result<IntKind, SimReason> {
    let pe = ap.exp + bp.exp;
    if !(int_gemm::EXP_LO..=int_gemm::EXP_HI).contains(&pe) {
        return Err(SimReason::ExpWindow);
    }
    if int_gemm::accum_bound_ok(inner, ap.amax, bp.amax) {
        Ok(IntKind::Whole)
    } else if int_gemm::seg_len(ap.amax, bp.amax).is_some() {
        Ok(IntKind::Split)
    } else {
        Err(SimReason::AccBound)
    }
}

/// Pack both operands and run the full eligibility condition for the
/// integer-domain lowerings at one GEMM site:
///
/// 1. `accum_dst` (the `dst +=` operand of the NN/TN flavours, `None`
///    for the assigning NT flavour) holds only `+0.0` bits — otherwise
///    the pre-existing values would have to be folded into the integer
///    accumulation, which the packing can't express;
/// 2. both operands pack onto common power-of-two grids;
/// 3. the product exponent sits in the exact-conversion window;
/// 4. the worst-case partial sum picks the lowering: within
///    [`int_gemm::ACC_BOUND`] → whole-reduction integer, otherwise
///    split accumulators when individual products still fit.
fn int_packs(
    a: &[f32],
    b: &[f32],
    inner: usize,
    accum_dst: Option<&[f32]>,
) -> Result<(Packed, Packed, IntKind), SimReason> {
    if let Some(d) = accum_dst {
        if !d.iter().all(|v| v.to_bits() == 0) {
            return Err(SimReason::DirtyDst);
        }
    }
    let ap = int_gemm::pack(a).ok_or(SimReason::Unpackable)?;
    let bp = int_gemm::pack(b).ok_or(SimReason::Unpackable)?;
    let kind = packed_kind(&ap, &bp, inner)?;
    Ok((ap, bp, kind))
}

/// Map a planning outcome onto the public [`QuantGemmImpl`].
fn kind_to_impl(kind: Result<IntKind, SimReason>) -> QuantGemmImpl {
    match kind {
        Ok(IntKind::Whole) => QuantGemmImpl::IntDomain,
        Ok(IntKind::Split) => QuantGemmImpl::Split,
        Err(_) => QuantGemmImpl::Simulated,
    }
}

/// The lowering the `*_qd` entry points would select for these operands
/// (with `int_domain` enabled). `inner` is the contraction depth (`kd`
/// for NN, `ua` for NT, `ba` for TN); `accum_dst` is the accumulated
/// destination for the NN/TN flavours, `None` for NT. Exposed so the
/// parity suite can assert the integer path actually engaged (a parity
/// test that silently fell back would prove nothing).
pub fn quant_gemm_plan(
    a: &[f32],
    b: &[f32],
    inner: usize,
    accum_dst: Option<&[f32]>,
) -> QuantGemmImpl {
    kind_to_impl(int_packs(a, b, inner, accum_dst).map(|(_, _, k)| k))
}

/// Integer NN tile: rows `i0 .. i0+rows` of `acc += a @ b`, dispatched
/// over the i8/i16 storage classes of the packed operands.
#[allow(clippy::too_many_arguments)]
fn int_nn_tile(
    ap: &Packed,
    bp: &Packed,
    acc: &mut [i32],
    i0: usize,
    rows: usize,
    kd: usize,
    n: usize,
) {
    use int_gemm::PackedInts as P;
    let r = i0 * kd..(i0 + rows) * kd;
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => int_gemm::imm_nn_serial(&av[r], &bv[..], acc, kd, n),
        (P::I8(av), P::I16(bv)) => int_gemm::imm_nn_serial(&av[r], &bv[..], acc, kd, n),
        (P::I16(av), P::I8(bv)) => int_gemm::imm_nn_serial(&av[r], &bv[..], acc, kd, n),
        (P::I16(av), P::I16(bv)) => int_gemm::imm_nn_serial(&av[r], &bv[..], acc, kd, n),
    }
}

/// Integer NT tile: rows `i0 .. i0+rows` of `acc = a @ b^T`.
#[allow(clippy::too_many_arguments)]
fn int_nt_tile(
    ap: &Packed,
    bp: &Packed,
    acc: &mut [i32],
    i0: usize,
    rows: usize,
    ua: usize,
    ib: usize,
) {
    use int_gemm::PackedInts as P;
    let r = i0 * ua..(i0 + rows) * ua;
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => int_gemm::imm_nt_serial(&av[r], &bv[..], acc, ua, ib),
        (P::I8(av), P::I16(bv)) => int_gemm::imm_nt_serial(&av[r], &bv[..], acc, ua, ib),
        (P::I16(av), P::I8(bv)) => int_gemm::imm_nt_serial(&av[r], &bv[..], acc, ua, ib),
        (P::I16(av), P::I16(bv)) => int_gemm::imm_nt_serial(&av[r], &bv[..], acc, ua, ib),
    }
}

/// Integer TN row-slab tile at offset `i0` (whole operands, the kernel
/// indexes the slab).
#[allow(clippy::too_many_arguments)]
fn int_tn_tile(
    ap: &Packed,
    bp: &Packed,
    acc: &mut [i32],
    ba: usize,
    ia: usize,
    ub: usize,
    i0: usize,
) {
    use int_gemm::PackedInts as P;
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => int_gemm::imm_tn_serial(&av[..], &bv[..], acc, ba, ia, ub, i0),
        (P::I8(av), P::I16(bv)) => int_gemm::imm_tn_serial(&av[..], &bv[..], acc, ba, ia, ub, i0),
        (P::I16(av), P::I8(bv)) => int_gemm::imm_tn_serial(&av[..], &bv[..], acc, ba, ia, ub, i0),
        (P::I16(av), P::I16(bv)) => int_gemm::imm_tn_serial(&av[..], &bv[..], acc, ba, ia, ub, i0),
    }
}

/// Integer-domain NN: same row partitioning, epilogue offsets and
/// tile-order stats merge as [`matmul_sl_q_into_threads`], with the i32
/// accumulator chunked in lockstep with `dst`.
#[allow(clippy::too_many_arguments)]
fn int_nn_run(
    ap: &Packed,
    bp: &Packed,
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(m).max(1);
    let mut acc = vec![0i32; m * n];
    if nt <= 1 {
        int_nn_tile(ap, bp, &mut acc, 0, m, kd, n);
        return epi.run_int(&acc, scale, n, bias, dst, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for ((ci, ochunk), achunk) in
            dst.chunks_mut(rows_per * n).enumerate().zip(acc.chunks_mut(rows_per * n))
        {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / n;
            tiles.push(s.spawn(move || {
                int_nn_tile(ap, bp, achunk, i0, rows, kd, n);
                epi.run_int(achunk, scale, n, bias, ochunk, (i0 * n) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("int matmul worker"));
        }
    });
    stats
}

/// Integer-domain NT: mirrors [`matmul_nt_sl_q_into_threads`].
#[allow(clippy::too_many_arguments)]
fn int_nt_run(
    ap: &Packed,
    bp: &Packed,
    dst: &mut [f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(m).max(1);
    let mut acc = vec![0i32; m * ib];
    if nt <= 1 {
        int_nt_tile(ap, bp, &mut acc, 0, m, ua, ib);
        return epi.run_int(&acc, scale, ib, None, dst, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for ((ci, ochunk), achunk) in
            dst.chunks_mut(rows_per * ib).enumerate().zip(acc.chunks_mut(rows_per * ib))
        {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / ib;
            tiles.push(s.spawn(move || {
                int_nt_tile(ap, bp, achunk, i0, rows, ua, ib);
                epi.run_int(achunk, scale, ib, None, ochunk, (i0 * ib) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("int matmul_nt worker"));
        }
    });
    stats
}

/// Integer-domain TN: mirrors [`matmul_tn_sl_q_into_threads`].
#[allow(clippy::too_many_arguments)]
fn int_tn_run(
    ap: &Packed,
    bp: &Packed,
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(ia).max(1);
    let mut acc = vec![0i32; ia * ub];
    if nt <= 1 {
        int_tn_tile(ap, bp, &mut acc, ba, ia, ub, 0);
        return epi.run_int(&acc, scale, ub, None, dst, 0);
    }
    let rows_per = ia.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for ((ci, ochunk), achunk) in
            dst.chunks_mut(rows_per * ub).enumerate().zip(acc.chunks_mut(rows_per * ub))
        {
            let i0 = ci * rows_per;
            tiles.push(s.spawn(move || {
                int_tn_tile(ap, bp, achunk, ba, ia, ub, i0);
                epi.run_int(achunk, scale, ub, None, ochunk, (i0 * ub) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("int matmul_tn worker"));
        }
    });
    stats
}

/// Split-accumulator NN tile: rows `i0 .. i0+rows` of `out = a @ b`
/// written as f32 (bailed elements come from the f32 replay, so the
/// tile writes f32 directly rather than an i32 accumulator).
#[allow(clippy::too_many_arguments)]
fn split_nn_tile(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    rows: usize,
    kd: usize,
    n: usize,
    prod: u64,
    scale: f32,
) {
    use int_gemm::PackedInts as P;
    let r = i0 * kd..(i0 + rows) * kd;
    let af = &a[r.clone()];
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => {
            int_gemm::imm_nn_split_serial(&av[r.clone()], &bv[..], af, b, out, kd, n, prod, scale)
        }
        (P::I8(av), P::I16(bv)) => {
            int_gemm::imm_nn_split_serial(&av[r.clone()], &bv[..], af, b, out, kd, n, prod, scale)
        }
        (P::I16(av), P::I8(bv)) => {
            int_gemm::imm_nn_split_serial(&av[r.clone()], &bv[..], af, b, out, kd, n, prod, scale)
        }
        (P::I16(av), P::I16(bv)) => {
            int_gemm::imm_nn_split_serial(&av[r.clone()], &bv[..], af, b, out, kd, n, prod, scale)
        }
    }
}

/// Split-accumulator NT tile: rows `i0 .. i0+rows` of `out = a @ b^T`.
#[allow(clippy::too_many_arguments)]
fn split_nt_tile(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    rows: usize,
    ua: usize,
    ib: usize,
    prod: u64,
    scale: f32,
) {
    use int_gemm::PackedInts as P;
    let r = i0 * ua..(i0 + rows) * ua;
    let af = &a[r.clone()];
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => {
            int_gemm::imm_nt_split_serial(&av[r.clone()], &bv[..], af, b, out, ua, ib, prod, scale)
        }
        (P::I8(av), P::I16(bv)) => {
            int_gemm::imm_nt_split_serial(&av[r.clone()], &bv[..], af, b, out, ua, ib, prod, scale)
        }
        (P::I16(av), P::I8(bv)) => {
            int_gemm::imm_nt_split_serial(&av[r.clone()], &bv[..], af, b, out, ua, ib, prod, scale)
        }
        (P::I16(av), P::I16(bv)) => {
            int_gemm::imm_nt_split_serial(&av[r.clone()], &bv[..], af, b, out, ua, ib, prod, scale)
        }
    }
}

/// Split-accumulator TN row-slab tile at offset `i0` (whole operands,
/// the kernel indexes the slab; `out.len()` fixes the slab width).
#[allow(clippy::too_many_arguments)]
fn split_tn_tile(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    i0: usize,
    prod: u64,
    scale: f32,
) {
    use int_gemm::PackedInts as P;
    match (&ap.ints, &bp.ints) {
        (P::I8(av), P::I8(bv)) => {
            int_gemm::imm_tn_split_serial(&av[..], &bv[..], a, b, out, ba, ia, ub, i0, prod, scale)
        }
        (P::I8(av), P::I16(bv)) => {
            int_gemm::imm_tn_split_serial(&av[..], &bv[..], a, b, out, ba, ia, ub, i0, prod, scale)
        }
        (P::I16(av), P::I8(bv)) => {
            int_gemm::imm_tn_split_serial(&av[..], &bv[..], a, b, out, ba, ia, ub, i0, prod, scale)
        }
        (P::I16(av), P::I16(bv)) => {
            int_gemm::imm_tn_split_serial(&av[..], &bv[..], a, b, out, ba, ia, ub, i0, prod, scale)
        }
    }
}

/// Split-accumulator NN: same row partitioning, epilogue offsets and
/// tile-order stats merge as [`matmul_sl_q_into_threads`]. The tiles
/// write f32 directly (bailed elements bypass the integer total), so
/// the epilogue is the plain bias-then-quantize [`QuantEpilogue::run_biased`]
/// the simulated kernel uses — not `run_int`.
#[allow(clippy::too_many_arguments)]
fn split_nn_run(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let prod = ap.amax as u64 * bp.amax as u64;
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        split_nn_tile(ap, bp, a, b, dst, 0, m, kd, n, prod, scale);
        return epi.run_biased(dst, n, bias, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * n).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / n;
            tiles.push(s.spawn(move || {
                split_nn_tile(ap, bp, a, b, ochunk, i0, rows, kd, n, prod, scale);
                epi.run_biased(ochunk, n, bias, (i0 * n) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("split matmul worker"));
        }
    });
    stats
}

/// Split-accumulator NT: mirrors [`matmul_nt_sl_q_into_threads`].
#[allow(clippy::too_many_arguments)]
fn split_nt_run(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let prod = ap.amax as u64 * bp.amax as u64;
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(m).max(1);
    if nt <= 1 {
        split_nt_tile(ap, bp, a, b, dst, 0, m, ua, ib, prod, scale);
        return epi.run_biased(dst, ib, None, 0);
    }
    let rows_per = m.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * ib).enumerate() {
            let i0 = ci * rows_per;
            let rows = ochunk.len() / ib;
            tiles.push(s.spawn(move || {
                split_nt_tile(ap, bp, a, b, ochunk, i0, rows, ua, ib, prod, scale);
                epi.run_biased(ochunk, ib, None, (i0 * ib) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("split matmul_nt worker"));
        }
    });
    stats
}

/// Split-accumulator TN: mirrors [`matmul_tn_sl_q_into_threads`].
#[allow(clippy::too_many_arguments)]
fn split_tn_run(
    ap: &Packed,
    bp: &Packed,
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
) -> QuantStats {
    let prod = ap.amax as u64 * bp.amax as u64;
    let scale = int_gemm::exp2f(ap.exp + bp.exp);
    let nt = threads.min(ia).max(1);
    if nt <= 1 {
        split_tn_tile(ap, bp, a, b, dst, ba, ia, ub, 0, prod, scale);
        return epi.run_biased(dst, ub, None, 0);
    }
    let rows_per = ia.div_ceil(nt);
    let mut stats = QuantStats::default();
    std::thread::scope(|s| {
        let mut tiles = Vec::new();
        for (ci, ochunk) in dst.chunks_mut(rows_per * ub).enumerate() {
            let i0 = ci * rows_per;
            tiles.push(s.spawn(move || {
                split_tn_tile(ap, bp, a, b, ochunk, ba, ia, ub, i0, prod, scale);
                epi.run_biased(ochunk, ub, None, (i0 * ub) as u64)
            }));
        }
        for t in tiles {
            stats.merge(t.join().expect("split matmul_tn worker"));
        }
    });
    stats
}

/// Dispatching form of [`matmul_sl_q_into_threads`]: when `int_domain`
/// is set and the site is eligible (see [`quant_gemm_plan`]), run the
/// integer-domain lowering (whole-reduction or split-accumulator);
/// otherwise the simulated kernel. All paths produce identical bits and
/// [`QuantStats`]. `tally` (when present) records the outcome of every
/// non-empty dispatch for the per-site `int_gemm_sites` report section.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_into_threads(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
    tally: Option<&GemmSiteTally>,
) -> QuantStats {
    if m > 0 && n > 0 {
        if int_domain {
            assert_eq!(a.len(), m * kd, "matmul_qd a size");
            assert_eq!(b.len(), kd * n, "matmul_qd b size");
            assert_eq!(dst.len(), m * n, "matmul_qd dst size");
            match int_packs(a, b, kd, Some(dst)) {
                Ok((ap, bp, kind)) => {
                    if let Some(t) = tally {
                        t.record_kind(kind);
                    }
                    return match kind {
                        IntKind::Whole => int_nn_run(&ap, &bp, bias, dst, m, kd, n, epi, threads),
                        IntKind::Split => {
                            split_nn_run(&ap, &bp, a, b, bias, dst, m, kd, n, epi, threads)
                        }
                    };
                }
                Err(why) => {
                    if let Some(t) = tally {
                        t.record_sim(why);
                    }
                }
            }
        } else if let Some(t) = tally {
            t.record_disabled();
        }
    }
    matmul_sl_q_into_threads(a, b, bias, dst, m, kd, n, epi, threads)
}

/// [`matmul_sl_qd_into_threads`] with the auto thread plan.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_into(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    int_domain: bool,
) -> QuantStats {
    matmul_sl_qd_into_threads(
        a,
        b,
        bias,
        dst,
        m,
        kd,
        n,
        epi,
        plan_threads(2 * m * kd * n, m),
        int_domain,
        None,
    )
}

/// Allocating dispatching NN form with explicit threads.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_threads(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * n];
    let st =
        matmul_sl_qd_into_threads(a, b, bias, &mut out, m, kd, n, epi, threads, int_domain, None);
    (out, st)
}

/// Dispatching fused quantized `[m,kd] @ [kd,n]`, auto-threaded.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    matmul_sl_qd_threads(a, b, bias, m, kd, n, epi, plan_threads(2 * m * kd * n, m), int_domain)
}

/// Dispatching form of [`matmul_nt_sl_q_into_threads`] (assigns `dst`;
/// no accumulated-destination eligibility condition).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_sl_qd_into_threads(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
    tally: Option<&GemmSiteTally>,
) -> QuantStats {
    if m > 0 && ib > 0 {
        if int_domain {
            assert_eq!(a.len(), m * ua, "matmul_nt_qd a size");
            assert_eq!(b.len(), ib * ua, "matmul_nt_qd b size");
            assert_eq!(dst.len(), m * ib, "matmul_nt_qd dst size");
            match int_packs(a, b, ua, None) {
                Ok((ap, bp, kind)) => {
                    if let Some(t) = tally {
                        t.record_kind(kind);
                    }
                    return match kind {
                        IntKind::Whole => int_nt_run(&ap, &bp, dst, m, ua, ib, epi, threads),
                        IntKind::Split => {
                            split_nt_run(&ap, &bp, a, b, dst, m, ua, ib, epi, threads)
                        }
                    };
                }
                Err(why) => {
                    if let Some(t) = tally {
                        t.record_sim(why);
                    }
                }
            }
        } else if let Some(t) = tally {
            t.record_disabled();
        }
    }
    matmul_nt_sl_q_into_threads(a, b, dst, m, ua, ib, epi, threads)
}

/// Allocating dispatching NT form with explicit threads.
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_sl_qd_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * ib];
    let st =
        matmul_nt_sl_qd_into_threads(a, b, &mut out, m, ua, ib, epi, threads, int_domain, None);
    (out, st)
}

/// Dispatching fused quantized `[m,ua] @ [ib,ua]^T`, auto-threaded.
pub fn matmul_nt_sl_qd(
    a: &[f32],
    b: &[f32],
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    matmul_nt_sl_qd_threads(a, b, m, ua, ib, epi, plan_threads(2 * m * ua * ib, m), int_domain)
}

/// Dispatching form of [`matmul_tn_sl_q_into_threads`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_sl_qd_into_threads(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
    tally: Option<&GemmSiteTally>,
) -> QuantStats {
    if ia > 0 && ub > 0 {
        if int_domain {
            assert_eq!(a.len(), ba * ia, "matmul_tn_qd a size");
            assert_eq!(b.len(), ba * ub, "matmul_tn_qd b size");
            assert_eq!(dst.len(), ia * ub, "matmul_tn_qd dst size");
            match int_packs(a, b, ba, Some(dst)) {
                Ok((ap, bp, kind)) => {
                    if let Some(t) = tally {
                        t.record_kind(kind);
                    }
                    return match kind {
                        IntKind::Whole => int_tn_run(&ap, &bp, dst, ba, ia, ub, epi, threads),
                        IntKind::Split => {
                            split_tn_run(&ap, &bp, a, b, dst, ba, ia, ub, epi, threads)
                        }
                    };
                }
                Err(why) => {
                    if let Some(t) = tally {
                        t.record_sim(why);
                    }
                }
            }
        } else if let Some(t) = tally {
            t.record_disabled();
        }
    }
    matmul_tn_sl_q_into_threads(a, b, dst, ba, ia, ub, epi, threads)
}

/// [`matmul_tn_sl_qd_into_threads`] with the auto thread plan.
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_sl_qd_into(
    a: &[f32],
    b: &[f32],
    dst: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    int_domain: bool,
) -> QuantStats {
    matmul_tn_sl_qd_into_threads(
        a,
        b,
        dst,
        ba,
        ia,
        ub,
        epi,
        plan_threads(2 * ba * ia * ub, ia),
        int_domain,
        None,
    )
}

/// Allocating dispatching TN form with explicit threads.
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_sl_qd_threads(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    threads: usize,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; ia * ub];
    let st =
        matmul_tn_sl_qd_into_threads(a, b, &mut out, ba, ia, ub, epi, threads, int_domain, None);
    (out, st)
}

/// Dispatching fused quantized `[ba,ia]^T @ [ba,ub]`, auto-threaded.
pub fn matmul_tn_sl_qd(
    a: &[f32],
    b: &[f32],
    ba: usize,
    ia: usize,
    ub: usize,
    epi: QuantEpilogue,
    int_domain: bool,
) -> (Vec<f32>, QuantStats) {
    matmul_tn_sl_qd_threads(a, b, ba, ia, ub, epi, plan_threads(2 * ba * ia * ub, ia), int_domain)
}

// ---------------------------------------------------------------------------
// Cached-b dispatch: the weight operand arrives pre-packed
// ---------------------------------------------------------------------------

/// Pack `a` and re-run the full eligibility condition of [`int_packs`]
/// against a **pre-packed** `b` operand. The cached pack carries the
/// same `amax`/`exp` a fresh pack of the same values would (packing is
/// deterministic), so the checks — clean accumulated destination,
/// exponent window, whole-vs-split accumulator bound — are decided
/// identically to the per-call path (both funnel through
/// [`packed_kind`]); only the redundant repack of `b` is skipped.
fn int_pack_a_cached(
    a: &[f32],
    bp: &Packed,
    inner: usize,
    accum_dst: Option<&[f32]>,
) -> Result<(Packed, IntKind), SimReason> {
    if let Some(d) = accum_dst {
        if !d.iter().all(|v| v.to_bits() == 0) {
            return Err(SimReason::DirtyDst);
        }
    }
    let ap = int_gemm::pack(a).ok_or(SimReason::Unpackable)?;
    let kind = packed_kind(&ap, bp, inner)?;
    Ok((ap, kind))
}

/// The lowering the `*_qd_cached` entry points would select given a
/// cached `b` pack (`None` = the cache recorded `b` as unpackable).
/// Exposed for the same engagement-assertion reason as
/// [`quant_gemm_plan`].
pub fn quant_gemm_plan_cached(
    a: &[f32],
    bp: Option<&Packed>,
    inner: usize,
    accum_dst: Option<&[f32]>,
) -> QuantGemmImpl {
    match bp {
        Some(bp) => kind_to_impl(int_pack_a_cached(a, bp, inner, accum_dst).map(|(_, k)| k)),
        None => QuantGemmImpl::Simulated,
    }
}

/// [`matmul_sl_qd_into_threads`] with the `b` operand's pack supplied by
/// a [`PackedCache`]: `Some(bp)` skips the per-call repack of `b`,
/// `None` means the cache found `b` unpackable and the call goes
/// straight to the simulated kernel. Callers only reach this entry with
/// the integer domain enabled; bit-identity to the uncached entry holds
/// because a valid cache feeds the kernel the byte-identical pack.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_cached_into_threads(
    a: &[f32],
    b: &[f32],
    bp: Option<&Packed>,
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
    threads: usize,
    tally: Option<&GemmSiteTally>,
) -> QuantStats {
    if m > 0 && n > 0 {
        match bp {
            Some(bp) => {
                assert_eq!(a.len(), m * kd, "matmul_qd a size");
                assert_eq!(b.len(), kd * n, "matmul_qd b size");
                assert_eq!(bp.len(), b.len(), "cached b pack length");
                assert_eq!(dst.len(), m * n, "matmul_qd dst size");
                match int_pack_a_cached(a, bp, kd, Some(dst)) {
                    Ok((ap, kind)) => {
                        if let Some(t) = tally {
                            t.record_kind(kind);
                        }
                        return match kind {
                            IntKind::Whole => {
                                int_nn_run(&ap, bp, bias, dst, m, kd, n, epi, threads)
                            }
                            IntKind::Split => {
                                split_nn_run(&ap, bp, a, b, bias, dst, m, kd, n, epi, threads)
                            }
                        };
                    }
                    Err(why) => {
                        if let Some(t) = tally {
                            t.record_sim(why);
                        }
                    }
                }
            }
            // A `None` slab means the cache already proved these weight
            // values unpackable — record the same reason a fresh pack
            // attempt would produce.
            None => {
                if let Some(t) = tally {
                    t.record_sim(SimReason::Unpackable);
                }
            }
        }
    }
    matmul_sl_q_into_threads(a, b, bias, dst, m, kd, n, epi, threads)
}

/// [`matmul_sl_qd_cached_into_threads`] with the auto thread plan.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_cached_into(
    a: &[f32],
    b: &[f32],
    bp: Option<&Packed>,
    bias: Option<&[f32]>,
    dst: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) -> QuantStats {
    matmul_sl_qd_cached_into_threads(
        a,
        b,
        bp,
        bias,
        dst,
        m,
        kd,
        n,
        epi,
        plan_threads(2 * m * kd * n, m),
        None,
    )
}

/// Allocating cached-b NN form, auto-threaded.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sl_qd_cached(
    a: &[f32],
    b: &[f32],
    bp: Option<&Packed>,
    bias: Option<&[f32]>,
    m: usize,
    kd: usize,
    n: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * n];
    let st = matmul_sl_qd_cached_into(a, b, bp, bias, &mut out, m, kd, n, epi);
    (out, st)
}

/// [`matmul_nt_sl_qd_threads`] with a cached `b` pack (the NT flavour's
/// `b` is the same weight slab the NN forward packs, so one cache entry
/// serves both orientations).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_sl_qd_cached_threads(
    a: &[f32],
    b: &[f32],
    bp: Option<&Packed>,
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
    threads: usize,
    tally: Option<&GemmSiteTally>,
) -> (Vec<f32>, QuantStats) {
    let mut out = vec![0.0f32; m * ib];
    if m > 0 && ib > 0 {
        match bp {
            Some(bp) => {
                assert_eq!(a.len(), m * ua, "matmul_nt_qd a size");
                assert_eq!(b.len(), ib * ua, "matmul_nt_qd b size");
                assert_eq!(bp.len(), b.len(), "cached b pack length");
                match int_pack_a_cached(a, bp, ua, None) {
                    Ok((ap, kind)) => {
                        if let Some(t) = tally {
                            t.record_kind(kind);
                        }
                        let st = match kind {
                            IntKind::Whole => {
                                int_nt_run(&ap, bp, &mut out, m, ua, ib, epi, threads)
                            }
                            IntKind::Split => {
                                split_nt_run(&ap, bp, a, b, &mut out, m, ua, ib, epi, threads)
                            }
                        };
                        return (out, st);
                    }
                    Err(why) => {
                        if let Some(t) = tally {
                            t.record_sim(why);
                        }
                    }
                }
            }
            None => {
                if let Some(t) = tally {
                    t.record_sim(SimReason::Unpackable);
                }
            }
        }
    }
    let st = matmul_nt_sl_q_into_threads(a, b, &mut out, m, ua, ib, epi, threads);
    (out, st)
}

/// Allocating cached-b NT form, auto-threaded.
pub fn matmul_nt_sl_qd_cached(
    a: &[f32],
    b: &[f32],
    bp: Option<&Packed>,
    m: usize,
    ua: usize,
    ib: usize,
    epi: QuantEpilogue,
) -> (Vec<f32>, QuantStats) {
    matmul_nt_sl_qd_cached_threads(a, b, bp, m, ua, ib, epi, plan_threads(2 * m * ua * ib, m), None)
}

/// `c[B,U] = a[B,I] @ b[I,U]` (blocked, parallel above the threshold).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let (ib, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ia, ib, "matmul inner dims: {:?} @ {:?}", a.shape(), b.shape());
    Tensor::from_vec(&[ba, ub], matmul_sl(a.data(), b.data(), ba, ia, ub))
}

/// [`matmul`] with an explicit thread count (bench/test hook).
pub fn par_matmul(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let (ib, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ia, ib, "par_matmul inner dims");
    Tensor::from_vec(&[ba, ub], matmul_sl_threads(a.data(), b.data(), ba, ia, ub, threads))
}

/// `c[B,I] = a[B,U] @ b[I,U]^T` (backprop through a dense layer).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ua) = (a.shape()[0], a.shape()[1]);
    let (ib, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ua, ub, "matmul_nt inner dims");
    Tensor::from_vec(&[ba, ib], matmul_nt_sl(a.data(), b.data(), ba, ua, ib))
}

/// `c[I,U] = a[B,I]^T @ b[B,U]` (weight gradient of a dense layer).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ba, ia) = (a.shape()[0], a.shape()[1]);
    let (bb, ub) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ba, bb, "matmul_tn batch dims");
    Tensor::from_vec(&[ia, ub], matmul_tn_sl(a.data(), b.data(), ba, ia, ub))
}

/// Row-wise log-softmax of a `[B, C]` tensor (numerically stabilized).
pub fn log_softmax(x: &Tensor) -> Tensor {
    let (b, c) = (x.shape()[0], x.shape()[1]);
    let mut out = x.data().to_vec();
    for row in out.chunks_mut(c) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| ((v - m) as f64).exp()).sum::<f64>().ln() as f32 + m;
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
    Tensor::from_vec(&[b, c], out)
}

/// Row-wise argmax of a `[B, C]` tensor.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let c = x.shape()[1];
    x.data()
        .chunks(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Sum over axis 0 of a `[B, C]` tensor → `[C]`.
pub fn sum_rows(x: &Tensor) -> Tensor {
    let (b, c) = (x.shape()[0], x.shape()[1]);
    Tensor::from_vec(&[c], sum_rows_sl(x.data(), b, c))
}

/// Sum over axis 0 of a flat `[b, c]` slice → `[c]`.
pub fn sum_rows_sl(x: &[f32], b: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; c];
    for n in 0..b {
        for j in 0..c {
            out[j] += x[n * c + j];
        }
    }
    out
}

/// One-hot encode labels into `[B, n_classes]`.
pub fn one_hot(labels: &[usize], n_classes: usize) -> Tensor {
    let mut out = vec![0.0f32; labels.len() * n_classes];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < n_classes, "label {l} out of range");
        out[i * n_classes + l] = 1.0;
    }
    Tensor::from_vec(&[labels.len(), n_classes], out)
}

/// Scale columns of a weight tensor so each incoming vector has norm ≤ c
/// (max-norm constraint, paper section 8.1). Fan-in axes: all but the last
/// for 2-D `[I, U]`; axis 1 for maxout `[k, I, U]`. `c ≤ 0` disables.
pub fn max_norm_inplace(w: &mut Tensor, c: f32) {
    if c <= 0.0 {
        return;
    }
    match w.shape().len() {
        2 => {
            let (i_dim, u_dim) = (w.shape()[0], w.shape()[1]);
            for u in 0..u_dim {
                let mut ss = 0.0f64;
                for i in 0..i_dim {
                    let v = w.data()[i * u_dim + u] as f64;
                    ss += v * v;
                }
                let norm = ss.sqrt() as f32;
                if norm > c {
                    let s = c / norm.max(1e-7);
                    for i in 0..i_dim {
                        w.data_mut()[i * u_dim + u] *= s;
                    }
                }
            }
        }
        3 => {
            let (k, i_dim, u_dim) = (w.shape()[0], w.shape()[1], w.shape()[2]);
            for kk in 0..k {
                for u in 0..u_dim {
                    let mut ss = 0.0f64;
                    for i in 0..i_dim {
                        let v = w.data()[(kk * i_dim + i) * u_dim + u] as f64;
                        ss += v * v;
                    }
                    let norm = ss.sqrt() as f32;
                    if norm > c {
                        let s = c / norm.max(1e-7);
                        for i in 0..i_dim {
                            w.data_mut()[(kk * i_dim + i) * u_dim + u] *= s;
                        }
                    }
                }
            }
        }
        d => panic!("max_norm: unsupported rank {d}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    fn rand_tensor(g: &mut Gen, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| g.f32_range(-2.0, 2.0)).collect())
    }

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                out.data_mut()[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        forall("matmul", |g: &mut Gen| {
            let (m, k, n) =
                (g.usize_range(1, 8), g.usize_range(1, 8), g.usize_range(1, 8));
            let a = rand_tensor(g, &[m, k]);
            let b = rand_tensor(g, &[k, n]);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            for (x, y) in fast.data().iter().zip(slow.data()) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_serial() {
        // Odd, non-divisible shapes exercise the chunking edge cases; the
        // blocked kernels keep per-element accumulation in k-order, so
        // serial and any thread count must agree EXACTLY.
        let mut g = Gen::new(0xBEEF);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 150, 5), (97, 300, 33), (64, 784, 128)] {
            let a = rand_tensor(&mut g, &[m, k]);
            let b = rand_tensor(&mut g, &[k, n]);
            let serial = par_matmul(&a, &b, 1);
            for threads in [2usize, 3, 4, 8] {
                let par = par_matmul(&a, &b, threads);
                assert_eq!(serial.data(), par.data(), "m={m} k={k} n={n} t={threads}");
            }
            let slow = naive_matmul(&a, &b);
            for (x, y) in serial.data().iter().zip(slow.data()) {
                assert!((x - y).abs() < 1e-3 * k as f32, "vs naive: {x} {y}");
            }
        }
    }

    #[test]
    fn parallel_nt_tn_match_serial() {
        let mut g = Gen::new(0xCAFE);
        let (b, i, u) = (53usize, 77usize, 31usize);
        let act = rand_tensor(&mut g, &[b, u]);
        let w = rand_tensor(&mut g, &[i, u]);
        let serial = matmul_nt_sl_threads(act.data(), w.data(), b, u, i, 1);
        let par = matmul_nt_sl_threads(act.data(), w.data(), b, u, i, 4);
        assert_eq!(serial, par);

        let xs = rand_tensor(&mut g, &[b, i]);
        let ys = rand_tensor(&mut g, &[b, u]);
        let serial = matmul_tn_sl_threads(xs.data(), ys.data(), b, i, u, 1);
        let par = matmul_tn_sl_threads(xs.data(), ys.data(), b, i, u, 4);
        assert_eq!(serial, par);
    }

    #[test]
    fn matmul_nt_tn_match_transpose_identities() {
        forall("nt/tn", |g: &mut Gen| {
            let (b, i, u) =
                (g.usize_range(1, 6), g.usize_range(1, 6), g.usize_range(1, 6));
            let a = rand_tensor(g, &[b, u]);
            let w = rand_tensor(g, &[i, u]);
            // a @ w^T via explicit transpose
            let mut wt = Tensor::zeros(&[u, i]);
            for x in 0..i {
                for y in 0..u {
                    wt.data_mut()[y * i + x] = w.at2(x, y);
                }
            }
            let want = naive_matmul(&a, &wt);
            let got = matmul_nt(&a, &w);
            for (x, y) in got.data().iter().zip(want.data()) {
                assert!((x - y).abs() < 1e-4);
            }

            let xs = rand_tensor(g, &[b, i]);
            let ys = rand_tensor(g, &[b, u]);
            let mut xt = Tensor::zeros(&[i, b]);
            for r in 0..b {
                for cidx in 0..i {
                    xt.data_mut()[cidx * b + r] = xs.at2(r, cidx);
                }
            }
            let want2 = naive_matmul(&xt, &ys);
            let got2 = matmul_tn(&xs, &ys);
            for (x, y) in got2.data().iter().zip(want2.data()) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn slice_matmul_views_match_tensor_path() {
        // The golden model contracts w[k,I,U] sub-blocks without copying;
        // the slice API on a sub-range must equal the copied-tensor path.
        let mut g = Gen::new(7);
        let (k, i, u, b) = (3usize, 11usize, 6usize, 9usize);
        let w = rand_tensor(&mut g, &[k, i, u]);
        let x = rand_tensor(&mut g, &[b, i]);
        for j in 0..k {
            let wj = Tensor::from_vec(&[i, u], w.data()[j * i * u..(j + 1) * i * u].to_vec());
            let want = matmul(&x, &wj);
            let got = matmul_sl(x.data(), &w.data()[j * i * u..(j + 1) * i * u], b, i, u);
            assert_eq!(want.data(), &got[..]);
        }
    }

    #[test]
    fn log_softmax_rows_sum_to_one() {
        forall("log_softmax", |g: &mut Gen| {
            let (b, c) = (g.usize_range(1, 5), g.usize_range(2, 10));
            let x = rand_tensor(g, &[b, c]);
            let ls = log_softmax(&x);
            for row in ls.data().chunks(c) {
                let s: f64 = row.iter().map(|v| (*v as f64).exp()).sum();
                assert!((s - 1.0).abs() < 1e-5, "sum={s}");
                assert!(row.iter().all(|v| *v <= 1e-6));
            }
        });
    }

    #[test]
    fn log_softmax_shift_invariant() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let y = Tensor::from_vec(&[1, 3], vec![101.0, 102.0, 103.0]);
        let a = log_softmax(&x);
        let b = log_softmax(&y);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_and_one_hot() {
        let x = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 0.5, 0.2, 0.4]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
        let oh = one_hot(&[1, 0], 3);
        assert_eq!(oh.data(), &[0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn sum_rows_matches_loop() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(sum_rows(&x).data(), &[5.0, 7.0, 9.0]);
    }

    #[test]
    fn max_norm_caps_column_norms() {
        forall("max_norm", |g: &mut Gen| {
            let (k, i, u) =
                (g.usize_range(1, 3), g.usize_range(1, 6), g.usize_range(1, 6));
            let mut w = rand_tensor(g, &[k, i, u]);
            w.map_inplace(|x| x * 10.0);
            max_norm_inplace(&mut w, 1.5);
            for kk in 0..k {
                for uu in 0..u {
                    let mut ss = 0.0f32;
                    for ii in 0..i {
                        let v = w.at3(kk, ii, uu);
                        ss += v * v;
                    }
                    assert!(ss.sqrt() <= 1.5 + 1e-4);
                }
            }
        });
    }

    #[test]
    fn max_norm_disabled_when_c_nonpositive() {
        let mut w = Tensor::from_vec(&[2, 2], vec![10., 10., 10., 10.]);
        let orig = w.clone();
        max_norm_inplace(&mut w, 0.0);
        assert_eq!(w, orig);
    }

    #[test]
    fn max_norm_leaves_small_columns_untouched() {
        let mut w = Tensor::from_vec(&[2, 1], vec![0.3, 0.4]); // norm 0.5
        max_norm_inplace(&mut w, 1.0);
        assert_eq!(w.data(), &[0.3, 0.4]);
    }

    /// Values on a 2^-4 grid with small magnitudes — always eligible for
    /// the integer-domain lowering at these test shapes.
    fn grid_vec(g: &mut Gen, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.i32_range(-100, 100) as f32 * 0.0625).collect()
    }

    #[test]
    fn qd_dispatch_is_bit_identical_to_simulated_on_grid_data() {
        use crate::arith::{FixedFormat, Quantizer};
        let mut g = Gen::new(0x1D0_6E44);
        let (m, kd, n) = (7usize, 13, 5);
        let a = grid_vec(&mut g, m * kd);
        let b = grid_vec(&mut g, kd * n);
        let bias = grid_vec(&mut g, n);
        let epi = QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(10, 3)));

        assert_eq!(
            quant_gemm_plan(&a, &b, kd, Some(&vec![0.0f32; m * n])),
            QuantGemmImpl::IntDomain
        );
        for threads in [1usize, 2, 4] {
            let (sim, st_sim) = matmul_sl_q_threads(&a, &b, Some(&bias), m, kd, n, epi, threads);
            let (int, st_int) =
                matmul_sl_qd_threads(&a, &b, Some(&bias), m, kd, n, epi, threads, true);
            assert_eq!(st_sim, st_int, "nn stats t={threads}");
            for (x, y) in sim.iter().zip(&int) {
                assert_eq!(x.to_bits(), y.to_bits(), "nn t={threads}");
            }

            let bt = b2_nt(&b, kd, n);
            let (sim, st_sim) = matmul_nt_sl_q_threads(&a, &bt, m, kd, n, epi, threads);
            let (int, st_int) = matmul_nt_sl_qd_threads(&a, &bt, m, kd, n, epi, threads, true);
            assert_eq!(st_sim, st_int, "nt stats t={threads}");
            for (x, y) in sim.iter().zip(&int) {
                assert_eq!(x.to_bits(), y.to_bits(), "nt t={threads}");
            }
        }
    }

    /// Reshape helper: an NT `b` operand `[ib, ua]` from the NN `b`.
    fn b2_nt(b: &[f32], kd: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * kd];
        for j in 0..n {
            for k in 0..kd {
                out[j * kd + k] = b[k * n + j];
            }
        }
        out
    }

    #[test]
    fn qd_tn_dispatch_is_bit_identical_to_simulated() {
        use crate::arith::{FixedFormat, Quantizer};
        let mut g = Gen::new(0x7E57_141);
        let (ba, ia, ub) = (9usize, 11, 6);
        let a = grid_vec(&mut g, ba * ia);
        let b = grid_vec(&mut g, ba * ub);
        let epi = QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(12, 0)));
        assert_eq!(
            quant_gemm_plan(&a, &b, ba, Some(&vec![0.0f32; ia * ub])),
            QuantGemmImpl::IntDomain
        );
        for threads in [1usize, 2, 4] {
            let (sim, st_sim) = matmul_tn_sl_q_threads(&a, &b, ba, ia, ub, epi, threads);
            let (int, st_int) = matmul_tn_sl_qd_threads(&a, &b, ba, ia, ub, epi, threads, true);
            assert_eq!(st_sim, st_int, "tn stats t={threads}");
            for (x, y) in sim.iter().zip(&int) {
                assert_eq!(x.to_bits(), y.to_bits(), "tn t={threads}");
            }
        }
    }

    #[test]
    fn qd_falls_back_when_ineligible_and_still_matches() {
        use crate::arith::Quantizer;
        let mut g = Gen::new(0xFA11_BACC);
        let (m, kd, n) = (4usize, 6, 3);
        // 0.1 has a 24-bit odd mantissa: never packs
        let mut a = grid_vec(&mut g, m * kd);
        a[5] = 0.1;
        let b = grid_vec(&mut g, kd * n);
        assert_eq!(quant_gemm_plan(&a, &b, kd, None), QuantGemmImpl::Simulated);

        // a non-(+0.0) accumulated dst also forces the simulated path
        let clean = grid_vec(&mut g, m * kd);
        let mut dirty = vec![0.0f32; m * n];
        dirty[2] = -0.0; // negative zero: bits != 0
        assert_eq!(quant_gemm_plan(&clean, &b, kd, Some(&dirty)), QuantGemmImpl::Simulated);
        assert_eq!(
            quant_gemm_plan(&clean, &b, kd, Some(&vec![0.0f32; m * n])),
            QuantGemmImpl::IntDomain
        );

        let epi = QuantEpilogue::new(Quantizer::float32());
        let (sim, st_sim) = matmul_sl_q_threads(&a, &b, None, m, kd, n, epi, 2);
        let (int, st_int) = matmul_sl_qd_threads(&a, &b, None, m, kd, n, epi, 2, true);
        assert_eq!(st_sim, st_int);
        for (x, y) in sim.iter().zip(&int) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Wide-format values on the 2^-4 grid (|int| up to 2047): single
    /// products fit the f32-exact bound but a handful of terms overflow
    /// it, so the planner must pick the split-accumulator lowering.
    fn wide_grid_vec(g: &mut Gen, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.i32_range(-2047, 2047) as f32 * 0.0625).collect()
    }

    #[test]
    fn qd_split_dispatch_is_bit_identical_and_tallied() {
        use crate::arith::{FixedFormat, Quantizer};
        let mut g = Gen::new(0x5917);
        let (m, kd, n) = (5usize, 8, 4);
        let mut a = wide_grid_vec(&mut g, m * kd);
        let mut b = wide_grid_vec(&mut g, kd * n);
        // Pin the amaxes so wc = kd·2047² > 2^24 while 2047² ≤ 2^24.
        a[0] = 2047.0 * 0.0625;
        b[0] = -2047.0 * 0.0625;
        let bias = grid_vec(&mut g, n);
        let epi = QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(16, 8)));
        assert_eq!(
            quant_gemm_plan(&a, &b, kd, Some(&vec![0.0f32; m * n])),
            QuantGemmImpl::Split
        );
        let tally = GemmSiteTally::new();
        for threads in [1usize, 2, 4] {
            let (sim, st_sim) = matmul_sl_q_threads(&a, &b, Some(&bias), m, kd, n, epi, threads);
            let mut out = vec![0.0f32; m * n];
            let st_split = matmul_sl_qd_into_threads(
                &a,
                &b,
                Some(&bias),
                &mut out,
                m,
                kd,
                n,
                epi,
                threads,
                true,
                Some(&tally),
            );
            assert_eq!(st_sim, st_split, "split nn stats t={threads}");
            for (x, y) in sim.iter().zip(&out) {
                assert_eq!(x.to_bits(), y.to_bits(), "split nn t={threads}");
            }
        }
        let c = tally.counts();
        assert_eq!((c.split, c.int, c.simulated()), (3, 0, 0));
    }

    #[test]
    fn gemm_site_tally_records_every_outcome_kind() {
        use crate::arith::Quantizer;
        let mut g = Gen::new(0x7A11_E7);
        let (m, kd, n) = (3usize, 5, 4);
        let a = grid_vec(&mut g, m * kd);
        let b = grid_vec(&mut g, kd * n);
        let epi = QuantEpilogue::new(Quantizer::float32());
        let tally = GemmSiteTally::new();
        assert!(tally.counts().is_empty());

        let mut out = vec![0.0f32; m * n];
        matmul_sl_qd_into_threads(&a, &b, None, &mut out, m, kd, n, epi, 1, false, Some(&tally));
        out.fill(0.0);
        matmul_sl_qd_into_threads(&a, &b, None, &mut out, m, kd, n, epi, 1, true, Some(&tally));
        let mut dirty = vec![0.0f32; m * n];
        dirty[1] = -0.0; // negative zero: bits != 0, accumulated dst is dirty
        matmul_sl_qd_into_threads(&a, &b, None, &mut dirty, m, kd, n, epi, 1, true, Some(&tally));
        let mut au = a.clone();
        au[0] = 0.1; // 24-bit odd mantissa: never packs
        out.fill(0.0);
        matmul_sl_qd_into_threads(&au, &b, None, &mut out, m, kd, n, epi, 1, true, Some(&tally));

        let c = tally.counts();
        assert_eq!((c.disabled, c.int, c.dirty_dst, c.unpackable), (1, 1, 1, 1));
        assert_eq!((c.split, c.exp_window, c.acc_bound), (0, 0, 0));
        assert_eq!(c.simulated(), 3);
        assert_eq!(c.total(), 4);
        let mut merged = GemmSiteCounts::default();
        merged.merge(&c);
        merged.merge(&c);
        assert_eq!(merged.total(), 8);
        assert!(!merged.is_empty());
    }

    #[test]
    fn qd_with_int_domain_off_is_the_simulated_path() {
        use crate::arith::{FixedFormat, Quantizer};
        let mut g = Gen::new(0x0FF);
        let (m, kd, n) = (3usize, 5, 4);
        let a = grid_vec(&mut g, m * kd);
        let b = grid_vec(&mut g, kd * n);
        let epi = QuantEpilogue::new(Quantizer::from_format(FixedFormat::new(8, 2)));
        let (sim, st_sim) = matmul_sl_q_threads(&a, &b, None, m, kd, n, epi, 1);
        let (off, st_off) = matmul_sl_qd_threads(&a, &b, None, m, kd, n, epi, 1, false);
        assert_eq!(st_sim, st_off);
        assert_eq!(
            sim.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            off.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
