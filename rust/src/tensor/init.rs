//! Parameter initialization (host side — the compiled step never inits).
//!
//! The manifest (`artifacts/manifest.json`) carries an init spec per
//! parameter: `glorot_uniform` with explicit fan-in/fan-out for weights,
//! `zeros` for biases — exactly what `python/compile/model.py` declares,
//! so the rust initializer is the single source of initial state.

use super::rng::Pcg32;
use super::Tensor;

/// Init spec as read from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    /// U(-limit, limit) with limit = sqrt(6 / (fan_in + fan_out))
    /// (Glorot & Bengio 2010 — what pylearn2's maxout used).
    GlorotUniform { fan_in: usize, fan_out: usize },
    Zeros,
}

impl InitSpec {
    /// Materialize a tensor of `shape` from this spec.
    pub fn realize(&self, shape: &[usize], rng: &mut Pcg32) -> Tensor {
        match self {
            InitSpec::Zeros => Tensor::zeros(shape),
            InitSpec::GlorotUniform { fan_in, fan_out } => {
                let limit = (6.0 / (*fan_in as f64 + *fan_out as f64)).sqrt() as f32;
                let n: usize = shape.iter().product();
                let data = (0..n).map(|_| rng.uniform_range(-limit, limit)).collect();
                Tensor::from_vec(shape, data)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_is_zero() {
        let mut rng = Pcg32::seeded(1);
        let t = InitSpec::Zeros.realize(&[3, 4], &mut rng);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn glorot_respects_limit_and_moments() {
        let mut rng = Pcg32::seeded(2);
        let spec = InitSpec::GlorotUniform { fan_in: 784, fan_out: 128 };
        let t = spec.realize(&[4, 784, 128], &mut rng);
        let limit = (6.0f64 / (784.0 + 128.0)).sqrt() as f32;
        assert!(t.data().iter().all(|&x| x.abs() <= limit));
        let mean = t.data().iter().sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < limit * 0.02, "mean={mean}");
        // variance of U(-L, L) is L²/3
        let var = t.data().iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        assert!((var - limit * limit / 3.0).abs() < limit * limit * 0.05);
    }

    #[test]
    fn deterministic_given_rng_state() {
        let spec = InitSpec::GlorotUniform { fan_in: 10, fan_out: 10 };
        let a = spec.realize(&[10, 10], &mut Pcg32::seeded(7));
        let b = spec.realize(&[10, 10], &mut Pcg32::seeded(7));
        assert_eq!(a, b);
    }
}
