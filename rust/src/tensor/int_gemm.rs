//! Integer-domain GEMM substrate: pack f32 operands that live on a common
//! power-of-two grid into i8/i16, multiply with i32 accumulators, and
//! prove the result bit-identical to the f32 kernels.
//!
//! The paper's point is that a low-precision *multiplier* is the cheap
//! unit; the fused kernels in [`super::ops`] still simulate fixed-point
//! with f32 multiplies. This module is the datapath that actually pays
//! in integers. The contract that makes it safe to swap in:
//!
//! **Eligibility ⇒ bit-identity.** A GEMM site may run in the integer
//! domain only when all of the following hold (checked per call by
//! [`pack`] + [`accum_bound_ok`] + the exponent window):
//!
//! 1. every element of both operands decomposes as `int · 2^p` with a
//!    *common* exponent `p` per operand and `|int| ≤ i16::MAX`
//!    ([`pack`] returns `None` otherwise — e.g. raw float32 data);
//! 2. the worst-case absolute sum `inner · amax_a · amax_b` is at most
//!    [`ACC_BOUND`] `= 2^24`: then every i32 partial sum is exact AND
//!    every f32 partial sum in the simulated kernel is exact (all
//!    intermediates are integers below the f32 mantissa limit), so the
//!    two paths compute the *same real number*, independent of k-order,
//!    blocking or zero-skipping;
//! 3. the product exponent `pa + pb` lies in `[`[`EXP_LO`]`, `[`EXP_HI`]`]`,
//!    so `acc as f32 * 2^(pa+pb)` is exact: any `S · 2^e` with
//!    `|S| ≤ 2^24` and `e ≥ -149` is representable (down to the f32
//!    subnormal floor) and `e ≤ 103` rules out overflow.
//!
//! Zero outputs agree in sign too: exact f32 accumulation that starts at
//! `+0.0` can only produce `+0.0` (IEEE-754 exact cancellation yields
//! `+0.0` in round-to-nearest, and `+0.0 + -0.0 = +0.0`), and an i32
//! accumulator of `0` converts to `+0.0`. Ineligible sites simply fall
//! back to the simulated kernels — which are the reference — so the
//! dispatch in `ops.rs` is bit-transparent *unconditionally*.
//!
//! **Split accumulators.** When condition 2 fails only because the
//! reduction is *deep* (the per-product magnitudes still satisfy
//! `amax_a · amax_b ≤ 2^24`, but `inner · amax_a · amax_b` does not),
//! the site is still exactly representable segment by segment: the
//! k-reduction is cut into segments, each segment accumulated exactly
//! in i32, and the segments folded in ascending k-order into an i64
//! running total. The fold alone is not enough for bit-identity — the
//! simulated kernel rounds after *every* f32 add — so the split
//! kernels size each segment from the running total's actual headroom:
//! with `prod = amax_a · amax_b`, the next segment takes
//! `(2^24 − |total|) / prod` terms (the first one therefore takes the
//! maximal [`seg_len`]). Inside such a segment every partial sum the
//! simulated kernel forms — `total` plus a prefix of the segment — is
//! an integer of magnitude ≤ `|total| + len · prod ≤ 2^24`, hence
//! every one of its f32 adds is exact, hence the simulated kernel
//! computes the same real number as the exact integer total and
//! `total as f32 * 2^(pa+pb)` reproduces its bits (same
//! exponent-window and zero-sign arguments as above). Real data
//! cancels, so the headroom regenerates and segments stay long; only
//! an output element whose `|total|` grows within one `prod` of the
//! bound — where not even a one-term segment is provably exact —
//! *bails*: it falls back to a verbatim replay of the simulated
//! kernel's own f32 loop (same k-order, same zero-skip behaviour per
//! orientation), so the answer is bit-identical by construction either
//! way. The other elements of the tile stay on the integer path.
//!
//! Inner loops are plain slice-zip reductions over widened i32 values:
//! contiguous layout, no gather, no data-dependent control flow inside
//! the innermost loop — the shape LLVM autovectorizes without `std::arch`
//! (the zero-dep constraint rules out mandatory intrinsics anyway).
//! On top of that the kernels hand-unroll the k-dimension 4-wide with
//! independent accumulators reduced in a fixed, documented order
//! (`(c0+c1)+(c2+c3)`): integer addition is associative, so the
//! reassociation cannot change a single bit — it only breaks the
//! loop-carried dependence chain so the backend can keep 4 MACs in
//! flight. i8 operands widen through the same generic path. The
//! pre-unroll NT dot-product loop survives as [`imm_nt_serial_ref`]
//! for A/B benchmarking.
//!
//! **Packed-operand caching.** Packing is a pure function of the operand
//! values, so a weight slab that has not changed since its last pack
//! repacks to byte-identical storage — [`PackedCache`] exploits that to
//! pack each weight slab once per value change (or adopted-scale move)
//! instead of once per GEMM call. A cache hit therefore feeds the
//! kernels the *exact* `Packed` the per-call path would rebuild, which
//! is why caching cannot perturb the bit-identity contract; the per-call
//! eligibility checks (accumulator bound, exponent window, clean
//! destination, the non-cached operand's packability) still run on
//! every dispatch. [`pack_calls`] counts every `pack` invocation
//! process-wide so benches and tests can measure packs avoided.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Maximum worst-case absolute sum for an eligible site: `2^24`, the f32
/// mantissa limit. Below it both the i32 and the simulated-f32
/// accumulations are exact (and i32 overflow is impossible by a margin
/// of `2^7`).
pub const ACC_BOUND: u64 = 1 << 24;

/// Lowest product exponent `pa + pb` for which `acc as f32 * 2^(pa+pb)`
/// is exact: the f32 subnormal floor `2^-149`.
pub const EXP_LO: i32 = -149;

/// Highest product exponent: `2^24 · 2^103 = 2^127 ≤ f32::MAX`, so the
/// conversion can never overflow.
pub const EXP_HI: i32 = 103;

/// K-dimension block size of the integer NN kernel (mirrors the f32
/// kernel's panel size; integer accumulation is exact so blocking is a
/// pure locality choice).
const KC: usize = 128;

/// Storage element of a packed operand: i8 or i16, widened to i32 in the
/// kernels' inner loops.
pub trait PackInt: Copy + Send + Sync {
    fn widen(self) -> i32;
}

impl PackInt for i8 {
    #[inline(always)]
    fn widen(self) -> i32 {
        self as i32
    }
}

impl PackInt for i16 {
    #[inline(always)]
    fn widen(self) -> i32 {
        self as i32
    }
}

/// The integer payload of a packed operand. i8 when every magnitude fits
/// (the common case for the paper's ≤ 8-bit storage grids), i16 up to
/// the 16-bit grids the sweeps use.
pub enum PackedInts {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

/// An f32 slice re-expressed exactly as `ints[i] · 2^exp`.
pub struct Packed {
    pub ints: PackedInts,
    /// Common power-of-two exponent: `value_i = ints[i] as f32 * 2^exp`.
    pub exp: i32,
    /// `max |ints[i]|` — input to the accumulator worst-case bound.
    pub amax: u32,
}

impl Packed {
    pub fn len(&self) -> usize {
        match &self.ints {
            PackedInts::I8(v) => v.len(),
            PackedInts::I16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the payload fits the narrow (i8) storage class.
    pub fn is_i8(&self) -> bool {
        matches!(self.ints, PackedInts::I8(_))
    }

    /// Exact inverse of [`pack`]: every element reproduces the original
    /// f32 bits (`-0.0` inputs come back as `+0.0`; pack treats all
    /// zeros as integer 0, which the GEMM bit-identity argument shows is
    /// unobservable in any accumulated output).
    pub fn unpack(&self) -> Vec<f32> {
        let s = exp2f(self.exp);
        match &self.ints {
            PackedInts::I8(v) => v.iter().map(|&i| i as f32 * s).collect(),
            PackedInts::I16(v) => v.iter().map(|&i| i as f32 * s).collect(),
        }
    }
}

/// Exact `2^e` as f32 for `e ∈ [-149, 127]` (computed in f64, where every
/// such power is normal, then narrowed — the narrowing is exact because
/// the value is representable, subnormals included).
pub fn exp2f(e: i32) -> f32 {
    2f64.powi(e) as f32
}

/// Decompose a finite f32 into `(m, e)` with `v = m · 2^e` and `m` odd
/// (or `(0, 0)` for ±0.0). Returns `None` for NaN/±inf.
fn decompose(v: f32) -> Option<(i32, i32)> {
    if v == 0.0 {
        return Some((0, 0));
    }
    let bits = v.to_bits();
    let biased = ((bits >> 23) & 0xFF) as i32;
    if biased == 0xFF {
        return None; // inf / NaN
    }
    let frac = (bits & 0x7F_FFFF) as i32;
    let (mut m, mut e) = if biased == 0 {
        (frac, -149) // subnormal
    } else {
        (frac | (1 << 23), biased - 127 - 23)
    };
    let tz = m.trailing_zeros() as i32;
    m >>= tz;
    e += tz;
    Some((if bits >> 31 != 0 { -m } else { m }, e))
}

/// Counts every [`pack`] invocation (hit or miss) process-wide.
static PACK_CALLS: AtomicU64 = AtomicU64::new(0);

/// Number of [`pack`] invocations since process start. Monotonic and
/// process-global (any thread, any caller), so only *deltas measured in
/// a single-threaded region* are meaningful — `bench_perf`'s
/// packed-vs-repack rows use it that way. Tests that need a
/// pollution-free count under a parallel test runner should prefer
/// [`PackedCache::builds`] via `Network::weight_pack_builds`.
pub fn pack_calls() -> u64 {
    PACK_CALLS.load(Ordering::Relaxed)
}

/// Pack an f32 slice onto a common power-of-two grid: `Some(p)` with
/// `xs[i] == p.ints[i] · 2^(p.exp)` exactly, or `None` when any element
/// is non-finite or the integers would not fit i16 (raw float32 data,
/// operands spanning > 15 octaves of grid, …). Quantized activations,
/// weights and gradients on the paper's storage formats always pack;
/// `None` just means "stay on the simulated path".
pub fn pack(xs: &[f32]) -> Option<Packed> {
    PACK_CALLS.fetch_add(1, Ordering::Relaxed);
    let mut dec = Vec::with_capacity(xs.len());
    let mut p: Option<i32> = None;
    for &v in xs {
        let (m, e) = decompose(v)?;
        if m != 0 {
            // fail fast on data that can never fit (odd mantissa wider
            // than 15 bits, e.g. generic float32 values)
            if m.unsigned_abs() > i16::MAX as u32 {
                return None;
            }
            p = Some(p.map_or(e, |p0| p0.min(e)));
        }
        dec.push((m, e));
    }
    let p = p.unwrap_or(0);
    let mut ints = Vec::with_capacity(xs.len());
    let mut amax: u32 = 0;
    for (m, e) in dec {
        if m == 0 {
            ints.push(0i16);
            continue;
        }
        let s = e - p; // ≥ 0 by construction of p
        if s > 14 {
            return None; // |m| ≥ 1 ⇒ |m << s| > i16::MAX
        }
        let mag = (m.unsigned_abs() as u64) << s;
        if mag > i16::MAX as u64 {
            return None;
        }
        amax = amax.max(mag as u32);
        ints.push(if m < 0 { -(mag as i16) } else { mag as i16 });
    }
    let ints = if amax <= i8::MAX as u32 {
        PackedInts::I8(ints.iter().map(|&v| v as i8).collect())
    } else {
        PackedInts::I16(ints)
    };
    Some(Packed { ints, exp: p, amax })
}

/// Worst-case absolute value of any partial sum at a GEMM site:
/// `inner · amax_a · amax_b` (saturating — a saturated value always
/// fails the bound check).
pub fn worst_case_sum(inner: usize, amax_a: u32, amax_b: u32) -> u64 {
    (inner as u64).saturating_mul(amax_a as u64).saturating_mul(amax_b as u64)
}

/// The accumulator eligibility bound: no i32 partial sum can exceed
/// `2^24`, which simultaneously guarantees i32 never overflows and the
/// simulated-f32 accumulation of the same products is exact.
pub fn accum_bound_ok(inner: usize, amax_a: u32, amax_b: u32) -> bool {
    worst_case_sum(inner, amax_a, amax_b) <= ACC_BOUND
}

/// Maximal exact-i32 segment length for a split-accumulator reduction:
/// the largest `s` with `s · amax_a · amax_b ≤` [`ACC_BOUND`] (so any
/// longer segment could exceed the bound in the worst case).
///
/// `None` when no split can help: a zero product means the whole-site
/// bound already accepts the site for any `inner` (splitting is moot),
/// and a product above `ACC_BOUND` means *individual products* are not
/// exactly representable in f32 — the simulated kernel rounds inside a
/// single multiply-add and no segmentation of the sum can reproduce
/// that, so the site must stay on the simulated path.
///
/// This is both the planner's Split-eligibility test (`Some` ⇒ the
/// split kernels apply) and the length of the kernels' *first* segment;
/// later segments shrink with the running total's remaining headroom
/// (see the module docs).
pub fn seg_len(amax_a: u32, amax_b: u32) -> Option<usize> {
    let prod = amax_a as u64 * amax_b as u64;
    if prod == 0 || prod > ACC_BOUND {
        return None;
    }
    Some((ACC_BOUND / prod) as usize)
}

/// Integer NN kernel: `out[m,n] += a[m,kd] @ b[kd,n]` in i32, with
/// `m = out.len() / n`. Same panel blocking as the f32 kernel (a pure
/// perf choice — integer accumulation is order-exact). The k-dimension
/// is unrolled 4-wide so one pass over the output row amortizes four
/// b-panel rows; the f32 kernel's per-k zero-skip coarsens to the quad
/// (an all-zero quad is skipped; a mixed quad multiplies its zeros
/// through, adding exact integer zeros — unobservable). The tail keeps
/// the original per-k skip.
pub fn imm_nn_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    kd: usize,
    n: usize,
) {
    if n == 0 || kd == 0 {
        return;
    }
    let m = out.len() / n;
    let mut kb = 0;
    while kb < kd {
        let kend = (kb + KC).min(kd);
        for i in 0..m {
            let arow = &a[i * kd..(i + 1) * kd];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut kk = kb;
            while kk + 4 <= kend {
                let (a0, a1, a2, a3) = (
                    arow[kk].widen(),
                    arow[kk + 1].widen(),
                    arow[kk + 2].widen(),
                    arow[kk + 3].widen(),
                );
                if (a0 | a1 | a2 | a3) != 0 {
                    let b0 = &b[kk * n..(kk + 1) * n];
                    let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                    let b2 = &b[(kk + 2) * n..(kk + 3) * n];
                    let b3 = &b[(kk + 3) * n..(kk + 4) * n];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o += (a0 * b0[j].widen() + a1 * b1[j].widen())
                            + (a2 * b2[j].widen() + a3 * b3[j].widen());
                    }
                }
                kk += 4;
            }
            for kk in kk..kend {
                let aik = arow[kk].widen();
                if aik == 0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv.widen();
                }
            }
        }
        kb = kend;
    }
}

/// Integer NT kernel: `out[m,ib] = a[m,ua] @ b[ib,ua]^T` (assigns dot
/// products), with `m = out.len() / ib`. The dot product runs 4
/// independent accumulators over `chunks_exact(4)` of both operands,
/// reduced in the fixed order `(c0+c1)+(c2+c3)` plus a linear tail —
/// bit-identical to the rolled [`imm_nt_serial_ref`] loop (integer
/// addition is associative) but free of its loop-carried dependence.
pub fn imm_nt_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    ua: usize,
    ib: usize,
) {
    if ib == 0 {
        return;
    }
    let m = out.len() / ib;
    for i in 0..m {
        let arow = &a[i * ua..(i + 1) * ua];
        let orow = &mut out[i * ib..(i + 1) * ib];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * ua..(j + 1) * ua];
            let ac = arow.chunks_exact(4);
            let bc = brow.chunks_exact(4);
            let (atail, btail) = (ac.remainder(), bc.remainder());
            let mut c = [0i32; 4];
            for (x4, y4) in ac.zip(bc) {
                c[0] += x4[0].widen() * y4[0].widen();
                c[1] += x4[1].widen() * y4[1].widen();
                c[2] += x4[2].widen() * y4[2].widen();
                c[3] += x4[3].widen() * y4[3].widen();
            }
            let mut acc = (c[0] + c[1]) + (c[2] + c[3]);
            for (&x, &y) in atail.iter().zip(btail) {
                acc += x.widen() * y.widen();
            }
            *o = acc;
        }
    }
}

/// The pre-unroll NT dot-product loop, kept as the A/B baseline for
/// `bench_perf`'s `unrolled int gemm` rows (and as a readable reference
/// for what [`imm_nt_serial`] must reproduce bit for bit).
pub fn imm_nt_serial_ref<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    ua: usize,
    ib: usize,
) {
    if ib == 0 {
        return;
    }
    let m = out.len() / ib;
    for i in 0..m {
        let arow = &a[i * ua..(i + 1) * ua];
        let orow = &mut out[i * ib..(i + 1) * ib];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * ua..(j + 1) * ua];
            let mut acc = 0i32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x.widen() * y.widen();
            }
            *o = acc;
        }
    }
}

/// Integer TN kernel for a row-slab: `out[ii,u] += a[nrow, i0+ii] *
/// b[nrow, u]` over all `ba` batch rows, `ii in 0..out.len()/ub`.
/// Unrolled 4-wide over batch rows (the TN reduction dimension) with
/// the same fixed `(v0·b0+v1·b1)+(v2·b2+v3·b3)` pairing as the NN
/// kernel; the per-row zero-skip coarsens to the quad, the tail keeps
/// the original per-row skip.
pub fn imm_tn_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    ba: usize,
    ia: usize,
    ub: usize,
    i0: usize,
) {
    if ub == 0 {
        return;
    }
    let icount = out.len() / ub;
    let mut r = 0;
    while r + 4 <= ba {
        let a0 = &a[r * ia..(r + 1) * ia];
        let a1 = &a[(r + 1) * ia..(r + 2) * ia];
        let a2 = &a[(r + 2) * ia..(r + 3) * ia];
        let a3 = &a[(r + 3) * ia..(r + 4) * ia];
        let b0 = &b[r * ub..(r + 1) * ub];
        let b1 = &b[(r + 1) * ub..(r + 2) * ub];
        let b2 = &b[(r + 2) * ub..(r + 3) * ub];
        let b3 = &b[(r + 3) * ub..(r + 4) * ub];
        for ii in 0..icount {
            let (v0, v1, v2, v3) = (
                a0[i0 + ii].widen(),
                a1[i0 + ii].widen(),
                a2[i0 + ii].widen(),
                a3[i0 + ii].widen(),
            );
            if (v0 | v1 | v2 | v3) == 0 {
                continue;
            }
            let orow = &mut out[ii * ub..(ii + 1) * ub];
            for (j, o) in orow.iter_mut().enumerate() {
                *o += (v0 * b0[j].widen() + v1 * b1[j].widen())
                    + (v2 * b2[j].widen() + v3 * b3[j].widen());
            }
        }
        r += 4;
    }
    for nrow in r..ba {
        let arow = &a[nrow * ia..(nrow + 1) * ia];
        let brow = &b[nrow * ub..(nrow + 1) * ub];
        for ii in 0..icount {
            let av = arow[i0 + ii].widen();
            if av == 0 {
                continue;
            }
            let orow = &mut out[ii * ub..(ii + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv.widen();
            }
        }
    }
}

/// Retire every live element whose running total is within one `prod`
/// of [`ACC_BOUND`] (not even a one-term segment is provably exact for
/// it), and return the maximum total magnitude among the survivors.
/// Shared by the split kernels' joint segment scheduling.
fn retire_and_headroom(
    totals: &[i64],
    bail: &mut [bool],
    n_alive: &mut usize,
    prod: u64,
) -> u64 {
    let mut hmax = 0u64;
    for (t, fl) in totals.iter().zip(bail.iter_mut()) {
        if *fl {
            continue;
        }
        let mag = t.unsigned_abs();
        if mag + prod > ACC_BOUND {
            *fl = true;
            *n_alive -= 1;
        } else {
            hmax = hmax.max(mag);
        }
    }
    hmax
}

/// Split-accumulator NN kernel: `out[m,n] = a[m,kd] @ b[kd,n]` written
/// as f32, bit-identical to the simulated f32 NN kernel run against a
/// clean (`+0.0`) destination. `ai`/`bi` are the packed integers of the
/// f32 operands `af`/`bf`, `prod = amax_a · amax_b` (the planner
/// guarantees `1 ≤ prod ≤` [`ACC_BOUND`]), `scale = 2^(pa+pb)`.
///
/// Per output row the k-reduction runs in adaptively-sized segments:
/// each segment takes `(ACC_BOUND − max_live |total|) / prod` terms
/// (the first therefore takes the maximal [`seg_len`]), accumulates
/// exactly in i32 (zero-skip on the a element, like the f32 kernel),
/// and folds into per-column i64 totals in ascending k-order. Within
/// such a segment every simulated-kernel partial sum has integer
/// magnitude ≤ `ACC_BOUND`, so live columns convert exactly as
/// `total as f32 * scale`; a column retired by the headroom check
/// replays the simulated kernel's own f32 loop instead.
#[allow(clippy::too_many_arguments)]
pub fn imm_nn_split_serial<A: PackInt, B: PackInt>(
    ai: &[A],
    bi: &[B],
    af: &[f32],
    bf: &[f32],
    out: &mut [f32],
    kd: usize,
    n: usize,
    prod: u64,
    scale: f32,
) {
    if n == 0 || kd == 0 {
        return;
    }
    debug_assert!(prod >= 1 && prod <= ACC_BOUND);
    let m = out.len() / n;
    let mut totals = vec![0i64; n];
    let mut bail = vec![false; n];
    let mut segacc = vec![0i32; n];
    for i in 0..m {
        totals.fill(0);
        bail.fill(false);
        let arow = &ai[i * kd..(i + 1) * kd];
        let mut n_alive = n;
        let mut k = 0;
        while k < kd && n_alive > 0 {
            let hmax = retire_and_headroom(&totals, &mut bail, &mut n_alive, prod);
            if n_alive == 0 {
                break;
            }
            let kend = k + (((ACC_BOUND - hmax) / prod) as usize).min(kd - k);
            segacc.fill(0);
            for kk in k..kend {
                let aik = arow[kk].widen();
                if aik == 0 {
                    continue;
                }
                let brow = &bi[kk * n..(kk + 1) * n];
                for (sa, &bv) in segacc.iter_mut().zip(brow) {
                    *sa += aik * bv.widen();
                }
            }
            for ((t, &fl), &sa) in totals.iter_mut().zip(&bail).zip(&segacc) {
                if !fl {
                    *t += sa as i64;
                }
            }
            k = kend;
        }
        let orow = &mut out[i * n..(i + 1) * n];
        for ((o, &t), &fl) in orow.iter_mut().zip(&totals).zip(&bail) {
            if !fl {
                *o = t as f32 * scale;
            }
        }
        if n_alive < n {
            // the simulated NN loop for the retired columns: ascending
            // k, zero-skip on the a element, from the clean +0.0 start
            let afrow = &af[i * kd..(i + 1) * kd];
            for (j, (o, &fl)) in orow.iter_mut().zip(&bail).enumerate() {
                if !fl {
                    continue;
                }
                let mut acc = 0.0f32;
                for (kk, &av) in afrow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * bf[kk * n + j];
                }
                *o = acc;
            }
        }
    }
}

/// Split-accumulator NT kernel: `out[m,ib] = a[m,ua] @ b[ib,ua]^T`
/// written as f32, bit-identical to the simulated f32 NT kernel (which
/// assigns dot products and has *no* zero-skip — the fallback replays
/// exactly that). Per-element adaptive segments as in
/// [`imm_nn_split_serial`], with the segment dot product unrolled
/// 4-wide like [`imm_nt_serial`].
pub fn imm_nt_split_serial<A: PackInt, B: PackInt>(
    ai: &[A],
    bi: &[B],
    af: &[f32],
    bf: &[f32],
    out: &mut [f32],
    ua: usize,
    ib: usize,
    prod: u64,
    scale: f32,
) {
    if ib == 0 {
        return;
    }
    debug_assert!(prod >= 1 && prod <= ACC_BOUND);
    let m = out.len() / ib;
    for i in 0..m {
        let arow = &ai[i * ua..(i + 1) * ua];
        let afrow = &af[i * ua..(i + 1) * ua];
        let orow = &mut out[i * ib..(i + 1) * ib];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &bi[j * ua..(j + 1) * ua];
            let mut total = 0i64;
            let mut exact = true;
            let mut k = 0;
            while k < ua {
                let mag = total.unsigned_abs();
                if mag + prod > ACC_BOUND {
                    exact = false;
                    break;
                }
                let kend = k + (((ACC_BOUND - mag) / prod) as usize).min(ua - k);
                let ac = arow[k..kend].chunks_exact(4);
                let bc = brow[k..kend].chunks_exact(4);
                let (atail, btail) = (ac.remainder(), bc.remainder());
                let mut c = [0i32; 4];
                for (x4, y4) in ac.zip(bc) {
                    c[0] += x4[0].widen() * y4[0].widen();
                    c[1] += x4[1].widen() * y4[1].widen();
                    c[2] += x4[2].widen() * y4[2].widen();
                    c[3] += x4[3].widen() * y4[3].widen();
                }
                let mut acc = (c[0] + c[1]) + (c[2] + c[3]);
                for (&x, &y) in atail.iter().zip(btail) {
                    acc += x.widen() * y.widen();
                }
                total += acc as i64;
                k = kend;
            }
            *o = if exact {
                total as f32 * scale
            } else {
                let bfrow = &bf[j * ua..(j + 1) * ua];
                let mut acc = 0.0f32;
                for (&x, &y) in afrow.iter().zip(bfrow) {
                    acc += x * y;
                }
                acc
            };
        }
    }
}

/// Split-accumulator TN kernel for a row-slab: `out[ii,u] = Σ_nrow
/// a[nrow, i0+ii] · b[nrow, u]` written as f32, bit-identical to the
/// simulated f32 TN kernel run against a clean destination (ascending
/// `nrow`, zero-skip on the a element — the fallback replays exactly
/// that). Adaptive segments cut the batch-row reduction jointly for
/// the whole slab; headroom, fold and bail as in
/// [`imm_nn_split_serial`].
#[allow(clippy::too_many_arguments)]
pub fn imm_tn_split_serial<A: PackInt, B: PackInt>(
    ai: &[A],
    bi: &[B],
    af: &[f32],
    bf: &[f32],
    out: &mut [f32],
    ba: usize,
    ia: usize,
    ub: usize,
    i0: usize,
    prod: u64,
    scale: f32,
) {
    if ub == 0 {
        return;
    }
    debug_assert!(prod >= 1 && prod <= ACC_BOUND);
    let icount = out.len() / ub;
    let mut totals = vec![0i64; icount * ub];
    let mut bail = vec![false; icount * ub];
    let mut segacc = vec![0i32; icount * ub];
    let mut n_alive = icount * ub;
    let mut r = 0;
    while r < ba && n_alive > 0 {
        let hmax = retire_and_headroom(&totals, &mut bail, &mut n_alive, prod);
        if n_alive == 0 {
            break;
        }
        let rend = r + (((ACC_BOUND - hmax) / prod) as usize).min(ba - r);
        segacc.fill(0);
        for nrow in r..rend {
            let arow = &ai[nrow * ia..(nrow + 1) * ia];
            let brow = &bi[nrow * ub..(nrow + 1) * ub];
            for ii in 0..icount {
                let av = arow[i0 + ii].widen();
                if av == 0 {
                    continue;
                }
                let srow = &mut segacc[ii * ub..(ii + 1) * ub];
                for (sa, &bv) in srow.iter_mut().zip(brow) {
                    *sa += av * bv.widen();
                }
            }
        }
        for ((t, &fl), &sa) in totals.iter_mut().zip(&bail).zip(&segacc) {
            if !fl {
                *t += sa as i64;
            }
        }
        r = rend;
    }
    for ((o, &t), &fl) in out.iter_mut().zip(&totals).zip(&bail) {
        if !fl {
            *o = t as f32 * scale;
        }
    }
    if n_alive < icount * ub {
        for ii in 0..icount {
            for u in 0..ub {
                if !bail[ii * ub + u] {
                    continue;
                }
                let mut acc = 0.0f32;
                for nrow in 0..ba {
                    let av = af[nrow * ia + i0 + ii];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * bf[nrow * ub + u];
                }
                out[ii * ub + u] = acc;
            }
        }
    }
}

/// A cached set of packed operand slabs (one weight layer's worth),
/// keyed on **value identity + adopted scale**:
///
/// * the *epoch*, a counter the owner bumps via [`PackedCache::invalidate`]
///   whenever the cached values change (the layer graph bumps it in
///   `sgd_update`, right after params are rewritten and re-quantized);
/// * the *scale key*, the owning group's adopted storage-format step as
///   `f32::to_bits` (so every dynamic scale move — `after_batch` ticks
///   and `adopt_int_bits` warmup transfer alike — forces a rebuild).
///
/// A hit returns the byte-identical `Packed` a fresh [`pack`] of the
/// same values would produce (packing is deterministic and
/// value-driven), so caching is invisible to the bit-identity contract;
/// a slab recorded as `None` means "these values do not pack" and the
/// caller falls back to the simulated kernels without re-attempting.
/// [`PackedCache::builds`] counts rebuild events for the invalidation
/// regression tests — it is per-cache state, immune to the parallel
/// test runner (unlike the global [`pack_calls`] counter).
#[derive(Default)]
pub struct PackedCache {
    /// Bumped by the owner on every value change.
    epoch: u64,
    /// The `(epoch, scale_bits)` the current slabs were built under.
    key: Option<(u64, u32)>,
    /// Shared so concurrent readers (data-parallel training workers)
    /// can hold the slab set across a whole GEMM loop without pinning
    /// the cache's lock: [`PackedCache::ensure`] hands out a clone of
    /// this `Arc` and the owner only swaps in a *new* vector on rebuild,
    /// never mutates one in place.
    slabs: Arc<Vec<Option<Packed>>>,
    builds: u64,
}

impl PackedCache {
    pub fn new() -> PackedCache {
        PackedCache::default()
    }

    /// Mark the cached values stale; the next [`PackedCache::ensure`]
    /// rebuilds every slab. Cheap (one counter bump) — callers invalidate
    /// unconditionally after updates rather than tracking whether the
    /// integer path is even enabled.
    pub fn invalidate(&mut self) {
        self.epoch += 1;
    }

    /// Number of slab-set rebuilds this cache has performed (= ensure
    /// misses). One training update or scale move costs exactly one.
    pub fn builds(&self) -> u64 {
        self.builds
    }

    /// Return the packed slabs for the current `(epoch, scale_bits)`
    /// key, rebuilding all `n_slabs` via `build(j)` on a key miss.
    ///
    /// Returns a shared handle rather than a borrow so a caller holding
    /// the cache behind a `Mutex` (the layer graph, once data-parallel
    /// workers share one `Network`) can drop the guard immediately and
    /// keep using the slabs while other workers hit the same cache.
    pub fn ensure(
        &mut self,
        scale_bits: u32,
        n_slabs: usize,
        mut build: impl FnMut(usize) -> Option<Packed>,
    ) -> Arc<Vec<Option<Packed>>> {
        let key = (self.epoch, scale_bits);
        if self.key != Some(key) || self.slabs.len() != n_slabs {
            self.slabs = Arc::new((0..n_slabs).map(&mut build).collect());
            self.key = Some(key);
            self.builds += 1;
        }
        Arc::clone(&self.slabs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2f_is_exact_at_the_extremes() {
        assert_eq!(exp2f(0), 1.0);
        assert_eq!(exp2f(-1), 0.5);
        assert_eq!(exp2f(10), 1024.0);
        assert_eq!(exp2f(-149).to_bits(), 1); // smallest subnormal
        assert_eq!(exp2f(-126), f32::MIN_POSITIVE);
        assert_eq!(exp2f(127), 2f32.powi(127));
    }

    #[test]
    fn decompose_roundtrips_odd_mantissas() {
        for v in [1.0f32, -1.0, 0.5, 3.0, -0.75, 1.5e-3, 2f32.powi(-149)] {
            let (m, e) = decompose(v).unwrap();
            assert!(m % 2 != 0, "mantissa must be odd for {v}");
            let back = m as f64 * 2f64.powi(e);
            assert_eq!(back as f32, v, "{v}");
        }
        assert_eq!(decompose(0.0), Some((0, 0)));
        assert_eq!(decompose(-0.0), Some((0, 0)));
        assert_eq!(decompose(f32::NAN), None);
        assert_eq!(decompose(f32::INFINITY), None);
    }

    #[test]
    fn pack_roundtrips_grid_values_exactly() {
        // values on a Q3.4 grid (step 1/16), mixed with zeros
        let step = 1.0f32 / 16.0;
        let xs: Vec<f32> = [-128i32, -37, -1, 0, 1, 5, 77, 127]
            .iter()
            .map(|&k| k as f32 * step)
            .collect();
        let p = pack(&xs).expect("grid values pack");
        assert!(!p.is_i8(), "amax 128 exceeds i8::MAX, needs i16");
        assert_eq!(p.amax, 128);
        let back = p.unpack();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pack_chooses_i8_when_it_fits() {
        let xs: Vec<f32> = (-127i32..=127).map(|k| k as f32 * 0.25).collect();
        let p = pack(&xs).expect("packs");
        assert!(p.is_i8());
        assert_eq!(p.amax, 127);
        assert_eq!(p.exp, -2);
        for (a, b) in xs.iter().zip(&p.unpack()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pack_handles_mixed_grids_via_common_exponent() {
        // 2.0 = 1·2^1 and 0.375 = 3·2^-3 → common p = -3: ints 16 and 3
        let p = pack(&[2.0, 0.375]).expect("packs");
        assert_eq!(p.exp, -3);
        assert_eq!(p.amax, 16);
        assert_eq!(p.unpack(), vec![2.0, 0.375]);
    }

    #[test]
    fn pack_rejects_wide_mantissas_and_nonfinite() {
        assert!(pack(&[0.1f32]).is_none(), "0.1 has a 24-bit odd mantissa");
        assert!(pack(&[f32::NAN]).is_none());
        assert!(pack(&[1.0, f32::INFINITY]).is_none());
        // > 15 octaves apart: ints would need > i16
        assert!(pack(&[1.0, 2f32.powi(-20)]).is_none());
    }

    #[test]
    fn pack_of_all_zeros_is_trivial() {
        let p = pack(&[0.0, -0.0, 0.0]).expect("zeros pack");
        assert_eq!((p.exp, p.amax), (0, 0));
        assert!(p.unpack().iter().all(|v| v.to_bits() == 0));
    }

    #[test]
    fn accum_bound_matches_definition() {
        assert!(accum_bound_ok(784, 64, 64)); // unit-scale data at mnist fan-in
        assert!(!accum_bound_ok(784, 512, 512)); // full-range 10-bit grids
        assert!(accum_bound_ok(0, u32::MAX, u32::MAX));
        assert!(accum_bound_ok(1 << 24, 1, 1));
        assert!(!accum_bound_ok(1 << 25, 1, 1));
        // saturating product can't sneak under the bound
        assert!(!accum_bound_ok(usize::MAX, u32::MAX, u32::MAX));
    }

    fn naive_nn(a: &[i32], b: &[i32], m: usize, kd: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for k in 0..kd {
                    out[i * n + j] += a[i * kd + k] * b[k * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn integer_kernels_match_naive_loops() {
        let mut state = 0x1234_5678u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 21) - 10
        };
        let (m, kd, n) = (5usize, 7usize, 4usize);
        let a8: Vec<i8> = (0..m * kd).map(|_| next() as i8).collect();
        let b16: Vec<i16> = (0..kd * n).map(|_| next() as i16).collect();
        let aw: Vec<i32> = a8.iter().map(|&v| v as i32).collect();
        let bw: Vec<i32> = b16.iter().map(|&v| v as i32).collect();

        let mut nn = vec![0i32; m * n];
        imm_nn_serial(&a8, &b16, &mut nn, kd, n);
        assert_eq!(nn, naive_nn(&aw, &bw, m, kd, n));

        // NT: a[m,kd] @ b2[n,kd]^T equals NN against transposed b2
        let b2: Vec<i16> = (0..n * kd).map(|_| next() as i16).collect();
        let mut b2t = vec![0i32; kd * n];
        for j in 0..n {
            for k in 0..kd {
                b2t[k * n + j] = b2[j * kd + k] as i32;
            }
        }
        let mut nt = vec![0i32; m * n];
        imm_nt_serial(&a8, &b2, &mut nt, kd, n);
        assert_eq!(nt, naive_nn(&aw, &b2t, m, kd, n));

        // TN: a[ba,ia]^T @ b[ba,ub], checked slab by slab
        let (ba, ia, ub) = (6usize, 5usize, 3usize);
        let at: Vec<i8> = (0..ba * ia).map(|_| next() as i8).collect();
        let bt: Vec<i8> = (0..ba * ub).map(|_| next() as i8).collect();
        let mut att = vec![0i32; ia * ba];
        for r in 0..ba {
            for c in 0..ia {
                att[c * ba + r] = at[r * ia + c] as i32;
            }
        }
        let btw: Vec<i32> = bt.iter().map(|&v| v as i32).collect();
        let want = naive_nn(&att, &btw, ia, ba, ub);
        for (i0, rows) in [(0usize, ia), (1, 2), (4, 1)] {
            let mut slab = vec![0i32; rows * ub];
            imm_tn_serial(&at, &bt, &mut slab, ba, ia, ub, i0);
            assert_eq!(slab[..], want[i0 * ub..(i0 + rows) * ub], "slab {i0}+{rows}");
        }
    }

    #[test]
    fn packed_cache_rebuilds_only_on_epoch_or_scale_change() {
        let xs: Vec<f32> = (-4i32..4).map(|k| k as f32 * 0.5).collect();
        let mut cache = PackedCache::new();
        let step = 0.5f32.to_bits();
        {
            let slabs = cache.ensure(step, 2, |_| pack(&xs));
            assert_eq!(slabs.len(), 2);
            assert!(slabs.iter().all(|s| s.is_some()));
        }
        assert_eq!(cache.builds(), 1);
        // same key: a hit, no rebuild
        cache.ensure(step, 2, |_| panic!("hit must not rebuild"));
        assert_eq!(cache.builds(), 1);
        // scale move: rebuild
        cache.ensure(0.25f32.to_bits(), 2, |_| pack(&xs));
        assert_eq!(cache.builds(), 2);
        // value change: rebuild, and the new packs are served
        cache.invalidate();
        let ys = [1.0f32, 3.0];
        let amax = cache.ensure(0.25f32.to_bits(), 2, |_| pack(&ys))[0]
            .as_ref()
            .unwrap()
            .amax;
        assert_eq!(cache.builds(), 3);
        assert_eq!(amax, 3);
        // a slab that fails to pack is cached as None (no re-attempt)
        cache.invalidate();
        assert!(cache.ensure(step, 1, |_| pack(&[0.1f32]))[0].is_none());
        assert_eq!(cache.builds(), 4);
        cache.ensure(step, 1, |_| panic!("None slabs are cached too"));
        assert_eq!(cache.builds(), 4);
    }

    #[test]
    fn pack_calls_counts_invocations() {
        let before = pack_calls();
        let _ = pack(&[1.0f32, 2.0]);
        let _ = pack(&[0.1f32]); // miss still counts
        assert!(pack_calls() >= before + 2);
    }

    #[test]
    fn seg_len_edges_match_the_spec() {
        assert_eq!(seg_len(0, 5), None, "zero product: whole-site bound already accepts");
        assert_eq!(seg_len(5, 0), None);
        assert_eq!(seg_len(1, 1), Some(1 << 24));
        assert_eq!(seg_len(4096, 4096), Some(1), "prod exactly 2^24");
        assert_eq!(seg_len(4096, 4097), None, "products not f32-exact");
        assert_eq!(seg_len(512, 512), Some(64), "the deep-l0 10-bit case");
        for (a, b) in [(1u32, 1u32), (3, 511), (127, 127), (511, 513), (2047, 2047), (4095, 4095)]
        {
            let s = seg_len(a, b).unwrap() as u64;
            let p = a as u64 * b as u64;
            assert!(s * p <= ACC_BOUND, "({a},{b}): safe");
            assert!((s + 1) * p > ACC_BOUND, "({a},{b}): maximal");
        }
    }

    #[test]
    fn unrolled_nt_matches_the_rolled_reference() {
        let mut state = 0xDEAD_BEEFu64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 201) - 100
        };
        // ua = 11 exercises two full quads plus a 3-term tail; 1 and 4
        // hit the all-tail and all-quad edges
        for (m, ua, ib) in [(3usize, 11usize, 4usize), (2, 8, 3), (1, 3, 2), (4, 1, 1), (2, 4, 2)]
        {
            let a: Vec<i16> = (0..m * ua).map(|_| next() as i16).collect();
            let b: Vec<i8> = (0..ib * ua).map(|_| next() as i8).collect();
            let mut fast = vec![0i32; m * ib];
            let mut slow = vec![0i32; m * ib];
            imm_nt_serial(&a, &b, &mut fast, ua, ib);
            imm_nt_serial_ref(&a, &b, &mut slow, ua, ib);
            assert_eq!(fast, slow, "({m},{ua},{ib})");
        }
    }

    /// The simulated NN kernel's per-element arithmetic: ascending k,
    /// zero-skip on the a element, f32 rounding after every add.
    fn ref_nn_f32(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for k in 0..kd {
                    let av = a[i * kd + k];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * b[k * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// The simulated NT kernel's per-element arithmetic (no zero-skip).
    fn ref_nt_f32(a: &[f32], b: &[f32], m: usize, ua: usize, ib: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * ib];
        for i in 0..m {
            for j in 0..ib {
                let mut acc = 0.0f32;
                for k in 0..ua {
                    acc += a[i * ua + k] * b[j * ua + k];
                }
                out[i * ib + j] = acc;
            }
        }
        out
    }

    /// The simulated TN kernel's per-element arithmetic for a row-slab.
    fn ref_tn_f32(
        a: &[f32],
        b: &[f32],
        ba: usize,
        ia: usize,
        ub: usize,
        i0: usize,
        icount: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; icount * ub];
        for ii in 0..icount {
            for u in 0..ub {
                let mut acc = 0.0f32;
                for nrow in 0..ba {
                    let av = a[nrow * ia + i0 + ii];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * b[nrow * ub + u];
                }
                out[ii * ub + u] = acc;
            }
        }
        out
    }

    fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn split_kernels_match_simulated_reference_on_deep_reductions() {
        // amax ≤ 512 at exp -6: prod ≤ 2^18 ≤ 2^24, first segment ≥ 64
        // terms; inner 300 pushes the whole-site worst case past 2^24,
        // so only the split path applies. Mixed-sign data keeps totals
        // small and the integer path live throughout.
        let exp = -6i32;
        let scale = exp2f(exp + exp);
        let s1 = exp2f(exp);
        let mut state = 0x517A_CC00u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i64 % 1025 - 512) as i32
        };
        let (m, kd, n) = (3usize, 300usize, 5usize);
        let ai: Vec<i16> = (0..m * kd).map(|_| next() as i16).collect();
        let bi: Vec<i16> = (0..kd * n).map(|_| next() as i16).collect();
        let af: Vec<f32> = ai.iter().map(|&v| v as f32 * s1).collect();
        let bf: Vec<f32> = bi.iter().map(|&v| v as f32 * s1).collect();
        let amax = |v: &[i16]| v.iter().map(|x| x.unsigned_abs() as u32).max().unwrap();
        let prod = amax(&ai) as u64 * amax(&bi) as u64;
        assert!(prod <= ACC_BOUND && kd as u64 * prod > ACC_BOUND, "split regime");

        let mut nn = vec![0.0f32; m * n];
        imm_nn_split_serial(&ai, &bi, &af, &bf, &mut nn, kd, n, prod, scale);
        assert_bits_eq(&nn, &ref_nn_f32(&af, &bf, m, kd, n), "nn");

        // NT over the same depth: b2[n, kd]
        let b2: Vec<i16> = (0..n * kd).map(|_| next() as i16).collect();
        let b2f: Vec<f32> = b2.iter().map(|&v| v as f32 * s1).collect();
        let prod_nt = amax(&ai) as u64 * amax(&b2) as u64;
        let mut nt = vec![0.0f32; m * n];
        imm_nt_split_serial(&ai, &b2, &af, &b2f, &mut nt, kd, n, prod_nt, scale);
        assert_bits_eq(&nt, &ref_nt_f32(&af, &b2f, m, kd, n), "nt");

        // TN: deep batch reduction, checked slab by slab
        let (ba, ia, ub) = (300usize, 4usize, 3usize);
        let at: Vec<i16> = (0..ba * ia).map(|_| next() as i16).collect();
        let bt: Vec<i16> = (0..ba * ub).map(|_| next() as i16).collect();
        let atf: Vec<f32> = at.iter().map(|&v| v as f32 * s1).collect();
        let btf: Vec<f32> = bt.iter().map(|&v| v as f32 * s1).collect();
        let prod_tn = amax(&at) as u64 * amax(&bt) as u64;
        for (i0, rows) in [(0usize, ia), (1, 2), (3, 1)] {
            let mut tn = vec![0.0f32; rows * ub];
            imm_tn_split_serial(&at, &bt, &atf, &btf, &mut tn, ba, ia, ub, i0, prod_tn, scale);
            assert_bits_eq(&tn, &ref_tn_f32(&atf, &btf, ba, ia, ub, i0, rows), "tn");
        }

        // i8 a-operand through the same generic path (deep enough that
        // the whole-site bound still rejects: 1200 · 100 · 512 > 2^24)
        let (m8, kd8, n8) = (2usize, 1200usize, 3usize);
        let a8: Vec<i8> = (0..m8 * kd8).map(|_| (next() % 101) as i8).collect();
        let b8: Vec<i16> = (0..kd8 * n8).map(|_| next() as i16).collect();
        let a8f: Vec<f32> = a8.iter().map(|&v| v as f32 * s1).collect();
        let b8f: Vec<f32> = b8.iter().map(|&v| v as f32 * s1).collect();
        let amax8 = a8.iter().map(|x| x.unsigned_abs() as u32).max().unwrap();
        let prod8 = amax8 as u64 * amax(&b8) as u64;
        assert!(prod8 <= ACC_BOUND && kd8 as u64 * prod8 > ACC_BOUND, "split regime");
        let mut nn8 = vec![0.0f32; m8 * n8];
        imm_nn_split_serial(&a8, &b8, &a8f, &b8f, &mut nn8, kd8, n8, prod8, scale);
        assert_bits_eq(&nn8, &ref_nn_f32(&a8f, &b8f, m8, kd8, n8), "nn i8");
    }

    #[test]
    fn split_kernels_bail_to_the_rounding_reference_on_adversarial_sums() {
        // All-positive maximal data: totals blow through 2^24, where the
        // simulated f32 kernel *rounds* — the split kernels must detect
        // the lost headroom, retire those elements and replay the
        // reference loop bit for bit. Column 1 mixes signs so it stays
        // live, pinning per-column bail isolation.
        let v = 4095i16; // v² = 16769025, within one product of 2^24
        let kd = 48usize;
        let ai: Vec<i16> = vec![v; kd]; // m = 1
        let mut bi = vec![0i16; kd * 2];
        for k in 0..kd {
            bi[k * 2] = v;
            bi[k * 2 + 1] = if k % 2 == 0 { v } else { -v };
        }
        let af: Vec<f32> = ai.iter().map(|&x| x as f32).collect();
        let bf: Vec<f32> = bi.iter().map(|&x| x as f32).collect();
        let prod = (v as u64) * (v as u64);
        let want = ref_nn_f32(&af, &bf, 1, kd, 2);
        // non-vacuity: the all-positive column really rounds (its exact
        // total 48·4095² needs a finer ulp than f32 has at 8·10^8)
        let exact = kd as f64 * (v as f64) * (v as f64);
        assert!(
            (want[0] as f64) != exact,
            "reference must round for the bail path to be exercised"
        );
        let mut nn = vec![0.0f32; 2];
        imm_nn_split_serial(&ai, &bi, &af, &bf, &mut nn, kd, 2, prod, 1.0);
        assert_bits_eq(&nn, &want, "nn bail");
        // the cancelling column stays on the exact integer path
        assert_eq!(nn[1], 0.0, "mixed-sign column cancels exactly");

        // NT: same adversarial row as a dot product
        let mut nt = vec![0.0f32; 1];
        let b_row: Vec<i16> = (0..kd).map(|k| bi[k * 2]).collect();
        let b_rowf: Vec<f32> = b_row.iter().map(|&x| x as f32).collect();
        imm_nt_split_serial(&ai, &b_row, &af, &b_rowf, &mut nt, kd, 1, prod, 1.0);
        assert_bits_eq(&nt, &ref_nt_f32(&af, &b_rowf, 1, kd, 1), "nt bail");

        // TN: 48 batch rows of maximal same-sign data
        let (ba, ia, ub) = (kd, 2usize, 2usize);
        let at: Vec<i16> = (0..ba * ia).map(|i| if i % ia == 0 { v } else { -v }).collect();
        let bt: Vec<i16> = vec![v; ba * ub];
        let atf: Vec<f32> = at.iter().map(|&x| x as f32).collect();
        let btf: Vec<f32> = bt.iter().map(|&x| x as f32).collect();
        let mut tn = vec![0.0f32; ia * ub];
        imm_tn_split_serial(&at, &bt, &atf, &btf, &mut tn, ba, ia, ub, 0, prod, 1.0);
        assert_bits_eq(&tn, &ref_tn_f32(&atf, &btf, ba, ia, ub, 0, ia), "tn bail");
    }

    #[test]
    fn split_kernels_handle_degenerate_shapes() {
        // inner = 0: nothing to reduce; a clean destination stays +0.0
        let mut out = vec![0.0f32; 4];
        imm_nn_split_serial::<i16, i16>(&[], &[], &[], &[], &mut out, 0, 2, 100, 1.0);
        assert!(out.iter().all(|v| v.to_bits() == 0));
        imm_nt_split_serial::<i16, i16>(&[1, 2], &[], &[1.0, 2.0], &[], &mut out, 0, 2, 100, 1.0);
        assert!(out.iter().all(|v| v.to_bits() == 0), "ua = 0 dots are empty sums");
        // inner = 1: a single product is always exact under prod ≤ 2^24
        let mut one = vec![0.0f32; 1];
        imm_nt_split_serial::<i16, i16>(
            &[4095],
            &[-4095],
            &[4095.0],
            &[-4095.0],
            &mut one,
            1,
            1,
            4095 * 4095,
            1.0,
        );
        assert_eq!(one[0], -16769025.0);
    }

    #[test]
    fn blocked_nn_handles_kd_across_panel_boundaries() {
        // kd > KC exercises the panel loop; exact integer accumulation
        // means blocking must be invisible
        let (m, kd, n) = (3usize, 300usize, 2usize);
        let a: Vec<i8> = (0..m * kd).map(|i| ((i % 5) as i8) - 2).collect();
        let b: Vec<i8> = (0..kd * n).map(|i| ((i % 7) as i8) - 3).collect();
        let aw: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        let bw: Vec<i32> = b.iter().map(|&v| v as i32).collect();
        let mut out = vec![0i32; m * n];
        imm_nn_serial(&a, &b, &mut out, kd, n);
        assert_eq!(out, naive_nn(&aw, &bw, m, kd, n));
    }
}
