//! Integer-domain GEMM substrate: pack f32 operands that live on a common
//! power-of-two grid into i8/i16, multiply with i32 accumulators, and
//! prove the result bit-identical to the f32 kernels.
//!
//! The paper's point is that a low-precision *multiplier* is the cheap
//! unit; the fused kernels in [`super::ops`] still simulate fixed-point
//! with f32 multiplies. This module is the datapath that actually pays
//! in integers. The contract that makes it safe to swap in:
//!
//! **Eligibility ⇒ bit-identity.** A GEMM site may run in the integer
//! domain only when all of the following hold (checked per call by
//! [`pack`] + [`accum_bound_ok`] + the exponent window):
//!
//! 1. every element of both operands decomposes as `int · 2^p` with a
//!    *common* exponent `p` per operand and `|int| ≤ i16::MAX`
//!    ([`pack`] returns `None` otherwise — e.g. raw float32 data);
//! 2. the worst-case absolute sum `inner · amax_a · amax_b` is at most
//!    [`ACC_BOUND`] `= 2^24`: then every i32 partial sum is exact AND
//!    every f32 partial sum in the simulated kernel is exact (all
//!    intermediates are integers below the f32 mantissa limit), so the
//!    two paths compute the *same real number*, independent of k-order,
//!    blocking or zero-skipping;
//! 3. the product exponent `pa + pb` lies in `[`[`EXP_LO`]`, `[`EXP_HI`]`]`,
//!    so `acc as f32 * 2^(pa+pb)` is exact: any `S · 2^e` with
//!    `|S| ≤ 2^24` and `e ≥ -149` is representable (down to the f32
//!    subnormal floor) and `e ≤ 103` rules out overflow.
//!
//! Zero outputs agree in sign too: exact f32 accumulation that starts at
//! `+0.0` can only produce `+0.0` (IEEE-754 exact cancellation yields
//! `+0.0` in round-to-nearest, and `+0.0 + -0.0 = +0.0`), and an i32
//! accumulator of `0` converts to `+0.0`. Ineligible sites simply fall
//! back to the simulated kernels — which are the reference — so the
//! dispatch in `ops.rs` is bit-transparent *unconditionally*.
//!
//! Inner loops are plain slice-zip reductions over widened i32 values:
//! contiguous layout, no gather, no data-dependent control flow inside
//! the innermost loop — the shape LLVM autovectorizes without `std::arch`
//! (the zero-dep constraint rules out mandatory intrinsics anyway).
//!
//! **Packed-operand caching.** Packing is a pure function of the operand
//! values, so a weight slab that has not changed since its last pack
//! repacks to byte-identical storage — [`PackedCache`] exploits that to
//! pack each weight slab once per value change (or adopted-scale move)
//! instead of once per GEMM call. A cache hit therefore feeds the
//! kernels the *exact* `Packed` the per-call path would rebuild, which
//! is why caching cannot perturb the bit-identity contract; the per-call
//! eligibility checks (accumulator bound, exponent window, clean
//! destination, the non-cached operand's packability) still run on
//! every dispatch. [`pack_calls`] counts every `pack` invocation
//! process-wide so benches and tests can measure packs avoided.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Maximum worst-case absolute sum for an eligible site: `2^24`, the f32
/// mantissa limit. Below it both the i32 and the simulated-f32
/// accumulations are exact (and i32 overflow is impossible by a margin
/// of `2^7`).
pub const ACC_BOUND: u64 = 1 << 24;

/// Lowest product exponent `pa + pb` for which `acc as f32 * 2^(pa+pb)`
/// is exact: the f32 subnormal floor `2^-149`.
pub const EXP_LO: i32 = -149;

/// Highest product exponent: `2^24 · 2^103 = 2^127 ≤ f32::MAX`, so the
/// conversion can never overflow.
pub const EXP_HI: i32 = 103;

/// K-dimension block size of the integer NN kernel (mirrors the f32
/// kernel's panel size; integer accumulation is exact so blocking is a
/// pure locality choice).
const KC: usize = 128;

/// Storage element of a packed operand: i8 or i16, widened to i32 in the
/// kernels' inner loops.
pub trait PackInt: Copy + Send + Sync {
    fn widen(self) -> i32;
}

impl PackInt for i8 {
    #[inline(always)]
    fn widen(self) -> i32 {
        self as i32
    }
}

impl PackInt for i16 {
    #[inline(always)]
    fn widen(self) -> i32 {
        self as i32
    }
}

/// The integer payload of a packed operand. i8 when every magnitude fits
/// (the common case for the paper's ≤ 8-bit storage grids), i16 up to
/// the 16-bit grids the sweeps use.
pub enum PackedInts {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

/// An f32 slice re-expressed exactly as `ints[i] · 2^exp`.
pub struct Packed {
    pub ints: PackedInts,
    /// Common power-of-two exponent: `value_i = ints[i] as f32 * 2^exp`.
    pub exp: i32,
    /// `max |ints[i]|` — input to the accumulator worst-case bound.
    pub amax: u32,
}

impl Packed {
    pub fn len(&self) -> usize {
        match &self.ints {
            PackedInts::I8(v) => v.len(),
            PackedInts::I16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the payload fits the narrow (i8) storage class.
    pub fn is_i8(&self) -> bool {
        matches!(self.ints, PackedInts::I8(_))
    }

    /// Exact inverse of [`pack`]: every element reproduces the original
    /// f32 bits (`-0.0` inputs come back as `+0.0`; pack treats all
    /// zeros as integer 0, which the GEMM bit-identity argument shows is
    /// unobservable in any accumulated output).
    pub fn unpack(&self) -> Vec<f32> {
        let s = exp2f(self.exp);
        match &self.ints {
            PackedInts::I8(v) => v.iter().map(|&i| i as f32 * s).collect(),
            PackedInts::I16(v) => v.iter().map(|&i| i as f32 * s).collect(),
        }
    }
}

/// Exact `2^e` as f32 for `e ∈ [-149, 127]` (computed in f64, where every
/// such power is normal, then narrowed — the narrowing is exact because
/// the value is representable, subnormals included).
pub fn exp2f(e: i32) -> f32 {
    2f64.powi(e) as f32
}

/// Decompose a finite f32 into `(m, e)` with `v = m · 2^e` and `m` odd
/// (or `(0, 0)` for ±0.0). Returns `None` for NaN/±inf.
fn decompose(v: f32) -> Option<(i32, i32)> {
    if v == 0.0 {
        return Some((0, 0));
    }
    let bits = v.to_bits();
    let biased = ((bits >> 23) & 0xFF) as i32;
    if biased == 0xFF {
        return None; // inf / NaN
    }
    let frac = (bits & 0x7F_FFFF) as i32;
    let (mut m, mut e) = if biased == 0 {
        (frac, -149) // subnormal
    } else {
        (frac | (1 << 23), biased - 127 - 23)
    };
    let tz = m.trailing_zeros() as i32;
    m >>= tz;
    e += tz;
    Some((if bits >> 31 != 0 { -m } else { m }, e))
}

/// Counts every [`pack`] invocation (hit or miss) process-wide.
static PACK_CALLS: AtomicU64 = AtomicU64::new(0);

/// Number of [`pack`] invocations since process start. Monotonic and
/// process-global (any thread, any caller), so only *deltas measured in
/// a single-threaded region* are meaningful — `bench_perf`'s
/// packed-vs-repack rows use it that way. Tests that need a
/// pollution-free count under a parallel test runner should prefer
/// [`PackedCache::builds`] via `Network::weight_pack_builds`.
pub fn pack_calls() -> u64 {
    PACK_CALLS.load(Ordering::Relaxed)
}

/// Pack an f32 slice onto a common power-of-two grid: `Some(p)` with
/// `xs[i] == p.ints[i] · 2^(p.exp)` exactly, or `None` when any element
/// is non-finite or the integers would not fit i16 (raw float32 data,
/// operands spanning > 15 octaves of grid, …). Quantized activations,
/// weights and gradients on the paper's storage formats always pack;
/// `None` just means "stay on the simulated path".
pub fn pack(xs: &[f32]) -> Option<Packed> {
    PACK_CALLS.fetch_add(1, Ordering::Relaxed);
    let mut dec = Vec::with_capacity(xs.len());
    let mut p: Option<i32> = None;
    for &v in xs {
        let (m, e) = decompose(v)?;
        if m != 0 {
            // fail fast on data that can never fit (odd mantissa wider
            // than 15 bits, e.g. generic float32 values)
            if m.unsigned_abs() > i16::MAX as u32 {
                return None;
            }
            p = Some(p.map_or(e, |p0| p0.min(e)));
        }
        dec.push((m, e));
    }
    let p = p.unwrap_or(0);
    let mut ints = Vec::with_capacity(xs.len());
    let mut amax: u32 = 0;
    for (m, e) in dec {
        if m == 0 {
            ints.push(0i16);
            continue;
        }
        let s = e - p; // ≥ 0 by construction of p
        if s > 14 {
            return None; // |m| ≥ 1 ⇒ |m << s| > i16::MAX
        }
        let mag = (m.unsigned_abs() as u64) << s;
        if mag > i16::MAX as u64 {
            return None;
        }
        amax = amax.max(mag as u32);
        ints.push(if m < 0 { -(mag as i16) } else { mag as i16 });
    }
    let ints = if amax <= i8::MAX as u32 {
        PackedInts::I8(ints.iter().map(|&v| v as i8).collect())
    } else {
        PackedInts::I16(ints)
    };
    Some(Packed { ints, exp: p, amax })
}

/// Worst-case absolute value of any partial sum at a GEMM site:
/// `inner · amax_a · amax_b` (saturating — a saturated value always
/// fails the bound check).
pub fn worst_case_sum(inner: usize, amax_a: u32, amax_b: u32) -> u64 {
    (inner as u64).saturating_mul(amax_a as u64).saturating_mul(amax_b as u64)
}

/// The accumulator eligibility bound: no i32 partial sum can exceed
/// `2^24`, which simultaneously guarantees i32 never overflows and the
/// simulated-f32 accumulation of the same products is exact.
pub fn accum_bound_ok(inner: usize, amax_a: u32, amax_b: u32) -> bool {
    worst_case_sum(inner, amax_a, amax_b) <= ACC_BOUND
}

/// Integer NN kernel: `out[m,n] += a[m,kd] @ b[kd,n]` in i32, with
/// `m = out.len() / n`. Same panel blocking and zero-skip as the f32
/// kernel (pure perf choices — integer accumulation is order-exact).
pub fn imm_nn_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    kd: usize,
    n: usize,
) {
    if n == 0 || kd == 0 {
        return;
    }
    let m = out.len() / n;
    let mut kb = 0;
    while kb < kd {
        let kend = (kb + KC).min(kd);
        for i in 0..m {
            let arow = &a[i * kd..(i + 1) * kd];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk].widen();
                if aik == 0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv.widen();
                }
            }
        }
        kb = kend;
    }
}

/// Integer NT kernel: `out[m,ib] = a[m,ua] @ b[ib,ua]^T` (assigns dot
/// products), with `m = out.len() / ib`.
pub fn imm_nt_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    ua: usize,
    ib: usize,
) {
    if ib == 0 {
        return;
    }
    let m = out.len() / ib;
    for i in 0..m {
        let arow = &a[i * ua..(i + 1) * ua];
        let orow = &mut out[i * ib..(i + 1) * ib];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * ua..(j + 1) * ua];
            let mut acc = 0i32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x.widen() * y.widen();
            }
            *o = acc;
        }
    }
}

/// Integer TN kernel for a row-slab: `out[ii,u] += a[nrow, i0+ii] *
/// b[nrow, u]` over all `ba` batch rows, `ii in 0..out.len()/ub`.
pub fn imm_tn_serial<A: PackInt, B: PackInt>(
    a: &[A],
    b: &[B],
    out: &mut [i32],
    ba: usize,
    ia: usize,
    ub: usize,
    i0: usize,
) {
    if ub == 0 {
        return;
    }
    let icount = out.len() / ub;
    for nrow in 0..ba {
        let arow = &a[nrow * ia..(nrow + 1) * ia];
        let brow = &b[nrow * ub..(nrow + 1) * ub];
        for ii in 0..icount {
            let av = arow[i0 + ii].widen();
            if av == 0 {
                continue;
            }
            let orow = &mut out[ii * ub..(ii + 1) * ub];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv.widen();
            }
        }
    }
}

/// A cached set of packed operand slabs (one weight layer's worth),
/// keyed on **value identity + adopted scale**:
///
/// * the *epoch*, a counter the owner bumps via [`PackedCache::invalidate`]
///   whenever the cached values change (the layer graph bumps it in
///   `sgd_update`, right after params are rewritten and re-quantized);
/// * the *scale key*, the owning group's adopted storage-format step as
///   `f32::to_bits` (so every dynamic scale move — `after_batch` ticks
///   and `adopt_int_bits` warmup transfer alike — forces a rebuild).
///
/// A hit returns the byte-identical `Packed` a fresh [`pack`] of the
/// same values would produce (packing is deterministic and
/// value-driven), so caching is invisible to the bit-identity contract;
/// a slab recorded as `None` means "these values do not pack" and the
/// caller falls back to the simulated kernels without re-attempting.
/// [`PackedCache::builds`] counts rebuild events for the invalidation
/// regression tests — it is per-cache state, immune to the parallel
/// test runner (unlike the global [`pack_calls`] counter).
#[derive(Default)]
pub struct PackedCache {
    /// Bumped by the owner on every value change.
    epoch: u64,
    /// The `(epoch, scale_bits)` the current slabs were built under.
    key: Option<(u64, u32)>,
    /// Shared so concurrent readers (data-parallel training workers)
    /// can hold the slab set across a whole GEMM loop without pinning
    /// the cache's lock: [`PackedCache::ensure`] hands out a clone of
    /// this `Arc` and the owner only swaps in a *new* vector on rebuild,
    /// never mutates one in place.
    slabs: Arc<Vec<Option<Packed>>>,
    builds: u64,
}

impl PackedCache {
    pub fn new() -> PackedCache {
        PackedCache::default()
    }

    /// Mark the cached values stale; the next [`PackedCache::ensure`]
    /// rebuilds every slab. Cheap (one counter bump) — callers invalidate
    /// unconditionally after updates rather than tracking whether the
    /// integer path is even enabled.
    pub fn invalidate(&mut self) {
        self.epoch += 1;
    }

    /// Number of slab-set rebuilds this cache has performed (= ensure
    /// misses). One training update or scale move costs exactly one.
    pub fn builds(&self) -> u64 {
        self.builds
    }

    /// Return the packed slabs for the current `(epoch, scale_bits)`
    /// key, rebuilding all `n_slabs` via `build(j)` on a key miss.
    ///
    /// Returns a shared handle rather than a borrow so a caller holding
    /// the cache behind a `Mutex` (the layer graph, once data-parallel
    /// workers share one `Network`) can drop the guard immediately and
    /// keep using the slabs while other workers hit the same cache.
    pub fn ensure(
        &mut self,
        scale_bits: u32,
        n_slabs: usize,
        mut build: impl FnMut(usize) -> Option<Packed>,
    ) -> Arc<Vec<Option<Packed>>> {
        let key = (self.epoch, scale_bits);
        if self.key != Some(key) || self.slabs.len() != n_slabs {
            self.slabs = Arc::new((0..n_slabs).map(&mut build).collect());
            self.key = Some(key);
            self.builds += 1;
        }
        Arc::clone(&self.slabs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2f_is_exact_at_the_extremes() {
        assert_eq!(exp2f(0), 1.0);
        assert_eq!(exp2f(-1), 0.5);
        assert_eq!(exp2f(10), 1024.0);
        assert_eq!(exp2f(-149).to_bits(), 1); // smallest subnormal
        assert_eq!(exp2f(-126), f32::MIN_POSITIVE);
        assert_eq!(exp2f(127), 2f32.powi(127));
    }

    #[test]
    fn decompose_roundtrips_odd_mantissas() {
        for v in [1.0f32, -1.0, 0.5, 3.0, -0.75, 1.5e-3, 2f32.powi(-149)] {
            let (m, e) = decompose(v).unwrap();
            assert!(m % 2 != 0, "mantissa must be odd for {v}");
            let back = m as f64 * 2f64.powi(e);
            assert_eq!(back as f32, v, "{v}");
        }
        assert_eq!(decompose(0.0), Some((0, 0)));
        assert_eq!(decompose(-0.0), Some((0, 0)));
        assert_eq!(decompose(f32::NAN), None);
        assert_eq!(decompose(f32::INFINITY), None);
    }

    #[test]
    fn pack_roundtrips_grid_values_exactly() {
        // values on a Q3.4 grid (step 1/16), mixed with zeros
        let step = 1.0f32 / 16.0;
        let xs: Vec<f32> = [-128i32, -37, -1, 0, 1, 5, 77, 127]
            .iter()
            .map(|&k| k as f32 * step)
            .collect();
        let p = pack(&xs).expect("grid values pack");
        assert!(!p.is_i8(), "amax 128 exceeds i8::MAX, needs i16");
        assert_eq!(p.amax, 128);
        let back = p.unpack();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pack_chooses_i8_when_it_fits() {
        let xs: Vec<f32> = (-127i32..=127).map(|k| k as f32 * 0.25).collect();
        let p = pack(&xs).expect("packs");
        assert!(p.is_i8());
        assert_eq!(p.amax, 127);
        assert_eq!(p.exp, -2);
        for (a, b) in xs.iter().zip(&p.unpack()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pack_handles_mixed_grids_via_common_exponent() {
        // 2.0 = 1·2^1 and 0.375 = 3·2^-3 → common p = -3: ints 16 and 3
        let p = pack(&[2.0, 0.375]).expect("packs");
        assert_eq!(p.exp, -3);
        assert_eq!(p.amax, 16);
        assert_eq!(p.unpack(), vec![2.0, 0.375]);
    }

    #[test]
    fn pack_rejects_wide_mantissas_and_nonfinite() {
        assert!(pack(&[0.1f32]).is_none(), "0.1 has a 24-bit odd mantissa");
        assert!(pack(&[f32::NAN]).is_none());
        assert!(pack(&[1.0, f32::INFINITY]).is_none());
        // > 15 octaves apart: ints would need > i16
        assert!(pack(&[1.0, 2f32.powi(-20)]).is_none());
    }

    #[test]
    fn pack_of_all_zeros_is_trivial() {
        let p = pack(&[0.0, -0.0, 0.0]).expect("zeros pack");
        assert_eq!((p.exp, p.amax), (0, 0));
        assert!(p.unpack().iter().all(|v| v.to_bits() == 0));
    }

    #[test]
    fn accum_bound_matches_definition() {
        assert!(accum_bound_ok(784, 64, 64)); // unit-scale data at mnist fan-in
        assert!(!accum_bound_ok(784, 512, 512)); // full-range 10-bit grids
        assert!(accum_bound_ok(0, u32::MAX, u32::MAX));
        assert!(accum_bound_ok(1 << 24, 1, 1));
        assert!(!accum_bound_ok(1 << 25, 1, 1));
        // saturating product can't sneak under the bound
        assert!(!accum_bound_ok(usize::MAX, u32::MAX, u32::MAX));
    }

    fn naive_nn(a: &[i32], b: &[i32], m: usize, kd: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for k in 0..kd {
                    out[i * n + j] += a[i * kd + k] * b[k * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn integer_kernels_match_naive_loops() {
        let mut state = 0x1234_5678u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as i32 % 21) - 10
        };
        let (m, kd, n) = (5usize, 7usize, 4usize);
        let a8: Vec<i8> = (0..m * kd).map(|_| next() as i8).collect();
        let b16: Vec<i16> = (0..kd * n).map(|_| next() as i16).collect();
        let aw: Vec<i32> = a8.iter().map(|&v| v as i32).collect();
        let bw: Vec<i32> = b16.iter().map(|&v| v as i32).collect();

        let mut nn = vec![0i32; m * n];
        imm_nn_serial(&a8, &b16, &mut nn, kd, n);
        assert_eq!(nn, naive_nn(&aw, &bw, m, kd, n));

        // NT: a[m,kd] @ b2[n,kd]^T equals NN against transposed b2
        let b2: Vec<i16> = (0..n * kd).map(|_| next() as i16).collect();
        let mut b2t = vec![0i32; kd * n];
        for j in 0..n {
            for k in 0..kd {
                b2t[k * n + j] = b2[j * kd + k] as i32;
            }
        }
        let mut nt = vec![0i32; m * n];
        imm_nt_serial(&a8, &b2, &mut nt, kd, n);
        assert_eq!(nt, naive_nn(&aw, &b2t, m, kd, n));

        // TN: a[ba,ia]^T @ b[ba,ub], checked slab by slab
        let (ba, ia, ub) = (6usize, 5usize, 3usize);
        let at: Vec<i8> = (0..ba * ia).map(|_| next() as i8).collect();
        let bt: Vec<i8> = (0..ba * ub).map(|_| next() as i8).collect();
        let mut att = vec![0i32; ia * ba];
        for r in 0..ba {
            for c in 0..ia {
                att[c * ba + r] = at[r * ia + c] as i32;
            }
        }
        let btw: Vec<i32> = bt.iter().map(|&v| v as i32).collect();
        let want = naive_nn(&att, &btw, ia, ba, ub);
        for (i0, rows) in [(0usize, ia), (1, 2), (4, 1)] {
            let mut slab = vec![0i32; rows * ub];
            imm_tn_serial(&at, &bt, &mut slab, ba, ia, ub, i0);
            assert_eq!(slab[..], want[i0 * ub..(i0 + rows) * ub], "slab {i0}+{rows}");
        }
    }

    #[test]
    fn packed_cache_rebuilds_only_on_epoch_or_scale_change() {
        let xs: Vec<f32> = (-4i32..4).map(|k| k as f32 * 0.5).collect();
        let mut cache = PackedCache::new();
        let step = 0.5f32.to_bits();
        {
            let slabs = cache.ensure(step, 2, |_| pack(&xs));
            assert_eq!(slabs.len(), 2);
            assert!(slabs.iter().all(|s| s.is_some()));
        }
        assert_eq!(cache.builds(), 1);
        // same key: a hit, no rebuild
        cache.ensure(step, 2, |_| panic!("hit must not rebuild"));
        assert_eq!(cache.builds(), 1);
        // scale move: rebuild
        cache.ensure(0.25f32.to_bits(), 2, |_| pack(&xs));
        assert_eq!(cache.builds(), 2);
        // value change: rebuild, and the new packs are served
        cache.invalidate();
        let ys = [1.0f32, 3.0];
        let amax = cache.ensure(0.25f32.to_bits(), 2, |_| pack(&ys))[0]
            .as_ref()
            .unwrap()
            .amax;
        assert_eq!(cache.builds(), 3);
        assert_eq!(amax, 3);
        // a slab that fails to pack is cached as None (no re-attempt)
        cache.invalidate();
        assert!(cache.ensure(step, 1, |_| pack(&[0.1f32]))[0].is_none());
        assert_eq!(cache.builds(), 4);
        cache.ensure(step, 1, |_| panic!("None slabs are cached too"));
        assert_eq!(cache.builds(), 4);
    }

    #[test]
    fn pack_calls_counts_invocations() {
        let before = pack_calls();
        let _ = pack(&[1.0f32, 2.0]);
        let _ = pack(&[0.1f32]); // miss still counts
        assert!(pack_calls() >= before + 2);
    }

    #[test]
    fn blocked_nn_handles_kd_across_panel_boundaries() {
        // kd > KC exercises the panel loop; exact integer accumulation
        // means blocking must be invisible
        let (m, kd, n) = (3usize, 300usize, 2usize);
        let a: Vec<i8> = (0..m * kd).map(|i| ((i % 5) as i8) - 2).collect();
        let b: Vec<i8> = (0..kd * n).map(|i| ((i % 7) as i8) - 3).collect();
        let aw: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        let bw: Vec<i32> = b.iter().map(|&v| v as i32).collect();
        let mut out = vec![0i32; m * n];
        imm_nn_serial(&a, &b, &mut out, kd, n);
        assert_eq!(out, naive_nn(&aw, &bw, m, kd, n));
    }
}
