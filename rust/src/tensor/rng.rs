//! Deterministic pseudo-random number generation (PCG32 + SplitMix64).
//!
//! Every stochastic choice in the stack — parameter init, dataset
//! synthesis, shuffling, stochastic rounding — flows through [`Pcg32`]
//! seeded from the experiment config, so whole training runs replay
//! bit-identically. (The *in-graph* dropout PRNG is separate: a
//! counter-based hash inside the compiled artifact, fed a per-step seed by
//! the trainer.)

/// PCG-XSH-RR 64/32 (O'Neill 2014): tiny, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with an arbitrary 64-bit seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    /// Derive an independent generator for a named sub-purpose (dataset,
    /// init, shuffle, ...) so adding one consumer never perturbs another.
    pub fn fork(&self, tag: u64) -> Pcg32 {
        Pcg32::new(self.state ^ splitmix(tag), self.inc ^ tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Unbiased integer in `[0, n)` (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n {
                return (m >> 32) as u32;
            }
            // reject the biased low region
            let t = n.wrapping_neg() % n;
            if l >= t {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u32) as usize
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.uniform() as f64).max(1e-12);
        let u2 = self.uniform() as f64;
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 finalizer (for seed derivation only).
fn splitmix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(17);
        let mut b = Pcg32::seeded(17);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(17);
        let mut b = Pcg32::seeded(18);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn forks_are_decorrelated() {
        let root = Pcg32::seeded(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_moments() {
        let mut g = Pcg32::seeded(5);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| g.uniform()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut g = Pcg32::seeded(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[g.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg32::seeded(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg32::seeded(3);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
