//! Minimal host tensor substrate.
//!
//! The coordinator and golden model need a small, fast, dependency-free
//! host tensor: contiguous `f32` storage + shape, the linear algebra the
//! maxout networks use (matmul, the k-filter einsum contractions, softmax,
//! reductions), a deterministic RNG ([`rng::Pcg32`]) and the paper's
//! initialization scheme (Glorot uniform + zero biases).
//!
//! This is deliberately *not* a general tensor library: every op the
//! training stack needs is implemented directly and tested against slow
//! obviously-correct loops, nothing more.

pub mod init;
pub mod int_gemm;
pub mod ops;
pub mod rng;

pub use rng::Pcg32;

use std::fmt;

/// The logical shape of one signal (one example / one activation row),
/// independent of the batch axis: either a flat feature vector or a
/// row-major H×W×C image. This is what the layer graph threads through
/// its `out_shape` contract and what `data::dataset_shape` reports, so
/// conv topologies can be validated against a dataset before any data
/// is generated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// A flat `d`-dimensional feature vector.
    Flat(usize),
    /// A row-major H×W×C image (NHWC once batched).
    Spatial { h: usize, w: usize, c: usize },
}

impl Shape {
    /// Flat element count (what a dense consumer of this signal sees).
    pub fn len(&self) -> usize {
        match *self {
            Shape::Flat(d) => d,
            Shape::Spatial { h, w, c } => h * w * c,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-example tensor dims: `[d]` or `[h, w, c]`.
    pub fn dims(&self) -> Vec<usize> {
        match *self {
            Shape::Flat(d) => vec![d],
            Shape::Spatial { h, w, c } => vec![h, w, c],
        }
    }

    /// The same signal viewed as a flat vector (what `Flatten` does).
    pub fn flattened(&self) -> Shape {
        Shape::Flat(self.len())
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Shape::Flat(d) => write!(f, "flat({d})"),
            Shape::Spatial { h, w, c } => write!(f, "{h}x{w}x{c}"),
        }
    }
}

/// A dense, contiguous, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Wrap existing data (length must match the shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row-major flat index for a 2-D tensor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row-major flat index for a 3-D tensor.
    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Max |x| over the tensor (range probe for scale initialization).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[6], (1..=6).map(|i| i as f32).collect());
        let t = t.reshape(&[2, 3]);
        assert_eq!(t.at2(1, 1), 5.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn shape_lengths_dims_and_display() {
        let f = Shape::Flat(784);
        assert_eq!(f.len(), 784);
        assert_eq!(f.dims(), vec![784]);
        assert_eq!(f.flattened(), f);
        assert_eq!(format!("{f}"), "flat(784)");
        let s = Shape::Spatial { h: 32, w: 32, c: 3 };
        assert_eq!(s.len(), 3072);
        assert_eq!(s.dims(), vec![32, 32, 3]);
        assert_eq!(s.flattened(), Shape::Flat(3072));
        assert_eq!(format!("{s}"), "32x32x3");
        assert!(!s.is_empty());
        assert!(Shape::Flat(0).is_empty());
    }

    #[test]
    fn abs_max_and_norm() {
        let t = Tensor::from_vec(&[4], vec![1.0, -3.0, 2.0, 0.0]);
        assert_eq!(t.abs_max(), 3.0);
        assert!((t.norm() - 14f32.sqrt()).abs() < 1e-6);
    }
}
