//! The layer-graph executor: topology as data, quantization sites
//! derived from the graph.
//!
//! The golden model used to be one hand-inlined 2-hidden-layer maxout
//! step (`MlpShape::pi_mlp` pinned the whole topology). This module
//! decomposes it into a [`Layer`] trait with three concrete layers —
//! [`MaxoutDense`], [`SoftmaxHead`], [`DropoutLayer`] — assembled into a
//! [`Network`] from a [`TopologySpec`], so depth/width sweeps and
//! CIFAR/SVHN-class MLP workloads are config changes, not code changes.
//!
//! **The bit-identity contract.** The graph executor is not "close to"
//! the monolithic step it replaced — it is bit-identical on the builtin
//! `pi_mlp`, across all four arithmetics, all four rounding modes, fused
//! and two-pass kernels, any thread count, and with dropout on
//! (`tests/graph_parity.rs` asserts exact `u32` bits against
//! [`super::reference`]). Three orderings make that hold, and every
//! layer implementation must preserve them:
//!
//! 1. **Site order.** [`GoldenQ`] numbers quantization sites in call
//!    order (stochastic-rounding streams key on the site index). The
//!    graph visits sites exactly as the monolith did: forward
//!    `Z,H` per maxout layer then the head's `Z`; backward `DZ,DW,DB`
//!    per compute layer top-down, with the produced `dx` quantized as
//!    the *next compute layer below*'s `DH` group **before** any
//!    intervening dropout mask is applied; update `w` then `b` per
//!    layer bottom-up, velocity before parameter.
//! 2. **Group table.** Scaling-factor groups stay layer-major
//!    (`group_index(row, kind) = row * N_KINDS + kind`) where `row` is
//!    the compute layer's position in the graph (dropout layers own no
//!    groups). [`Network::n_groups`] is therefore *derived from the
//!    graph* and is what
//!    [`ScaleController::fixed`]/[`ScaleController::dynamic`] take.
//! 3. **RNG draw order.** Dropout masks draw from one stream in forward
//!    graph order (input mask first, then after each hidden layer), so
//!    the graph replays the monolith's masks bit-for-bit.

use crate::arith::{QuantStats, RoundMode};
use crate::config::TopologySpec;
use crate::coordinator::ScaleController;
use crate::runtime::manifest::{
    KIND_B, KIND_DB, KIND_DH, KIND_DW, KIND_DZ, KIND_H, KIND_W, KIND_Z, N_KINDS,
};
use crate::tensor::{ops, Tensor};

use super::{
    apply_mask, Dropout, dropout_mask, GoldenOut, GoldenQ, MlpShape, Params,
    StepOptions, STOCHASTIC_SITE_SEED,
};

/// Per-step state a layer saves in `forward` for its `backward`. A
/// closed enum instead of `Box<dyn Any>`: the three layer kinds are a
/// deliberate vocabulary, and the variants keep tensor moves explicit.
pub enum Cache {
    /// Maxout: the (possibly dropout-masked) input + winning filter per
    /// `[B, U]` output.
    Maxout { x: Tensor, amax: Vec<u8> },
    /// Head: the (possibly dropout-masked) input.
    Head { x: Tensor },
    /// Dropout: the drawn mask (`None` = identity this step).
    Mask(Option<Vec<f32>>),
}

/// Where a [`DropoutLayer`] reads its rate from ([`StepOptions`] carries
/// the schedule's per-step input/hidden rates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropoutRole {
    Input,
    Hidden,
}

/// The per-step dropout stream, threaded through the forward pass. Draws
/// happen in graph order from the single [`Dropout`] RNG, which is what
/// keeps graph masks identical to the monolith's.
pub struct DropCtx<'a> {
    dropout: Option<&'a mut Dropout>,
}

impl<'a> DropCtx<'a> {
    /// Evaluation context: no masks, no RNG draws.
    pub fn eval() -> DropCtx<'static> {
        DropCtx { dropout: None }
    }

    /// Training context over the step's dropout state (if any).
    pub fn train(dropout: Option<&'a mut Dropout>) -> DropCtx<'a> {
        DropCtx { dropout }
    }

    fn mask(&mut self, n: usize, role: DropoutRole) -> Option<Vec<f32>> {
        let d = self.dropout.as_mut()?;
        let rate = match role {
            DropoutRole::Input => d.input_rate,
            DropoutRole::Hidden => d.hidden_rate,
        };
        dropout_mask(&mut d.rng, n, rate)
    }
}

/// Resolved per-step update hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct UpdateHp {
    pub lr: f32,
    pub mom: f32,
    pub max_norm: f32,
}

/// One node of the training graph.
///
/// A layer owns a contiguous run of the manifest-ordered parameter
/// vector (`n_params` tensors; the [`Network`] slices them out) and, if
/// it quantizes anything, one scaling-group *row* (`group_row`) in the
/// layer-major group table. Every quantization site a layer touches
/// registers against the shared [`GoldenQ`] in a fixed visit order — see
/// the module docs for the three orderings the implementations must
/// preserve.
pub trait Layer {
    /// Human-readable description for diagnostics.
    fn describe(&self) -> String;

    /// The scaling-group row this layer's sites record under; `None`
    /// for stateless layers with no quantization sites (dropout).
    fn group_row(&self) -> Option<usize>;

    /// Number of parameter tensors this layer owns (manifest order).
    fn n_params(&self) -> usize {
        0
    }

    /// Output feature width given the input feature width.
    fn out_dim(&self, d_in: usize) -> usize;

    /// Consume the layer input, produce its output plus whatever the
    /// backward pass needs. Quantization sites register against `q` in
    /// visit order.
    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache);

    /// Consume the gradient w.r.t. this layer's output; produce the
    /// parameter gradients (manifest order) and, when `dx_group` is
    /// `Some(row)`, the gradient w.r.t. the layer input quantized under
    /// `(row, DH)` — the *lower* compute layer's DH group, matching the
    /// monolith's (and L2's) attribution. `dx_group = None` means no
    /// consumer below needs `dx`.
    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>);

    /// SGD + momentum + max-norm + storage quantization over this
    /// layer's parameter run. Default: no parameters, nothing to do.
    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        let _ = (q, params, vels, grads, hp);
        debug_assert!(self.n_params() == 0, "parameterized layer must implement sgd_update");
    }
}

/// The shared dense-layer update rule (w then b, velocity quantized
/// unrecorded, parameter max-normed then quantized recorded) — exactly
/// the monolith's per-parameter sequence.
fn dense_sgd_update(
    q: &mut GoldenQ,
    group: usize,
    params: &mut [Tensor],
    vels: &mut [Tensor],
    grads: &[Tensor],
    hp: &UpdateHp,
) {
    debug_assert_eq!(params.len(), 2);
    debug_assert_eq!(grads.len(), 2);
    for i in 0..2 {
        let kind = if i == 0 { KIND_W } else { KIND_B };
        // v' = Q_up(mom*v - lr*g), stats NOT recorded (matches L2)
        for (vv, gv) in vels[i].data_mut().iter_mut().zip(grads[i].data()) {
            *vv = hp.mom * *vv - hp.lr * gv;
        }
        q.apply(&mut vels[i], group, kind, false);
        // p' = Q_up(maxnorm(p + v'))
        for (pv, vv) in params[i].data_mut().iter_mut().zip(vels[i].data()) {
            *pv += vv;
        }
        if kind == KIND_W {
            ops::max_norm_inplace(&mut params[i], hp.max_norm);
        }
        q.apply(&mut params[i], group, kind, true);
    }
}

// ---------------------------------------------------------------------------
// MaxoutDense
// ---------------------------------------------------------------------------

/// One maxout dense layer: per-filter `z_j = x @ w_j + b_j` (Z group,
/// one logical site across all `k` filter tiles, fused into the GEMM
/// epilogues), `h = max_j z_j` (H group). Params: `w [k, I, U]`,
/// `b [k, U]`.
pub struct MaxoutDense {
    pub units: usize,
    pub k: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
}

impl Layer for MaxoutDense {
    fn describe(&self) -> String {
        format!("maxout({}x{})@l{}", self.units, self.k, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_dim(&self, _d_in: usize) -> usize {
        self.units
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let batch = x.shape()[0];
        assert_eq!(x.shape()[1], d_in, "{}: input width", self.describe());

        // z for every filter, quantized as ONE logical site. Fused: each
        // filter's [B, U] tile gets bias + quantization in its GEMM
        // epilogue (base = the filter's offset in the [k, B, U] tensor).
        // Two-pass: materialize all k tiles, then sweep the whole tensor.
        // Identical per-element index stream → identical bits/counters.
        let mut zq = Tensor::zeros(&[k, batch, units]);
        let epi = q.epilogue(self.group, KIND_Z);
        let mut zst = QuantStats::default();
        for j in 0..k {
            let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
            let brow = &b.data()[j * units..(j + 1) * units];
            let dst = &mut zq.data_mut()[j * batch * units..(j + 1) * batch * units];
            if q.fused {
                zst.merge(ops::matmul_sl_q_into(
                    x.data(),
                    wj,
                    Some(brow),
                    dst,
                    batch,
                    d_in,
                    units,
                    epi.with_base((j * batch * units) as u64),
                ));
            } else {
                let zj = ops::matmul_sl(x.data(), wj, batch, d_in, units);
                for r in 0..batch {
                    for u in 0..units {
                        dst[r * units + u] = zj[r * units + u] + brow[u];
                    }
                }
            }
        }
        if !q.fused {
            zst = epi.run(zq.data_mut(), 0);
        }
        q.record(self.group, KIND_Z, zst);

        let mut h = Tensor::zeros(&[batch, units]);
        let mut amax = vec![0u8; batch * units];
        for r in 0..batch {
            for u in 0..units {
                let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
                for j in 0..k {
                    let v = zq.at3(j, r, u);
                    if v > best {
                        best = v;
                        bj = j as u8;
                    }
                }
                h.data_mut()[r * units + u] = best;
                amax[r * units + u] = bj;
            }
        }
        q.apply(&mut h, self.group, KIND_H, true);
        (h, Cache::Maxout { x, amax })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Maxout { x, amax } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let batch = x.shape()[0];

        // route dh to the winning filter, quantize (DZ group)
        let mut dz = Tensor::zeros(&[k, batch, units]);
        for r in 0..batch {
            for u in 0..units {
                let j = amax[r * units + u] as usize;
                dz.data_mut()[(j * batch + r) * units + u] = dy.at2(r, u);
            }
        }
        q.apply(&mut dz, self.group, KIND_DZ, true);

        // dw for every filter, quantized as ONE logical site (like the z
        // tiles in the forward pass). The dx contraction is NOT fused:
        // its per-filter products are summed across filters before the
        // total is quantized as the lower layer's DH group.
        let mut dw = Tensor::zeros(&[k, d_in, units]);
        let mut db = Tensor::zeros(&[k, units]);
        let mut dx = Tensor::zeros(&[batch, d_in]);
        let epi = q.epilogue(self.group, KIND_DW);
        let mut dwst = QuantStats::default();
        for j in 0..k {
            // contiguous [batch, units] view of this filter's dz
            let dzj = &dz.data()[j * batch * units..(j + 1) * batch * units];
            let dwj_dst = &mut dw.data_mut()[j * d_in * units..(j + 1) * d_in * units];
            if q.fused {
                dwst.merge(ops::matmul_tn_sl_q_into(
                    x.data(),
                    dzj,
                    dwj_dst,
                    batch,
                    d_in,
                    units,
                    epi.with_base((j * d_in * units) as u64),
                ));
            } else {
                let dwj = ops::matmul_tn_sl(x.data(), dzj, batch, d_in, units);
                dwj_dst.copy_from_slice(&dwj);
            }
            let dbj = ops::sum_rows_sl(dzj, batch, units);
            db.data_mut()[j * units..(j + 1) * units].copy_from_slice(&dbj);
            if dx_group.is_some() {
                let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
                let dxj = ops::matmul_nt_sl(dzj, wj, batch, units, d_in);
                for (a, &b) in dx.data_mut().iter_mut().zip(&dxj) {
                    *a += b;
                }
            }
        }
        if !q.fused {
            dwst = epi.run(dw.data_mut(), 0);
        }
        q.record(self.group, KIND_DW, dwst);
        q.apply(&mut db, self.group, KIND_DB, true);

        let dx = dx_group.map(|g| {
            q.apply(&mut dx, g, KIND_DH, true);
            dx
        });
        (vec![dw, db], dx)
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
    }
}

// ---------------------------------------------------------------------------
// SoftmaxHead
// ---------------------------------------------------------------------------

/// The classifier head: `z = x @ w + b` with the bias and Z-group
/// quantization fused into the GEMM epilogue. The softmax/cross-entropy
/// itself is loss machinery and lives in the [`Network`] driver (as it
/// did in the monolith); this layer's backward consumes the pre-quantized
/// `(p - y)/B` and owns the DZ/DW/DB sites plus the fused DH projection.
/// Params: `w [U, C]`, `b [C]`.
pub struct SoftmaxHead {
    pub n_classes: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
}

impl Layer for SoftmaxHead {
    fn describe(&self) -> String {
        format!("softmax({})@l{}", self.n_classes, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_dim(&self, _d_in: usize) -> usize {
        self.n_classes
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let batch = x.shape()[0];
        assert_eq!(x.shape()[1], units, "{}: input width", self.describe());

        let epi = q.epilogue(self.group, KIND_Z);
        let z = if q.fused {
            let (v, st) = ops::matmul_sl_q(
                x.data(),
                w.data(),
                Some(b.data()),
                batch,
                units,
                classes,
                epi,
            );
            q.record(self.group, KIND_Z, st);
            Tensor::from_vec(&[batch, classes], v)
        } else {
            let mut z = ops::matmul(&x, w);
            for r in 0..batch {
                for c in 0..classes {
                    z.data_mut()[r * classes + c] += b.data()[c];
                }
            }
            let st = epi.run(z.data_mut(), 0);
            q.record(self.group, KIND_Z, st);
            z
        };
        (z, Cache::Head { x })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        mut dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Head { x } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let batch = x.shape()[0];

        // dy arrives as the pre-quantized loss gradient (p - y)/B
        q.apply(&mut dy, self.group, KIND_DZ, true);
        let dz = dy;

        let epi = q.epilogue(self.group, KIND_DW);
        let dw = if q.fused {
            let (v, st) = ops::matmul_tn_sl_q(x.data(), dz.data(), batch, units, classes, epi);
            q.record(self.group, KIND_DW, st);
            Tensor::from_vec(&[units, classes], v)
        } else {
            let mut dw = ops::matmul_tn(x, &dz);
            let st = epi.run(dw.data_mut(), 0);
            q.record(self.group, KIND_DW, st);
            dw
        };
        let mut db = ops::sum_rows(&dz);
        q.apply(&mut db, self.group, KIND_DB, true);

        // dx quantized as the lower layer's DH group, fused into the NT
        // projection (the monolith's dh1 site, generalized)
        let dx = dx_group.map(|g| {
            let epi = q.epilogue(g, KIND_DH);
            if q.fused {
                let (v, st) =
                    ops::matmul_nt_sl_q(dz.data(), w.data(), batch, classes, units, epi);
                q.record(g, KIND_DH, st);
                Tensor::from_vec(&[batch, units], v)
            } else {
                let mut dx = ops::matmul_nt(&dz, w);
                let st = epi.run(dx.data_mut(), 0);
                q.record(g, KIND_DH, st);
                dx
            }
        });
        (vec![dw, db], dx)
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
    }
}

// ---------------------------------------------------------------------------
// DropoutLayer
// ---------------------------------------------------------------------------

/// Inverted dropout as a graph node: draws its mask from the step's
/// shared [`Dropout`] stream in forward graph order, masks in place, and
/// replays the mask over the gradient in backward. No quantization
/// sites, no parameters, identity in evaluation.
pub struct DropoutLayer {
    pub role: DropoutRole,
}

impl DropoutLayer {
    pub fn input() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Input }
    }

    pub fn hidden() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Hidden }
    }
}

impl Layer for DropoutLayer {
    fn describe(&self) -> String {
        match self.role {
            DropoutRole::Input => "dropout(input)".into(),
            DropoutRole::Hidden => "dropout(hidden)".into(),
        }
    }

    fn group_row(&self) -> Option<usize> {
        None
    }

    fn out_dim(&self, d_in: usize) -> usize {
        d_in
    }

    fn forward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        mut x: Tensor,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let mask = drop.mask(x.len(), self.role);
        apply_mask(&mut x, &mask);
        (x, Cache::Mask(mask))
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: &Cache,
        mut dy: Tensor,
        _dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Mask(mask) = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        apply_mask(&mut dy, mask);
        (Vec::new(), Some(dy))
    }
}

// ---------------------------------------------------------------------------
// Network
// ---------------------------------------------------------------------------

/// A maxout MLP assembled from [`Layer`]s, driving one train/eval step
/// over the manifest-ordered flat parameter vector. Built from a
/// [`TopologySpec`] (+ dataset dimensions) or, for the legacy call
/// sites, from an [`MlpShape`].
pub struct Network {
    layers: Vec<Box<dyn Layer>>,
    /// Per layer: (offset, count) into the flat manifest-order params.
    param_ranges: Vec<(usize, usize)>,
    n_group_rows: usize,
    d_in: usize,
    n_classes: usize,
}

impl Network {
    /// Realize a topology against a data source's dimensions. The layer
    /// sequence mirrors the monolithic step: input dropout, then per
    /// hidden layer a maxout dense + hidden dropout, then the head.
    pub fn from_topology(spec: &TopologySpec, d_in: usize, n_classes: usize) -> Network {
        // hard invariant, not a debug check: a spec that skipped
        // validate() must not silently build a head-only linear model
        assert!(!spec.hidden.is_empty(), "topology needs >= 1 hidden layer");
        let mut layers: Vec<Box<dyn Layer>> = Vec::with_capacity(2 * spec.hidden.len() + 2);
        layers.push(Box::new(DropoutLayer::input()));
        let mut row = 0;
        for &units in &spec.hidden {
            layers.push(Box::new(MaxoutDense { units, k: spec.k, group: row }));
            row += 1;
            layers.push(Box::new(DropoutLayer::hidden()));
        }
        layers.push(Box::new(SoftmaxHead { n_classes, group: row }));
        row += 1;

        let mut param_ranges = Vec::with_capacity(layers.len());
        let mut offset = 0;
        for l in &layers {
            param_ranges.push((offset, l.n_params()));
            offset += l.n_params();
        }
        Network { layers, param_ranges, n_group_rows: row, d_in, n_classes }
    }

    /// The 2-hidden-layer network an [`MlpShape`] describes (the legacy
    /// golden entry points drive this).
    pub fn from_mlp_shape(s: MlpShape) -> Network {
        let spec = TopologySpec::mlp(vec![s.units, s.units], s.k);
        Network::from_topology(&spec, s.d_in, s.n_classes)
    }

    /// Scaling-factor group count derived from the graph: one row of
    /// `N_KINDS` kinds per compute layer. This is the number
    /// [`ScaleController::fixed`]/[`ScaleController::dynamic`] take.
    pub fn n_groups(&self) -> usize {
        self.n_group_rows * N_KINDS
    }

    /// Number of compute layers (= group rows): hidden + head.
    pub fn n_compute_layers(&self) -> usize {
        self.n_group_rows
    }

    /// Flat input width the network consumes.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Total parameter tensors (manifest order: w0 b0 w1 b1 ...).
    pub fn n_params(&self) -> usize {
        self.param_ranges.last().map(|&(o, n)| o + n).unwrap_or(0)
    }

    /// One-line graph description for diagnostics.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.layers.iter().map(|l| l.describe()).collect();
        parts.join(" -> ")
    }

    /// Group row of the closest compute layer strictly below `pos`
    /// (`None` when `pos` is the bottom compute layer).
    fn group_row_below(&self, pos: usize) -> Option<usize> {
        self.layers[..pos].iter().rev().find_map(|l| l.group_row())
    }

    /// One full train step over the graph. Bit-identical to the
    /// monolithic reference on the builtin topology (see module docs);
    /// mutates params/vels in place.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &mut Params,
        vels: &mut Params,
        x: &Tensor,
        y: &Tensor,
        lr: f32,
        mom: f32,
        max_norm: f32,
        ctrl: &ScaleController,
        mut opts: StepOptions,
    ) -> GoldenOut {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
        q.fused = opts.fused;
        if opts.mode == RoundMode::Stochastic {
            // true stochastic rounding draws one uniform sample per
            // element from counter-based per-site streams (index-keyed,
            // so the fused and two-pass paths sample identically)
            q.stochastic_seed = Some(STOCHASTIC_SITE_SEED);
        }
        let batch = x.shape()[0];
        let classes = self.n_classes;
        let mut dctx = DropCtx::train(opts.dropout.as_mut());

        // ---- forward ----
        let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
        // one input copy buys by-value tensor flow through the whole
        // graph (layers move activations into their caches); negligible
        // next to the layer GEMMs — the `graph train step` bench rows
        // track this dispatch overhead against the monolith
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, cache) = layer.forward(&mut q, &params[o..o + n], h, &mut dctx);
            caches.push(cache);
            h = out;
        }
        let z = h;
        let logp = ops::log_softmax(&z);
        let mut loss = 0.0f64;
        for i in 0..batch * classes {
            loss -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let loss = (loss / batch as f64) as f32;

        // ---- backward ----
        // loss gradient dz = (p - y)/B, handed to the head pre-quantized
        let mut dz = Tensor::zeros(&[batch, classes]);
        for i in 0..batch * classes {
            dz.data_mut()[i] = (logp.data()[i].exp() - y.data()[i]) / batch as f32;
        }
        let mut grads: Vec<Vec<Tensor>> = Vec::with_capacity(self.layers.len());
        grads.resize_with(self.layers.len(), Vec::new);
        let mut dy = dz;
        for pos in (0..self.layers.len()).rev() {
            let layer = &self.layers[pos];
            let (o, n) = self.param_ranges[pos];
            if layer.group_row().is_some() {
                let dx_group = self.group_row_below(pos);
                let (g, dx) =
                    layer.backward(&mut q, &params[o..o + n], &caches[pos], dy, dx_group);
                grads[pos] = g;
                match dx {
                    Some(d) => dy = d,
                    // bottom compute layer: nothing below consumes dx
                    None => break,
                }
            } else {
                let (_, dx) = layer.backward(&mut q, &[], &caches[pos], dy, None);
                dy = dx.expect("stateless layers pass their gradient through");
            }
        }

        // ---- SGD + momentum + max-norm + storage quantization ----
        // (bottom-up = manifest parameter order, matching the monolith)
        let hp = UpdateHp { lr, mom, max_norm };
        for (pos, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[pos];
            if n == 0 {
                continue;
            }
            layer.sgd_update(
                &mut q,
                &mut params[o..o + n],
                &mut vels[o..o + n],
                &grads[pos],
                &hp,
            );
        }

        GoldenOut { loss, overflow: q.stats_matrix() }
    }

    /// Forward-only logits `[B, C]` (no dropout, no mutation),
    /// quantizing forward signals exactly as the train step does.
    pub fn eval_logits(
        &self,
        params: &Params,
        x: &Tensor,
        ctrl: &ScaleController,
        mode: RoundMode,
        half: bool,
    ) -> Tensor {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        let mut q = GoldenQ::with_half(ctrl, mode, half);
        let mut dctx = DropCtx::eval();
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, _) = layer.forward(&mut q, &params[o..o + n], h, &mut dctx);
            h = out;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::FixedFormat;
    use crate::runtime::manifest::group_index;
    use crate::runtime::ModelInfo;
    use crate::tensor::Pcg32;

    fn spec3() -> TopologySpec {
        TopologySpec::mlp(vec![10, 8, 6], 2)
    }

    /// Params + vels realized from the ModelInfo the same spec produces.
    fn state(spec: &TopologySpec, d_in: usize, n_classes: usize, seed: u64) -> (Params, Params) {
        let info = ModelInfo::from_topology(spec, d_in, n_classes);
        let mut rng = Pcg32::seeded(seed);
        let params: Vec<Tensor> =
            info.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
        let vels = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        (params, vels)
    }

    #[test]
    fn graph_derives_group_table_from_topology() {
        let net = Network::from_topology(&spec3(), 12, 4);
        assert_eq!(net.n_compute_layers(), 4);
        assert_eq!(net.n_groups(), 4 * N_KINDS);
        assert_eq!(net.n_params(), 8);
        assert_eq!(net.d_in(), 12);
        assert_eq!(net.n_classes(), 4);
        let desc = net.describe();
        assert!(desc.starts_with("dropout(input) -> maxout(10x2)@l0"), "{desc}");
        assert!(desc.ends_with("softmax(4)@l3"), "{desc}");
        // shape inference chains input width to class count
        let mut w = net.d_in();
        for l in &net.layers {
            w = l.out_dim(w);
        }
        assert_eq!(w, net.n_classes());
    }

    #[test]
    fn deep_topology_trains_and_counts_per_layer_overflow() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let n = 16;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let out = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            2.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[4 * N_KINDS, 3]);
        // per-layer totals reflect each layer's own width
        assert_eq!(out.overflow.at2(group_index(0, KIND_Z), 2), (2 * n * 10) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_Z), 2), (2 * n * 8) as f32);
        assert_eq!(out.overflow.at2(group_index(2, KIND_Z), 2), (2 * n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_Z), 2), (n * 4) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_DZ), 2), (n * 4) as f32);
        // DH flows into every layer below the head
        assert_eq!(out.overflow.at2(group_index(2, KIND_DH), 2), (n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_DH), 2), (n * 10) as f32);
    }

    #[test]
    fn deep_topology_loss_decreases() {
        let spec = TopologySpec::mlp(vec![16, 16, 16], 2);
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl =
            ScaleController::fixed(net.n_groups(), FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 5);
        let n = 16;
        let mut rng = Pcg32::seeded(6);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let (mut first, mut last) = (None, 0.0);
        for _ in 0..40 {
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.2,
                0.5,
                0.0,
                &ctrl,
                StepOptions::default(),
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    #[should_panic(expected = "Network::n_groups")]
    fn wrong_controller_size_is_rejected() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        // sized for 3 compute layers, but the graph has 4
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let x = Tensor::zeros(&[2, 12]);
        let y = ops::one_hot(&[0, 1], 4);
        let _ = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
    }

    #[test]
    fn eval_matches_zero_lr_forward_on_deep_net() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(12, 3),
            FixedFormat::new(12, 0),
        );
        let (params, _) = state(&spec, 12, 4, 8);
        let n = 8;
        let mut rng = Pcg32::seeded(4);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        // quantize storage as the trainer does at init
        let mut pq = params.clone();
        for (i, p) in pq.iter_mut().enumerate() {
            let g = group_index(i / 2, if i % 2 == 0 { KIND_W } else { KIND_B });
            crate::arith::Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
        }
        let logits = net.eval_logits(&pq, &x, &ctrl, RoundMode::HalfAway, false);
        let logp = ops::log_softmax(&logits);
        let mut want = 0.0f64;
        for i in 0..n * 4 {
            want -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let want = (want / n as f64) as f32;
        let (mut p2, mut v2) = (pq.clone(), state(&spec, 12, 4, 8).1);
        let out = net.train_step(
            &mut p2,
            &mut v2,
            &x,
            &y,
            0.0,
            0.0,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!((out.loss - want).abs() < 1e-5, "{want} vs {}", out.loss);
    }
}
