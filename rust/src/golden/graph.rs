//! The layer-graph executor: topology as data, quantization sites
//! derived from the graph, signals threaded as shape-aware tensors.
//!
//! The golden model used to be one hand-inlined 2-hidden-layer maxout
//! step (`MlpShape::pi_mlp` pinned the whole topology). This module
//! decomposes it into a [`Layer`] trait — dense layers
//! ([`MaxoutDense`], [`SoftmaxHead`]), spatial layers ([`MaxoutConv2d`],
//! [`MaxPool2d`], [`Flatten`]) and [`DropoutLayer`] — assembled into a
//! [`Network`] from a [`TopologySpec`], so depth/width sweeps *and* the
//! paper's CIFAR-10/SVHN-class maxout-conv workloads are config
//! changes, not code changes.
//!
//! **Signals are shape-aware.** Every layer declares its output
//! [`Shape`] (`Flat(d)` or `Spatial{h,w,c}`) as a function of its input
//! shape via [`Layer::out_shape`];
//! [`Network::from_topology_shaped`] chains the contract from the
//! dataset's shape (`data::dataset_shape`) down to the head at
//! construction time, so a conv stage over a flat dataset or an
//! over-pooled image is a config error, never a runtime panic.
//! Activations flow as `[B, ...shape.dims()]` tensors (NHWC for
//! spatial signals).
//!
//! **Conv rides the fused GEMM epilogues.** [`MaxoutConv2d`] lowers
//! each stage by im2col ([`super::conv`]): the SAME-padded stride-1
//! patch matrix is built once per step into a per-layer scratch buffer
//! (allocated on the first step of a run, reused afterwards), and each
//! maxout filter's weight slab rides `matmul_sl_qd_into` /
//! `matmul_tn_sl_qd_into` with the Z/DW quantization fused into the
//! tile epilogues — bit-identical to the direct nested-loop reference
//! kernels (`StepOptions::conv_direct`, `tests/conv_parity.rs`). The
//! `_qd` dispatch also lets eligible conv GEMMs run in the integer
//! domain (`StepOptions::int_domain`, `tests/int_gemm_parity.rs`).
//!
//! **Weight packs are cached across steps.** Each weight layer owns a
//! [`PackedCache`] keyed on its parameter-value epoch + the W group's
//! adopted scale step, so the integer-domain path re-packs a weight
//! slab only after `sgd_update` bumps the epoch or a scale adoption
//! moves the step; serve workers pre-pack every slab once at startup
//! via [`Network::prepack_int_operands`]. Eligibility is re-checked on
//! every call against the cached pack (the activation operand and the
//! accumulator bound are input-dependent), and a cache hit returns
//! byte-identical packs — packing is a pure function of the values —
//! so caching cannot perturb the bit-identity contract below.
//!
//! **The bit-identity contract.** The graph executor is not "close to"
//! the monolithic step it replaced — it is bit-identical on the builtin
//! `pi_mlp`, across all four arithmetics, all four rounding modes, fused
//! and two-pass kernels, any thread count, and with dropout on
//! (`tests/graph_parity.rs` asserts exact `u32` bits against
//! [`super::reference`]). Three orderings make that hold, and every
//! layer implementation must preserve them:
//!
//! 1. **Site order.** [`GoldenQ`] numbers quantization sites in call
//!    order (stochastic-rounding streams key on the site index). The
//!    graph visits sites exactly as the monolith did: forward
//!    `Z,H` per maxout stage (for conv stages `Z` in the conv layer and
//!    `H` in its pooling partner, mirroring L2's conv→Q_Z→max→pool→Q_H)
//!    then the head's `Z`; backward `DZ,DW,DB` per compute layer
//!    top-down, with the produced `dx` quantized as the *next compute
//!    layer below*'s `DH` group **before** any intervening dropout mask
//!    is applied (pooling/flatten backward is pure routing and owns no
//!    sites); update `w` then `b` per layer bottom-up, velocity before
//!    parameter.
//! 2. **Group table.** Scaling-factor groups stay layer-major
//!    (`group_index(row, kind) = row * N_KINDS + kind`) where `row` is
//!    the compute *stage*'s position in the graph (a conv layer and its
//!    pooling partner share one row; dropout/flatten own none).
//!    [`Network::n_groups`] is therefore *derived from the graph* and
//!    is what [`ScaleController::fixed`]/[`ScaleController::dynamic`]
//!    take — per-conv-layer dynamic scales need zero controller
//!    changes.
//! 3. **RNG draw order.** Dropout masks draw from one stream in forward
//!    graph order (input mask first, then after each stage), so the
//!    graph replays the monolith's masks bit-for-bit.

use std::cell::RefCell;

use crate::arith::{QuantStats, RoundMode};
use crate::config::TopologySpec;
use crate::coordinator::ScaleController;
use crate::runtime::manifest::{
    group_index, KIND_B, KIND_DB, KIND_DH, KIND_DW, KIND_DZ, KIND_H, KIND_W, KIND_Z, N_KINDS,
};
use crate::tensor::int_gemm::{self, PackedCache};
use crate::tensor::{ops, Shape, Tensor};

use super::conv::{self, ConvGeom};
use super::{
    apply_mask, Dropout, dropout_mask, GoldenOut, GoldenQ, MlpShape, Params,
    StepOptions, STOCHASTIC_SITE_SEED,
};

/// Per-step state a layer saves in `forward` for its `backward`. A
/// closed enum instead of `Box<dyn Any>`: the layer kinds are a
/// deliberate vocabulary, and the variants keep tensor moves explicit.
pub enum Cache {
    /// Maxout: the (possibly dropout-masked) input + winning filter per
    /// `[B, U]` output.
    Maxout { x: Tensor, amax: Vec<u8> },
    /// Head: the (possibly dropout-masked) input.
    Head { x: Tensor },
    /// Dropout: the drawn mask (`None` = identity this step).
    Mask(Option<Vec<f32>>),
    /// Conv: the (possibly dropout-masked) `[B, H, W, C]` input +
    /// winning filter per `[B·H·W, C_out]` output element. The im2col
    /// patch matrix itself stays in the layer's scratch buffer between
    /// forward and backward of the same step.
    Conv { x: Tensor, amax: Vec<u8> },
    /// Max pool: the input tensor shape + the flat input index of each
    /// window's argmax (routing targets for backward).
    Pool { in_shape: Vec<usize>, idx: Vec<u32> },
    /// Flatten: the spatial input shape to restore in backward.
    Flat { in_shape: Vec<usize> },
}

/// Where a [`DropoutLayer`] reads its rate from ([`StepOptions`] carries
/// the schedule's per-step input/hidden rates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropoutRole {
    Input,
    Hidden,
}

/// The per-step dropout stream, threaded through the forward pass. Draws
/// happen in graph order from the single [`Dropout`] RNG, which is what
/// keeps graph masks identical to the monolith's.
pub struct DropCtx<'a> {
    dropout: Option<&'a mut Dropout>,
}

impl<'a> DropCtx<'a> {
    /// Evaluation context: no masks, no RNG draws.
    pub fn eval() -> DropCtx<'static> {
        DropCtx { dropout: None }
    }

    /// Training context over the step's dropout state (if any).
    pub fn train(dropout: Option<&'a mut Dropout>) -> DropCtx<'a> {
        DropCtx { dropout }
    }

    fn mask(&mut self, n: usize, role: DropoutRole) -> Option<Vec<f32>> {
        let d = self.dropout.as_mut()?;
        let rate = match role {
            DropoutRole::Input => d.input_rate,
            DropoutRole::Hidden => d.hidden_rate,
        };
        dropout_mask(&mut d.rng, n, rate)
    }
}

/// Resolved per-step update hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct UpdateHp {
    pub lr: f32,
    pub mom: f32,
    pub max_norm: f32,
}

/// One node of the training graph.
///
/// A layer owns a contiguous run of the manifest-ordered parameter
/// vector (`n_params` tensors; the [`Network`] slices them out) and, if
/// it quantizes anything, one scaling-group *row* (`group_row`) in the
/// layer-major group table. Every quantization site a layer touches
/// registers against the shared [`GoldenQ`] in a fixed visit order — see
/// the module docs for the three orderings the implementations must
/// preserve.
pub trait Layer {
    /// Human-readable description for diagnostics.
    fn describe(&self) -> String;

    /// The scaling-group row this layer's sites record under; `None`
    /// for stateless layers with no quantization sites (dropout,
    /// flatten). A [`MaxPool2d`] reports its conv partner's row: the
    /// stage's `H` site lives on the pool side of the split.
    fn group_row(&self) -> Option<usize>;

    /// Number of parameter tensors this layer owns (manifest order).
    fn n_params(&self) -> usize {
        0
    }

    /// Output signal shape given the input signal shape — the
    /// shape-aware contract [`Network::from_topology_shaped`] chains
    /// through the whole graph at construction time. Errors are config
    /// errors (dense layer fed a spatial signal, conv fed a flat one,
    /// pooling below one pixel).
    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape>;

    /// Consume the layer input, produce its output plus whatever the
    /// backward pass needs. Quantization sites register against `q` in
    /// visit order.
    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache);

    /// Consume the gradient w.r.t. this layer's output; produce the
    /// parameter gradients (manifest order) and, when `dx_group` is
    /// `Some(row)`, the gradient w.r.t. the layer input quantized under
    /// `(row, DH)` — the *lower* compute layer's DH group, matching the
    /// monolith's (and L2's) attribution. `dx_group = None` means no
    /// consumer below needs `dx`.
    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>);

    /// SGD + momentum + max-norm + storage quantization over this
    /// layer's parameter run. Default: no parameters, nothing to do.
    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        let _ = (q, params, vels, grads, hp);
        debug_assert!(self.n_params() == 0, "parameterized layer must implement sgd_update");
    }

    /// Build this layer's packed-operand cache against the controller's
    /// adopted scales without running a forward pass. Serving calls
    /// this once per worker at startup (weights are static at inference
    /// time); layers without integer-eligible weight operands do
    /// nothing.
    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let _ = (ctrl, params);
    }

    /// Rebuild events of this layer's packed-operand cache since
    /// construction (0 for layers without one) — summed by
    /// [`Network::weight_pack_builds`] for the invalidation tests.
    fn pack_builds(&self) -> u64 {
        0
    }
}

/// The scale half of a weight layer's [`PackedCache`] key: the bit
/// pattern of the stage row's adopted W storage step. Dynamic-scale
/// updates (`ScaleController::after_batch`) and checkpoint adoption
/// (`adopt_int_bits`) both move the step, so keying on it re-packs on
/// every scale-change path without the layers subscribing to the
/// controller. (`step()` is 0.0 for float32 formats — a stable key;
/// those sites never pack anyway.)
fn weight_step_bits(ctrl: &ScaleController, row: usize) -> u32 {
    ctrl.format(group_index(row, KIND_W)).step().to_bits()
}

/// The shared dense-layer update rule (w then b, velocity quantized
/// unrecorded, parameter max-normed then quantized recorded) — exactly
/// the monolith's per-parameter sequence.
fn dense_sgd_update(
    q: &mut GoldenQ,
    group: usize,
    params: &mut [Tensor],
    vels: &mut [Tensor],
    grads: &[Tensor],
    hp: &UpdateHp,
) {
    debug_assert_eq!(params.len(), 2);
    debug_assert_eq!(grads.len(), 2);
    for i in 0..2 {
        let kind = if i == 0 { KIND_W } else { KIND_B };
        // v' = Q_up(mom*v - lr*g), stats NOT recorded (matches L2)
        for (vv, gv) in vels[i].data_mut().iter_mut().zip(grads[i].data()) {
            *vv = hp.mom * *vv - hp.lr * gv;
        }
        q.apply(&mut vels[i], group, kind, false);
        // p' = Q_up(maxnorm(p + v'))
        for (pv, vv) in params[i].data_mut().iter_mut().zip(vels[i].data()) {
            *pv += vv;
        }
        if kind == KIND_W {
            ops::max_norm_inplace(&mut params[i], hp.max_norm);
        }
        q.apply(&mut params[i], group, kind, true);
    }
}

// ---------------------------------------------------------------------------
// MaxoutDense
// ---------------------------------------------------------------------------

/// One maxout dense layer: per-filter `z_j = x @ w_j + b_j` (Z group,
/// one logical site across all `k` filter tiles, fused into the GEMM
/// epilogues), `h = max_j z_j` (H group). Params: `w [k, I, U]`,
/// `b [k, U]`.
pub struct MaxoutDense {
    pub units: usize,
    pub k: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
    /// Per-filter packed weight slabs for the integer-domain forward
    /// (one slab per maxout filter), invalidated by `sgd_update`.
    packs: RefCell<PackedCache>,
}

impl MaxoutDense {
    pub fn new(units: usize, k: usize, group: usize) -> MaxoutDense {
        MaxoutDense { units, k, group, packs: RefCell::new(PackedCache::new()) }
    }
}

impl Layer for MaxoutDense {
    fn describe(&self) -> String {
        format!("maxout({}x{})@l{}", self.units, self.k, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        crate::ensure!(
            matches!(in_shape, Shape::Flat(_)),
            "{}: needs a flat input, got {in_shape} (insert a flatten stage)",
            self.describe()
        );
        Ok(Shape::Flat(self.units))
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let batch = x.shape()[0];
        assert_eq!(x.shape()[1], d_in, "{}: input width", self.describe());

        // z for every filter, quantized as ONE logical site. Fused: each
        // filter's [B, U] tile gets bias + quantization in its GEMM
        // epilogue (base = the filter's offset in the [k, B, U] tensor).
        // Two-pass: materialize all k tiles, then sweep the whole tensor.
        // Identical per-element index stream → identical bits/counters.
        let mut zq = Tensor::zeros(&[k, batch, units]);
        let epi = q.epilogue(self.group, KIND_Z);
        let mut zst = QuantStats::default();
        // integer domain: serve each filter's GEMM from the cached
        // packed slab (built here on the first step after an update or
        // scale move, or by a serve worker's prepack)
        let mut packs = self.packs.borrow_mut();
        let cached = (q.fused && q.int_domain).then(|| {
            packs.ensure(weight_step_bits(q.ctrl, self.group), k, |j| {
                int_gemm::pack(&w.data()[j * d_in * units..(j + 1) * d_in * units])
            })
        });
        for j in 0..k {
            let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
            let brow = &b.data()[j * units..(j + 1) * units];
            let dst = &mut zq.data_mut()[j * batch * units..(j + 1) * batch * units];
            if let Some(c) = &cached {
                zst.merge(ops::matmul_sl_qd_cached_into(
                    x.data(),
                    wj,
                    c[j].as_ref(),
                    Some(brow),
                    dst,
                    batch,
                    d_in,
                    units,
                    epi.with_base((j * batch * units) as u64),
                ));
            } else if q.fused {
                zst.merge(ops::matmul_sl_qd_into(
                    x.data(),
                    wj,
                    Some(brow),
                    dst,
                    batch,
                    d_in,
                    units,
                    epi.with_base((j * batch * units) as u64),
                    q.int_domain,
                ));
            } else {
                let zj = ops::matmul_sl(x.data(), wj, batch, d_in, units);
                for r in 0..batch {
                    for u in 0..units {
                        dst[r * units + u] = zj[r * units + u] + brow[u];
                    }
                }
            }
        }
        if !q.fused {
            zst = epi.run(zq.data_mut(), 0);
        }
        q.record(self.group, KIND_Z, zst);

        let mut h = Tensor::zeros(&[batch, units]);
        let mut amax = vec![0u8; batch * units];
        for r in 0..batch {
            for u in 0..units {
                let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
                for j in 0..k {
                    let v = zq.at3(j, r, u);
                    if v > best {
                        best = v;
                        bj = j as u8;
                    }
                }
                h.data_mut()[r * units + u] = best;
                amax[r * units + u] = bj;
            }
        }
        q.apply(&mut h, self.group, KIND_H, true);
        (h, Cache::Maxout { x, amax })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Maxout { x, amax } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let batch = x.shape()[0];

        // route dh to the winning filter, quantize (DZ group)
        let mut dz = Tensor::zeros(&[k, batch, units]);
        for r in 0..batch {
            for u in 0..units {
                let j = amax[r * units + u] as usize;
                dz.data_mut()[(j * batch + r) * units + u] = dy.at2(r, u);
            }
        }
        q.apply(&mut dz, self.group, KIND_DZ, true);

        // dw for every filter, quantized as ONE logical site (like the z
        // tiles in the forward pass). The dx contraction is NOT fused:
        // its per-filter products are summed across filters before the
        // total is quantized as the lower layer's DH group.
        let mut dw = Tensor::zeros(&[k, d_in, units]);
        let mut db = Tensor::zeros(&[k, units]);
        let mut dx = Tensor::zeros(&[batch, d_in]);
        let epi = q.epilogue(self.group, KIND_DW);
        let mut dwst = QuantStats::default();
        for j in 0..k {
            // contiguous [batch, units] view of this filter's dz
            let dzj = &dz.data()[j * batch * units..(j + 1) * batch * units];
            let dwj_dst = &mut dw.data_mut()[j * d_in * units..(j + 1) * d_in * units];
            if q.fused {
                dwst.merge(ops::matmul_tn_sl_qd_into(
                    x.data(),
                    dzj,
                    dwj_dst,
                    batch,
                    d_in,
                    units,
                    epi.with_base((j * d_in * units) as u64),
                    q.int_domain,
                ));
            } else {
                let dwj = ops::matmul_tn_sl(x.data(), dzj, batch, d_in, units);
                dwj_dst.copy_from_slice(&dwj);
            }
            let dbj = ops::sum_rows_sl(dzj, batch, units);
            db.data_mut()[j * units..(j + 1) * units].copy_from_slice(&dbj);
            if dx_group.is_some() {
                let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
                let dxj = ops::matmul_nt_sl(dzj, wj, batch, units, d_in);
                for (a, &b) in dx.data_mut().iter_mut().zip(&dxj) {
                    *a += b;
                }
            }
        }
        if !q.fused {
            dwst = epi.run(dw.data_mut(), 0);
        }
        q.record(self.group, KIND_DW, dwst);
        q.apply(&mut db, self.group, KIND_DB, true);

        let dx = dx_group.map(|g| {
            q.apply(&mut dx, g, KIND_DH, true);
            dx
        });
        (vec![dw, db], dx)
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.borrow_mut().invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        self.packs.borrow_mut().ensure(weight_step_bits(ctrl, self.group), k, |j| {
            int_gemm::pack(&w.data()[j * d_in * units..(j + 1) * d_in * units])
        });
    }

    fn pack_builds(&self) -> u64 {
        self.packs.borrow().builds()
    }
}

// ---------------------------------------------------------------------------
// SoftmaxHead
// ---------------------------------------------------------------------------

/// The classifier head: `z = x @ w + b` with the bias and Z-group
/// quantization fused into the GEMM epilogue. The softmax/cross-entropy
/// itself is loss machinery and lives in the [`Network`] driver (as it
/// did in the monolith); this layer's backward consumes the pre-quantized
/// `(p - y)/B` and owns the DZ/DW/DB sites plus the fused DH projection.
/// Params: `w [U, C]`, `b [C]`.
pub struct SoftmaxHead {
    pub n_classes: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
    /// One packed slab of `w` serving both the forward NN product and
    /// the backward NT projection, invalidated by `sgd_update`.
    packs: RefCell<PackedCache>,
}

impl SoftmaxHead {
    pub fn new(n_classes: usize, group: usize) -> SoftmaxHead {
        SoftmaxHead { n_classes, group, packs: RefCell::new(PackedCache::new()) }
    }
}

impl Layer for SoftmaxHead {
    fn describe(&self) -> String {
        format!("softmax({})@l{}", self.n_classes, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        crate::ensure!(
            matches!(in_shape, Shape::Flat(_)),
            "{}: needs a flat input, got {in_shape} (insert a flatten stage)",
            self.describe()
        );
        Ok(Shape::Flat(self.n_classes))
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let batch = x.shape()[0];
        assert_eq!(x.shape()[1], units, "{}: input width", self.describe());

        let epi = q.epilogue(self.group, KIND_Z);
        let z = if q.fused && q.int_domain {
            let mut packs = self.packs.borrow_mut();
            let c = packs
                .ensure(weight_step_bits(q.ctrl, self.group), 1, |_| int_gemm::pack(w.data()));
            let (v, st) = ops::matmul_sl_qd_cached(
                x.data(),
                w.data(),
                c[0].as_ref(),
                Some(b.data()),
                batch,
                units,
                classes,
                epi,
            );
            q.record(self.group, KIND_Z, st);
            Tensor::from_vec(&[batch, classes], v)
        } else if q.fused {
            let (v, st) = ops::matmul_sl_qd(
                x.data(),
                w.data(),
                Some(b.data()),
                batch,
                units,
                classes,
                epi,
                q.int_domain,
            );
            q.record(self.group, KIND_Z, st);
            Tensor::from_vec(&[batch, classes], v)
        } else {
            let mut z = ops::matmul(&x, w);
            for r in 0..batch {
                for c in 0..classes {
                    z.data_mut()[r * classes + c] += b.data()[c];
                }
            }
            let st = epi.run(z.data_mut(), 0);
            q.record(self.group, KIND_Z, st);
            z
        };
        (z, Cache::Head { x })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        mut dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Head { x } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let batch = x.shape()[0];

        // dy arrives as the pre-quantized loss gradient (p - y)/B
        q.apply(&mut dy, self.group, KIND_DZ, true);
        let dz = dy;

        let epi = q.epilogue(self.group, KIND_DW);
        let dw = if q.fused {
            let (v, st) =
                ops::matmul_tn_sl_qd(x.data(), dz.data(), batch, units, classes, epi, q.int_domain);
            q.record(self.group, KIND_DW, st);
            Tensor::from_vec(&[units, classes], v)
        } else {
            let mut dw = ops::matmul_tn(x, &dz);
            let st = epi.run(dw.data_mut(), 0);
            q.record(self.group, KIND_DW, st);
            dw
        };
        let mut db = ops::sum_rows(&dz);
        q.apply(&mut db, self.group, KIND_DB, true);

        // dx quantized as the lower layer's DH group, fused into the NT
        // projection (the monolith's dh1 site, generalized)
        let dx = dx_group.map(|g| {
            let epi = q.epilogue(g, KIND_DH);
            if q.fused && q.int_domain {
                // the forward pass of this same step (or a worker's
                // prepack) already built the slab: this ensure is a hit
                let mut packs = self.packs.borrow_mut();
                let c = packs
                    .ensure(weight_step_bits(q.ctrl, self.group), 1, |_| int_gemm::pack(w.data()));
                let (v, st) = ops::matmul_nt_sl_qd_cached(
                    dz.data(),
                    w.data(),
                    c[0].as_ref(),
                    batch,
                    classes,
                    units,
                    epi,
                );
                q.record(g, KIND_DH, st);
                Tensor::from_vec(&[batch, units], v)
            } else if q.fused {
                let (v, st) = ops::matmul_nt_sl_qd(
                    dz.data(),
                    w.data(),
                    batch,
                    classes,
                    units,
                    epi,
                    q.int_domain,
                );
                q.record(g, KIND_DH, st);
                Tensor::from_vec(&[batch, units], v)
            } else {
                let mut dx = ops::matmul_nt(&dz, w);
                let st = epi.run(dx.data_mut(), 0);
                q.record(g, KIND_DH, st);
                dx
            }
        });
        (vec![dw, db], dx)
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.borrow_mut().invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        self.packs
            .borrow_mut()
            .ensure(weight_step_bits(ctrl, self.group), 1, |_| int_gemm::pack(w.data()));
    }

    fn pack_builds(&self) -> u64 {
        self.packs.borrow().builds()
    }
}

// ---------------------------------------------------------------------------
// DropoutLayer
// ---------------------------------------------------------------------------

/// Inverted dropout as a graph node: draws its mask from the step's
/// shared [`Dropout`] stream in forward graph order, masks in place, and
/// replays the mask over the gradient in backward. No quantization
/// sites, no parameters, identity in evaluation.
pub struct DropoutLayer {
    pub role: DropoutRole,
}

impl DropoutLayer {
    pub fn input() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Input }
    }

    pub fn hidden() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Hidden }
    }
}

impl Layer for DropoutLayer {
    fn describe(&self) -> String {
        match self.role {
            DropoutRole::Input => "dropout(input)".into(),
            DropoutRole::Hidden => "dropout(hidden)".into(),
        }
    }

    fn group_row(&self) -> Option<usize> {
        None
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        Ok(*in_shape)
    }

    fn forward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        mut x: Tensor,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let mask = drop.mask(x.len(), self.role);
        apply_mask(&mut x, &mask);
        (x, Cache::Mask(mask))
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: &Cache,
        mut dy: Tensor,
        _dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Mask(mask) = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        apply_mask(&mut dy, mask);
        (Vec::new(), Some(dy))
    }
}

// ---------------------------------------------------------------------------
// MaxoutConv2d
// ---------------------------------------------------------------------------

/// Per-run scratch for a conv layer: the im2col patch matrix (filled in
/// forward, read back by the same step's backward) and the summed
/// patch-space gradient. Allocated on the first step of a run and
/// reused afterwards — the buffers are the layer's, not the step's.
#[derive(Default)]
struct ConvScratch {
    patches: Vec<f32>,
    dpatch: Vec<f32>,
    /// One filter's patch-space gradient (the NT product's destination).
    dpj: Vec<f32>,
}

/// One maxout convolutional stage's *linear* half: SAME-padded stride-1
/// conv per maxout filter, `z_j = im2col(x) @ w_j + b_j` (Z group, one
/// logical site across all `k` filter tiles, fused into the GEMM
/// epilogues exactly like [`MaxoutDense`]'s), then `m = max_j z_j` over
/// the filters. The stage's spatial max pool + `H` quantization live in
/// its [`MaxPool2d`] partner (same group row), mirroring the L2 conv
/// stage's `conv → Q_Z → max_k → pool → Q_H` order. Params:
/// `w [k, ksize²·C_in, C_out]` (the im2col-lowered HWIO slab, so the
/// rank-3 max-norm path constrains each output channel's true conv
/// fan-in), `b [k, C_out]`.
pub struct MaxoutConv2d {
    pub c_out: usize,
    pub k: usize,
    /// Square kernel side; odd (SAME padding = `ksize / 2`).
    pub ksize: usize,
    /// This stage's row in the layer-major group table.
    pub group: usize,
    scratch: RefCell<ConvScratch>,
    /// Per-filter packed weight slabs for the integer-domain im2col
    /// forward, invalidated by `sgd_update`.
    packs: RefCell<PackedCache>,
}

impl MaxoutConv2d {
    pub fn new(c_out: usize, k: usize, ksize: usize, group: usize) -> MaxoutConv2d {
        MaxoutConv2d {
            c_out,
            k,
            ksize,
            group,
            scratch: RefCell::new(ConvScratch::default()),
            packs: RefCell::new(PackedCache::new()),
        }
    }

    /// Geometry for a concrete `[B, H, W, C]` input.
    fn geom(&self, x: &Tensor) -> (usize, ConvGeom) {
        let s = x.shape();
        assert_eq!(s.len(), 4, "{}: input must be [B, H, W, C]", self.describe());
        (
            s[0],
            ConvGeom { h: s[1], w: s[2], c_in: s[3], c_out: self.c_out, ksize: self.ksize },
        )
    }
}

impl Layer for MaxoutConv2d {
    fn describe(&self) -> String {
        format!("maxconv({}x{}k{})@l{}", self.c_out, self.k, self.ksize, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        let Shape::Spatial { h, w, .. } = *in_shape else {
            crate::bail!(
                "{}: needs a spatial input, got {in_shape} (conv topologies require an \
                 image dataset)",
                self.describe()
            );
        };
        crate::ensure!(
            self.ksize % 2 == 1,
            "{}: SAME padding needs an odd kernel size",
            self.describe()
        );
        Ok(Shape::Spatial { h, w, c: self.c_out })
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (batch, geom) = self.geom(&x);
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        assert_eq!(k, self.k, "{}: filter count", self.describe());
        assert_eq!(plen, geom.patch_len(), "{}: patch length", self.describe());
        let rows = geom.rows(batch);

        // z for every filter, quantized as ONE logical site: each
        // filter's [rows, C_out] tile rides one fused GEMM over the
        // shared patch matrix (base = the filter's offset in the
        // [k, rows, C_out] tensor) — identical per-element index stream
        // to one whole-tensor sweep, and bit-identical to the direct
        // nested-loop reference (q.conv_direct).
        let mut zq = Tensor::zeros(&[k, rows, c_out]);
        let epi = q.epilogue(self.group, KIND_Z);
        let mut zst = QuantStats::default();
        if q.conv_direct {
            for j in 0..k {
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                let brow = &b.data()[j * c_out..(j + 1) * c_out];
                let dst = &mut zq.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
                zst.merge(conv::conv2d_direct_q(
                    x.data(),
                    wj,
                    Some(brow),
                    dst,
                    batch,
                    &geom,
                    epi.with_base((j * rows * c_out) as u64),
                ));
            }
        } else {
            let mut scratch = self.scratch.borrow_mut();
            scratch.patches.resize(rows * plen, 0.0);
            conv::im2col_into(x.data(), batch, &geom, &mut scratch.patches);
            // integer domain: per-filter packed slabs, cached like the
            // dense layer's (the patch matrix re-packs every step — it
            // is input data; the weights are not)
            let mut packs = self.packs.borrow_mut();
            let cached = (q.fused && q.int_domain).then(|| {
                packs.ensure(weight_step_bits(q.ctrl, self.group), k, |j| {
                    int_gemm::pack(&w.data()[j * plen * c_out..(j + 1) * plen * c_out])
                })
            });
            for j in 0..k {
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                let brow = &b.data()[j * c_out..(j + 1) * c_out];
                let dst = &mut zq.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
                if let Some(c) = &cached {
                    zst.merge(ops::matmul_sl_qd_cached_into(
                        &scratch.patches,
                        wj,
                        c[j].as_ref(),
                        Some(brow),
                        dst,
                        rows,
                        plen,
                        c_out,
                        epi.with_base((j * rows * c_out) as u64),
                    ));
                } else if q.fused {
                    zst.merge(ops::matmul_sl_qd_into(
                        &scratch.patches,
                        wj,
                        Some(brow),
                        dst,
                        rows,
                        plen,
                        c_out,
                        epi.with_base((j * rows * c_out) as u64),
                        q.int_domain,
                    ));
                } else {
                    let zj = ops::matmul_sl(&scratch.patches, wj, rows, plen, c_out);
                    for r in 0..rows {
                        for o in 0..c_out {
                            dst[r * c_out + o] = zj[r * c_out + o] + brow[o];
                        }
                    }
                }
            }
            if !q.fused {
                zst = epi.run(zq.data_mut(), 0);
            }
        }
        q.record(self.group, KIND_Z, zst);

        // max over the k filters; the H quantization happens after the
        // spatial pool, in this stage's MaxPool2d partner
        let mut m = Tensor::zeros(&[batch, geom.h, geom.w, c_out]);
        let mut amax = vec![0u8; rows * c_out];
        for r in 0..rows {
            for o in 0..c_out {
                let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
                for j in 0..k {
                    let v = zq.at3(j, r, o);
                    if v > best {
                        best = v;
                        bj = j as u8;
                    }
                }
                m.data_mut()[r * c_out + o] = best;
                amax[r * c_out + o] = bj;
            }
        }
        (m, Cache::Conv { x, amax })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Conv { x, amax } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (batch, geom) = self.geom(x);
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let rows = geom.rows(batch);
        assert_eq!(dy.len(), rows * c_out, "{}: gradient size", self.describe());

        // route the (unpooled) gradient to the winning filter, quantize
        // (DZ group) — L2's combined max/pool subgradient, pool half
        // already routed by MaxPool2d
        let mut dz = Tensor::zeros(&[k, rows, c_out]);
        for (i, &g) in dy.data().iter().enumerate() {
            let j = amax[i] as usize;
            dz.data_mut()[j * rows * c_out + i] = g;
        }
        q.apply(&mut dz, self.group, KIND_DZ, true);

        // dw for every filter, quantized as ONE logical site over the
        // im2col patches (fused TN tiles, direct reference, or two-pass)
        let mut dw = Tensor::zeros(&[k, plen, c_out]);
        let mut db = Tensor::zeros(&[k, c_out]);
        let epi = q.epilogue(self.group, KIND_DW);
        let mut dwst = QuantStats::default();
        let mut scratch = self.scratch.borrow_mut();
        for j in 0..k {
            let dzj = &dz.data()[j * rows * c_out..(j + 1) * rows * c_out];
            let dwj_dst = &mut dw.data_mut()[j * plen * c_out..(j + 1) * plen * c_out];
            if q.conv_direct {
                dwst.merge(conv::conv2d_dw_direct_q(
                    x.data(),
                    dzj,
                    dwj_dst,
                    batch,
                    &geom,
                    epi.with_base((j * plen * c_out) as u64),
                ));
            } else if q.fused {
                // the forward pass of this same step filled the patches
                debug_assert_eq!(scratch.patches.len(), rows * plen);
                dwst.merge(ops::matmul_tn_sl_qd_into(
                    &scratch.patches,
                    dzj,
                    dwj_dst,
                    rows,
                    plen,
                    c_out,
                    epi.with_base((j * plen * c_out) as u64),
                    q.int_domain,
                ));
            } else {
                debug_assert_eq!(scratch.patches.len(), rows * plen);
                let dwj = ops::matmul_tn_sl(&scratch.patches, dzj, rows, plen, c_out);
                dwj_dst.copy_from_slice(&dwj);
            }
            let dbj = ops::sum_rows_sl(dzj, rows, c_out);
            db.data_mut()[j * c_out..(j + 1) * c_out].copy_from_slice(&dbj);
        }
        if !q.conv_direct && !q.fused {
            dwst = epi.run(dw.data_mut(), 0);
        }
        q.record(self.group, KIND_DW, dwst);
        q.apply(&mut db, self.group, KIND_DB, true);

        // dx: per-filter patch-space gradients summed across filters,
        // scattered back to image space, then the total quantized as the
        // lower stage's DH group (like the dense layers' summed dx)
        let dx = dx_group.map(|g| {
            scratch.dpatch.resize(rows * plen, 0.0);
            scratch.dpatch.fill(0.0);
            scratch.dpj.resize(rows * plen, 0.0);
            let scratch = &mut *scratch;
            for j in 0..k {
                let dzj = &dz.data()[j * rows * c_out..(j + 1) * rows * c_out];
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                ops::matmul_nt_sl_into(dzj, wj, &mut scratch.dpj, rows, c_out, plen);
                for (a, &v) in scratch.dpatch.iter_mut().zip(&scratch.dpj) {
                    *a += v;
                }
            }
            let mut dx = Tensor::zeros(&[batch, geom.h, geom.w, geom.c_in]);
            conv::col2im_add(&scratch.dpatch, batch, &geom, dx.data_mut());
            q.apply(&mut dx, g, KIND_DH, true);
            dx
        });
        (vec![dw, db], dx)
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        // w [k, ksize²·C_in, C_out] has the maxout [k, I, U] layout, so
        // the shared rule (incl. the rank-3 max-norm) applies verbatim
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.borrow_mut().invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        self.packs.borrow_mut().ensure(weight_step_bits(ctrl, self.group), k, |j| {
            int_gemm::pack(&w.data()[j * plen * c_out..(j + 1) * plen * c_out])
        });
    }

    fn pack_builds(&self) -> u64 {
        self.packs.borrow().builds()
    }
}

// ---------------------------------------------------------------------------
// MaxPool2d
// ---------------------------------------------------------------------------

/// Non-overlapping spatial max pool (window = stride = `pool`, VALID:
/// trailing rows/cols that don't fill a window are dropped, like L2's
/// `reduce_window`), followed by the owning conv stage's `H`-group
/// quantization — the second half of the L2 conv stage's
/// `conv → Q_Z → max_k → pool → Q_H` sequence. Backward is pure
/// routing to the cached argmax positions; the routed gradient's DZ
/// quantization belongs to the conv layer below, so `dx_group` is
/// deliberately ignored. `pool = 1` degenerates to the bare `H` site.
pub struct MaxPool2d {
    pub pool: usize,
    /// The conv partner's row in the layer-major group table.
    pub group: usize,
}

impl Layer for MaxPool2d {
    fn describe(&self) -> String {
        format!("maxpool({})@l{}", self.pool, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        let Shape::Spatial { h, w, c } = *in_shape else {
            crate::bail!("{}: needs a spatial input, got {in_shape}", self.describe());
        };
        crate::ensure!(self.pool >= 1, "{}: pool must be >= 1", self.describe());
        let (ph, pw) = (h / self.pool, w / self.pool);
        crate::ensure!(
            ph >= 1 && pw >= 1,
            "{}: pooling a {h}x{w} map below one pixel",
            self.describe()
        );
        Ok(Shape::Spatial { h: ph, w: pw, c })
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        _params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let s = x.shape();
        assert_eq!(s.len(), 4, "{}: input must be [B, H, W, C]", self.describe());
        let (batch, h, w, c) = (s[0], s[1], s[2], s[3]);
        let p = self.pool;
        let (ph, pw) = (h / p, w / p);
        let mut out = Tensor::zeros(&[batch, ph, pw, c]);
        let mut idx = vec![0u32; batch * ph * pw * c];
        for b in 0..batch {
            for oy in 0..ph {
                for ox in 0..pw {
                    for ch in 0..c {
                        let (mut best, mut bsrc) = (f32::NEG_INFINITY, 0u32);
                        for ky in 0..p {
                            for kx in 0..p {
                                let src =
                                    ((b * h + oy * p + ky) * w + ox * p + kx) * c + ch;
                                let v = x.data()[src];
                                if v > best {
                                    best = v;
                                    bsrc = src as u32;
                                }
                            }
                        }
                        let o = ((b * ph + oy) * pw + ox) * c + ch;
                        out.data_mut()[o] = best;
                        idx[o] = bsrc;
                    }
                }
            }
        }
        q.apply(&mut out, self.group, KIND_H, true);
        (out, Cache::Pool { in_shape: s.to_vec(), idx })
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        _dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Pool { in_shape, idx } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        // scatter to the winning positions; windows never overlap, so
        // each input cell receives at most one contribution
        let mut dx = Tensor::zeros(in_shape);
        for (i, &src) in idx.iter().enumerate() {
            dx.data_mut()[src as usize] += dy.data()[i];
        }
        (Vec::new(), Some(dx))
    }
}

// ---------------------------------------------------------------------------
// Flatten
// ---------------------------------------------------------------------------

/// Shape adapter between the spatial stages and the dense head:
/// `[B, H, W, C] → [B, H·W·C]` (row-major, so the bytes don't move).
/// No parameters, no quantization sites; backward restores the spatial
/// shape.
pub struct Flatten;

impl Layer for Flatten {
    fn describe(&self) -> String {
        "flatten".into()
    }

    fn group_row(&self) -> Option<usize> {
        None
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        Ok(in_shape.flattened())
    }

    fn forward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        x: Tensor,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let in_shape = x.shape().to_vec();
        let (b, d) = (in_shape[0], in_shape[1..].iter().product::<usize>());
        (x.reshape(&[b, d]), Cache::Flat { in_shape })
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: &Cache,
        dy: Tensor,
        _dx_group: Option<usize>,
    ) -> (Vec<Tensor>, Option<Tensor>) {
        let Cache::Flat { in_shape } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        (Vec::new(), Some(dy.reshape(in_shape)))
    }
}

// ---------------------------------------------------------------------------
// Network
// ---------------------------------------------------------------------------

/// A maxout network assembled from [`Layer`]s, driving one train/eval
/// step over the manifest-ordered flat parameter vector. Built from a
/// [`TopologySpec`] (+ the dataset's signal [`Shape`]) or, for the
/// legacy call sites, from an [`MlpShape`].
pub struct Network {
    layers: Vec<Box<dyn Layer>>,
    /// Per layer: (offset, count) into the flat manifest-order params.
    param_ranges: Vec<(usize, usize)>,
    n_group_rows: usize,
    /// The signal shape the network consumes (dataset-derived).
    in_shape: Shape,
    n_classes: usize,
}

impl Network {
    /// Realize a topology against a data source's signal shape. The
    /// layer sequence generalizes the monolithic step: input dropout;
    /// per conv stage a maxout-conv + max-pool + hidden dropout; a
    /// flatten when any conv stage exists; per hidden width a maxout
    /// dense + hidden dropout; then the head. The whole shape contract
    /// is chained through [`Layer::out_shape`] here, so topology/dataset
    /// mismatches fail at construction with the offending layer named.
    pub fn from_topology_shaped(
        spec: &TopologySpec,
        in_shape: Shape,
        n_classes: usize,
    ) -> crate::Result<Network> {
        // hard invariant, not a debug check: a spec that skipped
        // validate() must not silently build a head-only linear model
        assert!(
            !(spec.conv.is_empty() && spec.hidden.is_empty()),
            "topology needs >= 1 conv stage or hidden layer"
        );
        let mut layers: Vec<Box<dyn Layer>> =
            Vec::with_capacity(3 * spec.conv.len() + 2 * spec.hidden.len() + 3);
        layers.push(Box::new(DropoutLayer::input()));
        let mut row = 0;
        for cs in &spec.conv {
            layers.push(Box::new(MaxoutConv2d::new(cs.channels, spec.k, cs.ksize, row)));
            layers.push(Box::new(MaxPool2d { pool: cs.pool, group: row }));
            layers.push(Box::new(DropoutLayer::hidden()));
            row += 1;
        }
        if !spec.conv.is_empty() {
            layers.push(Box::new(Flatten));
        }
        for &units in &spec.hidden {
            layers.push(Box::new(MaxoutDense::new(units, spec.k, row)));
            row += 1;
            layers.push(Box::new(DropoutLayer::hidden()));
        }
        layers.push(Box::new(SoftmaxHead::new(n_classes, row)));
        row += 1;

        // chain the shape contract through the graph; a failure names
        // the layer and the shape it choked on
        let mut shape = in_shape;
        for l in &layers {
            shape = l.out_shape(&shape).map_err(|e| {
                crate::err!("topology '{}' does not fit input {in_shape}: {e}", spec.name)
            })?;
        }
        debug_assert_eq!(shape, Shape::Flat(n_classes));

        let mut param_ranges = Vec::with_capacity(layers.len());
        let mut offset = 0;
        for l in &layers {
            param_ranges.push((offset, l.n_params()));
            offset += l.n_params();
        }
        Ok(Network { layers, param_ranges, n_group_rows: row, in_shape, n_classes })
    }

    /// Realize an MLP topology against a flat input width (the legacy
    /// entry point; conv stages need [`Network::from_topology_shaped`]).
    pub fn from_topology(spec: &TopologySpec, d_in: usize, n_classes: usize) -> Network {
        assert!(
            spec.conv.is_empty(),
            "topology '{}' has conv stages: realize it with from_topology_shaped",
            spec.name
        );
        Network::from_topology_shaped(spec, Shape::Flat(d_in), n_classes)
            .expect("MLP topologies realize against any flat input")
    }

    /// The 2-hidden-layer network an [`MlpShape`] describes (the legacy
    /// golden entry points drive this).
    pub fn from_mlp_shape(s: MlpShape) -> Network {
        let spec = TopologySpec::mlp(vec![s.units, s.units], s.k);
        Network::from_topology(&spec, s.d_in, s.n_classes)
    }

    /// Scaling-factor group count derived from the graph: one row of
    /// `N_KINDS` kinds per compute layer. This is the number
    /// [`ScaleController::fixed`]/[`ScaleController::dynamic`] take.
    pub fn n_groups(&self) -> usize {
        self.n_group_rows * N_KINDS
    }

    /// Number of compute layers (= group rows): hidden + head.
    pub fn n_compute_layers(&self) -> usize {
        self.n_group_rows
    }

    /// Flat input width the network consumes.
    pub fn d_in(&self) -> usize {
        self.in_shape.len()
    }

    /// The dataset-derived signal shape the network consumes.
    pub fn in_shape(&self) -> Shape {
        self.in_shape
    }

    /// Per-example input dims (`[d]` or `[h, w, c]`) — what a batch
    /// tensor carries after its leading batch axis.
    pub fn input_dims(&self) -> Vec<usize> {
        self.in_shape.dims()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Total parameter tensors (manifest order: w0 b0 w1 b1 ...).
    pub fn n_params(&self) -> usize {
        self.param_ranges.last().map(|&(o, n)| o + n).unwrap_or(0)
    }

    /// One-line graph description for diagnostics.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.layers.iter().map(|l| l.describe()).collect();
        parts.join(" -> ")
    }

    /// Group row of the closest compute layer strictly below `pos`
    /// (`None` when `pos` is the bottom compute layer).
    fn group_row_below(&self, pos: usize) -> Option<usize> {
        self.layers[..pos].iter().rev().find_map(|l| l.group_row())
    }

    /// Pre-pack every weight layer's integer-GEMM operands against the
    /// controller's adopted scales. Serve workers call this once at
    /// startup so steady-state requests never re-pack static weights;
    /// training never needs it (forward builds lazily). Idempotent: a
    /// second call with the same params + scales is a cache hit.
    pub fn prepack_int_operands(&self, params: &Params, ctrl: &ScaleController) {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            layer.prepack(ctrl, &params[o..o + n]);
        }
    }

    /// Total packed-cache rebuild events across the graph's weight
    /// layers since construction. This is the pollution-free counter
    /// the cache-invalidation tests assert on: one build per weight
    /// layer per train step (or per scale adoption), exactly one per
    /// layer for a serve worker's lifetime — never one per GEMM. (The
    /// process-global [`int_gemm::pack_calls`] counter is only
    /// meaningful as a delta in single-threaded benches.)
    pub fn weight_pack_builds(&self) -> u64 {
        self.layers.iter().map(|l| l.pack_builds()).sum()
    }

    /// One full train step over the graph. Bit-identical to the
    /// monolithic reference on the builtin topology (see module docs);
    /// mutates params/vels in place.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &mut Params,
        vels: &mut Params,
        x: &Tensor,
        y: &Tensor,
        lr: f32,
        mom: f32,
        max_norm: f32,
        ctrl: &ScaleController,
        mut opts: StepOptions,
    ) -> GoldenOut {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
        q.fused = opts.fused;
        q.conv_direct = opts.conv_direct;
        q.int_domain = opts.int_domain;
        if opts.mode == RoundMode::Stochastic {
            // true stochastic rounding draws one uniform sample per
            // element from counter-based per-site streams (index-keyed,
            // so the fused and two-pass paths sample identically)
            q.stochastic_seed = Some(STOCHASTIC_SITE_SEED);
        }
        let batch = x.shape()[0];
        let classes = self.n_classes;
        let mut dctx = DropCtx::train(opts.dropout.as_mut());

        // ---- forward ----
        let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
        // one input copy buys by-value tensor flow through the whole
        // graph (layers move activations into their caches); negligible
        // next to the layer GEMMs — the `graph train step` bench rows
        // track this dispatch overhead against the monolith
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, cache) = layer.forward(&mut q, &params[o..o + n], h, &mut dctx);
            caches.push(cache);
            h = out;
        }
        let z = h;
        let logp = ops::log_softmax(&z);
        let mut loss = 0.0f64;
        for i in 0..batch * classes {
            loss -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let loss = (loss / batch as f64) as f32;

        // ---- backward ----
        // loss gradient dz = (p - y)/B, handed to the head pre-quantized
        let mut dz = Tensor::zeros(&[batch, classes]);
        for i in 0..batch * classes {
            dz.data_mut()[i] = (logp.data()[i].exp() - y.data()[i]) / batch as f32;
        }
        let mut grads: Vec<Vec<Tensor>> = Vec::with_capacity(self.layers.len());
        grads.resize_with(self.layers.len(), Vec::new);
        let mut dy = dz;
        for pos in (0..self.layers.len()).rev() {
            let layer = &self.layers[pos];
            let (o, n) = self.param_ranges[pos];
            if layer.group_row().is_some() {
                let dx_group = self.group_row_below(pos);
                let (g, dx) =
                    layer.backward(&mut q, &params[o..o + n], &caches[pos], dy, dx_group);
                grads[pos] = g;
                match dx {
                    Some(d) => dy = d,
                    // bottom compute layer: nothing below consumes dx
                    None => break,
                }
            } else {
                let (_, dx) = layer.backward(&mut q, &[], &caches[pos], dy, None);
                dy = dx.expect("stateless layers pass their gradient through");
            }
        }

        // ---- SGD + momentum + max-norm + storage quantization ----
        // (bottom-up = manifest parameter order, matching the monolith)
        let hp = UpdateHp { lr, mom, max_norm };
        for (pos, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[pos];
            if n == 0 {
                continue;
            }
            layer.sgd_update(
                &mut q,
                &mut params[o..o + n],
                &mut vels[o..o + n],
                &grads[pos],
                &hp,
            );
        }

        GoldenOut { loss, overflow: q.stats_matrix() }
    }

    /// Forward-only logits `[B, C]` (no dropout, no mutation),
    /// quantizing forward signals exactly as the train step does. Kernel
    /// selection (`fused`, `conv_direct`, `int_domain`) comes from the
    /// process-wide env defaults; callers that need explicit control
    /// (the serving path) use [`Network::eval_logits_opt`].
    pub fn eval_logits(
        &self,
        params: &Params,
        x: &Tensor,
        ctrl: &ScaleController,
        mode: RoundMode,
        half: bool,
    ) -> Tensor {
        self.eval_logits_opt(
            params,
            x,
            ctrl,
            &StepOptions { mode, half, ..Default::default() },
        )
    }

    /// [`Network::eval_logits`] with explicit [`StepOptions`]: the
    /// serving path honors a checkpoint-independent `int_domain` /
    /// `fused` choice per request batch instead of whatever the env
    /// said at process start. `opts.dropout` is ignored — eval never
    /// drops.
    pub fn eval_logits_opt(
        &self,
        params: &Params,
        x: &Tensor,
        ctrl: &ScaleController,
        opts: &StepOptions,
    ) -> Tensor {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
        q.fused = opts.fused;
        q.conv_direct = opts.conv_direct;
        q.int_domain = opts.int_domain;
        let mut dctx = DropCtx::eval();
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, _) = layer.forward(&mut q, &params[o..o + n], h, &mut dctx);
            h = out;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::FixedFormat;
    use crate::runtime::manifest::group_index;
    use crate::runtime::ModelInfo;
    use crate::tensor::Pcg32;

    fn spec3() -> TopologySpec {
        TopologySpec::mlp(vec![10, 8, 6], 2)
    }

    /// Params + vels realized from the ModelInfo the same spec produces.
    fn state(spec: &TopologySpec, d_in: usize, n_classes: usize, seed: u64) -> (Params, Params) {
        let info = ModelInfo::from_topology(spec, d_in, n_classes);
        let mut rng = Pcg32::seeded(seed);
        let params: Vec<Tensor> =
            info.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
        let vels = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        (params, vels)
    }

    #[test]
    fn graph_derives_group_table_from_topology() {
        let net = Network::from_topology(&spec3(), 12, 4);
        assert_eq!(net.n_compute_layers(), 4);
        assert_eq!(net.n_groups(), 4 * N_KINDS);
        assert_eq!(net.n_params(), 8);
        assert_eq!(net.d_in(), 12);
        assert_eq!(net.n_classes(), 4);
        let desc = net.describe();
        assert!(desc.starts_with("dropout(input) -> maxout(10x2)@l0"), "{desc}");
        assert!(desc.ends_with("softmax(4)@l3"), "{desc}");
        // the shape contract chains the input to the class count
        let mut shape = net.in_shape();
        for l in &net.layers {
            shape = l.out_shape(&shape).unwrap();
        }
        assert_eq!(shape, Shape::Flat(net.n_classes()));
    }

    /// The shared tiny conv fixture (2 conv stages + 1 dense + head over
    /// 8×8×2 inputs) — `tests/conv_parity.rs` trains the same spec.
    fn conv_spec() -> TopologySpec {
        crate::testing::tiny_conv_spec()
    }

    #[test]
    fn conv_topology_chains_shapes_and_derives_groups() {
        let in_shape = Shape::Spatial { h: 8, w: 8, c: 2 };
        let net = Network::from_topology_shaped(&conv_spec(), in_shape, 4).unwrap();
        // 2 conv stages + 1 dense + head = 4 group rows; pool layers
        // share their conv partner's row
        assert_eq!(net.n_compute_layers(), 4);
        assert_eq!(net.n_groups(), 4 * N_KINDS);
        assert_eq!(net.n_params(), 8);
        assert_eq!(net.d_in(), 128);
        assert_eq!(net.input_dims(), vec![8, 8, 2]);
        let desc = net.describe();
        assert!(desc.contains("maxconv(3x2k3)@l0 -> maxpool(2)@l0"), "{desc}");
        assert!(desc.contains("maxpool(2)@l1 -> dropout(hidden) -> flatten"), "{desc}");
        // 8x8 -> 4x4 -> 2x2, so the dense stage consumes 2*2*4 = 16
        let mut shape = in_shape;
        for l in &net.layers {
            shape = l.out_shape(&shape).unwrap();
        }
        assert_eq!(shape, Shape::Flat(4));
    }

    #[test]
    fn conv_realization_rejects_shape_mismatches() {
        // conv stage over a flat dataset
        let err = Network::from_topology_shaped(&conv_spec(), Shape::Flat(128), 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("spatial"), "{err:#}");
        // pooled below one pixel
        let deep = TopologySpec::conv_net(
            vec![crate::config::ConvStageSpec { channels: 2, ksize: 3, pool: 4 }; 3],
            vec![],
            2,
        );
        let err = Network::from_topology_shaped(&deep, Shape::Spatial { h: 8, w: 8, c: 1 }, 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("below one pixel"), "{err:#}");
    }

    #[test]
    fn conv_topology_trains_and_counts_per_stage_overflow() {
        let spec = conv_spec();
        let in_shape = Shape::Spatial { h: 8, w: 8, c: 2 };
        let net = Network::from_topology_shaped(&spec, in_shape, 4).unwrap();
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (mut params, mut vels) = crate::testing::topology_state(&spec, in_shape, 4, 3);
        let n = 6;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(
            &[n, 8, 8, 2],
            (0..n * 128).map(|_| rng.normal()).collect(),
        );
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let out = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            2.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[4 * N_KINDS, 3]);
        // stage 0: z over k filters at full 8x8 resolution, h after the
        // 2x2 pool; stage 1 runs at 4x4
        assert_eq!(out.overflow.at2(group_index(0, KIND_Z), 2), (2 * n * 64 * 3) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_H), 2), (n * 16 * 3) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_Z), 2), (2 * n * 16 * 4) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_H), 2), (n * 4 * 4) as f32);
        // the dense stage's DH comes from the head, the last conv
        // stage's DH from the dense layer (post-flatten), and stage 0's
        // DH from stage 1 at stage-0's pooled resolution
        assert_eq!(out.overflow.at2(group_index(2, KIND_DH), 2), (n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_DH), 2), (n * 16) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_DH), 2), (n * 16 * 3) as f32);
    }

    #[test]
    fn max_pool_routes_gradient_to_argmax() {
        let pool = MaxPool2d { pool: 2, group: 0 };
        let ctrl = ScaleController::fixed(8, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut q = GoldenQ::new(&ctrl, RoundMode::HalfAway);
        let x = Tensor::from_vec(
            &[1, 2, 2, 1],
            vec![1.0, 5.0, 2.0, 3.0], // window max is the 5 at (0, 1)
        );
        let mut drop = DropCtx::eval();
        let (h, cache) = pool.forward(&mut q, &[], x, &mut drop);
        assert_eq!(h.shape(), &[1, 1, 1, 1]);
        assert_eq!(h.data(), &[5.0]);
        let dy = Tensor::from_vec(&[1, 1, 1, 1], vec![7.0]);
        let (grads, dx) = pool.backward(&mut q, &[], &cache, dy, Some(0));
        assert!(grads.is_empty());
        assert_eq!(dx.unwrap().data(), &[0.0, 7.0, 0.0, 0.0]);
    }

    #[test]
    fn deep_topology_trains_and_counts_per_layer_overflow() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let n = 16;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let out = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            2.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[4 * N_KINDS, 3]);
        // per-layer totals reflect each layer's own width
        assert_eq!(out.overflow.at2(group_index(0, KIND_Z), 2), (2 * n * 10) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_Z), 2), (2 * n * 8) as f32);
        assert_eq!(out.overflow.at2(group_index(2, KIND_Z), 2), (2 * n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_Z), 2), (n * 4) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_DZ), 2), (n * 4) as f32);
        // DH flows into every layer below the head
        assert_eq!(out.overflow.at2(group_index(2, KIND_DH), 2), (n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_DH), 2), (n * 10) as f32);
    }

    #[test]
    fn deep_topology_loss_decreases() {
        let spec = TopologySpec::mlp(vec![16, 16, 16], 2);
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl =
            ScaleController::fixed(net.n_groups(), FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 5);
        let n = 16;
        let mut rng = Pcg32::seeded(6);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let (mut first, mut last) = (None, 0.0);
        for _ in 0..40 {
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.2,
                0.5,
                0.0,
                &ctrl,
                StepOptions::default(),
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        let first = first.expect("at least one training step ran, so the first loss is set");
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    #[should_panic(expected = "Network::n_groups")]
    fn wrong_controller_size_is_rejected() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        // sized for 3 compute layers, but the graph has 4
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let x = Tensor::zeros(&[2, 12]);
        let y = ops::one_hot(&[0, 1], 4);
        let _ = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
    }

    #[test]
    fn eval_matches_zero_lr_forward_on_deep_net() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(12, 3),
            FixedFormat::new(12, 0),
        );
        let (params, _) = state(&spec, 12, 4, 8);
        let n = 8;
        let mut rng = Pcg32::seeded(4);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        // quantize storage as the trainer does at init
        let mut pq = params.clone();
        for (i, p) in pq.iter_mut().enumerate() {
            let g = group_index(i / 2, if i % 2 == 0 { KIND_W } else { KIND_B });
            crate::arith::Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
        }
        let logits = net.eval_logits(&pq, &x, &ctrl, RoundMode::HalfAway, false);
        let logp = ops::log_softmax(&logits);
        let mut want = 0.0f64;
        for i in 0..n * 4 {
            want -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let want = (want / n as f64) as f32;
        let (mut p2, mut v2) = (pq.clone(), state(&spec, 12, 4, 8).1);
        let out = net.train_step(
            &mut p2,
            &mut v2,
            &x,
            &y,
            0.0,
            0.0,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!((out.loss - want).abs() < 1e-5, "{want} vs {}", out.loss);
    }
}
