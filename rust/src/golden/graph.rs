//! The layer-graph executor: topology as data, quantization sites
//! derived from the graph, signals threaded as shape-aware tensors.
//!
//! The golden model used to be one hand-inlined 2-hidden-layer maxout
//! step (`MlpShape::pi_mlp` pinned the whole topology). This module
//! decomposes it into a [`Layer`] trait — dense layers
//! ([`MaxoutDense`], [`SoftmaxHead`]), spatial layers ([`MaxoutConv2d`],
//! [`MaxPool2d`], [`Flatten`]) and [`DropoutLayer`] — assembled into a
//! [`Network`] from a [`TopologySpec`], so depth/width sweeps *and* the
//! paper's CIFAR-10/SVHN-class maxout-conv workloads are config
//! changes, not code changes.
//!
//! **Signals are shape-aware.** Every layer declares its output
//! [`Shape`] (`Flat(d)` or `Spatial{h,w,c}`) as a function of its input
//! shape via [`Layer::out_shape`];
//! [`Network::from_topology_shaped`] chains the contract from the
//! dataset's shape (`data::dataset_shape`) down to the head at
//! construction time, so a conv stage over a flat dataset or an
//! over-pooled image is a config error, never a runtime panic.
//! Activations flow as `[B, ...shape.dims()]` tensors (NHWC for
//! spatial signals).
//!
//! **Weights are shared, per-worker state is scratch.** A [`Network`]
//! holds only immutable-per-step state (the layer graph, the packed
//! weight caches behind mutexes) and is `Sync`: any number of
//! data-parallel workers can run [`Network::train_step`] shards or
//! [`Network::eval_logits`] concurrently against one instance. All
//! mutable per-pass state lives in a [`NetScratch`] (one
//! [`LayerScratch`] per layer: the conv im2col buffers that used to
//! hide in a `RefCell`), checked out of a pool per pass and returned
//! after.
//!
//! **Data-parallel training is bit-identical at any worker count.**
//! `StepOptions::dp_workers > 1` shards the batch row-wise across
//! scoped worker threads. Each worker replays the *identical*
//! quantization-site sequence over its shard (epilogue bases offset by
//! the shard's start row, so element-keyed stochastic streams see
//! full-batch indices), computes its own forward/backward *routing*,
//! and captures — without computing — the DW/DB epilogues. The driver
//! then (a) sums the f64 loss over shard log-probabilities in shard
//! order (the serial association), (b) reassembles the full-batch
//! GEMM operands, (c) computes each layer's dw/db centrally with the
//! captured epilogues ([`Layer::reduce_grads`]) — cross-shard f32
//! summations are never split, so non-associativity cannot bite —
//! and (d) folds worker [`QuantStats`] with the fixed-order
//! [`merge_stats_tree`] before the single bottom-up `sgd_update`.
//! `tests/dp_parity.rs` asserts exact u32 bits at N ∈ {1,2,3,4};
//! DESIGN.md §Data-parallel training walks the argument.
//!
//! **Conv rides the fused GEMM epilogues.** [`MaxoutConv2d`] lowers
//! each stage by im2col ([`super::conv`]): the SAME-padded stride-1
//! patch matrix is built once per step into the worker's
//! [`LayerScratch`] (allocated on the first step, reused afterwards),
//! and each maxout filter's weight slab rides `matmul_sl_qd_into` /
//! `matmul_tn_sl_qd_into` with the Z/DW quantization fused into the
//! tile epilogues — bit-identical to the direct nested-loop reference
//! kernels (`StepOptions::conv_direct`, `tests/conv_parity.rs`). The
//! `_qd` dispatch also lets eligible conv GEMMs run in the integer
//! domain (`StepOptions::int_domain`, `tests/int_gemm_parity.rs`).
//!
//! **Weight packs are cached across steps.** Each weight layer owns a
//! [`PackedCache`] keyed on its parameter-value epoch + the W group's
//! adopted scale step, so the integer-domain path re-packs a weight
//! slab only after `sgd_update` bumps the epoch or a scale adoption
//! moves the step; serve workers pre-pack every slab once at startup
//! via [`Network::prepack_int_operands`]. The cache hands out an `Arc`
//! of the packed slabs, so concurrent dp workers share one build per
//! step (the first to arrive builds; the mutex is never held across a
//! GEMM). Eligibility is re-checked on every call against the cached
//! pack, and a cache hit returns byte-identical packs — packing is a
//! pure function of the values — so caching cannot perturb the
//! bit-identity contract below.
//!
//! **The bit-identity contract.** The graph executor is not "close to"
//! the monolithic step it replaced — it is bit-identical on the builtin
//! `pi_mlp`, across all four arithmetics, all four rounding modes, fused
//! and two-pass kernels, any thread count, and with dropout on
//! (`tests/graph_parity.rs` asserts exact `u32` bits against
//! [`super::reference`]). Three orderings make that hold, and every
//! layer implementation must preserve them:
//!
//! 1. **Site order.** [`GoldenQ`] numbers quantization sites in call
//!    order (stochastic-rounding streams key on the site index). The
//!    graph visits sites exactly as the monolith did: forward
//!    `Z,H` per maxout stage (for conv stages `Z` in the conv layer and
//!    `H` in its pooling partner, mirroring L2's conv→Q_Z→max→pool→Q_H)
//!    then the head's `Z`; backward `DZ,DW,DB` per compute layer
//!    top-down, with the produced `dx` quantized as the *next compute
//!    layer below*'s `DH` group **before** any intervening dropout mask
//!    is applied (pooling/flatten backward is pure routing and owns no
//!    sites); update `w` then `b` per layer bottom-up, velocity before
//!    parameter. The DW/DB epilogues are *drawn* at their site
//!    positions inside `backward` but *run* centrally in
//!    [`Layer::reduce_grads`] — an epilogue is a pure value, so
//!    deferring its execution moves no site and changes no bits.
//! 2. **Group table.** Scaling-factor groups stay layer-major
//!    (`group_index(row, kind) = row * N_KINDS + kind`) where `row` is
//!    the compute *stage*'s position in the graph (a conv layer and its
//!    pooling partner share one row; dropout/flatten own none).
//!    [`Network::n_groups`] is therefore *derived from the graph* and
//!    is what [`ScaleController::fixed`]/[`ScaleController::dynamic`]
//!    take — per-conv-layer dynamic scales need zero controller
//!    changes.
//! 3. **RNG draw order.** Dropout masks draw from one stream in forward
//!    graph order (input mask first, then after each stage). The driver
//!    pre-draws every mask for the *full* batch before sharding
//!    ([`Network::train_step`]), so workers slice identical masks and
//!    the graph replays the monolith's draws bit-for-bit.

#![allow(clippy::too_many_arguments)]

use std::mem;
use std::sync::Mutex;

use crate::arith::{QuantEpilogue, QuantStats, RoundMode};
use crate::config::TopologySpec;
use crate::coordinator::ScaleController;
use crate::runtime::manifest::{
    group_index, KIND_B, KIND_DB, KIND_DH, KIND_DW, KIND_DZ, KIND_H, KIND_W, KIND_Z, N_KINDS,
};
use crate::tensor::int_gemm::{self, PackedCache};
use crate::tensor::{ops, Shape, Tensor};

use super::conv::{self, ConvGeom};
use super::{
    apply_mask, dropout_mask, merge_stats_tree, Dropout, GoldenOut, GoldenQ, MlpShape, Params,
    StepOptions, STOCHASTIC_SITE_SEED,
};

/// Per-step state a layer saves in `forward` for its `backward`. A
/// closed enum instead of `Box<dyn Any>`: the layer kinds are a
/// deliberate vocabulary, and the variants keep tensor moves explicit.
pub enum Cache {
    /// Maxout: the (possibly dropout-masked) input + winning filter per
    /// `[B, U]` output.
    Maxout { x: Tensor, amax: Vec<u8> },
    /// Head: the (possibly dropout-masked) input.
    Head { x: Tensor },
    /// Dropout: the drawn mask (`None` = identity this step).
    Mask(Option<Vec<f32>>),
    /// Conv: the (possibly dropout-masked) `[B, H, W, C]` input +
    /// winning filter per `[B·H·W, C_out]` output element. The im2col
    /// patch matrix itself stays in the worker's [`LayerScratch`]
    /// between forward and backward of the same pass.
    Conv { x: Tensor, amax: Vec<u8> },
    /// Max pool: the input tensor shape + the flat input index of each
    /// window's argmax (routing targets for backward).
    Pool { in_shape: Vec<usize>, idx: Vec<u32> },
    /// Flatten: the spatial input shape to restore in backward.
    Flat { in_shape: Vec<usize> },
}

/// Where a [`DropoutLayer`] reads its rate from ([`StepOptions`] carries
/// the schedule's per-step input/hidden rates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropoutRole {
    Input,
    Hidden,
}

/// One data-parallel worker's slice of the batch, threaded through
/// every layer call. The serial step is the degenerate shard
/// (`start = 0`, `rows = full`): there is exactly one code path, which
/// is the whole worker-count-invariance argument.
#[derive(Clone, Copy, Debug)]
pub struct ShardCtx {
    /// First batch row of this shard in the full batch.
    pub start: usize,
    /// Rows in this shard (the layer inputs carry this batch size).
    pub rows: usize,
    /// Full-batch row count (epilogue bases and the `(p - y)/B` loss
    /// gradient divide by this, never by `rows`).
    pub full: usize,
    /// Per-worker GEMM thread cap (`0` = the process-wide auto plan);
    /// dp workers split `LPDNN_THREADS` so N workers don't oversubscribe
    /// N-fold. Thread count never changes bits.
    pub threads: usize,
}

impl ShardCtx {
    /// The serial (1-worker) context over a full batch.
    pub fn serial(batch: usize) -> ShardCtx {
        ShardCtx { start: 0, rows: batch, full: batch, threads: 0 }
    }

    /// GEMM thread count for a kernel of `flops`/`rows` under this
    /// shard's cap.
    fn gemm_threads(&self, flops: usize, rows: usize) -> usize {
        ops::plan_threads_capped(flops, rows, self.threads)
    }
}

/// One layer's per-worker mutable buffers (today: the conv im2col
/// scratch — the patch matrix filled in forward, the patch-space
/// gradient buffers used by backward). Allocated on a worker's first
/// pass and reused afterwards; owned by a [`NetScratch`], never by the
/// shared [`Network`].
#[derive(Default)]
pub struct LayerScratch {
    patches: Vec<f32>,
    dpatch: Vec<f32>,
    /// One filter's patch-space gradient (the NT product's destination).
    dpj: Vec<f32>,
}

/// Per-worker mutable state for one pass over a [`Network`]: one
/// [`LayerScratch`] per layer. Checked out of the network's pool
/// (so steady-state steps don't reallocate) and returned after the
/// pass.
pub struct NetScratch {
    layers: Vec<LayerScratch>,
}

impl NetScratch {
    fn new(n_layers: usize) -> NetScratch {
        NetScratch { layers: (0..n_layers).map(|_| LayerScratch::default()).collect() }
    }
}

/// A weight layer's deferred gradient work: the shard's GEMM operands
/// plus the DW/DB epilogues captured at their site positions during the
/// worker's backward pass. The driver concatenates the shards' operands
/// back into full-batch tensors and hands them to
/// [`Layer::reduce_grads`] — the cross-shard summation inside the
/// dw/db contractions then happens in one kernel call with the serial
/// association, which is what keeps f32 reduction bits independent of
/// the worker count.
pub struct Deferred {
    /// The layer's left GEMM operand (dense/head: the cached input
    /// `[rows, I]`; conv: the im2col patch matrix `[rows·H·W, plen]`,
    /// or the raw `[rows, H, W, C]` input under `conv_direct`).
    x: Tensor,
    /// The routed, DZ-quantized gradient (`[slabs, rows·width]` flat).
    dz: Tensor,
    /// Maxout filter count (`1` for the head).
    slabs: usize,
    /// Per-batch-row width of one `dz` slab row block.
    width: usize,
    epi_dw: QuantEpilogue,
    epi_db: QuantEpilogue,
}

/// The per-pass dropout context. Masks are pre-drawn for the full batch
/// by the driver (in forward graph order, from the single [`Dropout`]
/// stream — identical draws to the serial step); each worker slices its
/// shard's rows out of the shared masks.
pub struct DropCtx<'a> {
    masks: Option<&'a [Option<Vec<f32>>]>,
    next: usize,
}

impl<'a> DropCtx<'a> {
    /// Evaluation context: no masks.
    pub fn eval() -> DropCtx<'static> {
        DropCtx { masks: None, next: 0 }
    }

    /// Training context over the step's pre-drawn full-batch masks
    /// (`None` = dropout off).
    pub fn train(masks: Option<&'a [Option<Vec<f32>>]>) -> DropCtx<'a> {
        DropCtx { masks, next: 0 }
    }

    /// This worker's rows of the next mask in graph order. `n` is the
    /// *shard* element count of the signal being masked.
    fn next_mask(&mut self, n: usize, sh: &ShardCtx) -> Option<Vec<f32>> {
        let all = self.masks?;
        let idx = self.next;
        // advance past the slot even when this mask is off (rate 0)
        self.next += 1;
        let m = all[idx].as_ref()?;
        let per = n / sh.rows;
        Some(m[sh.start * per..(sh.start + sh.rows) * per].to_vec())
    }
}

/// Resolved per-step update hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct UpdateHp {
    pub lr: f32,
    pub mom: f32,
    pub max_norm: f32,
}

/// One node of the training graph.
///
/// A layer owns a contiguous run of the manifest-ordered parameter
/// vector (`n_params` tensors; the [`Network`] slices them out) and, if
/// it quantizes anything, one scaling-group *row* (`group_row`) in the
/// layer-major group table. Every quantization site a layer touches
/// registers against the shared [`GoldenQ`] in a fixed visit order — see
/// the module docs for the three orderings the implementations must
/// preserve. Layers are `Send + Sync`: all per-pass mutable state lives
/// in the caller's [`LayerScratch`], and the packed-weight caches
/// serialize internally.
pub trait Layer: Send + Sync {
    /// Human-readable description for diagnostics.
    fn describe(&self) -> String;

    /// The scaling-group row this layer's sites record under; `None`
    /// for stateless layers with no quantization sites (dropout,
    /// flatten). A [`MaxPool2d`] reports its conv partner's row: the
    /// stage's `H` site lives on the pool side of the split.
    fn group_row(&self) -> Option<usize>;

    /// Number of parameter tensors this layer owns (manifest order).
    fn n_params(&self) -> usize {
        0
    }

    /// The dropout role of a [`DropoutLayer`] (`None` for everything
    /// else) — what the driver walks to pre-draw the step's masks in
    /// forward graph order.
    fn dropout_role(&self) -> Option<DropoutRole> {
        None
    }

    /// Output signal shape given the input signal shape — the
    /// shape-aware contract [`Network::from_topology_shaped`] chains
    /// through the whole graph at construction time. Errors are config
    /// errors (dense layer fed a spatial signal, conv fed a flat one,
    /// pooling below one pixel).
    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape>;

    /// Consume the layer input (the shard's rows), produce its output
    /// plus whatever the backward pass needs. Quantization sites
    /// register against `q` in visit order, with epilogue bases offset
    /// by the shard's start row so shard sweeps reproduce the serial
    /// whole-batch sweeps bit-for-bit.
    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        sh: &ShardCtx,
        scratch: &mut LayerScratch,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache);

    /// Consume the gradient w.r.t. this layer's output; produce the
    /// layer's [`Deferred`] gradient work (`None` for parameterless
    /// layers) and, when `dx_group` is `Some(row)`, the gradient w.r.t.
    /// the layer input quantized under `(row, DH)` — the *lower*
    /// compute layer's DH group, matching the monolith's (and L2's)
    /// attribution. `dx_group = None` means no consumer below needs
    /// `dx`. Parameter gradients are NOT computed here: the DW/DB
    /// epilogues are drawn at their site positions and carried in the
    /// `Deferred` for the driver's central [`Layer::reduce_grads`].
    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: Cache,
        dy: Tensor,
        dx_group: Option<usize>,
        sh: &ShardCtx,
        scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>);

    /// Compute this layer's parameter gradients (manifest order) from
    /// the reassembled full-batch operands and the worker-captured
    /// DW/DB epilogues. Runs once per step on the driver, after the
    /// workers join — the cross-shard f32 summation happens inside one
    /// kernel call, so its association (and bits) match the serial
    /// step exactly.
    fn reduce_grads(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        dz: Tensor,
        epi_dw: QuantEpilogue,
        epi_db: QuantEpilogue,
    ) -> Vec<Tensor> {
        let _ = (q, params, x, dz, epi_dw, epi_db);
        unreachable!("{}: layer defers no gradients", self.describe())
    }

    /// SGD + momentum + max-norm + storage quantization over this
    /// layer's parameter run. Default: no parameters, nothing to do.
    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        let _ = (q, params, vels, grads, hp);
        debug_assert!(self.n_params() == 0, "parameterized layer must implement sgd_update");
    }

    /// Build this layer's packed-operand cache against the controller's
    /// adopted scales without running a forward pass. Serving calls
    /// this once per worker at startup (weights are static at inference
    /// time); layers without integer-eligible weight operands do
    /// nothing.
    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let _ = (ctrl, params);
    }

    /// Rebuild events of this layer's packed-operand cache since
    /// construction (0 for layers without one) — summed by
    /// [`Network::weight_pack_builds`] for the invalidation tests.
    fn pack_builds(&self) -> u64 {
        0
    }

    /// Snapshot of this layer's per-site GEMM lowering-outcome counters
    /// as `(site key, counts)` rows (site keys are short: `"z"`, `"dh"`,
    /// `"dw"`). Default: no GEMM sites. Collected into the report-level
    /// `int_gemm_sites` map by [`Network::int_gemm_sites`].
    fn plan_counts(&self) -> Vec<(&'static str, ops::GemmSiteCounts)> {
        Vec::new()
    }
}

/// The scale half of a weight layer's [`PackedCache`] key: the bit
/// pattern of the stage row's adopted W storage step. Dynamic-scale
/// updates (`ScaleController::after_batch`) and checkpoint adoption
/// (`adopt_int_bits`) both move the step, so keying on it re-packs on
/// every scale-change path without the layers subscribing to the
/// controller. (`step()` is 0.0 for float32 formats — a stable key;
/// those sites never pack anyway.)
fn weight_step_bits(ctrl: &ScaleController, row: usize) -> u32 {
    ctrl.format(group_index(row, KIND_W)).step().to_bits()
}

/// The shared dense-layer update rule (w then b, velocity quantized
/// unrecorded, parameter max-normed then quantized recorded) — exactly
/// the monolith's per-parameter sequence.
fn dense_sgd_update(
    q: &mut GoldenQ,
    group: usize,
    params: &mut [Tensor],
    vels: &mut [Tensor],
    grads: &[Tensor],
    hp: &UpdateHp,
) {
    debug_assert_eq!(params.len(), 2);
    debug_assert_eq!(grads.len(), 2);
    for i in 0..2 {
        let kind = if i == 0 { KIND_W } else { KIND_B };
        // v' = Q_up(mom*v - lr*g), stats NOT recorded (matches L2)
        for (vv, gv) in vels[i].data_mut().iter_mut().zip(grads[i].data()) {
            *vv = hp.mom * *vv - hp.lr * gv;
        }
        q.apply(&mut vels[i], group, kind, false);
        // p' = Q_up(maxnorm(p + v'))
        for (pv, vv) in params[i].data_mut().iter_mut().zip(vels[i].data()) {
            *pv += vv;
        }
        if kind == KIND_W {
            ops::max_norm_inplace(&mut params[i], hp.max_norm);
        }
        q.apply(&mut params[i], group, kind, true);
    }
}

// ---------------------------------------------------------------------------
// MaxoutDense
// ---------------------------------------------------------------------------

/// One maxout dense layer: per-filter `z_j = x @ w_j + b_j` (Z group,
/// one logical site across all `k` filter tiles, fused into the GEMM
/// epilogues), `h = max_j z_j` (H group). Params: `w [k, I, U]`,
/// `b [k, U]`.
pub struct MaxoutDense {
    pub units: usize,
    pub k: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
    /// Per-filter packed weight slabs for the integer-domain forward
    /// (one slab per maxout filter), invalidated by `sgd_update`. The
    /// mutex only guards `ensure` — callers keep the returned `Arc`,
    /// so concurrent workers share one build and no lock spans a GEMM.
    packs: Mutex<PackedCache>,
    /// Lowering-outcome counters for the forward z GEMMs (atomic: all
    /// data-parallel workers record against the shared layer).
    tally_z: ops::GemmSiteTally,
    /// Lowering-outcome counters for the reduce-grads dw GEMMs.
    tally_dw: ops::GemmSiteTally,
}

impl MaxoutDense {
    pub fn new(units: usize, k: usize, group: usize) -> MaxoutDense {
        MaxoutDense {
            units,
            k,
            group,
            packs: Mutex::new(PackedCache::new()),
            tally_z: ops::GemmSiteTally::new(),
            tally_dw: ops::GemmSiteTally::new(),
        }
    }
}

impl Layer for MaxoutDense {
    fn describe(&self) -> String {
        format!("maxout({}x{})@l{}", self.units, self.k, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        crate::ensure!(
            matches!(in_shape, Shape::Flat(_)),
            "{}: needs a flat input, got {in_shape} (insert a flatten stage)",
            self.describe()
        );
        Ok(Shape::Flat(self.units))
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        sh: &ShardCtx,
        _scratch: &mut LayerScratch,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let rows = x.shape()[0];
        assert_eq!(x.shape()[1], d_in, "{}: input width", self.describe());

        // z for every filter, quantized as ONE logical site. Fused: each
        // filter's [rows, U] tile gets bias + quantization in its GEMM
        // epilogue (base = the filter tile's offset in the full-batch
        // [k, B, U] tensor, so a shard reproduces the serial index
        // stream). Two-pass: materialize all k tiles, then sweep each at
        // the same bases. Identical per-element index stream → identical
        // bits/counters.
        let mut zq = Tensor::zeros(&[k, rows, units]);
        let epi = q.epilogue(self.group, KIND_Z);
        let mut zst = QuantStats::default();
        // integer domain: serve each filter's GEMM from the cached
        // packed slab (built here on the first worker to arrive after an
        // update or scale move, or by a serve worker's prepack)
        let cached = (q.fused && q.int_domain).then(|| {
            self.packs.lock().expect("dense pack cache poisoned").ensure(
                weight_step_bits(q.ctrl, self.group),
                k,
                |j| int_gemm::pack(&w.data()[j * d_in * units..(j + 1) * d_in * units]),
            )
        });
        let t = sh.gemm_threads(2 * rows * d_in * units, rows);
        for j in 0..k {
            let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
            let brow = &b.data()[j * units..(j + 1) * units];
            let dst = &mut zq.data_mut()[j * rows * units..(j + 1) * rows * units];
            if let Some(c) = &cached {
                zst.merge(ops::matmul_sl_qd_cached_into_threads(
                    x.data(),
                    wj,
                    c[j].as_ref(),
                    Some(brow),
                    dst,
                    rows,
                    d_in,
                    units,
                    epi.with_base(((j * sh.full + sh.start) * units) as u64),
                    t,
                    Some(&self.tally_z),
                ));
            } else if q.fused {
                zst.merge(ops::matmul_sl_qd_into_threads(
                    x.data(),
                    wj,
                    Some(brow),
                    dst,
                    rows,
                    d_in,
                    units,
                    epi.with_base(((j * sh.full + sh.start) * units) as u64),
                    t,
                    q.int_domain,
                    Some(&self.tally_z),
                ));
            } else {
                let zj = ops::matmul_sl_threads(x.data(), wj, rows, d_in, units, t);
                for r in 0..rows {
                    for u in 0..units {
                        dst[r * units + u] = zj[r * units + u] + brow[u];
                    }
                }
            }
        }
        if !q.fused {
            for j in 0..k {
                let dst = &mut zq.data_mut()[j * rows * units..(j + 1) * rows * units];
                zst.merge(epi.run(dst, ((j * sh.full + sh.start) * units) as u64));
            }
        }
        q.record(self.group, KIND_Z, zst);

        let mut h = Tensor::zeros(&[rows, units]);
        let mut amax = vec![0u8; rows * units];
        for r in 0..rows {
            for u in 0..units {
                let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
                for j in 0..k {
                    let v = zq.at3(j, r, u);
                    if v > best {
                        best = v;
                        bj = j as u8;
                    }
                }
                h.data_mut()[r * units + u] = best;
                amax[r * units + u] = bj;
            }
        }
        q.apply_at(&mut h, self.group, KIND_H, true, (sh.start * units) as u64);
        (h, Cache::Maxout { x, amax })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: Cache,
        dy: Tensor,
        dx_group: Option<usize>,
        sh: &ShardCtx,
        scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Maxout { x, amax } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let rows = x.shape()[0];

        // route dh to the winning filter, quantize (DZ group) — per-slab
        // sweeps at the slabs' full-batch bases (= one whole-tensor
        // sweep in the serial shard)
        let mut dz = Tensor::zeros(&[k, rows, units]);
        for r in 0..rows {
            for u in 0..units {
                let j = amax[r * units + u] as usize;
                dz.data_mut()[(j * rows + r) * units + u] = dy.at2(r, u);
            }
        }
        let epi_dz = q.epilogue(self.group, KIND_DZ);
        let mut dzst = QuantStats::default();
        for j in 0..k {
            let dst = &mut dz.data_mut()[j * rows * units..(j + 1) * rows * units];
            dzst.merge(epi_dz.run(dst, ((j * sh.full + sh.start) * units) as u64));
        }
        q.record(self.group, KIND_DZ, dzst);

        // DW/DB sites are drawn HERE (serial site order) but run in
        // reduce_grads over the reassembled full batch
        let epi_dw = q.epilogue(self.group, KIND_DW);
        let epi_db = q.epilogue(self.group, KIND_DB);

        // dx: per-filter products summed across filters before the total
        // is quantized as the lower layer's DH group
        let dx = dx_group.map(|g| {
            let mut dx = Tensor::zeros(&[rows, d_in]);
            scratch.dpj.resize(rows * d_in, 0.0);
            let t = sh.gemm_threads(2 * rows * units * d_in, rows);
            for j in 0..k {
                let dzj = &dz.data()[j * rows * units..(j + 1) * rows * units];
                let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
                ops::matmul_nt_sl_into_threads(dzj, wj, &mut scratch.dpj, rows, units, d_in, t);
                for (a, &v) in dx.data_mut().iter_mut().zip(&scratch.dpj) {
                    *a += v;
                }
            }
            q.apply_at(&mut dx, g, KIND_DH, true, (sh.start * d_in) as u64);
            dx
        });
        (Some(Deferred { x, dz, slabs: k, width: units, epi_dw, epi_db }), dx)
    }

    fn reduce_grads(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        dz: Tensor,
        epi_dw: QuantEpilogue,
        epi_db: QuantEpilogue,
    ) -> Vec<Tensor> {
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let full = x.shape()[0];

        // dw for every filter, quantized as ONE logical site (like the z
        // tiles in the forward pass), over the full-batch operands
        let mut dw = Tensor::zeros(&[k, d_in, units]);
        let mut db = Tensor::zeros(&[k, units]);
        let mut dwst = QuantStats::default();
        for j in 0..k {
            let dzj = &dz.data()[j * full * units..(j + 1) * full * units];
            let dwj_dst = &mut dw.data_mut()[j * d_in * units..(j + 1) * d_in * units];
            if q.fused {
                dwst.merge(ops::matmul_tn_sl_qd_into_threads(
                    x.data(),
                    dzj,
                    dwj_dst,
                    full,
                    d_in,
                    units,
                    epi_dw.with_base((j * d_in * units) as u64),
                    ops::plan_threads_capped(2 * full * d_in * units, d_in, 0),
                    q.int_domain,
                    Some(&self.tally_dw),
                ));
            } else {
                let dwj = ops::matmul_tn_sl(x.data(), dzj, full, d_in, units);
                dwj_dst.copy_from_slice(&dwj);
            }
            let dbj = ops::sum_rows_sl(dzj, full, units);
            db.data_mut()[j * units..(j + 1) * units].copy_from_slice(&dbj);
        }
        if !q.fused {
            dwst = epi_dw.run(dw.data_mut(), 0);
        }
        q.record(self.group, KIND_DW, dwst);
        let dbst = epi_db.run(db.data_mut(), 0);
        q.record(self.group, KIND_DB, dbst);
        vec![dw, db]
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.lock().expect("dense pack cache poisoned").invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        self.packs.lock().expect("dense pack cache poisoned").ensure(
            weight_step_bits(ctrl, self.group),
            k,
            |j| int_gemm::pack(&w.data()[j * d_in * units..(j + 1) * d_in * units]),
        );
    }

    fn pack_builds(&self) -> u64 {
        self.packs.lock().expect("dense pack cache poisoned").builds()
    }

    fn plan_counts(&self) -> Vec<(&'static str, ops::GemmSiteCounts)> {
        vec![("z", self.tally_z.counts()), ("dw", self.tally_dw.counts())]
    }
}

// ---------------------------------------------------------------------------
// SoftmaxHead
// ---------------------------------------------------------------------------

/// The classifier head: `z = x @ w + b` with the bias and Z-group
/// quantization fused into the GEMM epilogue. The softmax/cross-entropy
/// itself is loss machinery and lives in the [`Network`] driver (as it
/// did in the monolith); this layer's backward consumes the pre-quantized
/// `(p - y)/B` and owns the DZ/DW/DB sites plus the fused DH projection.
/// Params: `w [U, C]`, `b [C]`.
pub struct SoftmaxHead {
    pub n_classes: usize,
    /// This layer's row in the layer-major group table.
    pub group: usize,
    /// One packed slab of `w` serving both the forward NN product and
    /// the backward NT projection, invalidated by `sgd_update`.
    packs: Mutex<PackedCache>,
    /// Lowering-outcome counters: forward z, backward dh projection,
    /// reduce-grads dw.
    tally_z: ops::GemmSiteTally,
    tally_dh: ops::GemmSiteTally,
    tally_dw: ops::GemmSiteTally,
}

impl SoftmaxHead {
    pub fn new(n_classes: usize, group: usize) -> SoftmaxHead {
        SoftmaxHead {
            n_classes,
            group,
            packs: Mutex::new(PackedCache::new()),
            tally_z: ops::GemmSiteTally::new(),
            tally_dh: ops::GemmSiteTally::new(),
            tally_dw: ops::GemmSiteTally::new(),
        }
    }
}

impl Layer for SoftmaxHead {
    fn describe(&self) -> String {
        format!("softmax({})@l{}", self.n_classes, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        crate::ensure!(
            matches!(in_shape, Shape::Flat(_)),
            "{}: needs a flat input, got {in_shape} (insert a flatten stage)",
            self.describe()
        );
        Ok(Shape::Flat(self.n_classes))
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        sh: &ShardCtx,
        _scratch: &mut LayerScratch,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let rows = x.shape()[0];
        assert_eq!(x.shape()[1], units, "{}: input width", self.describe());

        let epi = q.epilogue(self.group, KIND_Z).with_base((sh.start * classes) as u64);
        let t = sh.gemm_threads(2 * rows * units * classes, rows);
        let z = if q.fused && q.int_domain {
            let c = self.packs.lock().expect("head pack cache poisoned").ensure(
                weight_step_bits(q.ctrl, self.group),
                1,
                |_| int_gemm::pack(w.data()),
            );
            let mut v = vec![0.0f32; rows * classes];
            let st = ops::matmul_sl_qd_cached_into_threads(
                x.data(),
                w.data(),
                c[0].as_ref(),
                Some(b.data()),
                &mut v,
                rows,
                units,
                classes,
                epi,
                t,
                Some(&self.tally_z),
            );
            q.record(self.group, KIND_Z, st);
            Tensor::from_vec(&[rows, classes], v)
        } else if q.fused {
            let mut v = vec![0.0f32; rows * classes];
            let st = ops::matmul_sl_qd_into_threads(
                x.data(),
                w.data(),
                Some(b.data()),
                &mut v,
                rows,
                units,
                classes,
                epi,
                t,
                q.int_domain,
                Some(&self.tally_z),
            );
            q.record(self.group, KIND_Z, st);
            Tensor::from_vec(&[rows, classes], v)
        } else {
            let v = ops::matmul_sl_threads(x.data(), w.data(), rows, units, classes, t);
            let mut z = Tensor::from_vec(&[rows, classes], v);
            for r in 0..rows {
                for c in 0..classes {
                    z.data_mut()[r * classes + c] += b.data()[c];
                }
            }
            let st = epi.run(z.data_mut(), 0);
            q.record(self.group, KIND_Z, st);
            z
        };
        (z, Cache::Head { x })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: Cache,
        mut dy: Tensor,
        dx_group: Option<usize>,
        sh: &ShardCtx,
        _scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Head { x } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let rows = x.shape()[0];

        // dy arrives as the pre-quantized loss gradient (p - y)/B
        q.apply_at(&mut dy, self.group, KIND_DZ, true, (sh.start * classes) as u64);
        let dz = dy;

        // DW/DB sites drawn here, run centrally in reduce_grads
        let epi_dw = q.epilogue(self.group, KIND_DW);
        let epi_db = q.epilogue(self.group, KIND_DB);

        // dx quantized as the lower layer's DH group, fused into the NT
        // projection (the monolith's dh1 site, generalized)
        let dx = dx_group.map(|g| {
            let epi = q.epilogue(g, KIND_DH).with_base((sh.start * units) as u64);
            let t = sh.gemm_threads(2 * rows * classes * units, rows);
            if q.fused && q.int_domain {
                // the forward pass of this same step (or a worker's
                // prepack) already built the slab: this ensure is a hit
                let c = self.packs.lock().expect("head pack cache poisoned").ensure(
                    weight_step_bits(q.ctrl, self.group),
                    1,
                    |_| int_gemm::pack(w.data()),
                );
                let (v, st) = ops::matmul_nt_sl_qd_cached_threads(
                    dz.data(),
                    w.data(),
                    c[0].as_ref(),
                    rows,
                    classes,
                    units,
                    epi,
                    t,
                    Some(&self.tally_dh),
                );
                q.record(g, KIND_DH, st);
                Tensor::from_vec(&[rows, units], v)
            } else if q.fused {
                let mut v = vec![0.0f32; rows * units];
                let st = ops::matmul_nt_sl_qd_into_threads(
                    dz.data(),
                    w.data(),
                    &mut v,
                    rows,
                    classes,
                    units,
                    epi,
                    t,
                    q.int_domain,
                    Some(&self.tally_dh),
                );
                q.record(g, KIND_DH, st);
                Tensor::from_vec(&[rows, units], v)
            } else {
                let v = ops::matmul_nt_sl_threads(dz.data(), w.data(), rows, classes, units, t);
                let mut dx = Tensor::from_vec(&[rows, units], v);
                let st = epi.run(dx.data_mut(), 0);
                q.record(g, KIND_DH, st);
                dx
            }
        });
        (Some(Deferred { x, dz, slabs: 1, width: classes, epi_dw, epi_db }), dx)
    }

    fn reduce_grads(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        dz: Tensor,
        epi_dw: QuantEpilogue,
        epi_db: QuantEpilogue,
    ) -> Vec<Tensor> {
        let w = &params[0];
        let (units, classes) = (w.shape()[0], w.shape()[1]);
        let full = x.shape()[0];
        let dz = dz.reshape(&[full, classes]);

        let dw = if q.fused {
            let mut v = vec![0.0f32; units * classes];
            let st = ops::matmul_tn_sl_qd_into_threads(
                x.data(),
                dz.data(),
                &mut v,
                full,
                units,
                classes,
                epi_dw,
                ops::plan_threads_capped(2 * full * units * classes, units, 0),
                q.int_domain,
                Some(&self.tally_dw),
            );
            q.record(self.group, KIND_DW, st);
            Tensor::from_vec(&[units, classes], v)
        } else {
            let mut dw = ops::matmul_tn(&x, &dz);
            let st = epi_dw.run(dw.data_mut(), 0);
            q.record(self.group, KIND_DW, st);
            dw
        };
        let mut db = ops::sum_rows(&dz);
        let dbst = epi_db.run(db.data_mut(), 0);
        q.record(self.group, KIND_DB, dbst);
        vec![dw, db]
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.lock().expect("head pack cache poisoned").invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        self.packs.lock().expect("head pack cache poisoned").ensure(
            weight_step_bits(ctrl, self.group),
            1,
            |_| int_gemm::pack(w.data()),
        );
    }

    fn pack_builds(&self) -> u64 {
        self.packs.lock().expect("head pack cache poisoned").builds()
    }

    fn plan_counts(&self) -> Vec<(&'static str, ops::GemmSiteCounts)> {
        vec![
            ("z", self.tally_z.counts()),
            ("dh", self.tally_dh.counts()),
            ("dw", self.tally_dw.counts()),
        ]
    }
}

// ---------------------------------------------------------------------------
// DropoutLayer
// ---------------------------------------------------------------------------

/// Inverted dropout as a graph node: slices its shard's rows out of the
/// pre-drawn full-batch mask (drawn by the driver in forward graph
/// order from the step's shared [`Dropout`] stream), masks in place,
/// and replays the mask over the gradient in backward. No quantization
/// sites, no parameters, identity in evaluation.
pub struct DropoutLayer {
    pub role: DropoutRole,
}

impl DropoutLayer {
    pub fn input() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Input }
    }

    pub fn hidden() -> DropoutLayer {
        DropoutLayer { role: DropoutRole::Hidden }
    }
}

impl Layer for DropoutLayer {
    fn describe(&self) -> String {
        match self.role {
            DropoutRole::Input => "dropout(input)".into(),
            DropoutRole::Hidden => "dropout(hidden)".into(),
        }
    }

    fn group_row(&self) -> Option<usize> {
        None
    }

    fn dropout_role(&self) -> Option<DropoutRole> {
        Some(self.role)
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        Ok(*in_shape)
    }

    fn forward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        mut x: Tensor,
        sh: &ShardCtx,
        _scratch: &mut LayerScratch,
        drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let mask = drop.next_mask(x.len(), sh);
        apply_mask(&mut x, &mask);
        (x, Cache::Mask(mask))
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: Cache,
        mut dy: Tensor,
        _dx_group: Option<usize>,
        _sh: &ShardCtx,
        _scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Mask(mask) = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        apply_mask(&mut dy, &mask);
        (None, Some(dy))
    }
}

// ---------------------------------------------------------------------------
// MaxoutConv2d
// ---------------------------------------------------------------------------

/// One maxout convolutional stage's *linear* half: SAME-padded stride-1
/// conv per maxout filter, `z_j = im2col(x) @ w_j + b_j` (Z group, one
/// logical site across all `k` filter tiles, fused into the GEMM
/// epilogues exactly like [`MaxoutDense`]'s), then `m = max_j z_j` over
/// the filters. The stage's spatial max pool + `H` quantization live in
/// its [`MaxPool2d`] partner (same group row), mirroring the L2 conv
/// stage's `conv → Q_Z → max_k → pool → Q_H` order. Params:
/// `w [k, ksize²·C_in, C_out]` (the im2col-lowered HWIO slab, so the
/// rank-3 max-norm path constrains each output channel's true conv
/// fan-in), `b [k, C_out]`. The im2col buffers live in the worker's
/// [`LayerScratch`], not the layer — the layer itself is `Sync`.
pub struct MaxoutConv2d {
    pub c_out: usize,
    pub k: usize,
    /// Square kernel side; odd (SAME padding = `ksize / 2`).
    pub ksize: usize,
    /// This stage's row in the layer-major group table.
    pub group: usize,
    /// Per-filter packed weight slabs for the integer-domain im2col
    /// forward, invalidated by `sgd_update`.
    packs: Mutex<PackedCache>,
    /// Lowering-outcome counters: forward z (im2col path), reduce-grads
    /// dw. The direct-conv reference path never dispatches a GEMM, so
    /// it records nothing.
    tally_z: ops::GemmSiteTally,
    tally_dw: ops::GemmSiteTally,
}

impl MaxoutConv2d {
    pub fn new(c_out: usize, k: usize, ksize: usize, group: usize) -> MaxoutConv2d {
        MaxoutConv2d {
            c_out,
            k,
            ksize,
            group,
            packs: Mutex::new(PackedCache::new()),
            tally_z: ops::GemmSiteTally::new(),
            tally_dw: ops::GemmSiteTally::new(),
        }
    }

    /// Geometry for a concrete `[B, H, W, C]` input.
    fn geom(&self, x: &Tensor) -> (usize, ConvGeom) {
        let s = x.shape();
        assert_eq!(s.len(), 4, "{}: input must be [B, H, W, C]", self.describe());
        (
            s[0],
            ConvGeom { h: s[1], w: s[2], c_in: s[3], c_out: self.c_out, ksize: self.ksize },
        )
    }
}

impl Layer for MaxoutConv2d {
    fn describe(&self) -> String {
        format!("maxconv({}x{}k{})@l{}", self.c_out, self.k, self.ksize, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn n_params(&self) -> usize {
        2
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        let Shape::Spatial { h, w, .. } = *in_shape else {
            crate::bail!(
                "{}: needs a spatial input, got {in_shape} (conv topologies require an \
                 image dataset)",
                self.describe()
            );
        };
        crate::ensure!(
            self.ksize % 2 == 1,
            "{}: SAME padding needs an odd kernel size",
            self.describe()
        );
        Ok(Shape::Spatial { h, w, c: self.c_out })
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        sh: &ShardCtx,
        scratch: &mut LayerScratch,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let (w, b) = (&params[0], &params[1]);
        let (batch, geom) = self.geom(&x);
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        assert_eq!(k, self.k, "{}: filter count", self.describe());
        assert_eq!(plen, geom.patch_len(), "{}: patch length", self.describe());
        let rows = geom.rows(batch);
        // shard offsets in geometry-row units: one batch row spans H·W
        // spatial rows, so the full-batch epilogue bases scale with them
        let full_rows = geom.rows(sh.full);
        let start_rows = sh.start * geom.h * geom.w;

        // z for every filter, quantized as ONE logical site: each
        // filter's [rows, C_out] tile rides one fused GEMM over the
        // shared patch matrix (base = the filter tile's offset in the
        // full-batch [k, rows, C_out] tensor) — identical per-element
        // index stream to one whole-tensor sweep, and bit-identical to
        // the direct nested-loop reference (q.conv_direct).
        let mut zq = Tensor::zeros(&[k, rows, c_out]);
        let epi = q.epilogue(self.group, KIND_Z);
        let mut zst = QuantStats::default();
        if q.conv_direct {
            for j in 0..k {
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                let brow = &b.data()[j * c_out..(j + 1) * c_out];
                let dst = &mut zq.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
                zst.merge(conv::conv2d_direct_q(
                    x.data(),
                    wj,
                    Some(brow),
                    dst,
                    batch,
                    &geom,
                    epi.with_base(((j * full_rows + start_rows) * c_out) as u64),
                ));
            }
        } else {
            scratch.patches.resize(rows * plen, 0.0);
            conv::im2col_into(x.data(), batch, &geom, &mut scratch.patches);
            // integer domain: per-filter packed slabs, cached like the
            // dense layer's (the patch matrix re-packs every step — it
            // is input data; the weights are not)
            let cached = (q.fused && q.int_domain).then(|| {
                self.packs.lock().expect("conv pack cache poisoned").ensure(
                    weight_step_bits(q.ctrl, self.group),
                    k,
                    |j| int_gemm::pack(&w.data()[j * plen * c_out..(j + 1) * plen * c_out]),
                )
            });
            let t = sh.gemm_threads(2 * rows * plen * c_out, rows);
            for j in 0..k {
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                let brow = &b.data()[j * c_out..(j + 1) * c_out];
                let dst = &mut zq.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
                if let Some(c) = &cached {
                    zst.merge(ops::matmul_sl_qd_cached_into_threads(
                        &scratch.patches,
                        wj,
                        c[j].as_ref(),
                        Some(brow),
                        dst,
                        rows,
                        plen,
                        c_out,
                        epi.with_base(((j * full_rows + start_rows) * c_out) as u64),
                        t,
                        Some(&self.tally_z),
                    ));
                } else if q.fused {
                    zst.merge(ops::matmul_sl_qd_into_threads(
                        &scratch.patches,
                        wj,
                        Some(brow),
                        dst,
                        rows,
                        plen,
                        c_out,
                        epi.with_base(((j * full_rows + start_rows) * c_out) as u64),
                        t,
                        q.int_domain,
                        Some(&self.tally_z),
                    ));
                } else {
                    let zj = ops::matmul_sl_threads(&scratch.patches, wj, rows, plen, c_out, t);
                    for r in 0..rows {
                        for o in 0..c_out {
                            dst[r * c_out + o] = zj[r * c_out + o] + brow[o];
                        }
                    }
                }
            }
            if !q.fused {
                for j in 0..k {
                    let dst = &mut zq.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
                    zst.merge(epi.run(dst, ((j * full_rows + start_rows) * c_out) as u64));
                }
            }
        }
        q.record(self.group, KIND_Z, zst);

        // max over the k filters; the H quantization happens after the
        // spatial pool, in this stage's MaxPool2d partner
        let mut m = Tensor::zeros(&[batch, geom.h, geom.w, c_out]);
        let mut amax = vec![0u8; rows * c_out];
        for r in 0..rows {
            for o in 0..c_out {
                let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
                for j in 0..k {
                    let v = zq.at3(j, r, o);
                    if v > best {
                        best = v;
                        bj = j as u8;
                    }
                }
                m.data_mut()[r * c_out + o] = best;
                amax[r * c_out + o] = bj;
            }
        }
        (m, Cache::Conv { x, amax })
    }

    fn backward(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        cache: Cache,
        dy: Tensor,
        dx_group: Option<usize>,
        sh: &ShardCtx,
        scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Conv { x, amax } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        let w = &params[0];
        let (batch, geom) = self.geom(&x);
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let rows = geom.rows(batch);
        let full_rows = geom.rows(sh.full);
        let start_rows = sh.start * geom.h * geom.w;
        assert_eq!(dy.len(), rows * c_out, "{}: gradient size", self.describe());

        // route the (unpooled) gradient to the winning filter, quantize
        // (DZ group) — L2's combined max/pool subgradient, pool half
        // already routed by MaxPool2d
        let mut dz = Tensor::zeros(&[k, rows, c_out]);
        for (i, &g) in dy.data().iter().enumerate() {
            let j = amax[i] as usize;
            dz.data_mut()[j * rows * c_out + i] = g;
        }
        let epi_dz = q.epilogue(self.group, KIND_DZ);
        let mut dzst = QuantStats::default();
        for j in 0..k {
            let dst = &mut dz.data_mut()[j * rows * c_out..(j + 1) * rows * c_out];
            dzst.merge(epi_dz.run(dst, ((j * full_rows + start_rows) * c_out) as u64));
        }
        q.record(self.group, KIND_DZ, dzst);

        // DW/DB sites drawn here, run centrally in reduce_grads over the
        // reassembled full-batch patches (or raw input, conv_direct)
        let epi_dw = q.epilogue(self.group, KIND_DW);
        let epi_db = q.epilogue(self.group, KIND_DB);

        // dx: per-filter patch-space gradients summed across filters,
        // scattered back to image space, then the total quantized as the
        // lower stage's DH group (like the dense layers' summed dx)
        let dx = dx_group.map(|g| {
            scratch.dpatch.resize(rows * plen, 0.0);
            scratch.dpatch.fill(0.0);
            scratch.dpj.resize(rows * plen, 0.0);
            let t = sh.gemm_threads(2 * rows * c_out * plen, rows);
            for j in 0..k {
                let dzj = &dz.data()[j * rows * c_out..(j + 1) * rows * c_out];
                let wj = &w.data()[j * plen * c_out..(j + 1) * plen * c_out];
                ops::matmul_nt_sl_into_threads(dzj, wj, &mut scratch.dpj, rows, c_out, plen, t);
                for (a, &v) in scratch.dpatch.iter_mut().zip(&scratch.dpj) {
                    *a += v;
                }
            }
            let mut dx = Tensor::zeros(&[batch, geom.h, geom.w, geom.c_in]);
            conv::col2im_add(&scratch.dpatch, batch, &geom, dx.data_mut());
            q.apply_at(
                &mut dx,
                g,
                KIND_DH,
                true,
                (sh.start * geom.h * geom.w * geom.c_in) as u64,
            );
            dx
        });

        // ship the dw operand: the forward-filled patch matrix (moved
        // out — next step's forward refills it), or the raw input under
        // conv_direct
        let xop = if q.conv_direct {
            x
        } else {
            debug_assert_eq!(scratch.patches.len(), rows * plen);
            Tensor::from_vec(&[rows, plen], mem::take(&mut scratch.patches))
        };
        (
            Some(Deferred {
                x: xop,
                dz,
                slabs: k,
                width: geom.h * geom.w * c_out,
                epi_dw,
                epi_db,
            }),
            dx,
        )
    }

    fn reduce_grads(
        &self,
        q: &mut GoldenQ,
        params: &[Tensor],
        x: Tensor,
        dz: Tensor,
        epi_dw: QuantEpilogue,
        epi_db: QuantEpilogue,
    ) -> Vec<Tensor> {
        let w = &params[0];
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let mut dw = Tensor::zeros(&[k, plen, c_out]);
        let mut db = Tensor::zeros(&[k, c_out]);
        let mut dwst = QuantStats::default();
        if q.conv_direct {
            // x is the reassembled raw [B, H, W, C] input
            let (batch, geom) = self.geom(&x);
            let rows = geom.rows(batch);
            for j in 0..k {
                let dzj = &dz.data()[j * rows * c_out..(j + 1) * rows * c_out];
                let dwj_dst = &mut dw.data_mut()[j * plen * c_out..(j + 1) * plen * c_out];
                dwst.merge(conv::conv2d_dw_direct_q(
                    x.data(),
                    dzj,
                    dwj_dst,
                    batch,
                    &geom,
                    epi_dw.with_base((j * plen * c_out) as u64),
                ));
                let dbj = ops::sum_rows_sl(dzj, rows, c_out);
                db.data_mut()[j * c_out..(j + 1) * c_out].copy_from_slice(&dbj);
            }
        } else {
            // x is the reassembled [rows, plen] patch matrix
            let rows = x.shape()[0];
            for j in 0..k {
                let dzj = &dz.data()[j * rows * c_out..(j + 1) * rows * c_out];
                let dwj_dst = &mut dw.data_mut()[j * plen * c_out..(j + 1) * plen * c_out];
                if q.fused {
                    dwst.merge(ops::matmul_tn_sl_qd_into_threads(
                        x.data(),
                        dzj,
                        dwj_dst,
                        rows,
                        plen,
                        c_out,
                        epi_dw.with_base((j * plen * c_out) as u64),
                        ops::plan_threads_capped(2 * rows * plen * c_out, plen, 0),
                        q.int_domain,
                        Some(&self.tally_dw),
                    ));
                } else {
                    let dwj = ops::matmul_tn_sl(x.data(), dzj, rows, plen, c_out);
                    dwj_dst.copy_from_slice(&dwj);
                }
                let dbj = ops::sum_rows_sl(dzj, rows, c_out);
                db.data_mut()[j * c_out..(j + 1) * c_out].copy_from_slice(&dbj);
            }
            if !q.fused {
                dwst = epi_dw.run(dw.data_mut(), 0);
            }
        }
        q.record(self.group, KIND_DW, dwst);
        let dbst = epi_db.run(db.data_mut(), 0);
        q.record(self.group, KIND_DB, dbst);
        vec![dw, db]
    }

    fn sgd_update(
        &self,
        q: &mut GoldenQ,
        params: &mut [Tensor],
        vels: &mut [Tensor],
        grads: &[Tensor],
        hp: &UpdateHp,
    ) {
        // w [k, ksize²·C_in, C_out] has the maxout [k, I, U] layout, so
        // the shared rule (incl. the rank-3 max-norm) applies verbatim
        dense_sgd_update(q, self.group, params, vels, grads, hp);
        // the weights changed: the next integer-domain forward re-packs
        self.packs.lock().expect("conv pack cache poisoned").invalidate();
    }

    fn prepack(&self, ctrl: &ScaleController, params: &[Tensor]) {
        let w = &params[0];
        let (k, plen, c_out) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        self.packs.lock().expect("conv pack cache poisoned").ensure(
            weight_step_bits(ctrl, self.group),
            k,
            |j| int_gemm::pack(&w.data()[j * plen * c_out..(j + 1) * plen * c_out]),
        );
    }

    fn pack_builds(&self) -> u64 {
        self.packs.lock().expect("conv pack cache poisoned").builds()
    }

    fn plan_counts(&self) -> Vec<(&'static str, ops::GemmSiteCounts)> {
        vec![("z", self.tally_z.counts()), ("dw", self.tally_dw.counts())]
    }
}

// ---------------------------------------------------------------------------
// MaxPool2d
// ---------------------------------------------------------------------------

/// Non-overlapping spatial max pool (window = stride = `pool`, VALID:
/// trailing rows/cols that don't fill a window are dropped, like L2's
/// `reduce_window`), followed by the owning conv stage's `H`-group
/// quantization — the second half of the L2 conv stage's
/// `conv → Q_Z → max_k → pool → Q_H` sequence. Backward is pure
/// routing to the cached argmax positions; the routed gradient's DZ
/// quantization belongs to the conv layer below, so `dx_group` is
/// deliberately ignored. `pool = 1` degenerates to the bare `H` site.
pub struct MaxPool2d {
    pub pool: usize,
    /// The conv partner's row in the layer-major group table.
    pub group: usize,
}

impl Layer for MaxPool2d {
    fn describe(&self) -> String {
        format!("maxpool({})@l{}", self.pool, self.group)
    }

    fn group_row(&self) -> Option<usize> {
        Some(self.group)
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        let Shape::Spatial { h, w, c } = *in_shape else {
            crate::bail!("{}: needs a spatial input, got {in_shape}", self.describe());
        };
        crate::ensure!(self.pool >= 1, "{}: pool must be >= 1", self.describe());
        let (ph, pw) = (h / self.pool, w / self.pool);
        crate::ensure!(
            ph >= 1 && pw >= 1,
            "{}: pooling a {h}x{w} map below one pixel",
            self.describe()
        );
        Ok(Shape::Spatial { h: ph, w: pw, c })
    }

    fn forward(
        &self,
        q: &mut GoldenQ,
        _params: &[Tensor],
        x: Tensor,
        sh: &ShardCtx,
        _scratch: &mut LayerScratch,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let s = x.shape();
        assert_eq!(s.len(), 4, "{}: input must be [B, H, W, C]", self.describe());
        let (batch, h, w, c) = (s[0], s[1], s[2], s[3]);
        let p = self.pool;
        let (ph, pw) = (h / p, w / p);
        let mut out = Tensor::zeros(&[batch, ph, pw, c]);
        let mut idx = vec![0u32; batch * ph * pw * c];
        for b in 0..batch {
            for oy in 0..ph {
                for ox in 0..pw {
                    for ch in 0..c {
                        let (mut best, mut bsrc) = (f32::NEG_INFINITY, 0u32);
                        for ky in 0..p {
                            for kx in 0..p {
                                let src =
                                    ((b * h + oy * p + ky) * w + ox * p + kx) * c + ch;
                                let v = x.data()[src];
                                if v > best {
                                    best = v;
                                    bsrc = src as u32;
                                }
                            }
                        }
                        let o = ((b * ph + oy) * pw + ox) * c + ch;
                        out.data_mut()[o] = best;
                        idx[o] = bsrc;
                    }
                }
            }
        }
        q.apply_at(&mut out, self.group, KIND_H, true, (sh.start * ph * pw * c) as u64);
        (out, Cache::Pool { in_shape: s.to_vec(), idx })
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: Cache,
        dy: Tensor,
        _dx_group: Option<usize>,
        _sh: &ShardCtx,
        _scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Pool { in_shape, idx } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        // scatter to the winning positions; windows never overlap, so
        // each input cell receives at most one contribution
        let mut dx = Tensor::zeros(&in_shape);
        for (i, &src) in idx.iter().enumerate() {
            dx.data_mut()[src as usize] += dy.data()[i];
        }
        (None, Some(dx))
    }
}

// ---------------------------------------------------------------------------
// Flatten
// ---------------------------------------------------------------------------

/// Shape adapter between the spatial stages and the dense head:
/// `[B, H, W, C] → [B, H·W·C]` (row-major, so the bytes don't move).
/// No parameters, no quantization sites; backward restores the spatial
/// shape.
pub struct Flatten;

impl Layer for Flatten {
    fn describe(&self) -> String {
        "flatten".into()
    }

    fn group_row(&self) -> Option<usize> {
        None
    }

    fn out_shape(&self, in_shape: &Shape) -> crate::Result<Shape> {
        Ok(in_shape.flattened())
    }

    fn forward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        x: Tensor,
        _sh: &ShardCtx,
        _scratch: &mut LayerScratch,
        _drop: &mut DropCtx,
    ) -> (Tensor, Cache) {
        let in_shape = x.shape().to_vec();
        let (b, d) = (in_shape[0], in_shape[1..].iter().product::<usize>());
        (x.reshape(&[b, d]), Cache::Flat { in_shape })
    }

    fn backward(
        &self,
        _q: &mut GoldenQ,
        _params: &[Tensor],
        cache: Cache,
        dy: Tensor,
        _dx_group: Option<usize>,
        _sh: &ShardCtx,
        _scratch: &mut LayerScratch,
    ) -> (Option<Deferred>, Option<Tensor>) {
        let Cache::Flat { in_shape } = cache else {
            unreachable!("{}: wrong cache variant", self.describe())
        };
        (None, Some(dy.reshape(&in_shape)))
    }
}

// ---------------------------------------------------------------------------
// Network
// ---------------------------------------------------------------------------

/// Contiguous `(start, rows)` batch slices for `n` workers: the first
/// `batch % n` shards take one extra row, so uneven tails stay
/// deterministic and order-preserving.
fn shard_ranges(batch: usize, n: usize) -> Vec<(usize, usize)> {
    let (base, extra) = (batch / n, batch % n);
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let rows = base + usize::from(i < extra);
        out.push((start, rows));
        start += rows;
    }
    out
}

/// Copy one shard's rows out of a batch-major tensor (any rank).
fn shard_rows(x: &Tensor, start: usize, rows: usize) -> Tensor {
    let per: usize = x.shape()[1..].iter().product();
    let mut dims = x.shape().to_vec();
    dims[0] = rows;
    Tensor::from_vec(&dims, x.data()[start * per..(start + rows) * per].to_vec())
}

/// Reassemble one layer's shard [`Deferred`]s (in shard order) into the
/// full-batch dw/db operands: `x` concatenates batch-major; `dz`
/// interleaves per maxout slab, each shard block landing at its serial
/// position `(slab · full + shard_start) · width`. Returns worker 0's
/// captured epilogues — every worker drew the identical site, so any
/// worker's copy is THE serial epilogue.
fn assemble_deferred(mut parts: Vec<Deferred>) -> (Tensor, Tensor, QuantEpilogue, QuantEpilogue) {
    let (slabs, width) = (parts[0].slabs, parts[0].width);
    if parts.len() == 1 {
        let d = parts.pop().expect("one part");
        let rows = d.dz.len() / (slabs * width);
        return (d.x, d.dz.reshape(&[slabs, rows, width]), d.epi_dw, d.epi_db);
    }
    let (epi_dw, epi_db) = (parts[0].epi_dw, parts[0].epi_db);
    let full: usize = parts.iter().map(|d| d.dz.len() / (slabs * width)).sum();

    let mut x_dims = parts[0].x.shape().to_vec();
    x_dims[0] = parts.iter().map(|d| d.x.shape()[0]).sum();
    let mut xd = Vec::with_capacity(x_dims.iter().product());
    for d in &parts {
        xd.extend_from_slice(d.x.data());
    }
    let x = Tensor::from_vec(&x_dims, xd);

    let mut dz = Tensor::zeros(&[slabs, full, width]);
    let mut start = 0;
    for d in &parts {
        let rows = d.dz.len() / (slabs * width);
        for j in 0..slabs {
            let src = &d.dz.data()[j * rows * width..(j + 1) * rows * width];
            let at = (j * full + start) * width;
            dz.data_mut()[at..at + rows * width].copy_from_slice(src);
        }
        start += rows;
    }
    (x, dz, epi_dw, epi_db)
}

/// One data-parallel worker's results, handed back to the driver.
struct WorkerOut {
    /// The shard's `log_softmax` rows (the driver sums the f64 loss
    /// centrally, in shard order — the serial association).
    logp: Tensor,
    /// Per layer position: the deferred dw/db work (`None` for
    /// parameterless layers).
    deferred: Vec<Option<Deferred>>,
    stats: Vec<QuantStats>,
    site: u64,
    scratch: NetScratch,
}

/// A maxout network assembled from [`Layer`]s, driving one train/eval
/// step over the manifest-ordered flat parameter vector. Built from a
/// [`TopologySpec`] (+ the dataset's signal [`Shape`]) or, for the
/// legacy call sites, from an [`MlpShape`]. Holds shared state only
/// (`Sync`): per-pass buffers live in pooled [`NetScratch`]es.
pub struct Network {
    layers: Vec<Box<dyn Layer>>,
    /// Per layer: (offset, count) into the flat manifest-order params.
    param_ranges: Vec<(usize, usize)>,
    n_group_rows: usize,
    /// The signal shape the network consumes (dataset-derived).
    in_shape: Shape,
    n_classes: usize,
    /// Reusable per-worker scratch: checked out per pass, returned
    /// after, grown lazily to the high-water worker count.
    scratch_pool: Mutex<Vec<NetScratch>>,
}

impl Network {
    /// Realize a topology against a data source's signal shape. The
    /// layer sequence generalizes the monolithic step: input dropout;
    /// per conv stage a maxout-conv + max-pool + hidden dropout; a
    /// flatten when any conv stage exists; per hidden width a maxout
    /// dense + hidden dropout; then the head. The whole shape contract
    /// is chained through [`Layer::out_shape`] here, so topology/dataset
    /// mismatches fail at construction with the offending layer named.
    pub fn from_topology_shaped(
        spec: &TopologySpec,
        in_shape: Shape,
        n_classes: usize,
    ) -> crate::Result<Network> {
        // hard invariant, not a debug check: a spec that skipped
        // validate() must not silently build a head-only linear model
        assert!(
            !(spec.conv.is_empty() && spec.hidden.is_empty()),
            "topology needs >= 1 conv stage or hidden layer"
        );
        let mut layers: Vec<Box<dyn Layer>> =
            Vec::with_capacity(3 * spec.conv.len() + 2 * spec.hidden.len() + 3);
        layers.push(Box::new(DropoutLayer::input()));
        let mut row = 0;
        for cs in &spec.conv {
            layers.push(Box::new(MaxoutConv2d::new(cs.channels, spec.k, cs.ksize, row)));
            layers.push(Box::new(MaxPool2d { pool: cs.pool, group: row }));
            layers.push(Box::new(DropoutLayer::hidden()));
            row += 1;
        }
        if !spec.conv.is_empty() {
            layers.push(Box::new(Flatten));
        }
        for &units in &spec.hidden {
            layers.push(Box::new(MaxoutDense::new(units, spec.k, row)));
            row += 1;
            layers.push(Box::new(DropoutLayer::hidden()));
        }
        layers.push(Box::new(SoftmaxHead::new(n_classes, row)));
        row += 1;

        // chain the shape contract through the graph; a failure names
        // the layer and the shape it choked on
        let mut shape = in_shape;
        for l in &layers {
            shape = l.out_shape(&shape).map_err(|e| {
                crate::err!("topology '{}' does not fit input {in_shape}: {e}", spec.name)
            })?;
        }
        debug_assert_eq!(shape, Shape::Flat(n_classes));

        let mut param_ranges = Vec::with_capacity(layers.len());
        let mut offset = 0;
        for l in &layers {
            param_ranges.push((offset, l.n_params()));
            offset += l.n_params();
        }
        Ok(Network {
            layers,
            param_ranges,
            n_group_rows: row,
            in_shape,
            n_classes,
            scratch_pool: Mutex::new(Vec::new()),
        })
    }

    /// Realize an MLP topology against a flat input width (the legacy
    /// entry point; conv stages need [`Network::from_topology_shaped`]).
    pub fn from_topology(spec: &TopologySpec, d_in: usize, n_classes: usize) -> Network {
        assert!(
            spec.conv.is_empty(),
            "topology '{}' has conv stages: realize it with from_topology_shaped",
            spec.name
        );
        Network::from_topology_shaped(spec, Shape::Flat(d_in), n_classes)
            .expect("MLP topologies realize against any flat input")
    }

    /// The 2-hidden-layer network an [`MlpShape`] describes (the legacy
    /// golden entry points drive this).
    pub fn from_mlp_shape(s: MlpShape) -> Network {
        let spec = TopologySpec::mlp(vec![s.units, s.units], s.k);
        Network::from_topology(&spec, s.d_in, s.n_classes)
    }

    /// Scaling-factor group count derived from the graph: one row of
    /// `N_KINDS` kinds per compute layer. This is the number
    /// [`ScaleController::fixed`]/[`ScaleController::dynamic`] take.
    pub fn n_groups(&self) -> usize {
        self.n_group_rows * N_KINDS
    }

    /// Number of compute layers (= group rows): hidden + head.
    pub fn n_compute_layers(&self) -> usize {
        self.n_group_rows
    }

    /// Flat input width the network consumes.
    pub fn d_in(&self) -> usize {
        self.in_shape.len()
    }

    /// The dataset-derived signal shape the network consumes.
    pub fn in_shape(&self) -> Shape {
        self.in_shape
    }

    /// Per-example input dims (`[d]` or `[h, w, c]`) — what a batch
    /// tensor carries after its leading batch axis.
    pub fn input_dims(&self) -> Vec<usize> {
        self.in_shape.dims()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Total parameter tensors (manifest order: w0 b0 w1 b1 ...).
    pub fn n_params(&self) -> usize {
        self.param_ranges.last().map(|&(o, n)| o + n).unwrap_or(0)
    }

    /// One-line graph description for diagnostics.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.layers.iter().map(|l| l.describe()).collect();
        parts.join(" -> ")
    }

    /// Group row of the closest compute layer strictly below `pos`
    /// (`None` when `pos` is the bottom compute layer).
    fn group_row_below(&self, pos: usize) -> Option<usize> {
        self.layers[..pos].iter().rev().find_map(|l| l.group_row())
    }

    fn take_scratch(&self) -> NetScratch {
        self.scratch_pool
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_else(|| NetScratch::new(self.layers.len()))
    }

    fn return_scratch(&self, s: NetScratch) {
        self.scratch_pool.lock().expect("scratch pool poisoned").push(s);
    }

    /// Pre-draw every dropout mask for the full batch in forward graph
    /// order — the exact draw sequence the serial step used to make
    /// inline, so sharding cannot perturb the mask stream.
    fn predraw_masks(&self, d: &mut Dropout, batch: usize) -> Vec<Option<Vec<f32>>> {
        let mut masks = Vec::new();
        let mut shape = self.in_shape;
        for l in &self.layers {
            if let Some(role) = l.dropout_role() {
                let rate = match role {
                    DropoutRole::Input => d.input_rate,
                    DropoutRole::Hidden => d.hidden_rate,
                };
                masks.push(dropout_mask(&mut d.rng, batch * shape.len(), rate));
            }
            shape = l.out_shape(&shape).expect("shape contract validated at construction");
        }
        masks
    }

    /// One worker's forward + backward routing over its shard: returns
    /// the shard's `log_softmax` rows and the per-layer deferred dw/db
    /// work. The serial step IS this function over the full batch —
    /// one code path, any worker count.
    fn run_shard(
        &self,
        q: &mut GoldenQ,
        params: &Params,
        x: Tensor,
        y: &Tensor,
        sh: &ShardCtx,
        scratch: &mut NetScratch,
        masks: Option<&[Option<Vec<f32>>]>,
    ) -> (Tensor, Vec<Option<Deferred>>) {
        let classes = self.n_classes;
        let mut dctx = DropCtx::train(masks);

        // ---- forward ----
        let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
        let mut h = x;
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, cache) =
                layer.forward(q, &params[o..o + n], h, sh, &mut scratch.layers[li], &mut dctx);
            caches.push(cache);
            h = out;
        }
        let logp = ops::log_softmax(&h);

        // ---- backward ----
        // loss gradient dz = (p - y)/B over the shard's rows, divided by
        // the FULL batch; the f64 loss is summed centrally by the driver
        let mut dz = Tensor::zeros(&[sh.rows, classes]);
        for (i, v) in dz.data_mut().iter_mut().enumerate() {
            *v = (logp.data()[i].exp() - y.data()[sh.start * classes + i]) / sh.full as f32;
        }
        let mut deferred: Vec<Option<Deferred>> = Vec::with_capacity(self.layers.len());
        deferred.resize_with(self.layers.len(), || None);
        let mut dy = dz;
        for pos in (0..self.layers.len()).rev() {
            let layer = &self.layers[pos];
            let (o, n) = self.param_ranges[pos];
            let cache = caches.pop().expect("one cache per layer");
            if layer.group_row().is_some() {
                let dx_group = self.group_row_below(pos);
                let (d, dx) = layer.backward(
                    q,
                    &params[o..o + n],
                    cache,
                    dy,
                    dx_group,
                    sh,
                    &mut scratch.layers[pos],
                );
                deferred[pos] = d;
                match dx {
                    Some(d) => dy = d,
                    // bottom compute layer: nothing below consumes dx
                    None => break,
                }
            } else {
                let (d, dx) =
                    layer.backward(q, &[], cache, dy, None, sh, &mut scratch.layers[pos]);
                debug_assert!(d.is_none());
                dy = dx.expect("stateless layers pass their gradient through");
            }
        }
        (logp, deferred)
    }

    /// One full train step over the graph. Bit-identical to the
    /// monolithic reference on the builtin topology (see module docs)
    /// and bit-identical at any `opts.dp_workers` (`tests/dp_parity.rs`);
    /// mutates params/vels in place.
    pub fn train_step(
        &self,
        params: &mut Params,
        vels: &mut Params,
        x: &Tensor,
        y: &Tensor,
        lr: f32,
        mom: f32,
        max_norm: f32,
        ctrl: &ScaleController,
        mut opts: StepOptions,
    ) -> GoldenOut {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
        q.fused = opts.fused;
        q.conv_direct = opts.conv_direct;
        q.int_domain = opts.int_domain;
        if opts.mode == RoundMode::Stochastic {
            // true stochastic rounding draws one uniform sample per
            // element from counter-based per-site streams (index-keyed,
            // so fused/two-pass paths AND batch shards sample identically)
            q.stochastic_seed = Some(STOCHASTIC_SITE_SEED);
        }
        let batch = x.shape()[0];
        let classes = self.n_classes;
        let masks = opts.dropout.as_mut().map(|d| self.predraw_masks(d, batch));
        let masks_ref = masks.as_deref();

        let n = opts.dp_workers.max(1).min(batch);
        let ranges = shard_ranges(batch, n);
        let mut outs: Vec<WorkerOut> = if n == 1 {
            // serial = the degenerate 1-shard schedule, same code path
            let sh = ShardCtx::serial(batch);
            let mut wq = q.fork();
            let mut scratch = self.take_scratch();
            let (logp, deferred) =
                self.run_shard(&mut wq, params, x.clone(), y, &sh, &mut scratch, masks_ref);
            let (stats, site) = wq.into_parts();
            vec![WorkerOut { logp, deferred, stats, site, scratch }]
        } else {
            // split the process thread budget so N workers' GEMMs don't
            // oversubscribe N-fold (thread count never changes bits)
            let cap = (ops::max_threads() / n).max(1);
            let jobs: Vec<(ShardCtx, Tensor, NetScratch, GoldenQ)> = ranges
                .iter()
                .map(|&(start, rows)| {
                    (
                        ShardCtx { start, rows, full: batch, threads: cap },
                        shard_rows(x, start, rows),
                        self.take_scratch(),
                        q.fork(),
                    )
                })
                .collect();
            let net = &*self;
            let params_ro: &Params = &*params;
            std::thread::scope(|s| {
                let handles: Vec<_> = jobs
                    .into_iter()
                    .map(|(sh, xs, mut scratch, mut wq)| {
                        s.spawn(move || {
                            let (logp, deferred) = net.run_shard(
                                &mut wq, params_ro, xs, y, &sh, &mut scratch, masks_ref,
                            );
                            let (stats, site) = wq.into_parts();
                            WorkerOut { logp, deferred, stats, site, scratch }
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("dp worker panicked")).collect()
            })
        };

        // ---- loss: ONE running f64 accumulator over the shards' logp
        // rows in shard (= serial row) order — the serial association
        let mut loss = 0.0f64;
        for (out, &(start, _)) in outs.iter().zip(&ranges) {
            for (i, &lp) in out.logp.data().iter().enumerate() {
                loss -= (y.data()[start * classes + i] * lp) as f64;
            }
        }
        let loss = (loss / batch as f64) as f32;

        // ---- stats: fixed binary-tree merge, then adopt the shared
        // end-site so the update sweeps number exactly as in serial
        let site = outs[0].site;
        debug_assert!(
            outs.iter().all(|o| o.site == site),
            "dp workers must draw identical site sequences"
        );
        q.adopt(
            merge_stats_tree(outs.iter_mut().map(|o| mem::take(&mut o.stats)).collect()),
            site,
        );

        // ---- central dw/db: reassemble full-batch operands per layer,
        // run the captured epilogues once — cross-shard f32 sums happen
        // inside single kernel calls, so bits match serial at any N
        let mut grads: Vec<Vec<Tensor>> = Vec::with_capacity(self.layers.len());
        grads.resize_with(self.layers.len(), Vec::new);
        for pos in (0..self.layers.len()).rev() {
            let parts: Vec<Deferred> =
                outs.iter_mut().filter_map(|o| o.deferred[pos].take()).collect();
            if parts.is_empty() {
                continue;
            }
            debug_assert_eq!(parts.len(), outs.len(), "every worker defers the same layers");
            let (xf, dzf, epi_dw, epi_db) = assemble_deferred(parts);
            let (off, np) = self.param_ranges[pos];
            grads[pos] = self.layers[pos].reduce_grads(
                &mut q,
                &params[off..off + np],
                xf,
                dzf,
                epi_dw,
                epi_db,
            );
        }

        // ---- SGD + momentum + max-norm + storage quantization ----
        // (bottom-up = manifest parameter order, matching the monolith)
        let hp = UpdateHp { lr, mom, max_norm };
        for (pos, layer) in self.layers.iter().enumerate() {
            let (off, np) = self.param_ranges[pos];
            if np == 0 {
                continue;
            }
            layer.sgd_update(
                &mut q,
                &mut params[off..off + np],
                &mut vels[off..off + np],
                &grads[pos],
                &hp,
            );
        }

        for o in outs {
            self.return_scratch(o.scratch);
        }
        GoldenOut { loss, overflow: q.stats_matrix() }
    }

    /// Pre-pack every weight layer's integer-GEMM operands against the
    /// controller's adopted scales. Serve workers call this once at
    /// startup so steady-state requests never re-pack static weights;
    /// training never needs it (forward builds lazily). Idempotent: a
    /// second call with the same params + scales is a cache hit.
    pub fn prepack_int_operands(&self, params: &Params, ctrl: &ScaleController) {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            layer.prepack(ctrl, &params[o..o + n]);
        }
    }

    /// Total packed-cache rebuild events across the graph's weight
    /// layers since construction. This is the pollution-free counter
    /// the cache-invalidation tests assert on: one build per weight
    /// layer per train step at ANY worker count (the Arc-sharing cache
    /// serves every worker from the first build), exactly one per
    /// layer for a serve worker's lifetime — never one per GEMM. (The
    /// process-global [`int_gemm::pack_calls`] counter is only
    /// meaningful as a delta in single-threaded benches.)
    pub fn weight_pack_builds(&self) -> u64 {
        self.layers.iter().map(|l| l.pack_builds()).sum()
    }

    /// Per-site GEMM lowering-outcome counters across the graph, keyed
    /// `"<layer describe>.<site>"` (e.g. `"maxout(10x2)@l0.dw"`) in a
    /// stable map. Counts accumulate over the network's lifetime; the
    /// trainer snapshots them once at the end of a run for the report's
    /// `int_gemm_sites` section. Empty when no GEMM ever dispatched
    /// (e.g. conv-direct reference runs).
    pub fn int_gemm_sites(&self) -> std::collections::BTreeMap<String, ops::GemmSiteCounts> {
        let mut out = std::collections::BTreeMap::new();
        for layer in &self.layers {
            for (site, counts) in layer.plan_counts() {
                if !counts.is_empty() {
                    out.insert(format!("{}.{site}", layer.describe()), counts);
                }
            }
        }
        out
    }

    /// Forward-only logits `[B, C]` (no dropout, no mutation),
    /// quantizing forward signals exactly as the train step does. Kernel
    /// selection (`fused`, `conv_direct`, `int_domain`) comes from the
    /// process-wide env defaults; callers that need explicit control
    /// (the serving path) use [`Network::eval_logits_opt`].
    pub fn eval_logits(
        &self,
        params: &Params,
        x: &Tensor,
        ctrl: &ScaleController,
        mode: RoundMode,
        half: bool,
    ) -> Tensor {
        self.eval_logits_opt(
            params,
            x,
            ctrl,
            &StepOptions { mode, half, ..Default::default() },
        )
    }

    /// [`Network::eval_logits`] with explicit [`StepOptions`]: the
    /// serving path honors a checkpoint-independent `int_domain` /
    /// `fused` choice per request batch instead of whatever the env
    /// said at process start. `opts.dropout` is ignored — eval never
    /// drops.
    pub fn eval_logits_opt(
        &self,
        params: &Params,
        x: &Tensor,
        ctrl: &ScaleController,
        opts: &StepOptions,
    ) -> Tensor {
        assert_eq!(
            ctrl.n_groups(),
            self.n_groups(),
            "scale controller group count must be Network::n_groups()"
        );
        assert_eq!(params.len(), self.n_params(), "params/topology mismatch");
        let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
        q.fused = opts.fused;
        q.conv_direct = opts.conv_direct;
        q.int_domain = opts.int_domain;
        let sh = ShardCtx::serial(x.shape()[0]);
        let mut scratch = self.take_scratch();
        let mut dctx = DropCtx::eval();
        let mut h = x.clone();
        for (li, layer) in self.layers.iter().enumerate() {
            let (o, n) = self.param_ranges[li];
            let (out, _) = layer.forward(
                &mut q,
                &params[o..o + n],
                h,
                &sh,
                &mut scratch.layers[li],
                &mut dctx,
            );
            h = out;
        }
        self.return_scratch(scratch);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::FixedFormat;
    use crate::runtime::manifest::group_index;
    use crate::runtime::ModelInfo;
    use crate::tensor::Pcg32;

    fn spec3() -> TopologySpec {
        TopologySpec::mlp(vec![10, 8, 6], 2)
    }

    /// Params + vels realized from the ModelInfo the same spec produces.
    fn state(spec: &TopologySpec, d_in: usize, n_classes: usize, seed: u64) -> (Params, Params) {
        let info = ModelInfo::from_topology(spec, d_in, n_classes);
        let mut rng = Pcg32::seeded(seed);
        let params: Vec<Tensor> =
            info.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
        let vels = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        (params, vels)
    }

    #[test]
    fn graph_derives_group_table_from_topology() {
        let net = Network::from_topology(&spec3(), 12, 4);
        assert_eq!(net.n_compute_layers(), 4);
        assert_eq!(net.n_groups(), 4 * N_KINDS);
        assert_eq!(net.n_params(), 8);
        assert_eq!(net.d_in(), 12);
        assert_eq!(net.n_classes(), 4);
        let desc = net.describe();
        assert!(desc.starts_with("dropout(input) -> maxout(10x2)@l0"), "{desc}");
        assert!(desc.ends_with("softmax(4)@l3"), "{desc}");
        // the shape contract chains the input to the class count
        let mut shape = net.in_shape();
        for l in &net.layers {
            shape = l.out_shape(&shape).unwrap();
        }
        assert_eq!(shape, Shape::Flat(net.n_classes()));
    }

    /// The shared tiny conv fixture (2 conv stages + 1 dense + head over
    /// 8×8×2 inputs) — `tests/conv_parity.rs` trains the same spec.
    fn conv_spec() -> TopologySpec {
        crate::testing::tiny_conv_spec()
    }

    #[test]
    fn conv_topology_chains_shapes_and_derives_groups() {
        let in_shape = Shape::Spatial { h: 8, w: 8, c: 2 };
        let net = Network::from_topology_shaped(&conv_spec(), in_shape, 4).unwrap();
        // 2 conv stages + 1 dense + head = 4 group rows; pool layers
        // share their conv partner's row
        assert_eq!(net.n_compute_layers(), 4);
        assert_eq!(net.n_groups(), 4 * N_KINDS);
        assert_eq!(net.n_params(), 8);
        assert_eq!(net.d_in(), 128);
        assert_eq!(net.input_dims(), vec![8, 8, 2]);
        let desc = net.describe();
        assert!(desc.contains("maxconv(3x2k3)@l0 -> maxpool(2)@l0"), "{desc}");
        assert!(desc.contains("maxpool(2)@l1 -> dropout(hidden) -> flatten"), "{desc}");
        // 8x8 -> 4x4 -> 2x2, so the dense stage consumes 2*2*4 = 16
        let mut shape = in_shape;
        for l in &net.layers {
            shape = l.out_shape(&shape).unwrap();
        }
        assert_eq!(shape, Shape::Flat(4));
    }

    #[test]
    fn conv_realization_rejects_shape_mismatches() {
        // conv stage over a flat dataset
        let err = Network::from_topology_shaped(&conv_spec(), Shape::Flat(128), 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("spatial"), "{err:#}");
        // pooled below one pixel
        let deep = TopologySpec::conv_net(
            vec![crate::config::ConvStageSpec { channels: 2, ksize: 3, pool: 4 }; 3],
            vec![],
            2,
        );
        let err = Network::from_topology_shaped(&deep, Shape::Spatial { h: 8, w: 8, c: 1 }, 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("below one pixel"), "{err:#}");
    }

    #[test]
    fn conv_topology_trains_and_counts_per_stage_overflow() {
        let spec = conv_spec();
        let in_shape = Shape::Spatial { h: 8, w: 8, c: 2 };
        let net = Network::from_topology_shaped(&spec, in_shape, 4).unwrap();
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (mut params, mut vels) = crate::testing::topology_state(&spec, in_shape, 4, 3);
        let n = 6;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(
            &[n, 8, 8, 2],
            (0..n * 128).map(|_| rng.normal()).collect(),
        );
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let out = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            2.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[4 * N_KINDS, 3]);
        // stage 0: z over k filters at full 8x8 resolution, h after the
        // 2x2 pool; stage 1 runs at 4x4
        assert_eq!(out.overflow.at2(group_index(0, KIND_Z), 2), (2 * n * 64 * 3) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_H), 2), (n * 16 * 3) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_Z), 2), (2 * n * 16 * 4) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_H), 2), (n * 4 * 4) as f32);
        // the dense stage's DH comes from the head, the last conv
        // stage's DH from the dense layer (post-flatten), and stage 0's
        // DH from stage 1 at stage-0's pooled resolution
        assert_eq!(out.overflow.at2(group_index(2, KIND_DH), 2), (n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_DH), 2), (n * 16) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_DH), 2), (n * 16 * 3) as f32);
    }

    #[test]
    fn max_pool_routes_gradient_to_argmax() {
        let pool = MaxPool2d { pool: 2, group: 0 };
        let ctrl = ScaleController::fixed(8, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let mut q = GoldenQ::new(&ctrl, RoundMode::HalfAway);
        let x = Tensor::from_vec(
            &[1, 2, 2, 1],
            vec![1.0, 5.0, 2.0, 3.0], // window max is the 5 at (0, 1)
        );
        let sh = ShardCtx::serial(1);
        let mut scratch = LayerScratch::default();
        let mut drop = DropCtx::eval();
        let (h, cache) = pool.forward(&mut q, &[], x, &sh, &mut scratch, &mut drop);
        assert_eq!(h.shape(), &[1, 1, 1, 1]);
        assert_eq!(h.data(), &[5.0]);
        let dy = Tensor::from_vec(&[1, 1, 1, 1], vec![7.0]);
        let (d, dx) = pool.backward(&mut q, &[], cache, dy, Some(0), &sh, &mut scratch);
        assert!(d.is_none());
        assert_eq!(dx.unwrap().data(), &[0.0, 7.0, 0.0, 0.0]);
    }

    #[test]
    fn shard_ranges_cover_uneven_batches() {
        // the first batch % n shards absorb the remainder, one row each
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(shard_ranges(4, 4), vec![(0, 1), (1, 1), (2, 1), (3, 1)]);
        assert_eq!(shard_ranges(5, 1), vec![(0, 5)]);
        // ranges tile the batch exactly
        for (batch, n) in [(10, 4), (7, 3), (16, 5)] {
            let r = shard_ranges(batch, n);
            assert_eq!(r.len(), n);
            let mut at = 0;
            for &(start, rows) in &r {
                assert_eq!(start, at);
                at += rows;
            }
            assert_eq!(at, batch);
        }
    }

    #[test]
    fn dp_train_step_matches_serial_bits() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (p0, v0) = state(&spec, 12, 4, 3);
        let n = 10; // uneven over 3 workers: shards of 4, 3, 3
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let run = |workers: usize| {
            let (mut params, mut vels) = (p0.clone(), v0.clone());
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.1,
                0.5,
                2.0,
                &ctrl,
                StepOptions { dp_workers: workers, ..Default::default() },
            );
            (out, params, vels)
        };
        let (o1, p1, vv1) = run(1);
        let (o3, p3, vv3) = run(3);
        assert_eq!(o1.loss.to_bits(), o3.loss.to_bits());
        assert_eq!(o1.overflow.data(), o3.overflow.data());
        for (a, b) in p1.iter().zip(&p3).chain(vv1.iter().zip(&vv3)) {
            let ab: Vec<u32> = a.data().iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn deep_topology_trains_and_counts_per_layer_overflow() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(10, 3),
            FixedFormat::new(12, 0),
        );
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let n = 16;
        let mut rng = Pcg32::seeded(9);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let out = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            2.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!(out.loss.is_finite());
        assert_eq!(out.overflow.shape(), &[4 * N_KINDS, 3]);
        // per-layer totals reflect each layer's own width
        assert_eq!(out.overflow.at2(group_index(0, KIND_Z), 2), (2 * n * 10) as f32);
        assert_eq!(out.overflow.at2(group_index(1, KIND_Z), 2), (2 * n * 8) as f32);
        assert_eq!(out.overflow.at2(group_index(2, KIND_Z), 2), (2 * n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_Z), 2), (n * 4) as f32);
        assert_eq!(out.overflow.at2(group_index(3, KIND_DZ), 2), (n * 4) as f32);
        // DH flows into every layer below the head
        assert_eq!(out.overflow.at2(group_index(2, KIND_DH), 2), (n * 6) as f32);
        assert_eq!(out.overflow.at2(group_index(0, KIND_DH), 2), (n * 10) as f32);
    }

    #[test]
    fn deep_topology_loss_decreases() {
        let spec = TopologySpec::mlp(vec![16, 16, 16], 2);
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl =
            ScaleController::fixed(net.n_groups(), FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 5);
        let n = 16;
        let mut rng = Pcg32::seeded(6);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        let (mut first, mut last) = (None, 0.0);
        for _ in 0..40 {
            let out = net.train_step(
                &mut params,
                &mut vels,
                &x,
                &y,
                0.2,
                0.5,
                0.0,
                &ctrl,
                StepOptions::default(),
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        let first = first.expect("at least one training step ran, so the first loss is set");
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    #[should_panic(expected = "Network::n_groups")]
    fn wrong_controller_size_is_rejected() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        // sized for 3 compute layers, but the graph has 4
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (mut params, mut vels) = state(&spec, 12, 4, 3);
        let x = Tensor::zeros(&[2, 12]);
        let y = ops::one_hot(&[0, 1], 4);
        let _ = net.train_step(
            &mut params,
            &mut vels,
            &x,
            &y,
            0.1,
            0.5,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
    }

    #[test]
    fn eval_matches_zero_lr_forward_on_deep_net() {
        let spec = spec3();
        let net = Network::from_topology(&spec, 12, 4);
        let ctrl = ScaleController::fixed(
            net.n_groups(),
            FixedFormat::new(12, 3),
            FixedFormat::new(12, 0),
        );
        let (params, _) = state(&spec, 12, 4, 8);
        let n = 8;
        let mut rng = Pcg32::seeded(4);
        let x = Tensor::from_vec(&[n, 12], (0..n * 12).map(|_| rng.normal()).collect());
        let labels: Vec<usize> = (0..n).map(|_| rng.below(4) as usize).collect();
        let y = ops::one_hot(&labels, 4);
        // quantize storage as the trainer does at init
        let mut pq = params.clone();
        for (i, p) in pq.iter_mut().enumerate() {
            let g = group_index(i / 2, if i % 2 == 0 { KIND_W } else { KIND_B });
            crate::arith::Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
        }
        let logits = net.eval_logits(&pq, &x, &ctrl, RoundMode::HalfAway, false);
        let logp = ops::log_softmax(&logits);
        let mut want = 0.0f64;
        for i in 0..n * 4 {
            want -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let want = (want / n as f64) as f32;
        let (mut p2, mut v2) = (pq.clone(), state(&spec, 12, 4, 8).1);
        let out = net.train_step(
            &mut p2,
            &mut v2,
            &x,
            &y,
            0.0,
            0.0,
            0.0,
            &ctrl,
            StepOptions::default(),
        );
        assert!((out.loss - want).abs() < 1e-5, "{want} vs {}", out.loss);
    }
}
