//! Conv lowering: im2col patch extraction plus direct nested-loop
//! reference kernels for the quantized maxout-conv stages.
//!
//! The graph's [`MaxoutConv2d`](super::MaxoutConv2d) layer lowers every
//! convolution onto the existing fused quantize-aware GEMM kernels
//! ([`crate::tensor::ops::matmul_sl_qd_into`] & co., so eligible conv
//! GEMMs also ride the integer-domain lowering under
//! `StepOptions::int_domain` / `LPDNN_INT_GEMM=1` — bit-identically,
//! see `tests/int_gemm_parity.rs`): [`im2col_into`]
//! materializes the SAME-padded stride-1 patch matrix
//! `[B·H·W, ksize²·C_in]` once per step (into a per-layer scratch buffer
//! reused across steps), and each maxout filter's `[patch_len, C_out]`
//! weight slab rides one GEMM with the Z/DW quantization fused into the
//! tile epilogues — so every conv multiply passes through exactly the
//! same low-precision machinery as the dense layers. Under the integer
//! domain the weight slabs additionally come from the layer's
//! [`PackedCache`](crate::tensor::int_gemm::PackedCache) (packed once
//! per update/scale-move, or once per serve worker at prepack); the
//! patch matrix is input data and re-packs every call.
//!
//! **The bit-identity invariant.** The direct kernels here
//! ([`conv2d_direct_q`], [`conv2d_dw_direct_q`]) are nested-loop
//! references that accumulate each output element in the *same order*
//! as the im2col-lowered GEMMs (ascending `(kh, kw, c_in)` for the
//! forward product, ascending patch-row for the weight gradient) and
//! skip zero inputs exactly where the blocked kernels do (`aik == 0.0`
//! fast-path — which is also how the GEMM treats the padding zeros the
//! patch matrix materializes). Both paths therefore produce **exact
//! `u32`-identical outputs and identical [`QuantStats`]** for every
//! arithmetic, every rounding mode and any thread count —
//! `tests/conv_parity.rs` enforces it, and `bench_perf`'s `conv train
//! step` rows track the im2col speedup against this reference.

use crate::arith::{QuantEpilogue, QuantStats};

/// Geometry of one SAME-padded, stride-1 conv stage (odd `ksize`).
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c_in: usize,
    /// Output channels per maxout filter.
    pub c_out: usize,
    /// Square kernel side (odd; SAME padding is `ksize / 2`).
    pub ksize: usize,
}

impl ConvGeom {
    /// SAME padding on each side.
    pub fn pad(&self) -> usize {
        self.ksize / 2
    }

    /// Flattened patch length `ksize² · c_in` (the GEMM's k dimension).
    pub fn patch_len(&self) -> usize {
        self.ksize * self.ksize * self.c_in
    }

    /// Patch-matrix rows for a batch: one per output pixel.
    pub fn rows(&self, batch: usize) -> usize {
        batch * self.h * self.w
    }
}

/// Materialize the SAME-padded stride-1 patch matrix: row
/// `(b·H + y)·W + x` holds the receptive field of output pixel
/// `(b, y, x)` in ascending `(kh, kw, c_in)` order, with out-of-bounds
/// taps written as literal zeros. `x` is `[B, H, W, C_in]` row-major;
/// `out` must be `rows(batch) · patch_len()` long and is fully
/// overwritten.
pub fn im2col_into(x: &[f32], batch: usize, g: &ConvGeom, out: &mut [f32]) {
    let (h, w, c_in, ks) = (g.h, g.w, g.c_in, g.ksize);
    let pad = g.pad();
    let plen = g.patch_len();
    assert_eq!(x.len(), batch * h * w * c_in, "im2col input size");
    assert_eq!(out.len(), g.rows(batch) * plen, "im2col output size");
    for b in 0..batch {
        for y in 0..h {
            for xx in 0..w {
                let row = ((b * h + y) * w + xx) * plen;
                for kh in 0..ks {
                    let sy = (y + kh) as isize - pad as isize;
                    for kw in 0..ks {
                        let sx = (xx + kw) as isize - pad as isize;
                        let dst = &mut out
                            [row + (kh * ks + kw) * c_in..row + (kh * ks + kw + 1) * c_in];
                        if sy < 0 || sy >= h as isize || sx < 0 || sx >= w as isize {
                            dst.fill(0.0);
                        } else {
                            let src = ((b * h + sy as usize) * w + sx as usize) * c_in;
                            dst.copy_from_slice(&x[src..src + c_in]);
                        }
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col_into`]: scatter-accumulate a patch-space gradient
/// `[B·H·W, patch_len]` back onto the input image gradient
/// `[B, H, W, C_in]` (added onto `dx`). Gather-formulated — each `dx`
/// element sums its `(kh, kw)` taps in ascending order — so the result
/// is deterministic and independent of any tiling.
pub fn col2im_add(dpatch: &[f32], batch: usize, g: &ConvGeom, dx: &mut [f32]) {
    let (h, w, c_in, ks) = (g.h, g.w, g.c_in, g.ksize);
    let pad = g.pad();
    let plen = g.patch_len();
    assert_eq!(dpatch.len(), g.rows(batch) * plen, "col2im patch size");
    assert_eq!(dx.len(), batch * h * w * c_in, "col2im output size");
    for b in 0..batch {
        for u in 0..h {
            for v in 0..w {
                let dst = &mut dx[((b * h + u) * w + v) * c_in..((b * h + u) * w + v + 1) * c_in];
                for kh in 0..ks {
                    // the output pixel whose tap (kh, kw) reads (u, v)
                    let y = (u + pad) as isize - kh as isize;
                    if y < 0 || y >= h as isize {
                        continue;
                    }
                    for kw in 0..ks {
                        let xx = (v + pad) as isize - kw as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        let row = ((b * h + y as usize) * w + xx as usize) * plen
                            + (kh * ks + kw) * c_in;
                        for (o, &p) in dst.iter_mut().zip(&dpatch[row..row + c_in]) {
                            *o += p;
                        }
                    }
                }
            }
        }
    }
}

/// Run a quantization epilogue over a conv output tile exactly as the
/// fused GEMM kernels do: bias-then-quantize via the shared
/// [`QuantEpilogue::run_biased`] — the same single implementation the
/// GEMM tile epilogues and the split-accumulator runners use, so the
/// direct reference can never drift from the fused paths.
fn tile_epilogue(
    dst: &mut [f32],
    c_out: usize,
    bias: Option<&[f32]>,
    epi: QuantEpilogue,
) -> QuantStats {
    epi.run_biased(dst, c_out, bias, 0)
}

/// Direct nested-loop reference for one filter's forward conv:
/// `dst[(b,y,x), o] += Σ_{kh,kw,ci} x[b, y+kh-pad, x+kw-pad, ci] ·
/// w[(kh,kw,ci), o]`, then bias add + quantization epilogue over the
/// whole tile. Accumulation visits `(kh, kw, ci)` ascending and skips
/// zero input taps — the exact element order (and zero fast-path) of
/// the im2col-lowered GEMM, so the two are bit-identical. `dst` is
/// accumulated onto (pass zeros for a plain product).
pub fn conv2d_direct_q(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    dst: &mut [f32],
    batch: usize,
    g: &ConvGeom,
    epi: QuantEpilogue,
) -> QuantStats {
    let (h, ww, c_in, c_out, ks) = (g.h, g.w, g.c_in, g.c_out, g.ksize);
    let pad = g.pad();
    assert_eq!(x.len(), batch * h * ww * c_in, "conv2d input size");
    assert_eq!(w.len(), g.patch_len() * c_out, "conv2d weight size");
    assert_eq!(dst.len(), g.rows(batch) * c_out, "conv2d output size");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), c_out, "conv2d bias size");
    }
    for b in 0..batch {
        for y in 0..h {
            for xx in 0..ww {
                let orow = &mut dst[((b * h + y) * ww + xx) * c_out
                    ..((b * h + y) * ww + xx + 1) * c_out];
                for kh in 0..ks {
                    let sy = (y + kh) as isize - pad as isize;
                    if sy < 0 || sy >= h as isize {
                        continue; // padding taps are zero: the GEMM skips them too
                    }
                    for kw in 0..ks {
                        let sx = (xx + kw) as isize - pad as isize;
                        if sx < 0 || sx >= ww as isize {
                            continue;
                        }
                        let src = ((b * h + sy as usize) * ww + sx as usize) * c_in;
                        for (ci, &v) in x[src..src + c_in].iter().enumerate() {
                            if v == 0.0 {
                                continue; // matches the blocked kernels' zero fast-path
                            }
                            let wrow = &w[((kh * ks + kw) * c_in + ci) * c_out
                                ..((kh * ks + kw) * c_in + ci + 1) * c_out];
                            for (o, &wv) in orow.iter_mut().zip(wrow) {
                                *o += v * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    tile_epilogue(dst, c_out, bias, epi)
}

/// Direct nested-loop reference for one filter's weight gradient:
/// `dst[(kh,kw,ci), o] += Σ_rows patch[row, (kh,kw,ci)] · dz[row, o]`
/// without materializing the patch matrix, then the quantization
/// epilogue over the tile. Accumulates over patch rows ascending with
/// the zero fast-path — the element order of `matmul_tn_sl_q` on the
/// im2col matrix, so the two are bit-identical.
pub fn conv2d_dw_direct_q(
    x: &[f32],
    dz: &[f32],
    dst: &mut [f32],
    batch: usize,
    g: &ConvGeom,
    epi: QuantEpilogue,
) -> QuantStats {
    let (h, w, c_in, c_out, ks) = (g.h, g.w, g.c_in, g.c_out, g.ksize);
    let pad = g.pad();
    assert_eq!(x.len(), batch * h * w * c_in, "conv2d_dw input size");
    assert_eq!(dz.len(), g.rows(batch) * c_out, "conv2d_dw dz size");
    assert_eq!(dst.len(), g.patch_len() * c_out, "conv2d_dw output size");
    for b in 0..batch {
        for y in 0..h {
            for xx in 0..w {
                let dzrow = &dz[((b * h + y) * w + xx) * c_out
                    ..((b * h + y) * w + xx + 1) * c_out];
                for kh in 0..ks {
                    let sy = (y + kh) as isize - pad as isize;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for kw in 0..ks {
                        let sx = (xx + kw) as isize - pad as isize;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let src = ((b * h + sy as usize) * w + sx as usize) * c_in;
                        for (ci, &v) in x[src..src + c_in].iter().enumerate() {
                            if v == 0.0 {
                                continue;
                            }
                            let orow = &mut dst[((kh * ks + kw) * c_in + ci) * c_out
                                ..((kh * ks + kw) * c_in + ci + 1) * c_out];
                            for (o, &gv) in orow.iter_mut().zip(dzrow) {
                                *o += v * gv;
                            }
                        }
                    }
                }
            }
        }
    }
    tile_epilogue(dst, c_out, None, epi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::Quantizer;
    use crate::tensor::{ops, Pcg32};

    fn geom() -> ConvGeom {
        ConvGeom { h: 5, w: 4, c_in: 2, c_out: 3, ksize: 3 }
    }

    /// Random image with ~15% exact zeros so the zero fast-paths fire.
    fn image(g: &ConvGeom, batch: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..batch * g.h * g.w * g.c_in)
            .map(|_| {
                if rng.uniform() < 0.15 {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn im2col_extracts_padded_patches() {
        // 2x2 single-channel image, 3x3 kernel: the (0,0) patch is the
        // image's top-left neighborhood with a zero border.
        let g = ConvGeom { h: 2, w: 2, c_in: 1, c_out: 1, ksize: 3 };
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut patches = vec![f32::NAN; g.rows(1) * g.patch_len()];
        im2col_into(&x, 1, &g, &mut patches);
        // output pixel (0,0): rows (kh,kw) over [-1..1]^2
        assert_eq!(
            &patches[..9],
            &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]
        );
        // output pixel (1,1): centered on value 4
        assert_eq!(
            &patches[27..36],
            &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn direct_conv_matches_im2col_gemm_bitwise() {
        let g = geom();
        let batch = 3;
        let x = image(&g, batch, 1);
        let mut rng = Pcg32::seeded(2);
        let w: Vec<f32> = (0..g.patch_len() * g.c_out).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..g.c_out).map(|_| rng.normal()).collect();
        let epi = QuantEpilogue::new(Quantizer::float32());

        let mut direct = vec![0.0f32; g.rows(batch) * g.c_out];
        let st_d = conv2d_direct_q(&x, &w, Some(&bias), &mut direct, batch, &g, epi);

        let mut patches = vec![0.0f32; g.rows(batch) * g.patch_len()];
        im2col_into(&x, batch, &g, &mut patches);
        let mut lowered = vec![0.0f32; g.rows(batch) * g.c_out];
        let st_g = ops::matmul_sl_q_into(
            &patches,
            &w,
            Some(&bias),
            &mut lowered,
            g.rows(batch),
            g.patch_len(),
            g.c_out,
            epi,
        );
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&direct), bits(&lowered));
        assert_eq!(st_d, st_g);
    }

    #[test]
    fn direct_dw_matches_patch_gemm_bitwise() {
        let g = geom();
        let batch = 3;
        let x = image(&g, batch, 3);
        let mut rng = Pcg32::seeded(4);
        let dz: Vec<f32> = (0..g.rows(batch) * g.c_out).map(|_| rng.normal()).collect();
        let epi = QuantEpilogue::new(Quantizer::float32());

        let mut direct = vec![0.0f32; g.patch_len() * g.c_out];
        let st_d = conv2d_dw_direct_q(&x, &dz, &mut direct, batch, &g, epi);

        let mut patches = vec![0.0f32; g.rows(batch) * g.patch_len()];
        im2col_into(&x, batch, &g, &mut patches);
        let mut lowered = vec![0.0f32; g.patch_len() * g.c_out];
        let st_g = ops::matmul_tn_sl_q_into(
            &patches,
            &dz,
            &mut lowered,
            g.rows(batch),
            g.patch_len(),
            g.c_out,
            epi,
        );
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&direct), bits(&lowered));
        assert_eq!(st_d, st_g);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // Small-integer values keep every f32 sum exact, so the adjoint
        // identity <im2col(x), p> == <x, col2im(p)> holds bit-for-bit.
        let g = ConvGeom { h: 3, w: 3, c_in: 2, c_out: 1, ksize: 3 };
        let batch = 2;
        let mut rng = Pcg32::seeded(5);
        let x: Vec<f32> = (0..batch * g.h * g.w * g.c_in)
            .map(|_| rng.below(7) as f32 - 3.0)
            .collect();
        let p: Vec<f32> = (0..g.rows(batch) * g.patch_len())
            .map(|_| rng.below(7) as f32 - 3.0)
            .collect();
        let mut patches = vec![0.0f32; p.len()];
        im2col_into(&x, batch, &g, &mut patches);
        let lhs: f64 = patches.iter().zip(&p).map(|(&a, &b)| (a * b) as f64).sum();
        let mut dx = vec![0.0f32; x.len()];
        col2im_add(&p, batch, &g, &mut dx);
        let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| (a * b) as f64).sum();
        assert_eq!(lhs, rhs);
    }
}
