//! The pre-refactor **monolithic** pi_mlp train step, kept verbatim as
//! the bit-identity reference for the layer-graph executor.
//!
//! This module is the hand-inlined 2-hidden-layer maxout forward /
//! backward / update that `golden::train_step_opt` used to *be* before
//! the step became a thin driver over [`super::Network`]. It exists for
//! two consumers:
//!
//! * `tests/graph_parity.rs` asserts that the graph-built `pi_mlp`
//!   reproduces this step **bit-for-bit** — exact `u32` loss/parameter/
//!   velocity bits and exact overflow counters — across all four
//!   arithmetics, all four rounding modes, fused and two-pass kernels,
//!   and with dropout on.
//! * `bench_perf`'s `graph train step` rows measure the layer-graph
//!   dispatch overhead against this monolith.
//!
//! Do not "improve" this code: its value is that it does not change.
//! New functionality goes in [`super::graph`].

use crate::arith::{QuantStats, RoundMode};
use crate::coordinator::ScaleController;
use crate::runtime::manifest::{
    KIND_B, KIND_DB, KIND_DH, KIND_DW, KIND_DZ, KIND_H, KIND_W, KIND_Z,
};
use crate::tensor::{ops, Tensor};

use super::{
    apply_mask, dropout_mask, GoldenOut, GoldenQ, MlpShape, Params, StepOptions,
    STOCHASTIC_SITE_SEED,
};

/// Forward through one maxout dense layer: per-filter z = x@w_j + b_j,
/// quantized (Z group), then h = max_j, quantized (H group).
/// Returns (h, argmax filter per [B,U]).
fn maxout_fwd(
    q: &mut GoldenQ,
    layer: usize,
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
) -> (Tensor, Vec<u8>) {
    let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    let batch = x.shape()[0];
    assert_eq!(x.shape()[1], d_in);

    // z for every filter, quantized as ONE logical site. Fused: each
    // filter's [B, U] tile gets bias + quantization in its GEMM epilogue
    // (base = the filter's offset in the [k, B, U] tensor). Two-pass:
    // materialize all k tiles, then sweep the whole tensor. Identical
    // per-element index stream → identical bits and counters.
    let mut zq = Tensor::zeros(&[k, batch, units]);
    let epi = q.epilogue(layer, KIND_Z);
    let mut zst = QuantStats::default();
    for j in 0..k {
        let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
        let brow = &b.data()[j * units..(j + 1) * units];
        let dst = &mut zq.data_mut()[j * batch * units..(j + 1) * batch * units];
        if q.fused {
            zst.merge(ops::matmul_sl_q_into(
                x.data(),
                wj,
                Some(brow),
                dst,
                batch,
                d_in,
                units,
                epi.with_base((j * batch * units) as u64),
            ));
        } else {
            let zj = ops::matmul_sl(x.data(), wj, batch, d_in, units);
            for r in 0..batch {
                for u in 0..units {
                    dst[r * units + u] = zj[r * units + u] + brow[u];
                }
            }
        }
    }
    if !q.fused {
        zst = epi.run(zq.data_mut(), 0);
    }
    q.record(layer, KIND_Z, zst);

    let mut h = Tensor::zeros(&[batch, units]);
    let mut amax = vec![0u8; batch * units];
    for r in 0..batch {
        for u in 0..units {
            let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
            for j in 0..k {
                let v = zq.at3(j, r, u);
                if v > best {
                    best = v;
                    bj = j as u8;
                }
            }
            h.data_mut()[r * units + u] = best;
            amax[r * units + u] = bj;
        }
    }
    q.apply(&mut h, layer, KIND_H, true);
    (h, amax)
}

/// One full monolithic train step with explicit [`StepOptions`] (the
/// pre-refactor `golden::train_step_opt`). Mutates params/vels in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step_opt(
    shape: MlpShape,
    params: &mut Params,
    vels: &mut Params,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    mom: f32,
    max_norm: f32,
    ctrl: &ScaleController,
    mut opts: StepOptions,
) -> GoldenOut {
    let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
    q.fused = opts.fused;
    if opts.mode == RoundMode::Stochastic {
        // true stochastic rounding draws one uniform sample per element
        // from counter-based per-site streams (index-keyed, so the fused
        // and two-pass paths sample identically)
        q.stochastic_seed = Some(STOCHASTIC_SITE_SEED);
    }
    let batch = x.shape()[0];
    let (k, units, classes) = (shape.k, shape.units, shape.n_classes);

    // ---- input dropout (native path) ----
    let x_masked;
    let x: &Tensor = match opts.dropout.as_mut() {
        Some(d) => match dropout_mask(&mut d.rng, x.len(), d.input_rate) {
            Some(m) => {
                let mut xm = x.clone();
                apply_mask(&mut xm, &Some(m));
                x_masked = xm;
                &x_masked
            }
            None => x,
        },
        None => x,
    };

    // ---- forward ----
    let (mut h0, amax0) = maxout_fwd(&mut q, 0, x, &params[0], &params[1]);
    let m0 = opts
        .dropout
        .as_mut()
        .and_then(|d| dropout_mask(&mut d.rng, h0.len(), d.hidden_rate));
    apply_mask(&mut h0, &m0);
    let (mut h1, amax1) = maxout_fwd(&mut q, 1, &h0, &params[2], &params[3]);
    let m1 = opts
        .dropout
        .as_mut()
        .and_then(|d| dropout_mask(&mut d.rng, h1.len(), d.hidden_rate));
    apply_mask(&mut h1, &m1);
    let epi = q.epilogue(2, KIND_Z);
    let z2 = if q.fused {
        let (v, st) = ops::matmul_sl_q(
            h1.data(),
            params[4].data(),
            Some(params[5].data()),
            batch,
            units,
            classes,
            epi,
        );
        q.record(2, KIND_Z, st);
        Tensor::from_vec(&[batch, classes], v)
    } else {
        let mut z2 = ops::matmul(&h1, &params[4]);
        for r in 0..batch {
            for c in 0..classes {
                z2.data_mut()[r * classes + c] += params[5].data()[c];
            }
        }
        let st = epi.run(z2.data_mut(), 0);
        q.record(2, KIND_Z, st);
        z2
    };
    let logp = ops::log_softmax(&z2);
    let mut loss = 0.0f64;
    for i in 0..batch * classes {
        loss -= (y.data()[i] * logp.data()[i]) as f64;
    }
    let loss = (loss / batch as f64) as f32;

    // ---- backward ----
    // softmax head: dz = (p - y)/B, quantized
    let mut dz2 = Tensor::zeros(&[batch, classes]);
    for i in 0..batch * classes {
        dz2.data_mut()[i] = (logp.data()[i].exp() - y.data()[i]) / batch as f32;
    }
    q.apply(&mut dz2, 2, KIND_DZ, true);
    let epi = q.epilogue(2, KIND_DW);
    let dw2 = if q.fused {
        let (v, st) = ops::matmul_tn_sl_q(h1.data(), dz2.data(), batch, units, classes, epi);
        q.record(2, KIND_DW, st);
        Tensor::from_vec(&[units, classes], v)
    } else {
        let mut dw2 = ops::matmul_tn(&h1, &dz2);
        let st = epi.run(dw2.data_mut(), 0);
        q.record(2, KIND_DW, st);
        dw2
    };
    let mut db2 = ops::sum_rows(&dz2);
    q.apply(&mut db2, 2, KIND_DB, true);
    let epi = q.epilogue(1, KIND_DH);
    let mut dh1 = if q.fused {
        let (v, st) =
            ops::matmul_nt_sl_q(dz2.data(), params[4].data(), batch, classes, units, epi);
        q.record(1, KIND_DH, st);
        Tensor::from_vec(&[batch, units], v)
    } else {
        let mut dh1 = ops::matmul_nt(&dz2, &params[4]);
        let st = epi.run(dh1.data_mut(), 0);
        q.record(1, KIND_DH, st);
        dh1
    };
    apply_mask(&mut dh1, &m1);

    let (dw1, db1, mut dh0) =
        maxout_bwd(&mut q, 1, &h0, &params[2], &dh1, &amax1, k, units, true);
    q.apply(&mut dh0, 0, KIND_DH, true);
    apply_mask(&mut dh0, &m0);
    let (dw0, db0, _) = maxout_bwd(&mut q, 0, x, &params[0], &dh0, &amax0, k, units, false);

    // ---- SGD + momentum + max-norm + storage quantization ----
    let grads = [dw0, db0, dw1, db1, dw2, db2];
    for (i, g) in grads.iter().enumerate() {
        let layer = i / 2;
        let kind = if i % 2 == 0 { KIND_W } else { KIND_B };
        // v' = Q_up(mom*v - lr*g), stats NOT recorded (matches L2)
        for (vv, gv) in vels[i].data_mut().iter_mut().zip(g.data()) {
            *vv = mom * *vv - lr * gv;
        }
        q.apply(&mut vels[i], layer, kind, false);
        // p' = Q_up(maxnorm(p + v'))
        for (pv, vv) in params[i].data_mut().iter_mut().zip(vels[i].data()) {
            *pv += vv;
        }
        if kind == KIND_W {
            ops::max_norm_inplace(&mut params[i], max_norm);
        }
        q.apply(&mut params[i], layer, kind, true);
    }

    GoldenOut { loss, overflow: q.stats_matrix() }
}

/// Forward-only logits `[B, C]` for evaluation (no dropout, no mutation),
/// quantizing forward signals exactly as the monolithic train step does.
pub fn eval_logits(
    shape: MlpShape,
    params: &Params,
    x: &Tensor,
    ctrl: &ScaleController,
    mode: RoundMode,
    half: bool,
) -> Tensor {
    let batch = x.shape()[0];
    let classes = shape.n_classes;
    let mut q = GoldenQ::with_half(ctrl, mode, half);
    let (h0, _) = maxout_fwd(&mut q, 0, x, &params[0], &params[1]);
    let (h1, _) = maxout_fwd(&mut q, 1, &h0, &params[2], &params[3]);
    let epi = q.epilogue(2, KIND_Z);
    if q.fused {
        let (v, _st) = ops::matmul_sl_q(
            h1.data(),
            params[4].data(),
            Some(params[5].data()),
            batch,
            shape.units,
            classes,
            epi,
        );
        Tensor::from_vec(&[batch, classes], v)
    } else {
        let mut z2 = ops::matmul(&h1, &params[4]);
        for r in 0..batch {
            for c in 0..classes {
                z2.data_mut()[r * classes + c] += params[5].data()[c];
            }
        }
        let _ = epi.run(z2.data_mut(), 0);
        z2
    }
}

/// Backward through a maxout dense layer: route dh to the winning filter,
/// quantize dz/dw/db; optionally produce dx (pre-quantization — the caller
/// quantizes it as the lower layer's DH group, matching L2's ordering).
#[allow(clippy::too_many_arguments)]
fn maxout_bwd(
    q: &mut GoldenQ,
    layer: usize,
    x: &Tensor,
    w: &Tensor,
    dh: &Tensor,
    amax: &[u8],
    k: usize,
    _units: usize,
    need_dx: bool,
) -> (Tensor, Tensor, Tensor) {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let units = dh.shape()[1];

    let mut dz = Tensor::zeros(&[k, batch, units]);
    for r in 0..batch {
        for u in 0..units {
            let j = amax[r * units + u] as usize;
            dz.data_mut()[(j * batch + r) * units + u] = dh.at2(r, u);
        }
    }
    q.apply(&mut dz, layer, KIND_DZ, true);

    // dw for every filter, quantized as ONE logical site (like the z
    // tiles in the forward pass). The dx contraction is NOT fused: its
    // per-filter products are summed across filters before the caller
    // quantizes the total as the lower layer's DH group.
    let mut dw = Tensor::zeros(&[k, d_in, units]);
    let mut db = Tensor::zeros(&[k, units]);
    let mut dx = Tensor::zeros(&[batch, d_in]);
    let epi = q.epilogue(layer, KIND_DW);
    let mut dwst = QuantStats::default();
    for j in 0..k {
        // contiguous [batch, units] view of this filter's dz
        let dzj = &dz.data()[j * batch * units..(j + 1) * batch * units];
        let dwj_dst = &mut dw.data_mut()[j * d_in * units..(j + 1) * d_in * units];
        if q.fused {
            dwst.merge(ops::matmul_tn_sl_q_into(
                x.data(),
                dzj,
                dwj_dst,
                batch,
                d_in,
                units,
                epi.with_base((j * d_in * units) as u64),
            ));
        } else {
            let dwj = ops::matmul_tn_sl(x.data(), dzj, batch, d_in, units);
            dwj_dst.copy_from_slice(&dwj);
        }
        let dbj = ops::sum_rows_sl(dzj, batch, units);
        db.data_mut()[j * units..(j + 1) * units].copy_from_slice(&dbj);
        if need_dx {
            let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
            let dxj = ops::matmul_nt_sl(dzj, wj, batch, units, d_in);
            for (a, &b) in dx.data_mut().iter_mut().zip(&dxj) {
                *a += b;
            }
        }
    }
    if !q.fused {
        dwst = epi.run(dw.data_mut(), 0);
    }
    q.record(layer, KIND_DW, dwst);
    q.apply(&mut db, layer, KIND_DB, true);
    (dw, db, dx)
}
