//! Golden model: the pure-Rust training engine, now organized as a
//! composable **layer graph**.
//!
//! Same signals, same quantization hooks, same update rule as
//! `python/compile/model.py`, implemented over the host [`Tensor`] ops
//! and [`crate::arith::Quantizer`]. It serves three roles:
//!
//! 1. *Cross-validate the AOT bridge*: an integration test (behind the
//!    `pjrt` feature) trains both paths from identical state and asserts
//!    losses, updated parameters and overflow counters agree within
//!    float32 reassociation tolerance.
//! 2. *Reference for rounding ablations*: the ablation bench drives
//!    alternative [`RoundMode`]s (the compiled artifact pins half-away).
//! 3. *The native training engine*: [`crate::runtime::NativeBackend`]
//!    drives a [`Network`] built from the experiment's
//!    [`TopologySpec`](crate::config::TopologySpec) — see DESIGN.md
//!    §Backends and §Layer graph.
//!
//! The module is split in four:
//!
//! * **this file** — the shared quantization context ([`GoldenQ`]: per
//!   group quantizers, stat accumulation, site numbering), the step
//!   option types, and thin compatibility drivers
//!   ([`train_step_opt`]/[`eval_logits`]) that run the 2-hidden-layer
//!   [`MlpShape`] topology through the graph;
//! * [`graph`] — the [`Layer`] trait ([`MaxoutDense`], [`SoftmaxHead`],
//!   [`MaxoutConv2d`], [`MaxPool2d`], [`Flatten`], [`DropoutLayer`])
//!   and the [`Network`] executor: topology as data, signals threaded
//!   as shape-aware tensors, scaling groups derived from the graph;
//! * [`conv`] — the conv lowering: im2col patch extraction (so every
//!   conv multiply rides the fused quantized GEMM epilogues) plus the
//!   bit-identical direct nested-loop reference kernels
//!   (`tests/conv_parity.rs`);
//! * [`reference`] — the pre-refactor monolithic pi_mlp step, frozen as
//!   the bit-identity reference (`tests/graph_parity.rs` proves the
//!   graph reproduces it exactly; `bench_perf` tracks graph overhead
//!   against it).
//!
//! The hot contractions run on the blocked/parallel slice kernels in
//! [`crate::tensor::ops`], with the Z, DW and DX group quantizations
//! fused into the GEMM epilogues ([`StepOptions::fused`], env
//! `LPDNN_FUSED=0` for the bit-identical two-pass reference path — see
//! `tests/fused_parity.rs`, DESIGN.md §Fused quantized GEMM).
//!
//! The compiled artifact's in-graph hash-PRNG dropout is a device detail
//! and is not mirrored bit-for-bit; the native path implements standard
//! inverted dropout from the host [`Pcg32`] stream instead
//! ([`StepOptions::dropout`]). Cross-checks against the device run with
//! dropout disabled.

pub mod conv;
pub mod graph;
pub mod reference;

pub use graph::{
    Cache, Deferred, DropCtx, DropoutLayer, DropoutRole, Flatten, Layer, LayerScratch, MaxPool2d,
    MaxoutConv2d, MaxoutDense, NetScratch, Network, ShardCtx, SoftmaxHead, UpdateHp,
};

use std::sync::OnceLock;

use crate::arith::{ElemRng, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use crate::coordinator::ScaleController;
use crate::runtime::manifest::group_index;
use crate::tensor::{Pcg32, Tensor};

/// Base seed of the counter-based stochastic-rounding streams every
/// train step under [`RoundMode::Stochastic`] forks its per-site
/// [`ElemRng`]s from. A fixed constant (not derived from the experiment
/// seed) so that rounding noise is a property of the *site*, never of
/// the run — listed alongside [`RNG_FORK_INIT`] and co. in the trainer's
/// RNG-stream table (`coordinator::trainer`).
///
/// [`RNG_FORK_INIT`]: crate::coordinator::RNG_FORK_INIT
pub const STOCHASTIC_SITE_SEED: u64 = 0x57CC_4A57;

/// Default for [`StepOptions::fused`]: the fused quantized-GEMM kernels
/// are on unless `LPDNN_FUSED=0` (which forces the two-pass reference
/// path — an A/B hook for `bench_perf` and debugging; results are
/// bit-identical either way).
pub fn fused_default() -> bool {
    static FUSED: OnceLock<bool> = OnceLock::new();
    *FUSED.get_or_init(|| std::env::var("LPDNN_FUSED").map(|v| v != "0").unwrap_or(true))
}

/// Default for [`StepOptions::int_domain`]: the integer-domain GEMM
/// lowering (`tensor::int_gemm` + the `*_qd` dispatch) engages when
/// `LPDNN_INT_GEMM` is set to anything but `0`. Off by default — the
/// simulated path is the reference; the integer path is bit-identical
/// wherever eligible (`tests/int_gemm_parity.rs`) and falls back to
/// simulated where not, so flipping this switch never changes results.
/// Only fused sites dispatch (with `LPDNN_FUSED=0` the two-pass
/// reference path runs and `LPDNN_INT_GEMM` is ignored). Weight
/// operands are packed through per-layer caches rather than per call:
/// a [`Network`]'s weight slabs re-pack only after an update or scale
/// move ([`graph`] module docs, DESIGN.md §Integer-domain GEMM).
pub fn int_gemm_default() -> bool {
    static INT_GEMM: OnceLock<bool> = OnceLock::new();
    *INT_GEMM.get_or_init(|| std::env::var("LPDNN_INT_GEMM").map(|v| v != "0").unwrap_or(false))
}

/// Default for [`StepOptions::dp_workers`]: `LPDNN_DP_WORKERS` when set
/// (clamped to at least 1), else 1 (serial). Data-parallel sharding is
/// bit-identical at any worker count (`tests/dp_parity.rs`), so this is
/// purely a throughput knob — see [`Network::train_step`] and DESIGN.md
/// §Data-parallel training.
pub fn dp_workers_default() -> usize {
    static DP: OnceLock<usize> = OnceLock::new();
    *DP.get_or_init(|| {
        std::env::var("LPDNN_DP_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(1)
            .max(1)
    })
}

/// 2-hidden-layer maxout MLP shape description — the legacy fixed-depth
/// entry points ([`train_step_opt`], [`reference`]) take it; the graph
/// subsystem generalizes it to [`crate::config::TopologySpec`].
#[derive(Clone, Copy, Debug)]
pub struct MlpShape {
    pub d_in: usize,
    pub units: usize,
    pub k: usize,
    pub n_classes: usize,
}

impl MlpShape {
    /// Shape for a maxout MLP over the named dataset: input/output
    /// dimensions come from the data source
    /// ([`crate::data::dataset_dims`]), not from hardcoded constants.
    pub fn for_dataset(dataset: &str, units: usize, k: usize) -> crate::Result<MlpShape> {
        let (d_in, n_classes) = crate::data::dataset_dims(dataset)?;
        Ok(MlpShape { d_in, units, k, n_classes })
    }
}

/// Parameters/velocities: w0 [k,I,U], b0 [k,U], w1 [k,U,U], b1 [k,U],
/// w2 [U,C], b2 [C] — manifest order.
pub type Params = Vec<Tensor>;

/// The golden train step's outputs.
#[derive(Debug)]
pub struct GoldenOut {
    pub loss: f32,
    /// `[n_groups, 3]` overflow matrix, same layout as the artifact's.
    pub overflow: Tensor,
}

/// Host-side inverted dropout for the native path (the compiled path does
/// dropout in-graph). Masks are drawn from `rng`, so a run replays
/// bit-identically given the experiment seed.
#[derive(Clone, Debug)]
pub struct Dropout {
    pub input_rate: f32,
    pub hidden_rate: f32,
    pub rng: Pcg32,
}

/// Per-step options for [`train_step_opt`] / [`Network::train_step`].
#[derive(Clone, Debug)]
pub struct StepOptions {
    /// Rounding mode for every quantization hook (canonical: half-away).
    pub mode: RoundMode,
    /// Simulate float16: round-trip every hooked signal through binary16
    /// instead of a fixed point grid (paper Table 1 / Table 3 rows).
    pub half: bool,
    /// Inverted dropout (native path only; `None` = off).
    pub dropout: Option<Dropout>,
    /// Quantize the Z/DW/DX groups inside the GEMM epilogues (fused
    /// kernels) instead of with a second whole-tensor sweep. Bit-identical
    /// either way; see [`fused_default`].
    pub fused: bool,
    /// Run conv stages through the direct nested-loop reference kernels
    /// instead of the im2col-lowered GEMMs. Bit-identical either way
    /// (`tests/conv_parity.rs`); a perf A/B hook for `bench_perf`'s
    /// `conv train step` rows.
    pub conv_direct: bool,
    /// Run eligible fused GEMM sites in the integer domain (i8/i16
    /// operands, i32 accumulators) instead of simulated f32. Bit-identical
    /// either way (`tests/int_gemm_parity.rs`); see [`int_gemm_default`].
    pub int_domain: bool,
    /// Data-parallel worker count: shard the batch across this many
    /// workers, each running forward/backward on its shard, with
    /// gradients reduced centrally and stats merged in a fixed tree
    /// order. Bit-identical to 1-worker at any count
    /// (`tests/dp_parity.rs`); see [`dp_workers_default`].
    pub dp_workers: usize,
}

impl Default for StepOptions {
    fn default() -> Self {
        StepOptions {
            mode: RoundMode::HalfAway,
            half: false,
            dropout: None,
            fused: fused_default(),
            conv_direct: false,
            int_domain: int_gemm_default(),
            dp_workers: dp_workers_default(),
        }
    }
}

/// One quantization context: per-group quantizers + stat accumulation.
///
/// Every quantization *site* (one logical tensor hooked as one group)
/// draws a [`QuantEpilogue`] via `epilogue`; GEMM-adjacent sites hand it
/// to the fused kernels, everything else runs it as a tensor sweep
/// (`apply`). Sites are numbered in call order so stochastic-rounding
/// streams never overlap between sites, while within a site samples are
/// keyed on the element's flat index — which is what keeps the fused
/// (tiled, threaded) and two-pass paths bit-identical. The graph layers
/// ([`graph`]) and the frozen monolith ([`reference`]) share this one
/// context type, so "same sites in the same order" is the whole parity
/// argument.
pub struct GoldenQ<'c> {
    ctrl: &'c ScaleController,
    pub mode: RoundMode,
    /// Float16 simulation: binary16 round-trip instead of the fixed grid.
    pub half: bool,
    /// Route GEMM-adjacent sites through the fused kernels (true) or the
    /// two-pass reference path (false). Same bits either way.
    pub fused: bool,
    /// Route conv stages through the direct nested-loop reference
    /// kernels instead of the im2col-lowered GEMMs. Same bits either way.
    pub conv_direct: bool,
    /// Run eligible fused GEMM sites in the integer domain. Same bits
    /// either way (only fused sites consult this).
    pub int_domain: bool,
    stats: Vec<QuantStats>,
    /// Base seed for the counter-based stochastic-rounding streams
    /// (`None` = deterministic midpoint sample, like `apply_slice`).
    pub stochastic_seed: Option<u64>,
    /// Quantization-site counter (advanced by `epilogue`).
    site: u64,
}

impl<'c> GoldenQ<'c> {
    pub fn new(ctrl: &'c ScaleController, mode: RoundMode) -> Self {
        Self::with_half(ctrl, mode, false)
    }

    pub fn with_half(ctrl: &'c ScaleController, mode: RoundMode, half: bool) -> Self {
        GoldenQ {
            ctrl,
            mode,
            half,
            fused: fused_default(),
            conv_direct: false,
            int_domain: int_gemm_default(),
            stats: vec![QuantStats::default(); ctrl.n_groups()],
            stochastic_seed: None,
            site: 0,
        }
    }

    fn quantizer(&self, g: usize) -> Quantizer {
        let mut q = Quantizer::from_format(self.ctrl.format(g));
        q.mode = self.mode;
        q
    }

    /// The epilogue for the next quantization site of group
    /// (layer, kind). Advances the site counter — fused and two-pass
    /// consumers of one logical site must share a single epilogue value.
    fn epilogue(&mut self, layer: usize, kind: usize) -> QuantEpilogue {
        let g = group_index(layer, kind);
        let mut epi = if self.half {
            // binary16 round-trip; only totals are counted (the scale
            // controller is static under float16, so over/half are unused).
            QuantEpilogue::half_sim()
        } else {
            QuantEpilogue::new(self.quantizer(g))
        };
        if let Some(seed) = self.stochastic_seed {
            epi = epi.with_rng(ElemRng::for_site(seed, self.site));
        }
        self.site += 1;
        epi
    }

    /// Fold one site's overflow counters into group (layer, kind).
    fn record(&mut self, layer: usize, kind: usize, st: QuantStats) {
        self.stats[group_index(layer, kind)].merge(st);
    }

    /// Two-pass tensor quantization for the non-GEMM sites (H, DZ, DB,
    /// storage, and the multi-filter DH accumulation).
    fn apply(&mut self, t: &mut Tensor, layer: usize, kind: usize, record: bool) {
        self.apply_at(t, layer, kind, record, 0);
    }

    /// Like `apply`, but quantizing a shard whose elements start at
    /// logical flat index `offset` of the full-batch tensor. Stochastic
    /// rounding streams are keyed on the full-batch index, so a shard
    /// sweep at its offset reproduces the serial whole-tensor sweep
    /// bit-for-bit (the tiling-invariance contract of
    /// [`crate::arith::QuantEpilogue`]).
    fn apply_at(&mut self, t: &mut Tensor, layer: usize, kind: usize, record: bool, offset: u64) {
        let epi = self.epilogue(layer, kind);
        let st = epi.run(t.data_mut(), offset);
        if record {
            self.record(layer, kind, st);
        }
    }

    /// A fresh context for a data-parallel worker: same controller,
    /// modes and site position, zeroed stat accumulators. Every worker
    /// replays the identical site sequence over its shard, so forked
    /// epilogues are bit-identical across workers; the driver folds the
    /// workers' stats back with [`merge_stats_tree`] + `adopt`.
    fn fork(&self) -> GoldenQ<'c> {
        GoldenQ {
            ctrl: self.ctrl,
            mode: self.mode,
            half: self.half,
            fused: self.fused,
            conv_direct: self.conv_direct,
            int_domain: self.int_domain,
            stats: vec![QuantStats::default(); self.stats.len()],
            stochastic_seed: self.stochastic_seed,
            site: self.site,
        }
    }

    /// Decompose a worker context into (per-group stats, end site) for
    /// the reduction step.
    fn into_parts(self) -> (Vec<QuantStats>, u64) {
        (self.stats, self.site)
    }

    /// Fold tree-merged worker stats into this context and fast-forward
    /// the site counter past the workers' shared site sequence, so the
    /// sites that follow (the update sweeps) number exactly as in the
    /// serial step.
    fn adopt(&mut self, merged: Vec<QuantStats>, site: u64) {
        debug_assert_eq!(merged.len(), self.stats.len());
        for (g, st) in self.stats.iter_mut().zip(merged) {
            g.merge(st);
        }
        self.site = site;
    }

    fn stats_matrix(&self) -> Tensor {
        let g = self.stats.len();
        let mut d = Vec::with_capacity(g * 3);
        for s in &self.stats {
            d.extend_from_slice(&[s.n_over as f32, s.n_half as f32, s.n_total as f32]);
        }
        Tensor::from_vec(&[g, 3], d)
    }
}

/// Draw an inverted-dropout mask (scale 1/(1-rate) on keep, 0 on drop).
fn dropout_mask(rng: &mut Pcg32, n: usize, rate: f32) -> Option<Vec<f32>> {
    if rate <= 0.0 {
        return None;
    }
    let scale = 1.0 / (1.0 - rate);
    Some((0..n).map(|_| if rng.uniform() < rate { 0.0 } else { scale }).collect())
}

fn apply_mask(t: &mut Tensor, mask: &Option<Vec<f32>>) {
    if let Some(m) = mask {
        for (v, &s) in t.data_mut().iter_mut().zip(m) {
            *v *= s;
        }
    }
}

/// Reduce per-worker stat vectors (one [`QuantStats`] per group each)
/// in a fixed binary-tree order: adjacent pairs merge level by level,
/// an odd tail carries up unmerged. The counters are u64 sums, so any
/// association yields the same totals (`tests/dp_parity.rs` asserts
/// flat ≡ tree); the tree order is still pinned as the reduction
/// contract so a future non-associative statistic cannot silently
/// depend on the worker count.
pub fn merge_stats_tree(mut levels: Vec<Vec<QuantStats>>) -> Vec<QuantStats> {
    assert!(!levels.is_empty(), "merge_stats_tree: no worker stats");
    while levels.len() > 1 {
        let mut next = Vec::with_capacity((levels.len() + 1) / 2);
        let mut it = levels.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (ga, gb) in a.iter_mut().zip(b) {
                    ga.merge(gb);
                }
            }
            next.push(a);
        }
        levels = next;
    }
    levels.pop().expect("merge tree always leaves one level")
}

/// One full golden train step with the canonical options (no dropout, no
/// float16). Mutates params/vels in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    shape: MlpShape,
    params: &mut Params,
    vels: &mut Params,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    mom: f32,
    max_norm: f32,
    ctrl: &ScaleController,
    mode: RoundMode,
) -> GoldenOut {
    train_step_opt(
        shape,
        params,
        vels,
        x,
        y,
        lr,
        mom,
        max_norm,
        ctrl,
        StepOptions { mode, ..Default::default() },
    )
}

/// One full train step with explicit [`StepOptions`]: a thin driver that
/// runs the 2-hidden-layer `shape` topology through the graph executor
/// ([`Network::train_step`]). Mutates params/vels in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step_opt(
    shape: MlpShape,
    params: &mut Params,
    vels: &mut Params,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    mom: f32,
    max_norm: f32,
    ctrl: &ScaleController,
    opts: StepOptions,
) -> GoldenOut {
    Network::from_mlp_shape(shape)
        .train_step(params, vels, x, y, lr, mom, max_norm, ctrl, opts)
}

/// Forward-only logits `[B, C]` for evaluation (no dropout, no mutation),
/// quantizing forward signals exactly as the train step does — a thin
/// driver over [`Network::eval_logits`].
pub fn eval_logits(
    shape: MlpShape,
    params: &Params,
    x: &Tensor,
    ctrl: &ScaleController,
    mode: RoundMode,
    half: bool,
) -> Tensor {
    Network::from_mlp_shape(shape).eval_logits(params, x, ctrl, mode, half)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{float16, FixedFormat};
    use crate::runtime::manifest::{KIND_B, KIND_DZ, KIND_H, KIND_W, KIND_Z};
    use crate::tensor::{ops, Pcg32};

    use crate::testing::{mlp_batch as batch, mlp_state as init_state, tiny_mlp as tiny_shape};

    #[test]
    fn float32_loss_decreases_over_steps() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 1);
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let out = train_step(
                s, &mut params, &mut vels, &x, &y, 0.2, 0.5, 0.0, &ctrl, RoundMode::HalfAway,
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn quantized_params_live_on_grid() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 3);
        let up = FixedFormat::new(12, 0);
        let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), up);
        let (x, y) = batch(s, 8, 4);
        // initial params must be quantized by the caller (as the Trainer
        // does); here the first step's output is what we check.
        let _ = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 2.0, &ctrl, RoundMode::HalfAway,
        );
        for p in &params {
            for &v in p.data() {
                let kq = v / up.step();
                assert!((kq - kq.round()).abs() < 1e-3, "off grid: {v}");
            }
        }
    }

    #[test]
    fn overflow_totals_match_signal_sizes() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 5);
        let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        let n = 16;
        let (x, y) = batch(s, n, 6);
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::HalfAway,
        );
        let st = out.overflow;
        // z group of layer 0: k*B*U values; h group: B*U
        assert_eq!(st.at2(group_index(0, KIND_Z), 2), (s.k * n * s.units) as f32);
        assert_eq!(st.at2(group_index(0, KIND_H), 2), (n * s.units) as f32);
        // w group counts the weight tensor only (velocity unrecorded)
        assert_eq!(
            st.at2(group_index(0, KIND_W), 2),
            (s.k * s.d_in * s.units) as f32
        );
        // softmax dz: B*C
        assert_eq!(st.at2(group_index(2, KIND_DZ), 2), (n * s.n_classes) as f32);
    }

    #[test]
    fn max_norm_respected_after_update() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 7);
        for p in params.iter_mut() {
            p.map_inplace(|v| v * 30.0);
        }
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 8, 8);
        let c = 1.0;
        let _ = train_step(
            s, &mut params, &mut vels, &x, &y, 0.0, 0.0, c, &ctrl, RoundMode::HalfAway,
        );
        let w0 = &params[0];
        for j in 0..s.k {
            for u in 0..s.units {
                let mut ss = 0.0f32;
                for i in 0..s.d_in {
                    ss += w0.at3(j, i, u).powi(2);
                }
                assert!(ss.sqrt() <= c + 1e-4);
            }
        }
    }

    #[test]
    fn stochastic_rounding_mode_runs() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 9);
        let ctrl = ScaleController::fixed(24, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 10);
        let mut q_ctx_probe = GoldenQ::new(&ctrl, RoundMode::Stochastic);
        q_ctx_probe.stochastic_seed = Some(11);
        // true stochastic rounding through the counter-based per-site
        // streams (what train_step enables for RoundMode::Stochastic):
        let mut t = Tensor::from_vec(&[4], vec![0.3, 0.7, -0.2, 5.0]);
        q_ctx_probe.apply(&mut t, 0, KIND_Z, true);
        assert!(t.data().iter().all(|v| v.is_finite()));
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::Stochastic,
        );
        assert!(out.loss.is_finite());
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::HalfEven,
        );
        assert!(out.loss.is_finite());
    }

    #[test]
    fn half_mode_keeps_signals_on_f16_grid_and_learns() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 21);
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 22);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let out = train_step_opt(
                s,
                &mut params,
                &mut vels,
                &x,
                &y,
                0.2,
                0.5,
                0.0,
                &ctrl,
                StepOptions { half: true, ..Default::default() },
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(last < first.unwrap() * 0.7, "{first:?} -> {last}");
        // parameters are exactly representable in binary16
        for p in &params {
            for &v in p.data() {
                assert_eq!(v, float16::half_roundtrip(v), "not on f16 grid: {v}");
            }
        }
    }

    #[test]
    fn dropout_masks_scale_and_replay_deterministically() {
        let s = tiny_shape();
        let ctrl = ScaleController::fixed(24, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 30);
        let run = |seed: u64| {
            let (mut params, mut vels) = init_state(s, 31);
            let opts = StepOptions {
                dropout: Some(Dropout {
                    input_rate: 0.2,
                    hidden_rate: 0.5,
                    rng: Pcg32::seeded(seed),
                }),
                ..Default::default()
            };
            let out = train_step_opt(
                s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, opts,
            );
            (out.loss, params)
        };
        let (l1, p1) = run(77);
        let (l2, p2) = run(77);
        assert_eq!(l1, l2);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.data(), b.data());
        }
        // a different mask seed takes a different step
        let (l3, _) = run(78);
        assert_ne!(l1, l3);
    }

    #[test]
    fn zero_rate_dropout_is_identity() {
        let s = tiny_shape();
        let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 40);
        let (mut p1, mut v1) = init_state(s, 41);
        let (mut p2, mut v2) = init_state(s, 41);
        let a = train_step(
            s, &mut p1, &mut v1, &x, &y, 0.1, 0.5, 2.0, &ctrl, RoundMode::HalfAway,
        );
        let opts = StepOptions {
            dropout: Some(Dropout {
                input_rate: 0.0,
                hidden_rate: 0.0,
                rng: Pcg32::seeded(1),
            }),
            ..Default::default()
        };
        let b = train_step_opt(s, &mut p2, &mut v2, &x, &y, 0.1, 0.5, 2.0, &ctrl, opts);
        assert_eq!(a.loss, b.loss);
        for (t1, t2) in p1.iter().zip(&p2) {
            assert_eq!(t1.data(), t2.data());
        }
    }

    #[test]
    fn eval_logits_match_zero_lr_train_step_loss() {
        // A zero-LR train step's loss equals the cross-entropy of the
        // eval logits — forward paths agree.
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 50);
        let ctrl = ScaleController::fixed(24, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 51);
        // params pre-quantized as the Trainer does at init
        for (i, p) in params.iter_mut().enumerate() {
            let kind = if i % 2 == 0 { KIND_W } else { KIND_B };
            let g = group_index(i / 2, kind);
            Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
        }
        let probe = train_step(
            s, &mut params.clone(), &mut vels, &x, &y, 0.0, 0.0, 0.0, &ctrl,
            RoundMode::HalfAway,
        );
        let logits = eval_logits(s, &params, &x, &ctrl, RoundMode::HalfAway, false);
        let logp = ops::log_softmax(&logits);
        let mut loss = 0.0f64;
        for i in 0..x.shape()[0] * s.n_classes {
            loss -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let loss = (loss / x.shape()[0] as f64) as f32;
        assert!((loss - probe.loss).abs() < 1e-5, "{loss} vs {}", probe.loss);
    }

    #[test]
    fn mlp_shape_dims_derive_from_the_dataset() {
        let s = MlpShape::for_dataset("digits", 128, 4).unwrap();
        assert_eq!((s.d_in, s.n_classes), (784, 10));
        let s = MlpShape::for_dataset("cifar_like", 64, 2).unwrap();
        assert_eq!((s.d_in, s.n_classes), (3072, 10));
        assert!(MlpShape::for_dataset("imagenet", 128, 4).is_err());
    }
}
