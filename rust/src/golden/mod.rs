//! Golden model: a pure-rust, from-scratch mirror of the compiled
//! `pi_mlp` train step — and the compute core of the native backend.
//!
//! Same signals, same quantization hooks, same update rule as
//! `python/compile/model.py`, implemented over the host [`Tensor`] ops and
//! [`crate::arith::Quantizer`]. It serves three roles:
//!
//! 1. *Cross-validate the AOT bridge*: an integration test (behind the
//!    `pjrt` feature) trains both paths from identical state and asserts
//!    losses, updated parameters and overflow counters agree within
//!    float32 reassociation tolerance.
//! 2. *Reference for rounding ablations*: the ablation bench drives
//!    alternative [`RoundMode`]s (the compiled artifact pins half-away).
//! 3. *The native training engine*: [`crate::runtime::NativeBackend`]
//!    drives [`train_step_opt`] / [`eval_logits`] through the same
//!    `Trainer` loop as the compiled path — see DESIGN.md §Backends.
//!
//! The hot contractions run on the blocked/parallel slice kernels in
//! [`crate::tensor::ops`], contracting per-filter sub-blocks of the
//! `[k, I, U]` weight tensors without materializing copies. The Z, DW
//! and DX group quantizations ride the *fused* quantize-aware kernels
//! (`matmul_sl_q` & co.): rounding, clipping and overflow counting run
//! in the GEMM block epilogue instead of as a second whole-tensor sweep.
//! [`StepOptions::fused`] (default on; `LPDNN_FUSED=0` flips it) selects
//! between the fused kernels and the two-pass reference path — the two
//! are bit-identical in outputs and overflow counters at any thread
//! count (`tests/fused_parity.rs`, DESIGN.md §Fused quantized GEMM).
//!
//! The compiled artifact's in-graph hash-PRNG dropout is a device detail
//! and is not mirrored bit-for-bit; the native path implements standard
//! inverted dropout from the host [`Pcg32`] stream instead
//! ([`StepOptions::dropout`]). Cross-checks against the device run with
//! dropout disabled.

use std::sync::OnceLock;

use crate::arith::{ElemRng, QuantEpilogue, QuantStats, Quantizer, RoundMode};
use crate::coordinator::ScaleController;
use crate::runtime::manifest::{
    group_index, KIND_B, KIND_DB, KIND_DH, KIND_DW, KIND_DZ, KIND_H, KIND_W, KIND_Z,
};
use crate::tensor::{ops, Pcg32, Tensor};

/// Default for [`StepOptions::fused`]: the fused quantized-GEMM kernels
/// are on unless `LPDNN_FUSED=0` (which forces the two-pass reference
/// path — an A/B hook for `bench_perf` and debugging; results are
/// bit-identical either way).
pub fn fused_default() -> bool {
    static FUSED: OnceLock<bool> = OnceLock::new();
    *FUSED.get_or_init(|| std::env::var("LPDNN_FUSED").map(|v| v != "0").unwrap_or(true))
}

/// Maxout MLP shape description (matches the manifest's pi_mlp).
#[derive(Clone, Copy, Debug)]
pub struct MlpShape {
    pub d_in: usize,
    pub units: usize,
    pub k: usize,
    pub n_classes: usize,
}

impl MlpShape {
    pub fn pi_mlp(units: usize, k: usize) -> Self {
        MlpShape { d_in: 784, units, k, n_classes: 10 }
    }
}

/// Parameters/velocities: w0 [k,I,U], b0 [k,U], w1 [k,U,U], b1 [k,U],
/// w2 [U,C], b2 [C] — manifest order.
pub type Params = Vec<Tensor>;

/// The golden train step's outputs.
#[derive(Debug)]
pub struct GoldenOut {
    pub loss: f32,
    /// `[n_groups, 3]` overflow matrix, same layout as the artifact's.
    pub overflow: Tensor,
}

/// Host-side inverted dropout for the native path (the compiled path does
/// dropout in-graph). Masks are drawn from `rng`, so a run replays
/// bit-identically given the experiment seed.
#[derive(Clone, Debug)]
pub struct Dropout {
    pub input_rate: f32,
    pub hidden_rate: f32,
    pub rng: Pcg32,
}

/// Per-step options for [`train_step_opt`].
#[derive(Clone, Debug)]
pub struct StepOptions {
    /// Rounding mode for every quantization hook (canonical: half-away).
    pub mode: RoundMode,
    /// Simulate float16: round-trip every hooked signal through binary16
    /// instead of a fixed point grid (paper Table 1 / Table 3 rows).
    pub half: bool,
    /// Inverted dropout (native path only; `None` = off).
    pub dropout: Option<Dropout>,
    /// Quantize the Z/DW/DX groups inside the GEMM epilogues (fused
    /// kernels) instead of with a second whole-tensor sweep. Bit-identical
    /// either way; see [`fused_default`].
    pub fused: bool,
}

impl Default for StepOptions {
    fn default() -> Self {
        StepOptions {
            mode: RoundMode::HalfAway,
            half: false,
            dropout: None,
            fused: fused_default(),
        }
    }
}

/// One quantization context: per-group quantizers + stat accumulation.
///
/// Every quantization *site* (one logical tensor hooked as one group)
/// draws a [`QuantEpilogue`] via [`Self::epilogue`]; GEMM-adjacent sites
/// hand it to the fused kernels, everything else runs it as a tensor
/// sweep ([`Self::apply`]). Sites are numbered in call order so
/// stochastic-rounding streams never overlap between sites, while within
/// a site samples are keyed on the element's flat index — which is what
/// keeps the fused (tiled, threaded) and two-pass paths bit-identical.
pub struct GoldenQ<'c> {
    ctrl: &'c ScaleController,
    pub mode: RoundMode,
    /// Float16 simulation: binary16 round-trip instead of the fixed grid.
    pub half: bool,
    /// Route GEMM-adjacent sites through the fused kernels (true) or the
    /// two-pass reference path (false). Same bits either way.
    pub fused: bool,
    stats: Vec<QuantStats>,
    /// Base seed for the counter-based stochastic-rounding streams
    /// (`None` = deterministic midpoint sample, like `apply_slice`).
    pub stochastic_seed: Option<u64>,
    /// Quantization-site counter (advanced by [`Self::epilogue`]).
    site: u64,
}

impl<'c> GoldenQ<'c> {
    pub fn new(ctrl: &'c ScaleController, mode: RoundMode) -> Self {
        Self::with_half(ctrl, mode, false)
    }

    pub fn with_half(ctrl: &'c ScaleController, mode: RoundMode, half: bool) -> Self {
        GoldenQ {
            ctrl,
            mode,
            half,
            fused: fused_default(),
            stats: vec![QuantStats::default(); ctrl.n_groups()],
            stochastic_seed: None,
            site: 0,
        }
    }

    fn quantizer(&self, g: usize) -> Quantizer {
        let mut q = Quantizer::from_format(self.ctrl.format(g));
        q.mode = self.mode;
        q
    }

    /// The epilogue for the next quantization site of group
    /// (layer, kind). Advances the site counter — fused and two-pass
    /// consumers of one logical site must share a single epilogue value.
    fn epilogue(&mut self, layer: usize, kind: usize) -> QuantEpilogue {
        let g = group_index(layer, kind);
        let mut epi = if self.half {
            // binary16 round-trip; only totals are counted (the scale
            // controller is static under float16, so over/half are unused).
            QuantEpilogue::half_sim()
        } else {
            QuantEpilogue::new(self.quantizer(g))
        };
        if let Some(seed) = self.stochastic_seed {
            epi = epi.with_rng(ElemRng::for_site(seed, self.site));
        }
        self.site += 1;
        epi
    }

    /// Fold one site's overflow counters into group (layer, kind).
    fn record(&mut self, layer: usize, kind: usize, st: QuantStats) {
        self.stats[group_index(layer, kind)].merge(st);
    }

    /// Two-pass tensor quantization for the non-GEMM sites (H, DZ, DB,
    /// storage, and the multi-filter DH accumulation).
    fn apply(&mut self, t: &mut Tensor, layer: usize, kind: usize, record: bool) {
        let epi = self.epilogue(layer, kind);
        let st = epi.run(t.data_mut(), 0);
        if record {
            self.record(layer, kind, st);
        }
    }

    fn stats_matrix(&self) -> Tensor {
        let g = self.stats.len();
        let mut d = Vec::with_capacity(g * 3);
        for s in &self.stats {
            d.extend_from_slice(&[s.n_over as f32, s.n_half as f32, s.n_total as f32]);
        }
        Tensor::from_vec(&[g, 3], d)
    }
}

/// Forward through one maxout dense layer: per-filter z = x@w_j + b_j,
/// quantized (Z group), then h = max_j, quantized (H group).
/// Returns (h, argmax filter per [B,U]).
fn maxout_fwd(
    q: &mut GoldenQ,
    layer: usize,
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
) -> (Tensor, Vec<u8>) {
    let (k, d_in, units) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    let batch = x.shape()[0];
    assert_eq!(x.shape()[1], d_in);

    // z for every filter, quantized as ONE logical site. Fused: each
    // filter's [B, U] tile gets bias + quantization in its GEMM epilogue
    // (base = the filter's offset in the [k, B, U] tensor). Two-pass:
    // materialize all k tiles, then sweep the whole tensor. Identical
    // per-element index stream → identical bits and counters.
    let mut zq = Tensor::zeros(&[k, batch, units]);
    let epi = q.epilogue(layer, KIND_Z);
    let mut zst = QuantStats::default();
    for j in 0..k {
        let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
        let brow = &b.data()[j * units..(j + 1) * units];
        let dst = &mut zq.data_mut()[j * batch * units..(j + 1) * batch * units];
        if q.fused {
            zst.merge(ops::matmul_sl_q_into(
                x.data(),
                wj,
                Some(brow),
                dst,
                batch,
                d_in,
                units,
                epi.with_base((j * batch * units) as u64),
            ));
        } else {
            let zj = ops::matmul_sl(x.data(), wj, batch, d_in, units);
            for r in 0..batch {
                for u in 0..units {
                    dst[r * units + u] = zj[r * units + u] + brow[u];
                }
            }
        }
    }
    if !q.fused {
        zst = epi.run(zq.data_mut(), 0);
    }
    q.record(layer, KIND_Z, zst);

    let mut h = Tensor::zeros(&[batch, units]);
    let mut amax = vec![0u8; batch * units];
    for r in 0..batch {
        for u in 0..units {
            let (mut best, mut bj) = (f32::NEG_INFINITY, 0u8);
            for j in 0..k {
                let v = zq.at3(j, r, u);
                if v > best {
                    best = v;
                    bj = j as u8;
                }
            }
            h.data_mut()[r * units + u] = best;
            amax[r * units + u] = bj;
        }
    }
    q.apply(&mut h, layer, KIND_H, true);
    (h, amax)
}

/// Draw an inverted-dropout mask (scale 1/(1-rate) on keep, 0 on drop).
fn dropout_mask(rng: &mut Pcg32, n: usize, rate: f32) -> Option<Vec<f32>> {
    if rate <= 0.0 {
        return None;
    }
    let scale = 1.0 / (1.0 - rate);
    Some((0..n).map(|_| if rng.uniform() < rate { 0.0 } else { scale }).collect())
}

fn apply_mask(t: &mut Tensor, mask: &Option<Vec<f32>>) {
    if let Some(m) = mask {
        for (v, &s) in t.data_mut().iter_mut().zip(m) {
            *v *= s;
        }
    }
}

/// One full golden train step with the canonical options (no dropout, no
/// float16). Mutates params/vels in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    shape: MlpShape,
    params: &mut Params,
    vels: &mut Params,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    mom: f32,
    max_norm: f32,
    ctrl: &ScaleController,
    mode: RoundMode,
) -> GoldenOut {
    train_step_opt(
        shape,
        params,
        vels,
        x,
        y,
        lr,
        mom,
        max_norm,
        ctrl,
        StepOptions { mode, ..Default::default() },
    )
}

/// One full train step with explicit [`StepOptions`] (the native
/// backend's entry point). Mutates params/vels in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step_opt(
    shape: MlpShape,
    params: &mut Params,
    vels: &mut Params,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    mom: f32,
    max_norm: f32,
    ctrl: &ScaleController,
    mut opts: StepOptions,
) -> GoldenOut {
    let mut q = GoldenQ::with_half(ctrl, opts.mode, opts.half);
    q.fused = opts.fused;
    if opts.mode == RoundMode::Stochastic {
        // true stochastic rounding draws one uniform sample per element
        // from counter-based per-site streams (index-keyed, so the fused
        // and two-pass paths sample identically)
        q.stochastic_seed = Some(0x57CC_4A57);
    }
    let batch = x.shape()[0];
    let (k, units, classes) = (shape.k, shape.units, shape.n_classes);

    // ---- input dropout (native path) ----
    let x_masked;
    let x: &Tensor = match opts.dropout.as_mut() {
        Some(d) => match dropout_mask(&mut d.rng, x.len(), d.input_rate) {
            Some(m) => {
                let mut xm = x.clone();
                apply_mask(&mut xm, &Some(m));
                x_masked = xm;
                &x_masked
            }
            None => x,
        },
        None => x,
    };

    // ---- forward ----
    let (mut h0, amax0) = maxout_fwd(&mut q, 0, x, &params[0], &params[1]);
    let m0 = opts
        .dropout
        .as_mut()
        .and_then(|d| dropout_mask(&mut d.rng, h0.len(), d.hidden_rate));
    apply_mask(&mut h0, &m0);
    let (mut h1, amax1) = maxout_fwd(&mut q, 1, &h0, &params[2], &params[3]);
    let m1 = opts
        .dropout
        .as_mut()
        .and_then(|d| dropout_mask(&mut d.rng, h1.len(), d.hidden_rate));
    apply_mask(&mut h1, &m1);
    let epi = q.epilogue(2, KIND_Z);
    let z2 = if q.fused {
        let (v, st) = ops::matmul_sl_q(
            h1.data(),
            params[4].data(),
            Some(params[5].data()),
            batch,
            units,
            classes,
            epi,
        );
        q.record(2, KIND_Z, st);
        Tensor::from_vec(&[batch, classes], v)
    } else {
        let mut z2 = ops::matmul(&h1, &params[4]);
        for r in 0..batch {
            for c in 0..classes {
                z2.data_mut()[r * classes + c] += params[5].data()[c];
            }
        }
        let st = epi.run(z2.data_mut(), 0);
        q.record(2, KIND_Z, st);
        z2
    };
    let logp = ops::log_softmax(&z2);
    let mut loss = 0.0f64;
    for i in 0..batch * classes {
        loss -= (y.data()[i] * logp.data()[i]) as f64;
    }
    let loss = (loss / batch as f64) as f32;

    // ---- backward ----
    // softmax head: dz = (p - y)/B, quantized
    let mut dz2 = Tensor::zeros(&[batch, classes]);
    for i in 0..batch * classes {
        dz2.data_mut()[i] = (logp.data()[i].exp() - y.data()[i]) / batch as f32;
    }
    q.apply(&mut dz2, 2, KIND_DZ, true);
    let epi = q.epilogue(2, KIND_DW);
    let dw2 = if q.fused {
        let (v, st) = ops::matmul_tn_sl_q(h1.data(), dz2.data(), batch, units, classes, epi);
        q.record(2, KIND_DW, st);
        Tensor::from_vec(&[units, classes], v)
    } else {
        let mut dw2 = ops::matmul_tn(&h1, &dz2);
        let st = epi.run(dw2.data_mut(), 0);
        q.record(2, KIND_DW, st);
        dw2
    };
    let mut db2 = ops::sum_rows(&dz2);
    q.apply(&mut db2, 2, KIND_DB, true);
    let epi = q.epilogue(1, KIND_DH);
    let mut dh1 = if q.fused {
        let (v, st) =
            ops::matmul_nt_sl_q(dz2.data(), params[4].data(), batch, classes, units, epi);
        q.record(1, KIND_DH, st);
        Tensor::from_vec(&[batch, units], v)
    } else {
        let mut dh1 = ops::matmul_nt(&dz2, &params[4]);
        let st = epi.run(dh1.data_mut(), 0);
        q.record(1, KIND_DH, st);
        dh1
    };
    apply_mask(&mut dh1, &m1);

    let (dw1, db1, mut dh0) =
        maxout_bwd(&mut q, 1, &h0, &params[2], &dh1, &amax1, k, units, true);
    q.apply(&mut dh0, 0, KIND_DH, true);
    apply_mask(&mut dh0, &m0);
    let (dw0, db0, _) = maxout_bwd(&mut q, 0, x, &params[0], &dh0, &amax0, k, units, false);

    // ---- SGD + momentum + max-norm + storage quantization ----
    let grads = [dw0, db0, dw1, db1, dw2, db2];
    for (i, g) in grads.iter().enumerate() {
        let layer = i / 2;
        let kind = if i % 2 == 0 { KIND_W } else { KIND_B };
        // v' = Q_up(mom*v - lr*g), stats NOT recorded (matches L2)
        for (vv, gv) in vels[i].data_mut().iter_mut().zip(g.data()) {
            *vv = mom * *vv - lr * gv;
        }
        q.apply(&mut vels[i], layer, kind, false);
        // p' = Q_up(maxnorm(p + v'))
        for (pv, vv) in params[i].data_mut().iter_mut().zip(vels[i].data()) {
            *pv += vv;
        }
        if kind == KIND_W {
            ops::max_norm_inplace(&mut params[i], max_norm);
        }
        q.apply(&mut params[i], layer, kind, true);
    }

    GoldenOut { loss, overflow: q.stats_matrix() }
}

/// Forward-only logits `[B, C]` for evaluation (no dropout, no mutation),
/// quantizing forward signals exactly as the train step does.
pub fn eval_logits(
    shape: MlpShape,
    params: &Params,
    x: &Tensor,
    ctrl: &ScaleController,
    mode: RoundMode,
    half: bool,
) -> Tensor {
    let batch = x.shape()[0];
    let classes = shape.n_classes;
    let mut q = GoldenQ::with_half(ctrl, mode, half);
    let (h0, _) = maxout_fwd(&mut q, 0, x, &params[0], &params[1]);
    let (h1, _) = maxout_fwd(&mut q, 1, &h0, &params[2], &params[3]);
    let epi = q.epilogue(2, KIND_Z);
    if q.fused {
        let (v, _st) = ops::matmul_sl_q(
            h1.data(),
            params[4].data(),
            Some(params[5].data()),
            batch,
            shape.units,
            classes,
            epi,
        );
        Tensor::from_vec(&[batch, classes], v)
    } else {
        let mut z2 = ops::matmul(&h1, &params[4]);
        for r in 0..batch {
            for c in 0..classes {
                z2.data_mut()[r * classes + c] += params[5].data()[c];
            }
        }
        let _ = epi.run(z2.data_mut(), 0);
        z2
    }
}

/// Backward through a maxout dense layer: route dh to the winning filter,
/// quantize dz/dw/db; optionally produce dx (pre-quantization — the caller
/// quantizes it as the lower layer's DH group, matching L2's ordering).
#[allow(clippy::too_many_arguments)]
fn maxout_bwd(
    q: &mut GoldenQ,
    layer: usize,
    x: &Tensor,
    w: &Tensor,
    dh: &Tensor,
    amax: &[u8],
    k: usize,
    _units: usize,
    need_dx: bool,
) -> (Tensor, Tensor, Tensor) {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let units = dh.shape()[1];

    let mut dz = Tensor::zeros(&[k, batch, units]);
    for r in 0..batch {
        for u in 0..units {
            let j = amax[r * units + u] as usize;
            dz.data_mut()[(j * batch + r) * units + u] = dh.at2(r, u);
        }
    }
    q.apply(&mut dz, layer, KIND_DZ, true);

    // dw for every filter, quantized as ONE logical site (like the z
    // tiles in the forward pass). The dx contraction is NOT fused: its
    // per-filter products are summed across filters before the caller
    // quantizes the total as the lower layer's DH group.
    let mut dw = Tensor::zeros(&[k, d_in, units]);
    let mut db = Tensor::zeros(&[k, units]);
    let mut dx = Tensor::zeros(&[batch, d_in]);
    let epi = q.epilogue(layer, KIND_DW);
    let mut dwst = QuantStats::default();
    for j in 0..k {
        // contiguous [batch, units] view of this filter's dz
        let dzj = &dz.data()[j * batch * units..(j + 1) * batch * units];
        let dwj_dst = &mut dw.data_mut()[j * d_in * units..(j + 1) * d_in * units];
        if q.fused {
            dwst.merge(ops::matmul_tn_sl_q_into(
                x.data(),
                dzj,
                dwj_dst,
                batch,
                d_in,
                units,
                epi.with_base((j * d_in * units) as u64),
            ));
        } else {
            let dwj = ops::matmul_tn_sl(x.data(), dzj, batch, d_in, units);
            dwj_dst.copy_from_slice(&dwj);
        }
        let dbj = ops::sum_rows_sl(dzj, batch, units);
        db.data_mut()[j * units..(j + 1) * units].copy_from_slice(&dbj);
        if need_dx {
            let wj = &w.data()[j * d_in * units..(j + 1) * d_in * units];
            let dxj = ops::matmul_nt_sl(dzj, wj, batch, units, d_in);
            for (a, &b) in dx.data_mut().iter_mut().zip(&dxj) {
                *a += b;
            }
        }
    }
    if !q.fused {
        dwst = epi.run(dw.data_mut(), 0);
    }
    q.record(layer, KIND_DW, dwst);
    q.apply(&mut db, layer, KIND_DB, true);
    (dw, db, dx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{float16, FixedFormat};
    use crate::tensor::Pcg32;

    use crate::testing::{mlp_batch as batch, mlp_state as init_state, tiny_mlp as tiny_shape};

    #[test]
    fn float32_loss_decreases_over_steps() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 1);
        let ctrl = ScaleController::fixed(3, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let out = train_step(
                s, &mut params, &mut vels, &x, &y, 0.2, 0.5, 0.0, &ctrl, RoundMode::HalfAway,
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn quantized_params_live_on_grid() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 3);
        let up = FixedFormat::new(12, 0);
        let ctrl = ScaleController::fixed(3, FixedFormat::new(10, 3), up);
        let (x, y) = batch(s, 8, 4);
        // initial params must be quantized by the caller (as the Trainer
        // does); here the first step's output is what we check.
        let _ = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 2.0, &ctrl, RoundMode::HalfAway,
        );
        for p in &params {
            for &v in p.data() {
                let kq = v / up.step();
                assert!((kq - kq.round()).abs() < 1e-3, "off grid: {v}");
            }
        }
    }

    #[test]
    fn overflow_totals_match_signal_sizes() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 5);
        let ctrl = ScaleController::fixed(3, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        let n = 16;
        let (x, y) = batch(s, n, 6);
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::HalfAway,
        );
        let st = out.overflow;
        // z group of layer 0: k*B*U values; h group: B*U
        assert_eq!(st.at2(group_index(0, KIND_Z), 2), (s.k * n * s.units) as f32);
        assert_eq!(st.at2(group_index(0, KIND_H), 2), (n * s.units) as f32);
        // w group counts the weight tensor only (velocity unrecorded)
        assert_eq!(
            st.at2(group_index(0, KIND_W), 2),
            (s.k * s.d_in * s.units) as f32
        );
        // softmax dz: B*C
        assert_eq!(st.at2(group_index(2, KIND_DZ), 2), (n * s.n_classes) as f32);
    }

    #[test]
    fn max_norm_respected_after_update() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 7);
        for p in params.iter_mut() {
            p.map_inplace(|v| v * 30.0);
        }
        let ctrl = ScaleController::fixed(3, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 8, 8);
        let c = 1.0;
        let _ = train_step(
            s, &mut params, &mut vels, &x, &y, 0.0, 0.0, c, &ctrl, RoundMode::HalfAway,
        );
        let w0 = &params[0];
        for j in 0..s.k {
            for u in 0..s.units {
                let mut ss = 0.0f32;
                for i in 0..s.d_in {
                    ss += w0.at3(j, i, u).powi(2);
                }
                assert!(ss.sqrt() <= c + 1e-4);
            }
        }
    }

    #[test]
    fn stochastic_rounding_mode_runs() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 9);
        let ctrl = ScaleController::fixed(3, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 10);
        let mut q_ctx_probe = GoldenQ::new(&ctrl, RoundMode::Stochastic);
        q_ctx_probe.stochastic_seed = Some(11);
        // true stochastic rounding through the counter-based per-site
        // streams (what train_step enables for RoundMode::Stochastic):
        let mut t = Tensor::from_vec(&[4], vec![0.3, 0.7, -0.2, 5.0]);
        q_ctx_probe.apply(&mut t, 0, KIND_Z, true);
        assert!(t.data().iter().all(|v| v.is_finite()));
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::Stochastic,
        );
        assert!(out.loss.is_finite());
        let out = train_step(
            s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, RoundMode::HalfEven,
        );
        assert!(out.loss.is_finite());
    }

    #[test]
    fn half_mode_keeps_signals_on_f16_grid_and_learns() {
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 21);
        let ctrl = ScaleController::fixed(3, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 22);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let out = train_step_opt(
                s,
                &mut params,
                &mut vels,
                &x,
                &y,
                0.2,
                0.5,
                0.0,
                &ctrl,
                StepOptions { half: true, ..Default::default() },
            );
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(last < first.unwrap() * 0.7, "{first:?} -> {last}");
        // parameters are exactly representable in binary16
        for p in &params {
            for &v in p.data() {
                assert_eq!(v, float16::half_roundtrip(v), "not on f16 grid: {v}");
            }
        }
    }

    #[test]
    fn dropout_masks_scale_and_replay_deterministically() {
        let s = tiny_shape();
        let ctrl = ScaleController::fixed(3, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        let (x, y) = batch(s, 16, 30);
        let run = |seed: u64| {
            let (mut params, mut vels) = init_state(s, 31);
            let opts = StepOptions {
                dropout: Some(Dropout {
                    input_rate: 0.2,
                    hidden_rate: 0.5,
                    rng: Pcg32::seeded(seed),
                }),
                ..Default::default()
            };
            let out = train_step_opt(
                s, &mut params, &mut vels, &x, &y, 0.1, 0.5, 0.0, &ctrl, opts,
            );
            (out.loss, params)
        };
        let (l1, p1) = run(77);
        let (l2, p2) = run(77);
        assert_eq!(l1, l2);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.data(), b.data());
        }
        // a different mask seed takes a different step
        let (l3, _) = run(78);
        assert_ne!(l1, l3);
    }

    #[test]
    fn zero_rate_dropout_is_identity() {
        let s = tiny_shape();
        let ctrl = ScaleController::fixed(3, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 40);
        let (mut p1, mut v1) = init_state(s, 41);
        let (mut p2, mut v2) = init_state(s, 41);
        let a = train_step(
            s, &mut p1, &mut v1, &x, &y, 0.1, 0.5, 2.0, &ctrl, RoundMode::HalfAway,
        );
        let opts = StepOptions {
            dropout: Some(Dropout {
                input_rate: 0.0,
                hidden_rate: 0.0,
                rng: Pcg32::seeded(1),
            }),
            ..Default::default()
        };
        let b = train_step_opt(s, &mut p2, &mut v2, &x, &y, 0.1, 0.5, 2.0, &ctrl, opts);
        assert_eq!(a.loss, b.loss);
        for (t1, t2) in p1.iter().zip(&p2) {
            assert_eq!(t1.data(), t2.data());
        }
    }

    #[test]
    fn eval_logits_match_zero_lr_train_step_loss() {
        // A zero-LR train step's loss equals the cross-entropy of the
        // eval logits — forward paths agree.
        let s = tiny_shape();
        let (mut params, mut vels) = init_state(s, 50);
        let ctrl = ScaleController::fixed(3, FixedFormat::new(12, 3), FixedFormat::new(12, 0));
        let (x, y) = batch(s, 8, 51);
        // params pre-quantized as the Trainer does at init
        for (i, p) in params.iter_mut().enumerate() {
            let kind = if i % 2 == 0 { KIND_W } else { KIND_B };
            let g = group_index(i / 2, kind);
            Quantizer::from_format(ctrl.format(g)).apply_slice(p.data_mut());
        }
        let probe = train_step(
            s, &mut params.clone(), &mut vels, &x, &y, 0.0, 0.0, 0.0, &ctrl,
            RoundMode::HalfAway,
        );
        let logits = eval_logits(s, &params, &x, &ctrl, RoundMode::HalfAway, false);
        let logp = ops::log_softmax(&logits);
        let mut loss = 0.0f64;
        for i in 0..x.shape()[0] * s.n_classes {
            loss -= (y.data()[i] * logp.data()[i]) as f64;
        }
        let loss = (loss / x.shape()[0] as f64) as f32;
        assert!((loss - probe.loss).abs() < 1e-5, "{loss} vs {}", probe.loss);
    }
}
