//! lpdnn CLI: the L3 leader entrypoint.
//!
//! See `lpdnn help` (or `cli::help()`) for the subcommand reference.

use lpdnn::arith::FixedFormat;
use lpdnn::cli::{self, Args};
use lpdnn::config::{Arithmetic, BackendKind, ExperimentConfig};
use lpdnn::coordinator::Trainer;
use lpdnn::data::Dataset;
use lpdnn::error::Context;
use lpdnn::runtime::{create_backend, Manifest};
use lpdnn::tensor::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> lpdnn::Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_train(&args), // eval = train with --steps 1 semantics; kept for discoverability
        "datasets" => cmd_datasets(&args),
        "formats" => cmd_formats(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "-h" | "--help" => {
            print!("{}", cli::help());
            Ok(())
        }
        other => lpdnn::bail!("unknown subcommand '{other}' (try `lpdnn help`)"),
    }
}

/// Build an ExperimentConfig from either --config or individual flags.
/// `--backend` always wins over the config file (quick A/B runs).
fn config_from_args(args: &Args) -> lpdnn::Result<ExperimentConfig> {
    if let Some(path) = args.get_opt("config") {
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let mut cfg = ExperimentConfig::from_toml_str(&text)?;
        if let Some(b) = args.get_opt("backend") {
            cfg.backend = BackendKind::parse(&b)?;
        }
        return Ok(cfg);
    }
    let mut cfg = ExperimentConfig::default();
    cfg.name = args.get("name", "cli");
    cfg.model = args.get("model", "pi_mlp");
    cfg.backend = BackendKind::parse(&args.get("backend", "native"))?;
    cfg.data.dataset = args.get("dataset", "digits");
    cfg.data.n_train = args.get_parse("n-train", cfg.data.n_train)?;
    cfg.data.n_test = args.get_parse("n-test", cfg.data.n_test)?;

    let arith = args.get("arith", "float32");
    cfg.arithmetic = match arith.as_str() {
        "float32" => Arithmetic::Float32,
        "half" | "float16" => Arithmetic::Half,
        "fixed" => Arithmetic::Fixed {
            bits_comp: args.get_parse("bits-comp", 20)?,
            bits_up: args.get_parse("bits-up", 20)?,
            int_bits: args.get_parse("int-bits", 5)?,
        },
        "dynamic" => Arithmetic::Dynamic {
            bits_comp: args.get_parse("bits-comp", 10)?,
            bits_up: args.get_parse("bits-up", 12)?,
            max_overflow_rate: args.get_parse("max-overflow-rate", 1e-4)?,
            update_every_examples: args.get_parse("update-every", 10_000)?,
            init_int_bits: args.get_parse("init-int-bits", 3)?,
            warmup_steps: args.get_parse("warmup", 0)?,
        },
        other => lpdnn::bail!("unknown --arith '{other}'"),
    };

    cfg.train.steps = args.get_parse("steps", cfg.train.steps)?;
    cfg.train.seed = args.get_parse("seed", cfg.train.seed)?;
    cfg.train.lr_start = args.get_parse("lr", cfg.train.lr_start)?;
    cfg.train.lr_end = args.get_parse("lr-end", cfg.train.lr_start / 10.0)?;
    cfg.train.dropout_input = args.get_parse("dropout-input", cfg.train.dropout_input)?;
    cfg.train.dropout_hidden = args.get_parse("dropout-hidden", cfg.train.dropout_hidden)?;
    cfg.train.max_norm = args.get_parse("max-norm", cfg.train.max_norm)?;
    cfg.train.eval_every = args.get_parse("eval-every", cfg.train.eval_every)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> lpdnn::Result<()> {
    let cfg = config_from_args(args)?;
    let loss_csv = args.get_opt("loss-csv");
    let verbose = args.has("verbose");
    args.finish()?;

    let mut backend = create_backend(cfg.backend)?;
    let mut trainer = Trainer::new(backend.as_mut(), cfg.clone());
    trainer.verbose = verbose;

    eprintln!(
        "training '{}': backend={} model={} dataset={} arith={} steps={}",
        cfg.name,
        cfg.backend.label(),
        cfg.model,
        cfg.data.dataset,
        cfg.arithmetic.label(),
        cfg.train.steps
    );
    let result = trainer.run()?;

    println!("experiment:      {}", result.config_name);
    println!("backend:         {}", result.backend_name);
    println!("arithmetic:      {}", cfg.arithmetic.label());
    println!("steps:           {}", result.steps_run);
    println!("final loss:      {:.4}", result.train_loss);
    println!("test error:      {:.4} ({:.2}%)", result.test_error, 100.0 * result.test_error);
    println!("wallclock:       {:.2?}", result.wallclock);
    if matches!(cfg.arithmetic, Arithmetic::Dynamic { .. }) {
        println!("final int_bits:  {:?}", result.final_int_bits);
        println!(
            "scale moves:     {}",
            result.metrics.scale_moves.iter().map(|&(_, n)| n).sum::<usize>()
        );
    }
    if let Some(path) = loss_csv {
        result.metrics.write_loss_csv(&path)?;
        println!("loss curve:      {path}");
    }
    Ok(())
}

fn cmd_datasets(args: &Args) -> lpdnn::Result<()> {
    let n_train = args.get_parse("n-train", 256usize)?;
    let n_test = args.get_parse("n-test", 64usize)?;
    args.finish()?;
    let rng = Pcg32::seeded(1);
    let mut table = lpdnn::bench_support::Table::new(&[
        "dataset", "dimension", "labels", "train", "test", "paper analogue",
    ]);
    for (name, analogue) in [
        ("digits", "MNIST (60K 28x28 gray)"),
        ("clusters", "PI MNIST control"),
        ("cifar_like", "CIFAR10 (50K 32x32 colour)"),
        ("svhn_like", "SVHN (604K 32x32 colour)"),
    ] {
        let ds = Dataset::generate(name, n_train, n_test, &rng)?;
        let dim: usize = ds.train.example_len();
        table.row(&[
            name.to_string(),
            format!("{dim} {:?}", ds.train.example_shape()),
            format!("{}", ds.n_classes),
            format!("{}", ds.train.len()),
            format!("{}", ds.test.len()),
            analogue.to_string(),
        ]);
    }
    println!("Dataset overview (synthetic substitutes; paper Table 2):");
    table.print();
    Ok(())
}

fn cmd_formats(args: &Args) -> lpdnn::Result<()> {
    args.finish()?;
    println!("Floating point formats (paper Table 1):");
    let mut t = lpdnn::bench_support::Table::new(&["format", "total", "exponent", "mantissa"]);
    t.row(&["double".into(), "64".into(), "11".into(), "52".into()]);
    t.row(&["single".into(), "32".into(), "8".into(), "23".into()]);
    t.row(&["half".into(), "16".into(), "5".into(), "10".into()]);
    t.print();

    println!("\nFixed point formats used in the reproduction:");
    let mut t = lpdnn::bench_support::Table::new(&["format", "step (LSB)", "range", "levels"]);
    for (label, fmt) in [
        ("fixed 20-bit, radix 5 (paper 9.2)", FixedFormat::new(20, 5)),
        ("dynamic comp 10-bit", FixedFormat::new(10, 3)),
        ("dynamic up 12-bit", FixedFormat::new(12, 0)),
        ("wide 31-bit (figs 1/3)", FixedFormat::new(31, 5)),
    ] {
        t.row(&[
            format!("{label} [{fmt}]"),
            format!("{:.3e}", fmt.step()),
            format!("[-{}, {})", fmt.maxv(), fmt.maxv()),
            format!("2^{}", fmt.total_bits),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> lpdnn::Result<()> {
    args.finish()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut t = lpdnn::bench_support::Table::new(&[
        "artifact", "model", "mode", "graph", "inputs", "outputs",
    ]);
    for (key, a) in &manifest.artifacts {
        t.row(&[
            key.clone(),
            a.model.clone(),
            a.mode.clone(),
            a.graph.clone(),
            format!("{}", a.inputs.len()),
            format!("{}", a.outputs.len()),
        ]);
    }
    println!("Compiled artifacts in {:?}:", manifest.dir);
    t.print();
    for (name, m) in &manifest.models {
        println!(
            "model {name}: input {:?}, {} layers, {} groups, train batch {}, eval batch {}",
            m.input_shape, m.n_layers, m.n_groups, m.train_batch, m.eval_batch
        );
    }
    println!("(artifacts feed the pjrt backend; the default native backend needs none)");
    Ok(())
}
