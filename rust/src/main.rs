//! lpdnn CLI: the L3 leader entrypoint.
//!
//! See `lpdnn help` (or `cli::help()`) for the subcommand reference.

use std::sync::Arc;

use lpdnn::arith::FixedFormat;
use lpdnn::checkpoint::Checkpoint;
use lpdnn::cli::{self, Args};
use lpdnn::config::{Arithmetic, BackendKind, ExperimentConfig, TopologySpec};
use lpdnn::coordinator::{
    LossCsvObserver, Session, StderrProgress, SweepPoint, SweepReport,
};
use lpdnn::data::{Batcher, Dataset};
use lpdnn::error::Context;
use lpdnn::runtime::{Backend, BackendSpec, Manifest};
use lpdnn::coordinator::oversubscription_warning;
use lpdnn::serve::{serve_closed_loop, serve_open_loop, ServeOptions};
use lpdnn::tensor::{ops, Pcg32};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> lpdnn::Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_train(&args), // eval = train with --steps 1 semantics; kept for discoverability
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "datasets" => cmd_datasets(&args),
        "formats" => cmd_formats(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "-h" | "--help" => {
            print!("{}", cli::help());
            Ok(())
        }
        other => lpdnn::bail!("unknown subcommand '{other}' (try `lpdnn help`)"),
    }
}

/// Apply the `--topology` flag: an explicit maxout-MLP topology
/// (builtin name, `WIDTHxDEPTH`, or comma widths, optionally `@kN`)
/// that overrides the model; it is realized against the dataset dims.
fn apply_topology_flag(args: &Args, cfg: &mut ExperimentConfig) -> lpdnn::Result<()> {
    if let Some(t) = args.get_opt("topology") {
        let spec = TopologySpec::parse_cli(&t)?;
        cfg.model = spec.name.clone();
        cfg.topology = Some(spec);
    }
    Ok(())
}

/// Build an ExperimentConfig from either --config or individual flags.
/// `--backend` and `--topology` always win over the config file (quick
/// A/B runs).
fn config_from_args(args: &Args) -> lpdnn::Result<ExperimentConfig> {
    if let Some(path) = args.get_opt("config") {
        let text = cli::read_file_arg("config", &path)?;
        let mut cfg = ExperimentConfig::from_toml_str(&text)
            .with_context(|| format!("--config {path}"))?;
        if let Some(b) = args.get_opt("backend") {
            cfg.backend = BackendKind::parse(&b)?;
        }
        apply_topology_flag(args, &mut cfg)?;
        cfg.validate()?;
        return Ok(cfg);
    }
    let mut cfg = ExperimentConfig::default();
    cfg.name = args.get("name", "cli");
    cfg.model = args.get("model", "pi_mlp");
    apply_topology_flag(args, &mut cfg)?;
    cfg.backend = BackendKind::parse(&args.get("backend", "native"))?;
    cfg.data.dataset = args.get("dataset", "digits");
    cfg.data.n_train = args.get_parse("n-train", cfg.data.n_train)?;
    cfg.data.n_test = args.get_parse("n-test", cfg.data.n_test)?;

    let arith = args.get("arith", "float32");
    cfg.arithmetic = match arith.as_str() {
        "float32" => Arithmetic::Float32,
        "half" | "float16" => Arithmetic::Half,
        "fixed" => Arithmetic::Fixed {
            bits_comp: args.get_parse("bits-comp", 20)?,
            bits_up: args.get_parse("bits-up", 20)?,
            int_bits: args.get_parse("int-bits", 5)?,
        },
        "dynamic" => Arithmetic::Dynamic {
            bits_comp: args.get_parse("bits-comp", 10)?,
            bits_up: args.get_parse("bits-up", 12)?,
            max_overflow_rate: args.get_parse("max-overflow-rate", 1e-4)?,
            update_every_examples: args.get_parse("update-every", 10_000)?,
            init_int_bits: args.get_parse("init-int-bits", 3)?,
            warmup_steps: args.get_parse("warmup", 0)?,
        },
        other => lpdnn::bail!("unknown --arith '{other}'"),
    };

    cfg.train.steps = args.get_parse("steps", cfg.train.steps)?;
    cfg.train.seed = args.get_parse("seed", cfg.train.seed)?;
    cfg.train.lr_start = args.get_parse("lr", cfg.train.lr_start)?;
    cfg.train.lr_end = args.get_parse("lr-end", cfg.train.lr_start / 10.0)?;
    cfg.train.dropout_input = args.get_parse("dropout-input", cfg.train.dropout_input)?;
    cfg.train.dropout_hidden = args.get_parse("dropout-hidden", cfg.train.dropout_hidden)?;
    cfg.train.max_norm = args.get_parse("max-norm", cfg.train.max_norm)?;
    cfg.train.eval_every = args.get_parse("eval-every", cfg.train.eval_every)?;
    Ok(cfg)
}

/// Cores the OS reports, or 0 when unknown (the warning stays quiet).
fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
}

fn cmd_train(args: &Args) -> lpdnn::Result<()> {
    let cfg = config_from_args(args)?;
    let loss_csv = args.get_opt("loss-csv");
    let save_path = args.get_opt("save");
    // data-parallel training workers; unset defers to LPDNN_DP_WORKERS
    // (bit-identical at any value — tests/dp_parity.rs)
    let dp_workers = match args.get_opt("dp-workers") {
        Some(v) => {
            Some(v.parse::<usize>().map_err(|e| lpdnn::err!("--dp-workers {v}: {e}"))?)
        }
        None => None,
    };
    let verbose = args.has("verbose");
    args.finish()?;

    // Catch unwritable output paths before the training run, not after.
    if let Some(p) = &save_path {
        cli::preflight_writable("save", p)?;
    }
    if let Some(p) = &loss_csv {
        cli::preflight_writable("loss-csv", p)?;
    }

    let dp = dp_workers.unwrap_or_else(lpdnn::golden::dp_workers_default).max(1);
    if let Some(w) = oversubscription_warning(
        "--dp-workers",
        dp,
        "LPDNN_THREADS",
        ops::max_threads(),
        available_cores(),
    ) {
        eprintln!("{w}");
    }
    let mut spec = BackendSpec::new(cfg.backend);
    if let Some(n) = dp_workers {
        spec = spec.with_dp_workers(n);
    }
    let mut session = Session::new(spec);
    if verbose {
        session.add_observer(Arc::new(StderrProgress::new()));
    }
    let csv_obs = loss_csv.as_ref().map(|p| Arc::new(LossCsvObserver::new(p)));
    if let Some(obs) = &csv_obs {
        session.add_observer(obs.clone());
    }

    eprintln!(
        "training '{}': backend={} model={} dataset={} arith={} steps={}",
        cfg.name,
        cfg.backend.label(),
        cfg.model,
        cfg.data.dataset,
        cfg.arithmetic.label(),
        cfg.train.steps
    );
    let result = session.run(cfg.clone())?;

    println!("experiment:      {}", result.config_name);
    println!("backend:         {}", result.backend_name);
    println!("arithmetic:      {}", cfg.arithmetic.label());
    println!("steps:           {}", result.steps_run);
    println!("final loss:      {:.4}", result.train_loss);
    println!("test error:      {:.4} ({:.2}%)", result.test_error, 100.0 * result.test_error);
    println!("wallclock:       {:.2?}", result.wallclock);
    if matches!(cfg.arithmetic, Arithmetic::Dynamic { .. }) {
        println!("final int_bits:  {:?}", result.final_int_bits);
        println!(
            "scale moves:     {}",
            result.metrics.scale_moves.iter().map(|&(_, n)| n).sum::<usize>()
        );
    }
    if let Some(obs) = &csv_obs {
        if let Some(e) = obs.first_error() {
            lpdnn::bail!("{e}");
        }
    }
    if let Some(path) = loss_csv {
        println!("loss curve:      {path}");
    }
    if let Some(path) = &save_path {
        let params = session.params_host()?;
        let ckpt = Checkpoint::from_run(&cfg, &result, params)?;
        ckpt.save(path).with_context(|| format!("--save {path}"))?;
        let n: usize = ckpt.params.iter().map(|t| t.len()).sum();
        println!("checkpoint:      {path} ({n} params in {} tensors)", ckpt.params.len());
    }
    Ok(())
}

/// Restore a checkpoint and re-run its test-set evaluation, failing
/// unless the recomputed error matches the train-time eval bit-exactly
/// (the round-trip proof `train --save` promises).
fn cmd_infer(args: &Args) -> lpdnn::Result<()> {
    let load = args.get_opt("load");
    args.finish()?;
    let Some(path) = load else {
        lpdnn::bail!("infer needs --load <ckpt.json> (written by train --save)");
    };

    let text = cli::read_file_arg("load", &path)?;
    let ckpt = Checkpoint::parse(&text).with_context(|| format!("--load {path}"))?;
    let restored = ckpt.restore()?;
    let cfg = ckpt.to_config();
    cfg.validate()?;

    let mut backend = BackendSpec::new(cfg.backend).create()?;
    let model = backend.begin_run(&cfg)?;
    backend.load_params(ckpt.params.clone())?;

    eprintln!(
        "inferring '{}': model={} dataset={} arith={} n_test={}",
        ckpt.name,
        restored.spec.name,
        ckpt.dataset,
        ckpt.arithmetic.label(),
        ckpt.n_test
    );
    // The same dataset recipe the trainer used: ckpt.n_test is stored
    // already rounded to the eval batch, so this regenerates the
    // identical test split.
    let root_rng = Pcg32::seeded(ckpt.seed);
    let dataset = Dataset::generate(&ckpt.dataset, ckpt.n_train, ckpt.n_test, &root_rng)?;

    let t0 = std::time::Instant::now();
    let mut errors = 0usize;
    let mut total = 0usize;
    for (x, y, n_real) in Batcher::eval_batches(&dataset.test, model.eval_batch, model.n_classes) {
        errors += backend.eval_errors(&restored.ctrl, &x, &y, n_real)?;
        total += n_real;
    }
    let err = errors as f64 / total as f64;

    println!("experiment:      {}", ckpt.name);
    println!("checkpoint:      {path}");
    println!("arithmetic:      {}", ckpt.arithmetic.label());
    println!("test error:      {err:.4} ({errors}/{total})");
    println!("wallclock:       {:.2?}", t0.elapsed());
    lpdnn::ensure!(
        err.to_bits() == ckpt.test_error.to_bits(),
        "restored test error {err} does not match the checkpoint's train-time \
         eval {} — the checkpoint did not round-trip bit-exactly",
        ckpt.test_error
    );
    println!("matches the train-time eval bit-exactly");
    Ok(())
}

/// Serve batched quantized inference from a checkpoint under the
/// built-in closed-loop load generator, then persist the latency /
/// throughput / batch-fill table as versioned JSON.
fn cmd_serve(args: &Args) -> lpdnn::Result<()> {
    let load = args.get_opt("load");
    let d = ServeOptions::default();
    let opts = ServeOptions {
        requests: args.get_parse("requests", d.requests)?,
        concurrency: args.get_parse("concurrency", d.concurrency)?,
        workers: args.get_parse("workers", d.workers)?,
        max_batch: args.get_parse("max-batch", d.max_batch)?,
        max_wait: std::time::Duration::from_micros(
            args.get_parse("max-wait-us", d.max_wait.as_micros() as u64)?,
        ),
        queue_cap: args.get_parse("queue-cap", d.queue_cap)?,
        open_rate: args.get_parse("open-rate", d.open_rate)?,
        open_seed: args.get_parse("open-seed", d.open_seed)?,
        ..d
    };
    let bench_json = args.get("bench-json", "BENCH_serve.json");
    args.finish()?;
    let Some(path) = load else {
        lpdnn::bail!("serve needs --load <ckpt.json> (written by train --save)");
    };
    cli::preflight_writable("bench-json", &bench_json)?;

    let text = cli::read_file_arg("load", &path)?;
    let ckpt = Checkpoint::parse(&text).with_context(|| format!("--load {path}"))?;
    let restored = ckpt.restore()?;
    let root_rng = Pcg32::seeded(ckpt.seed);
    let dataset = Dataset::generate(&ckpt.dataset, ckpt.n_train, ckpt.n_test, &root_rng)?;

    let load = if opts.open_rate > 0.0 {
        format!("open_rate={}rps seed={}", opts.open_rate, opts.open_seed)
    } else {
        format!("concurrency={}", opts.concurrency)
    };
    eprintln!(
        "serving '{}': model={} arith={} requests={} {load} workers={} \
         max_batch={} max_wait={}us int_domain={}",
        ckpt.name,
        restored.spec.name,
        ckpt.arithmetic.label(),
        opts.requests,
        opts.workers,
        opts.max_batch,
        opts.max_wait.as_micros(),
        opts.int_domain
    );
    let params = Arc::new(ckpt.params.clone());
    let report = if opts.open_rate > 0.0 {
        // open loop: seeded Poisson arrivals that do not wait for
        // responses, so the percentiles include honest queueing delay
        serve_open_loop(&restored, params, &dataset.test, &opts)?
    } else {
        serve_closed_loop(&restored, params, &dataset.test, &opts)?
    };

    let table = report.table();
    table.print();
    cli::write_file_arg(
        "bench-json",
        &bench_json,
        &format!("{}\n", table.to_json().to_string_pretty()),
    )?;
    println!("bench json:      {bench_json}");
    Ok(())
}

/// The valid `--axis` values with their default `--points`. The arith
/// default omits float32: the baseline every sweep runs first *is* the
/// float32 row, so a float32 point would just repeat that run to
/// report 1.00x.
const SWEEP_AXES: [(&str, &str); 5] = [
    ("arith", "half,fixed,dynamic"),
    ("comp-bits", "8,10,12,16,20"),
    ("up-bits", "8,10,12,16,20"),
    ("int-bits", "0,2,4,5,6,8"),
    ("overflow-rate", "1e-5,1e-4,1e-3,1e-2"),
];

/// A quantized copy of the base arithmetic, or a clear error.
fn require_quantized(base: &Arithmetic, axis: &str) -> lpdnn::Result<Arithmetic> {
    match base {
        Arithmetic::Fixed { .. } | Arithmetic::Dynamic { .. } => Ok(base.clone()),
        _ => lpdnn::bail!(
            "axis '{axis}' needs a quantized base arithmetic \
             (pass --arith fixed or --arith dynamic)"
        ),
    }
}

/// Resolve one `--points` value on the chosen axis into an arithmetic.
/// `scale_budget` mirrors cmd_sweep's step handling: only the built-in
/// default budget (no explicit --steps/--config) scales the dynamic
/// point's warmup by LPDNN_BENCH_SCALE.
fn apply_axis(
    base: &Arithmetic,
    axis: &str,
    value: &str,
    n_train: usize,
    scale_budget: bool,
) -> lpdnn::Result<Arithmetic> {
    let parse_bits = |v: &str| -> lpdnn::Result<i32> {
        v.parse().map_err(|e| lpdnn::err!("--points value '{v}': {e}"))
    };
    Ok(match axis {
        "arith" => match value {
            "float32" => Arithmetic::Float32,
            "half" | "float16" => Arithmetic::Half,
            "fixed" => Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 },
            "dynamic" => Arithmetic::Dynamic {
                bits_comp: 10,
                bits_up: 12,
                max_overflow_rate: 1e-4,
                // paper: every 10 000 examples; scaled to the configured
                // corpus so the controller ticks comparably often
                update_every_examples: (n_train / 2).max(512),
                init_int_bits: 3,
                warmup_steps: if scale_budget {
                    lpdnn::bench_support::scaled(50)
                } else {
                    50
                },
            },
            other => lpdnn::bail!("unknown arithmetic '{other}' on the arith axis"),
        },
        "comp-bits" => {
            let mut a = require_quantized(base, axis)?;
            match &mut a {
                Arithmetic::Fixed { bits_comp, .. } | Arithmetic::Dynamic { bits_comp, .. } => {
                    *bits_comp = parse_bits(value)?;
                }
                _ => unreachable!(),
            }
            a
        }
        "up-bits" => {
            let mut a = require_quantized(base, axis)?;
            match &mut a {
                Arithmetic::Fixed { bits_up, .. } | Arithmetic::Dynamic { bits_up, .. } => {
                    *bits_up = parse_bits(value)?;
                }
                _ => unreachable!(),
            }
            a
        }
        "int-bits" => match base {
            Arithmetic::Fixed { .. } => {
                let mut a = base.clone();
                if let Arithmetic::Fixed { int_bits, .. } = &mut a {
                    *int_bits = parse_bits(value)?;
                }
                a
            }
            _ => lpdnn::bail!("axis 'int-bits' needs --arith fixed (the paper's Figure 1)"),
        },
        "overflow-rate" => match base {
            Arithmetic::Dynamic { .. } => {
                let mut a = base.clone();
                if let Arithmetic::Dynamic { max_overflow_rate, .. } = &mut a {
                    *max_overflow_rate = value
                        .parse()
                        .map_err(|e| lpdnn::err!("--points value '{value}': {e}"))?;
                }
                a
            }
            _ => lpdnn::bail!("axis 'overflow-rate' needs --arith dynamic"),
        },
        _ => unreachable!("axis membership is validated in build_sweep"),
    })
}

/// Expand the base config + axis + points into (baseline, sweep points).
fn build_sweep(
    base: &ExperimentConfig,
    axis: &str,
    points: Option<&str>,
    scale_budget: bool,
) -> lpdnn::Result<(ExperimentConfig, Vec<SweepPoint>)> {
    let Some(&(_, default_points)) = SWEEP_AXES.iter().find(|(a, _)| *a == axis) else {
        let known: Vec<&str> = SWEEP_AXES.iter().map(|&(a, _)| a).collect();
        lpdnn::bail!("unknown sweep axis '{axis}' (expected one of {})", known.join("|"));
    };
    let mut baseline = base.clone();
    baseline.name = format!("{}-baseline", base.name);
    baseline.arithmetic = Arithmetic::Float32;

    let values: Vec<String> = points
        .unwrap_or(default_points)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if values.is_empty() {
        lpdnn::bail!("no sweep points: pass --points v1,v2,... for axis '{axis}'");
    }

    let mut out = Vec::with_capacity(values.len());
    for v in &values {
        let mut cfg = base.clone();
        cfg.name = format!("{}-{v}", base.name);
        cfg.arithmetic = apply_axis(&base.arithmetic, axis, v, base.data.n_train, scale_budget)?;
        out.push(SweepPoint { label: v.clone(), cfg });
    }
    Ok((baseline, out))
}

fn cmd_sweep(args: &Args) -> lpdnn::Result<()> {
    // An explicit budget — the --steps flag or a user-authored config
    // file — is honored verbatim; only the built-in default scales by
    // LPDNN_BENCH_SCALE (so smoke runs like CI's stay tiny without
    // silently rescaling configured experiments).
    let has_config = args.get_opt("config").is_some();
    let has_steps_flag = args.get_opt("steps").is_some();
    if has_config && has_steps_flag {
        lpdnn::bail!("--steps conflicts with --config (set steps in the config file)");
    }
    let explicit_steps = has_steps_flag || has_config;
    let mut base = config_from_args(args)?;
    let axis = args.get("axis", "arith");
    let points_flag = args.get_opt("points");
    let jobs = args.get_parse("jobs", 1usize)?.max(1);
    let report_path = args.get_opt("report");
    let loss_csv = args.get_opt("loss-csv");
    let verbose = args.has("verbose");
    args.finish()?;

    // Catch unwritable output paths before the sweep burns its budget.
    // --loss-csv never writes its base path (per_label suffixes it per
    // point), so probe a suffixed sibling in the same directory — the
    // probe file is cleaned up again on success.
    if let Some(p) = &report_path {
        cli::preflight_writable("report", p)?;
    }
    if let Some(p) = &loss_csv {
        let probe = LossCsvObserver::per_label(p).path_for("preflight");
        cli::preflight_writable_probe("loss-csv", p, &probe)?;
    }

    if !explicit_steps {
        base.train.steps = lpdnn::bench_support::scaled(base.train.steps);
    }
    if base.name == "cli" {
        base.name = format!("sweep-{axis}");
    }
    let (baseline, points) = build_sweep(&base, &axis, points_flag.as_deref(), !explicit_steps)?;

    if let Some(w) = oversubscription_warning(
        "--jobs",
        jobs,
        "LPDNN_THREADS",
        ops::max_threads(),
        available_cores(),
    ) {
        eprintln!("{w}");
    }
    let mut session = Session::new(BackendSpec::new(base.backend)).with_jobs(jobs);
    if verbose {
        session.add_observer(Arc::new(StderrProgress::new()));
    }
    let csv_obs = loss_csv.as_ref().map(|p| Arc::new(LossCsvObserver::per_label(p)));
    if let Some(obs) = &csv_obs {
        session.add_observer(obs.clone());
    }

    eprintln!(
        "sweep '{}': backend={} axis={} points={} jobs={} steps={}",
        base.name,
        base.backend.label(),
        axis,
        points.len(),
        jobs,
        base.train.steps
    );
    let outcome = session.sweep(&baseline, &points)?;

    println!(
        "baseline '{}' error: {:.4}",
        outcome.baseline.config_name,
        outcome.baseline_error()
    );
    let mut table =
        lpdnn::bench_support::Table::new(&["point", "test error", "normalized", "wallclock"]);
    for r in &outcome.rows {
        table.row(&[
            r.label.clone(),
            format!("{:.4}", r.test_error),
            format!("{:.2}x", r.normalized),
            format!("{:.1?}", r.wallclock),
        ]);
    }
    table.print();

    if let Some(obs) = &csv_obs {
        if let Some(e) = obs.first_error() {
            lpdnn::bail!("{e}");
        }
    }
    if let Some(path) = &loss_csv {
        println!("loss curves:     {path} (one file per point, suffixed by label)");
    }
    if let Some(path) = report_path {
        SweepReport::from_outcome(&outcome, jobs)
            .write(&path)
            .with_context(|| format!("--report {path}"))?;
        println!("report:          {path}");
    }
    Ok(())
}

fn cmd_datasets(args: &Args) -> lpdnn::Result<()> {
    let n_train = args.get_parse("n-train", 256usize)?;
    let n_test = args.get_parse("n-test", 64usize)?;
    args.finish()?;
    let rng = Pcg32::seeded(1);
    let mut table = lpdnn::bench_support::Table::new(&[
        "dataset", "dimension", "labels", "train", "test", "paper analogue",
    ]);
    for (name, analogue) in [
        ("digits", "MNIST (60K 28x28 gray)"),
        ("clusters", "PI MNIST control"),
        ("cifar_like", "CIFAR10 (50K 32x32 colour)"),
        ("svhn_like", "SVHN (604K 32x32 colour)"),
    ] {
        let ds = Dataset::generate(name, n_train, n_test, &rng)?;
        let dim: usize = ds.train.example_len();
        table.row(&[
            name.to_string(),
            format!("{dim} {:?}", ds.train.example_shape()),
            format!("{}", ds.n_classes),
            format!("{}", ds.train.len()),
            format!("{}", ds.test.len()),
            analogue.to_string(),
        ]);
    }
    println!("Dataset overview (synthetic substitutes; paper Table 2):");
    table.print();
    Ok(())
}

fn cmd_formats(args: &Args) -> lpdnn::Result<()> {
    args.finish()?;
    println!("Floating point formats (paper Table 1):");
    let mut t = lpdnn::bench_support::Table::new(&["format", "total", "exponent", "mantissa"]);
    t.row(&["double".into(), "64".into(), "11".into(), "52".into()]);
    t.row(&["single".into(), "32".into(), "8".into(), "23".into()]);
    t.row(&["half".into(), "16".into(), "5".into(), "10".into()]);
    t.print();

    println!("\nFixed point formats used in the reproduction:");
    let mut t = lpdnn::bench_support::Table::new(&["format", "step (LSB)", "range", "levels"]);
    for (label, fmt) in [
        ("fixed 20-bit, radix 5 (paper 9.2)", FixedFormat::new(20, 5)),
        ("dynamic comp 10-bit", FixedFormat::new(10, 3)),
        ("dynamic up 12-bit", FixedFormat::new(12, 0)),
        ("wide 31-bit (figs 1/3)", FixedFormat::new(31, 5)),
    ] {
        t.row(&[
            format!("{label} [{fmt}]"),
            format!("{:.3e}", fmt.step()),
            format!("[-{}, {})", fmt.maxv(), fmt.maxv()),
            format!("2^{}", fmt.total_bits),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> lpdnn::Result<()> {
    args.finish()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut t = lpdnn::bench_support::Table::new(&[
        "artifact", "model", "mode", "graph", "inputs", "outputs",
    ]);
    for (key, a) in &manifest.artifacts {
        t.row(&[
            key.clone(),
            a.model.clone(),
            a.mode.clone(),
            a.graph.clone(),
            format!("{}", a.inputs.len()),
            format!("{}", a.outputs.len()),
        ]);
    }
    println!("Compiled artifacts in {:?}:", manifest.dir);
    t.print();
    for (name, m) in &manifest.models {
        println!(
            "model {name}: input {:?}, {} layers, {} groups, train batch {}, eval batch {}",
            m.input_shape, m.n_layers, m.n_groups, m.train_batch, m.eval_batch
        );
    }
    println!("(artifacts feed the pjrt backend; the default native backend needs none)");
    Ok(())
}
