//! SVHN stand-in: a coloured digit glyph over a cluttered colour
//! background with distractor digit fragments, LCN-preprocessed the same
//! way as the paper's SVHN pipeline (section 8.3, after Zeiler & Fergus).
//!
//! SVHN is the dataset where the paper's dynamic fixed point degrades
//! most (4.95% vs 2.71% float32 in Table 3): cluttered, high-variance
//! inputs stress the shared per-group scales. The generator reproduces
//! that regime: foreground/background contrast varies per example, and
//! off-centre distractor glyphs inject exactly the kind of outlier
//! activations that force scale-up decisions.

use super::{glyphs, preprocess, Dataset, Split};
use crate::tensor::{Pcg32, Tensor};

pub const SIDE: usize = 32;

/// Colour channels. **Layout contract**: every example is row-major
/// H×W×C (NHWC once batched) — pixel `(r, c)` channel `ch` lives at
/// flat index `(r * SIDE + c) * CH + ch`, matching `cifar_like` and
/// what `data::dataset_shape` reports to the conv stages.
pub const CH: usize = 3;

fn render_example(class: usize, rng: &mut Pcg32) -> Vec<f32> {
    let d = SIDE * SIDE;
    // cluttered background: low-frequency colour blobs + noise
    let mut img = vec![0.0f32; d * CH];
    let (bx, by) = (rng.uniform_range(0.0, 6.3), rng.uniform_range(0.0, 6.3));
    let bg: [f32; 3] =
        [rng.uniform_range(0.1, 0.9), rng.uniform_range(0.1, 0.9), rng.uniform_range(0.1, 0.9)];
    for r in 0..SIDE {
        for c in 0..SIDE {
            let blob =
                0.15 * ((r as f32 * 0.4 + bx).sin() + (c as f32 * 0.35 + by).cos());
            for ch in 0..CH {
                img[(r * SIDE + c) * CH + ch] =
                    (bg[ch] + blob + rng.uniform_range(-0.1, 0.1)).clamp(0.0, 1.0);
            }
        }
    }

    // distractor fragments: 1–2 dim glyphs clipped at the borders
    let n_distract = rng.usize_range(1, 2);
    for _ in 0..n_distract {
        let dd = rng.below(10) as usize;
        let mut jit = glyphs::Jitter::sample(rng);
        jit.scale *= 0.8;
        jit.dx += if rng.bool() { 0.55 } else { -0.55 }; // pushed off-centre
        let frag = glyphs::render(dd, SIDE, &jit);
        let tint: [f32; 3] = [
            rng.uniform_range(0.3, 1.0),
            rng.uniform_range(0.3, 1.0),
            rng.uniform_range(0.3, 1.0),
        ];
        for i in 0..d {
            if frag[i] > 0.0 {
                for ch in 0..CH {
                    let p = &mut img[i * CH + ch];
                    *p = (*p * (1.0 - 0.5 * frag[i]) + 0.5 * frag[i] * tint[ch])
                        .clamp(0.0, 1.0);
                }
            }
        }
    }

    // the labelled foreground digit, centred, contrasting colour
    let jit = glyphs::Jitter::sample(rng);
    let fg_digit = glyphs::render(class, SIDE, &jit);
    let fg: [f32; 3] = [
        (1.0 - bg[0]).clamp(0.1, 0.95),
        (1.0 - bg[1]).clamp(0.1, 0.95),
        (1.0 - bg[2]).clamp(0.1, 0.95),
    ];
    for i in 0..d {
        if fg_digit[i] > 0.0 {
            for ch in 0..CH {
                let p = &mut img[i * CH + ch];
                *p = (*p * (1.0 - fg_digit[i]) + fg_digit[i] * fg[ch]).clamp(0.0, 1.0);
            }
        }
    }
    img
}

fn make_split(n: usize, rng: &mut Pcg32) -> Split {
    let d = SIDE * SIDE * CH;
    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        x.extend(render_example(class, rng));
        labels.push(class);
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xs = vec![0.0f32; n * d];
    let mut ls = vec![0usize; n];
    for (new_i, &old_i) in order.iter().enumerate() {
        xs[new_i * d..(new_i + 1) * d].copy_from_slice(&x[old_i * d..(old_i + 1) * d]);
        ls[new_i] = labels[old_i];
    }
    Split { x: Tensor::from_vec(&[n, SIDE, SIDE, CH], xs), labels: ls }
}

/// Generate + LCN-preprocess (paper 8.3).
pub fn generate(n_train: usize, n_test: usize, rng: &mut Pcg32) -> Dataset {
    let mut train = make_split(n_train, &mut rng.fork(1));
    let mut test = make_split(n_test, &mut rng.fork(2));
    preprocess::local_contrast_normalize(&mut train.x, 3);
    preprocess::local_contrast_normalize(&mut test.x, 3);
    Dataset { name: "svhn_like".into(), train, test, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_normalized_images() {
        let ds = generate(32, 8, &mut Pcg32::seeded(1));
        assert_eq!(ds.train.x.shape(), &[32, 32, 32, 3]);
        assert!(ds.train.x.data().iter().all(|v| v.is_finite()));
        // LCN output is roughly zero-mean
        let mean: f32 =
            ds.train.x.data().iter().sum::<f32>() / ds.train.x.len() as f32;
        assert!(mean.abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn higher_variance_than_digits_pre_lcn() {
        // The stress property: svhn-like raw images carry much more
        // background energy than the clean digits dataset.
        let mut rng = Pcg32::seeded(2);
        let raw = make_split(64, &mut rng);
        let var = |xs: &[f32]| {
            let m = xs.iter().sum::<f32>() / xs.len() as f32;
            xs.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / xs.len() as f32
        };
        let digit_split = super::super::digits::generate(64, 1, &mut Pcg32::seeded(2));
        // digits are mostly black background → lower mean than svhn clutter
        let digit_mean =
            digit_split.train.x.data().iter().sum::<f32>() / digit_split.train.x.len() as f32;
        let svhn_mean = raw.x.data().iter().sum::<f32>() / raw.x.len() as f32;
        assert!(svhn_mean > digit_mean + 0.1, "svhn {svhn_mean} vs digits {digit_mean}");
        assert!(var(raw.x.data()) > 0.01);
    }
}
