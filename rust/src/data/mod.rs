//! Dataset substrate: synthetic stand-ins for MNIST / CIFAR10 / SVHN.
//!
//! The build environment has no network and no dataset files, so per the
//! substitution rule (DESIGN.md §Substitutions) we synthesize datasets
//! that exercise the same code paths and the same *numeric regimes* the
//! paper's benchmarks do:
//!
//! * [`digits`]     — 28×28 grayscale stroke-rendered digits (MNIST-like);
//!                    consumed flattened by `pi_mlp` and spatially by
//!                    `conv`.
//! * [`clusters`]   — 784-d Gaussian mixture; a pure permutation-invariant
//!                    control task with no spatial structure at all.
//! * [`cifar_like`] — 32×32×3 colour+frequency texture classes with the
//!                    paper's CIFAR10 preprocessing (GCN + ZCA whitening).
//! * [`svhn_like`]  — 32×32×3 digit glyph over cluttered colour background
//!                    with distractors, LCN-preprocessed (paper 8.3).
//!
//! Everything is deterministic given the experiment seed: generation,
//! preprocessing and shuffling all derive from forks of one [`Pcg32`].

pub mod batcher;
pub mod cifar_like;
pub mod clusters;
pub mod digits;
pub mod glyphs;
pub mod linalg;
pub mod preprocess;
pub mod svhn_like;

pub use batcher::Batcher;

use crate::tensor::{Pcg32, Shape, Tensor};

/// An in-memory labelled dataset split.
#[derive(Clone, Debug)]
pub struct Split {
    /// `[n, ...example_shape]`, row-major.
    pub x: Tensor,
    /// Class labels in `[0, n_classes)`.
    pub labels: Vec<usize>,
}

impl Split {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Per-example shape (without the leading n axis).
    pub fn example_shape(&self) -> &[usize] {
        &self.x.shape()[1..]
    }

    /// Flat length of one example.
    pub fn example_len(&self) -> usize {
        self.example_shape().iter().product()
    }

    /// Borrow example `i` as a flat slice.
    pub fn example(&self, i: usize) -> &[f32] {
        let d = self.example_len();
        &self.x.data()[i * d..(i + 1) * d]
    }
}

/// A train/test dataset pair plus bookkeeping.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Split,
    pub test: Split,
    pub n_classes: usize,
}

/// Static per-dataset signal shape `(example Shape, n_classes)` — what
/// a topology needs to realize its layers *before* any data is
/// generated (model realization happens ahead of dataset synthesis).
/// Spatial datasets report their row-major H×W×C geometry (what the
/// conv stages consume); `clusters` is the one genuinely flat source.
/// Must agree with what [`Dataset::generate`] produces; a test pins it.
pub fn dataset_shape(name: &str) -> crate::Result<(Shape, usize)> {
    match name {
        "digits" => Ok((Shape::Spatial { h: digits::SIDE, w: digits::SIDE, c: 1 }, 10)),
        "clusters" => Ok((Shape::Flat(784), 10)),
        "cifar_like" => Ok((
            Shape::Spatial { h: cifar_like::SIDE, w: cifar_like::SIDE, c: cifar_like::CH },
            10,
        )),
        "svhn_like" => Ok((
            Shape::Spatial { h: svhn_like::SIDE, w: svhn_like::SIDE, c: svhn_like::CH },
            10,
        )),
        other => crate::bail!("unknown dataset '{other}'"),
    }
}

/// Flat per-dataset dimensions `(example_len, n_classes)` — the
/// [`dataset_shape`] view MLP consumers see (e.g. `cifar_like` as a
/// 3072-d vector).
pub fn dataset_dims(name: &str) -> crate::Result<(usize, usize)> {
    let (shape, n_classes) = dataset_shape(name)?;
    Ok((shape.len(), n_classes))
}

impl Dataset {
    /// Generate the named dataset (see module docs) deterministically.
    pub fn generate(
        name: &str,
        n_train: usize,
        n_test: usize,
        rng: &Pcg32,
    ) -> crate::Result<Dataset> {
        match name {
            "digits" => Ok(digits::generate(n_train, n_test, &mut rng.fork(0xD161))),
            "clusters" => Ok(clusters::generate(n_train, n_test, &mut rng.fork(0xC105))),
            "cifar_like" => Ok(cifar_like::generate(n_train, n_test, &mut rng.fork(0xC1FA))),
            "svhn_like" => Ok(svhn_like::generate(n_train, n_test, &mut rng.fork(0x54E7))),
            other => crate::bail!("unknown dataset '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_generate_with_right_shapes() {
        let rng = Pcg32::seeded(7);
        for (name, shape) in [
            ("digits", vec![28usize, 28, 1]),
            ("clusters", vec![784]),
            ("cifar_like", vec![32, 32, 3]),
            ("svhn_like", vec![32, 32, 3]),
        ] {
            let ds = Dataset::generate(name, 64, 32, &rng).unwrap();
            assert_eq!(ds.train.len(), 64, "{name}");
            assert_eq!(ds.test.len(), 32, "{name}");
            assert_eq!(ds.train.example_shape(), &shape[..], "{name}");
            assert_eq!(ds.n_classes, 10, "{name}");
            assert!(ds.train.labels.iter().all(|&l| l < 10));
            assert!(ds.train.x.data().iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::generate("digits", 16, 8, &Pcg32::seeded(3)).unwrap();
        let b = Dataset::generate("digits", 16, 8, &Pcg32::seeded(3)).unwrap();
        assert_eq!(a.train.x.data(), b.train.x.data());
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn different_seeds_give_different_data() {
        let a = Dataset::generate("digits", 16, 8, &Pcg32::seeded(3)).unwrap();
        let b = Dataset::generate("digits", 16, 8, &Pcg32::seeded(4)).unwrap();
        assert_ne!(a.train.x.data(), b.train.x.data());
    }

    #[test]
    fn unknown_dataset_rejected() {
        assert!(Dataset::generate("imagenet", 8, 8, &Pcg32::seeded(1)).is_err());
        assert!(dataset_dims("imagenet").is_err());
    }

    #[test]
    fn static_dims_match_generated_data() {
        let rng = Pcg32::seeded(11);
        for name in ["digits", "clusters", "cifar_like", "svhn_like"] {
            let (d_in, n_classes) = dataset_dims(name).unwrap();
            let ds = Dataset::generate(name, 4, 2, &rng).unwrap();
            assert_eq!(d_in, ds.train.example_len(), "{name}");
            assert_eq!(n_classes, ds.n_classes, "{name}");
        }
    }

    #[test]
    fn static_shapes_match_generated_data() {
        let rng = Pcg32::seeded(12);
        for name in ["digits", "clusters", "cifar_like", "svhn_like"] {
            let (shape, n_classes) = dataset_shape(name).unwrap();
            let ds = Dataset::generate(name, 4, 2, &rng).unwrap();
            assert_eq!(shape.dims(), ds.train.example_shape(), "{name}");
            assert_eq!(n_classes, ds.n_classes, "{name}");
            // dataset_dims is exactly the flattened view of the shape
            assert_eq!(dataset_dims(name).unwrap().0, shape.len(), "{name}");
        }
        assert_eq!(
            dataset_shape("cifar_like").unwrap().0,
            Shape::Spatial { h: 32, w: 32, c: 3 }
        );
        assert_eq!(dataset_shape("clusters").unwrap().0, Shape::Flat(784));
        assert!(dataset_shape("imagenet").is_err());
    }

    #[test]
    fn labels_roughly_balanced() {
        let ds = Dataset::generate("digits", 1000, 10, &Pcg32::seeded(5)).unwrap();
        let mut counts = [0usize; 10];
        for &l in &ds.train.labels {
            counts[l] += 1;
        }
        for &c in &counts {
            assert!(c > 50, "class counts {counts:?}");
        }
    }
}
