//! Procedural stroke-font digit renderer.
//!
//! Each digit 0–9 is a set of line segments on the unit square (a
//! seven-segment skeleton with a couple of diagonal embellishments so 1/7
//! and 6/9 separate cleanly). [`render`] draws the segments into a
//! grayscale raster with anti-aliased stroke width after a random affine
//! jitter (rotation, scale, translation, shear) — enough intra-class
//! variation to make the task non-trivial, the same role MNIST's
//! handwriting variation plays.

use crate::tensor::Pcg32;

/// One stroke: a line segment in unit-square coordinates (y grows down).
#[derive(Clone, Copy, Debug)]
pub struct Seg(pub f32, pub f32, pub f32, pub f32);

/// Segment endpoints for the seven-segment skeleton.
const A: Seg = Seg(0.25, 0.12, 0.75, 0.12); // top
const B: Seg = Seg(0.75, 0.12, 0.75, 0.50); // upper right
const C: Seg = Seg(0.75, 0.50, 0.75, 0.88); // lower right
const D: Seg = Seg(0.25, 0.88, 0.75, 0.88); // bottom
const E: Seg = Seg(0.25, 0.50, 0.25, 0.88); // lower left
const F: Seg = Seg(0.25, 0.12, 0.25, 0.50); // upper left
const G: Seg = Seg(0.25, 0.50, 0.75, 0.50); // middle

/// The strokes of each digit.
pub fn strokes(digit: usize) -> Vec<Seg> {
    match digit {
        0 => vec![A, B, C, D, E, F],
        1 => vec![Seg(0.5, 0.12, 0.5, 0.88), Seg(0.35, 0.28, 0.5, 0.12)],
        2 => vec![A, B, G, E, D],
        3 => vec![A, B, G, C, D],
        4 => vec![F, G, B, C],
        5 => vec![A, F, G, C, D],
        6 => vec![A, F, G, E, D, C],
        7 => vec![A, Seg(0.75, 0.12, 0.45, 0.88)],
        8 => vec![A, B, C, D, E, F, G],
        9 => vec![A, B, C, D, F, G],
        _ => panic!("digit out of range: {digit}"),
    }
}

/// Affine jitter parameters drawn per example.
#[derive(Clone, Copy, Debug)]
pub struct Jitter {
    pub angle: f32,
    pub scale: f32,
    pub dx: f32,
    pub dy: f32,
    pub shear: f32,
    pub stroke: f32,
}

impl Jitter {
    /// Sample a plausible handwriting-ish jitter.
    pub fn sample(rng: &mut Pcg32) -> Jitter {
        Jitter {
            angle: rng.uniform_range(-0.22, 0.22), // ±12.6°
            scale: rng.uniform_range(0.80, 1.10),
            dx: rng.uniform_range(-0.08, 0.08),
            dy: rng.uniform_range(-0.08, 0.08),
            shear: rng.uniform_range(-0.15, 0.15),
            stroke: rng.uniform_range(0.045, 0.075),
        }
    }

    /// The identity jitter (for tests / golden renders).
    pub fn identity() -> Jitter {
        Jitter { angle: 0.0, scale: 1.0, dx: 0.0, dy: 0.0, shear: 0.0, stroke: 0.06 }
    }

    /// Apply to a unit-square point (centre-anchored).
    fn apply(&self, x: f32, y: f32) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let sheared = cx + self.shear * cy;
        let (s, c) = self.angle.sin_cos();
        let rx = c * sheared - s * cy;
        let ry = s * sheared + c * cy;
        (rx * self.scale + 0.5 + self.dx, ry * self.scale + 0.5 + self.dy)
    }
}

/// Distance from point `(px, py)` to segment `seg` (all unit-square).
fn seg_distance(seg: &Seg, px: f32, py: f32) -> f32 {
    let (x0, y0, x1, y1) = (seg.0, seg.1, seg.2, seg.3);
    let (vx, vy) = (x1 - x0, y1 - y0);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 0.0 {
        (((px - x0) * vx + (py - y0) * vy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (qx, qy) = (x0 + t * vx, y0 + t * vy);
    ((px - qx) * (px - qx) + (py - qy) * (py - qy)).sqrt()
}

/// Render `digit` into a `side × side` grayscale raster in `[0, 1]`.
/// Intensity falls off linearly across half a stroke width (cheap AA).
pub fn render(digit: usize, side: usize, jitter: &Jitter) -> Vec<f32> {
    // Transform the strokes once, then rasterize by distance.
    let segs: Vec<Seg> = strokes(digit)
        .iter()
        .map(|s| {
            let (x0, y0) = jitter.apply(s.0, s.1);
            let (x1, y1) = jitter.apply(s.2, s.3);
            Seg(x0, y0, x1, y1)
        })
        .collect();

    let mut img = vec![0.0f32; side * side];
    let inv = 1.0 / side as f32;
    for r in 0..side {
        let py = (r as f32 + 0.5) * inv;
        for cidx in 0..side {
            let px = (cidx as f32 + 0.5) * inv;
            let mut v = 0.0f32;
            for seg in &segs {
                let d = seg_distance(seg, px, py);
                let t = 1.0 - (d - jitter.stroke * 0.5).max(0.0) / (jitter.stroke * 0.5);
                v = v.max(t.clamp(0.0, 1.0));
            }
            img[r * side + cidx] = v;
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_digit_renders_nonempty() {
        for d in 0..10 {
            let img = render(d, 28, &Jitter::identity());
            let ink: f32 = img.iter().sum();
            assert!(ink > 10.0, "digit {d} too faint: {ink}");
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn digits_are_mutually_distinguishable() {
        // L2 distance between clean renders of distinct digits must be
        // well above zero (sanity: classes don't collapse).
        let imgs: Vec<Vec<f32>> =
            (0..10).map(|d| render(d, 28, &Jitter::identity())).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d2: f32 = imgs[i]
                    .iter()
                    .zip(&imgs[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(d2.sqrt() > 2.0, "digits {i} and {j} too similar: {d2}");
            }
        }
    }

    #[test]
    fn jitter_changes_but_preserves_class_structure() {
        let mut rng = Pcg32::seeded(11);
        let clean = render(3, 28, &Jitter::identity());
        let jit = render(3, 28, &Jitter::sample(&mut rng));
        assert_ne!(clean, jit);
        // a jittered 3 is still closer to a clean 3 than to a clean 0
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let clean0 = render(0, 28, &Jitter::identity());
        assert!(d(&jit, &clean) < d(&jit, &clean0));
    }

    #[test]
    fn seg_distance_basics() {
        let s = Seg(0.0, 0.0, 1.0, 0.0);
        assert!((seg_distance(&s, 0.5, 0.0)).abs() < 1e-6);
        assert!((seg_distance(&s, 0.5, 0.3) - 0.3).abs() < 1e-6);
        assert!((seg_distance(&s, 2.0, 0.0) - 1.0).abs() < 1e-6); // clamped to endpoint
    }

    #[test]
    #[should_panic(expected = "digit out of range")]
    fn bad_digit_panics() {
        strokes(10);
    }
}
