//! Pure permutation-invariant task: a 784-d Gaussian mixture.
//!
//! Ten class centres drawn on a sphere, examples = centre + isotropic
//! noise. No spatial structure whatsoever — the control experiment for
//! `pi_mlp` runs where we want the numeric-format effects isolated from
//! convolutional inductive bias.

use super::{Dataset, Split};
use crate::tensor::{Pcg32, Tensor};

pub const DIM: usize = 784;
const CENTRE_NORM: f32 = 4.0;
const NOISE_SD: f32 = 0.9;

fn make_centres(rng: &mut Pcg32) -> Vec<Vec<f32>> {
    (0..10)
        .map(|_| {
            let mut c: Vec<f32> = (0..DIM).map(|_| rng.normal()).collect();
            let norm = (c.iter().map(|v| v * v).sum::<f32>()).sqrt();
            for v in &mut c {
                *v *= CENTRE_NORM / norm;
            }
            c
        })
        .collect()
}

fn make_split(n: usize, centres: &[Vec<f32>], rng: &mut Pcg32) -> Split {
    let mut x = Vec::with_capacity(n * DIM);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        let c = &centres[class];
        x.extend(c.iter().map(|&m| m + NOISE_SD * rng.normal()));
        labels.push(class);
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xs = vec![0.0f32; n * DIM];
    let mut ls = vec![0usize; n];
    for (new_i, &old_i) in order.iter().enumerate() {
        xs[new_i * DIM..(new_i + 1) * DIM]
            .copy_from_slice(&x[old_i * DIM..(old_i + 1) * DIM]);
        ls[new_i] = labels[old_i];
    }
    Split { x: Tensor::from_vec(&[n, DIM], xs), labels: ls }
}

/// Generate the Gaussian-mixture dataset (shared centres across splits).
pub fn generate(n_train: usize, n_test: usize, rng: &mut Pcg32) -> Dataset {
    let centres = make_centres(&mut rng.fork(0));
    let train = make_split(n_train, &centres, &mut rng.fork(1));
    let test = make_split(n_test, &centres, &mut rng.fork(2));
    Dataset { name: "clusters".into(), train, test, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centres_have_target_norm() {
        let centres = make_centres(&mut Pcg32::seeded(1));
        for c in &centres {
            let norm = c.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - CENTRE_NORM).abs() < 1e-3);
        }
    }

    #[test]
    fn classes_linearly_separable_by_nearest_centre() {
        let mut rng = Pcg32::seeded(2);
        let centres = make_centres(&mut rng.fork(0));
        let split = make_split(500, &centres, &mut rng.fork(1));
        let mut correct = 0;
        for i in 0..split.len() {
            let ex = split.example(i);
            let pred = centres
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da: f32 = ex.iter().zip(*a).map(|(x, y)| (x - y) * (x - y)).sum();
                    let db: f32 = ex.iter().zip(*b).map(|(x, y)| (x - y) * (x - y)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
                .0;
            if pred == split.labels[i] {
                correct += 1;
            }
        }
        // centres 3σ-ish apart in 784-d: nearest-centre is near-perfect
        assert!(correct as f64 / split.len() as f64 > 0.95);
    }

    #[test]
    fn train_test_share_centres() {
        // Same class ⇒ same centre in both splits: the distance between a
        // class's train mean and its test mean must be dominated by noise
        // (≈ σ·√(2·784/n_per_class)), NOT by centre separation — and must
        // be clearly smaller than the cross-class distance.
        let ds = generate(2000, 2000, &mut Pcg32::seeded(3));
        let mean_of = |split: &Split, class: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; DIM];
            let mut count = 0;
            for i in 0..split.len() {
                if split.labels[i] == class {
                    for (a, &v) in acc.iter_mut().zip(split.example(i)) {
                        *a += v;
                    }
                    count += 1;
                }
            }
            acc.iter().map(|v| v / count as f32).collect()
        };
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        let same = dist(&mean_of(&ds.train, 4), &mean_of(&ds.test, 4));
        let cross = dist(&mean_of(&ds.train, 4), &mean_of(&ds.test, 7));
        // n_per_class = 200 ⇒ noise distance ≈ 0.9·√(2·784/200) ≈ 2.5
        assert!(same < 3.5, "same-class mean distance {same}");
        assert!(cross > same + 1.0, "cross {cross} vs same {same}");
    }
}
