//! Dense symmetric linear algebra for ZCA whitening (paper section 8.2).
//!
//! From scratch: covariance of a data matrix and a cyclic Jacobi
//! eigensolver for symmetric matrices. Jacobi is O(d³) per sweep, so the
//! preprocessing layer applies ZCA *patch-wise* (blocks of ≤ 192 dims) —
//! see `preprocess.rs` for the block-diagonal substitution note.

use crate::tensor::Tensor;

/// Covariance (biased, 1/n) of rows of `x: [n, d]` around their mean.
/// Returns `(mean[d], cov[d, d])`.
pub fn covariance(x: &Tensor) -> (Vec<f32>, Tensor) {
    let (n, d) = (x.shape()[0], x.shape()[1]);
    assert!(n > 0);
    let xd = x.data();
    let mut mean = vec![0.0f64; d];
    for row in xd.chunks(d) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut cov = vec![0.0f64; d * d];
    for row in xd.chunks(d) {
        for i in 0..d {
            let ci = row[i] as f64 - mean[i];
            // symmetric: fill upper triangle only, mirror later
            for j in i..d {
                cov[i * d + j] += ci * (row[j] as f64 - mean[j]);
            }
        }
    }
    let inv_n = 1.0 / n as f64;
    let mut out = vec![0.0f32; d * d];
    for i in 0..d {
        for j in i..d {
            let v = (cov[i * d + j] * inv_n) as f32;
            out[i * d + j] = v;
            out[j * d + i] = v;
        }
    }
    (
        mean.iter().map(|&m| m as f32).collect(),
        Tensor::from_vec(&[d, d], out),
    )
}

/// Eigendecomposition of a symmetric matrix by the cyclic Jacobi method.
/// Returns `(eigenvalues[d], eigenvectors[d, d])` with eigenvectors in
/// ROWS (`v[k] · a · v[k]^T = λ_k`), ordered as produced (unsorted).
pub fn jacobi_eigh(a: &Tensor, max_sweeps: usize, tol: f64) -> (Vec<f32>, Tensor) {
    let d = a.shape()[0];
    assert_eq!(a.shape(), &[d, d], "square matrix required");
    let mut m: Vec<f64> = a.data().iter().map(|&v| v as f64).collect();
    // v starts as identity; accumulates the rotations (rows = eigenvectors).
    let mut v = vec![0.0f64; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        // Frobenius norm of the off-diagonal part.
        let mut off = 0.0f64;
        for i in 0..d {
            for j in (i + 1)..d {
                off += 2.0 * m[i * d + j] * m[i * d + j];
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                // accumulate rotation into v (rows)
                for k in 0..d {
                    let vpk = v[p * d + k];
                    let vqk = v[q * d + k];
                    v[p * d + k] = c * vpk - s * vqk;
                    v[q * d + k] = s * vpk + c * vqk;
                }
            }
        }
    }

    let eigvals: Vec<f32> = (0..d).map(|i| m[i * d + i] as f32).collect();
    let eigvecs = Tensor::from_vec(&[d, d], v.iter().map(|&x| x as f32).collect());
    (eigvals, eigvecs)
}

/// ZCA whitening transform `W = V^T diag(1/sqrt(λ+eps)) V` from a
/// covariance matrix (paper 8.2 preprocessing). Rows of `V` are the
/// eigenvectors as returned by [`jacobi_eigh`].
pub fn zca_matrix(cov: &Tensor, eps: f32) -> Tensor {
    let d = cov.shape()[0];
    let (vals, vecs) = jacobi_eigh(cov, 30, 1e-10);
    // W[i,j] = Σ_k v[k,i] * s_k * v[k,j], s_k = 1/sqrt(λ_k + eps)
    let vd = vecs.data();
    let mut out = vec![0.0f32; d * d];
    for k in 0..d {
        let s = 1.0 / (vals[k].max(0.0) + eps).sqrt();
        let row = &vd[k * d..(k + 1) * d];
        for i in 0..d {
            let vi = row[i] * s;
            if vi == 0.0 {
                continue;
            }
            let orow = &mut out[i * d..(i + 1) * d];
            for (o, &vj) in orow.iter_mut().zip(row) {
                *o += vi * vj;
            }
        }
    }
    Tensor::from_vec(&[d, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn rand_sym(d: usize, rng: &mut Pcg32) -> Tensor {
        let mut m = vec![0.0f32; d * d];
        for i in 0..d {
            for j in i..d {
                let v = rng.uniform_range(-1.0, 1.0);
                m[i * d + j] = v;
                m[j * d + i] = v;
            }
            m[i * d + i] += d as f32; // diagonally dominant → PD
        }
        Tensor::from_vec(&[d, d], m)
    }

    #[test]
    fn covariance_of_known_data() {
        // two perfectly anticorrelated dims
        let x = Tensor::from_vec(&[4, 2], vec![1., -1., -1., 1., 2., -2., -2., 2.]);
        let (mean, cov) = covariance(&x);
        assert_eq!(mean, vec![0.0, 0.0]);
        assert!((cov.at2(0, 0) - 2.5).abs() < 1e-6);
        assert!((cov.at2(0, 1) + 2.5).abs() < 1e-6);
        assert!((cov.at2(1, 0) - cov.at2(0, 1)).abs() < 1e-9);
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        let mut rng = Pcg32::seeded(5);
        for d in [2usize, 5, 16] {
            let a = rand_sym(d, &mut rng);
            let (vals, vecs) = jacobi_eigh(&a, 30, 1e-12);
            // A ≈ Σ_k λ_k v_k v_k^T
            for i in 0..d {
                for j in 0..d {
                    let mut acc = 0.0f64;
                    for k in 0..d {
                        acc += vals[k] as f64
                            * vecs.at2(k, i) as f64
                            * vecs.at2(k, j) as f64;
                    }
                    assert!(
                        (acc as f32 - a.at2(i, j)).abs() < 1e-3,
                        "d={d} ({i},{j}): {acc} vs {}",
                        a.at2(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn jacobi_eigenvectors_orthonormal() {
        let mut rng = Pcg32::seeded(9);
        let a = rand_sym(12, &mut rng);
        let (_, vecs) = jacobi_eigh(&a, 30, 1e-12);
        let d = 12;
        for i in 0..d {
            for j in 0..d {
                let dot: f32 = (0..d).map(|k| vecs.at2(i, k) * vecs.at2(j, k)).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({i},{j}): {dot}");
            }
        }
    }

    #[test]
    fn known_2x2_eigenvalues() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3.
        let a = Tensor::from_vec(&[2, 2], vec![2., 1., 1., 2.]);
        let (mut vals, _) = jacobi_eigh(&a, 20, 1e-14);
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((vals[0] - 1.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn zca_whitens_correlated_data() {
        // Generate correlated 6-d data, whiten, check covariance ≈ I.
        let mut rng = Pcg32::seeded(13);
        let d = 6;
        let n = 4000;
        let mut xs = vec![0.0f32; n * d];
        for row in xs.chunks_mut(d) {
            let shared = rng.normal();
            for (j, v) in row.iter_mut().enumerate() {
                *v = shared * 0.8 + rng.normal() * (0.2 + 0.1 * j as f32);
            }
        }
        let x = Tensor::from_vec(&[n, d], xs);
        let (mean, cov) = covariance(&x);
        let w = zca_matrix(&cov, 1e-5);
        // apply: y = W (x - mean)
        let mut ys = vec![0.0f32; n * d];
        for (yrow, xrow) in ys.chunks_mut(d).zip(x.data().chunks(d)) {
            for i in 0..d {
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += w.at2(i, j) * (xrow[j] - mean[j]);
                }
                yrow[i] = acc;
            }
        }
        let (_, cov_y) = covariance(&Tensor::from_vec(&[n, d], ys));
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (cov_y.at2(i, j) - want).abs() < 0.05,
                    "cov[{i},{j}] = {}",
                    cov_y.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn zca_is_symmetric() {
        // ZCA (unlike PCA whitening) is the unique symmetric whitener.
        let mut rng = Pcg32::seeded(17);
        let a = rand_sym(8, &mut rng);
        let w = zca_matrix(&a, 1e-4);
        for i in 0..8 {
            for j in 0..8 {
                assert!((w.at2(i, j) - w.at2(j, i)).abs() < 1e-4);
            }
        }
    }
}
