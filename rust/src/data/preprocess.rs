//! Preprocessing: the paper's pipelines, from scratch.
//!
//! * [`global_contrast_normalize`] — GCN (paper 8.2, CIFAR10): per example,
//!   subtract the mean and scale to unit (thresholded) norm.
//! * [`zca_whiten_patches`] — ZCA whitening (paper 8.2). The paper whitens
//!   full 3072-d images; a dense 3072-d eigendecomposition is outside this
//!   substrate's budget, so we whiten **8×8×3 patches block-diagonally**
//!   (16 blocks per 32×32×3 image, one shared 192-d transform fit on
//!   training patches). This preserves what matters for the paper's
//!   question — decorrelated, variance-equalized inputs with the heavier
//!   tails whitening produces — at O(192³) instead of O(3072³). Documented
//!   in DESIGN.md §Substitutions.
//! * [`local_contrast_normalize`] — LCN (paper 8.3, SVHN, after Zeiler &
//!   Fergus 2013): subtractive + divisive normalization with a box window
//!   per channel.

use super::linalg;
use crate::tensor::Tensor;

/// GCN: x ← (x − mean(x)) / max(‖x − mean‖ / √d, floor) per example.
pub fn global_contrast_normalize(x: &mut Tensor, floor: f32) {
    let d: usize = x.shape()[1..].iter().product();
    for row in x.data_mut().chunks_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let mut ss = 0.0f64;
        for v in row.iter_mut() {
            *v -= mean;
            ss += (*v as f64) * (*v as f64);
        }
        let scale = ((ss / d as f64).sqrt() as f32).max(floor);
        for v in row.iter_mut() {
            *v /= scale;
        }
    }
}

/// Patch geometry for block-diagonal ZCA on NHWC images.
const PATCH: usize = 8;

/// Fit a shared ZCA transform on the training split's patches and apply it
/// to both splits. Images must be `[n, h, w, c]` with `h, w` divisible by
/// the 8-pixel patch size.
pub fn zca_whiten_patches(train: &mut Tensor, test: &mut Tensor, eps: f32) {
    let (h, w, c) = (train.shape()[1], train.shape()[2], train.shape()[3]);
    assert!(h % PATCH == 0 && w % PATCH == 0, "image not patch-divisible");
    let pd = PATCH * PATCH * c;

    // Gather training patches into a [n_patches, pd] matrix.
    let patches = extract_patches(train);
    let pmat = Tensor::from_vec(&[patches.len() / pd, pd], patches);
    let (mean, cov) = linalg::covariance(&pmat);
    let wmat = linalg::zca_matrix(&cov, eps);

    apply_patchwise(train, &mean, &wmat);
    apply_patchwise(test, &mean, &wmat);

    // Rescale to unit global RMS (fit on train): whitening divides by
    // √(λ+eps), which for near-null directions inflates magnitudes by up
    // to 1/√eps — harmless for decorrelation, but the training-dynamics
    // contract (activation ranges the paper's radix sweep assumes) wants
    // inputs O(1).
    let n = train.len();
    let rms = (train.data().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        / n as f64)
        .sqrt()
        .max(1e-6) as f32;
    for t in [train, test] {
        for v in t.data_mut().iter_mut() {
            *v /= rms;
        }
    }
}

fn extract_patches(x: &Tensor) -> Vec<f32> {
    let (n, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let pd = PATCH * PATCH * c;
    let mut out = Vec::with_capacity(n * (h / PATCH) * (w / PATCH) * pd);
    let xd = x.data();
    for img in 0..n {
        for pr in (0..h).step_by(PATCH) {
            for pc in (0..w).step_by(PATCH) {
                for r in 0..PATCH {
                    let base = ((img * h + pr + r) * w + pc) * c;
                    out.extend_from_slice(&xd[base..base + PATCH * c]);
                }
            }
        }
    }
    out
}

fn apply_patchwise(x: &mut Tensor, mean: &[f32], wmat: &Tensor) {
    let (n, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let pd = PATCH * PATCH * c;
    let xd = x.data_mut();
    let mut buf = vec![0.0f32; pd];
    let mut outbuf = vec![0.0f32; pd];
    for img in 0..n {
        for pr in (0..h).step_by(PATCH) {
            for pc in (0..w).step_by(PATCH) {
                // gather
                for r in 0..PATCH {
                    let base = ((img * h + pr + r) * w + pc) * c;
                    buf[r * PATCH * c..(r + 1) * PATCH * c]
                        .copy_from_slice(&xd[base..base + PATCH * c]);
                }
                // y = W (p - mean)
                for (b, &m) in buf.iter_mut().zip(mean) {
                    *b -= m;
                }
                let wd = wmat.data();
                for i in 0..pd {
                    let mut acc = 0.0f32;
                    let row = &wd[i * pd..(i + 1) * pd];
                    for (wv, bv) in row.iter().zip(&buf) {
                        acc += wv * bv;
                    }
                    outbuf[i] = acc;
                }
                // scatter
                for r in 0..PATCH {
                    let base = ((img * h + pr + r) * w + pc) * c;
                    xd[base..base + PATCH * c]
                        .copy_from_slice(&outbuf[r * PATCH * c..(r + 1) * PATCH * c]);
                }
            }
        }
    }
}

/// LCN: per channel, subtract a box-window local mean then divide by
/// max(local std, mean-of-local-stds) — Zeiler & Fergus 2013 style with a
/// box kernel instead of a Gaussian (same regime, cheaper).
pub fn local_contrast_normalize(x: &mut Tensor, radius: usize) {
    let (n, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let xd = x.data_mut();
    let mut centered = vec![0.0f32; h * w];
    let mut stds = vec![0.0f32; h * w];
    for img in 0..n {
        for ch in 0..c {
            // local mean pass
            for r in 0..h {
                for cc in 0..w {
                    let (mut acc, mut cnt) = (0.0f32, 0u32);
                    for rr in r.saturating_sub(radius)..=(r + radius).min(h - 1) {
                        for c2 in cc.saturating_sub(radius)..=(cc + radius).min(w - 1) {
                            acc += xd[((img * h + rr) * w + c2) * c + ch];
                            cnt += 1;
                        }
                    }
                    centered[r * w + cc] =
                        xd[((img * h + r) * w + cc) * c + ch] - acc / cnt as f32;
                }
            }
            // local std pass on the centered map
            let mut std_sum = 0.0f64;
            for r in 0..h {
                for cc in 0..w {
                    let (mut acc, mut cnt) = (0.0f32, 0u32);
                    for rr in r.saturating_sub(radius)..=(r + radius).min(h - 1) {
                        for c2 in cc.saturating_sub(radius)..=(cc + radius).min(w - 1) {
                            let v = centered[rr * w + c2];
                            acc += v * v;
                            cnt += 1;
                        }
                    }
                    let s = (acc / cnt as f32).sqrt();
                    stds[r * w + cc] = s;
                    std_sum += s as f64;
                }
            }
            let mean_std = (std_sum / (h * w) as f64) as f32;
            for r in 0..h {
                for cc in 0..w {
                    let denom = stds[r * w + cc].max(mean_std).max(1e-4);
                    xd[((img * h + r) * w + cc) * c + ch] = centered[r * w + cc] / denom;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn rand_images(n: usize, h: usize, w: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let total = n * h * w * c;
        // correlated pixels: base + per-pixel noise
        let mut data = vec![0.0f32; total];
        for img in 0..n {
            let base = rng.uniform_range(0.2, 0.8);
            for v in &mut data[img * h * w * c..(img + 1) * h * w * c] {
                *v = base + rng.uniform_range(-0.2, 0.2);
            }
        }
        Tensor::from_vec(&[n, h, w, c], data)
    }

    #[test]
    fn gcn_zero_mean_unit_norm() {
        let mut x = rand_images(8, 8, 8, 1, 1);
        global_contrast_normalize(&mut x, 1e-8);
        let d = 64;
        for row in x.data().chunks(d) {
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let rms: f32 = (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
            assert!(mean.abs() < 1e-4, "mean={mean}");
            assert!((rms - 1.0).abs() < 1e-3, "rms={rms}");
        }
    }

    #[test]
    fn gcn_floor_prevents_blowup_on_constant_images() {
        let mut x = Tensor::full(&[1, 4, 4, 1], 0.5);
        global_contrast_normalize(&mut x, 1e-2);
        assert!(x.data().iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn zca_patches_decorrelate() {
        let mut train = rand_images(128, 16, 16, 3, 2);
        let mut test = rand_images(16, 16, 16, 3, 3);
        zca_whiten_patches(&mut train, &mut test, 1e-3);
        // after whitening, patch covariance ≈ identity ⇒ per-dim variance ≈ 1
        let patches = extract_patches(&train);
        let pd = PATCH * PATCH * 3;
        let pmat = Tensor::from_vec(&[patches.len() / pd, pd], patches);
        let (_, cov) = linalg::covariance(&pmat);
        let mut diag_err = 0.0f32;
        let mut offdiag_max = 0.0f32;
        for i in 0..pd {
            diag_err += (cov.at2(i, i) - 1.0).abs();
            for j in 0..i {
                offdiag_max = offdiag_max.max(cov.at2(i, j).abs());
            }
        }
        assert!(diag_err / (pd as f32) < 0.15, "mean diag err {}", diag_err / pd as f32);
        assert!(offdiag_max < 0.3, "offdiag {offdiag_max}");
    }

    #[test]
    fn lcn_flattens_illumination_gradient() {
        // an image with a strong global gradient: LCN should leave roughly
        // zero-mean, bounded output
        let (h, w) = (16, 16);
        let mut data = vec![0.0f32; h * w];
        for r in 0..h {
            for c in 0..w {
                data[r * w + c] = r as f32 * 0.5 + c as f32 * 0.1;
            }
        }
        let mut x = Tensor::from_vec(&[1, h, w, 1], data);
        local_contrast_normalize(&mut x, 2);
        let mean: f32 = x.data().iter().sum::<f32>() / (h * w) as f32;
        assert!(mean.abs() < 0.3, "mean={mean}");
        assert!(x.data().iter().all(|v| v.abs() < 10.0));
    }

    #[test]
    fn lcn_is_finite_on_flat_images() {
        let mut x = Tensor::full(&[2, 8, 8, 3], 0.7);
        local_contrast_normalize(&mut x, 2);
        assert!(x.data().iter().all(|v| v.is_finite()));
    }
}
