//! MNIST-like dataset: 28×28×1 stroke-rendered digits with jitter + noise.
//!
//! Pixels land in `[0, 1]` like MNIST's normalized intensities; a small
//! additive noise floor plays the role of scanning artifacts. Consumed
//! flattened (784) by `pi_mlp` and as NHWC `[28, 28, 1]` by `conv` — the
//! tensor layout is the same bytes either way.

use super::{glyphs, Dataset, Split};
use crate::tensor::{Pcg32, Tensor};

pub const SIDE: usize = 28;

fn make_split(n: usize, rng: &mut Pcg32) -> Split {
    let d = SIDE * SIDE;
    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = (i % 10).max(0); // balanced classes, order shuffled below
        let jit = glyphs::Jitter::sample(rng);
        let mut img = glyphs::render(digit, SIDE, &jit);
        for v in &mut img {
            *v = (*v + rng.uniform_range(-0.04, 0.04)).clamp(0.0, 1.0);
        }
        x.extend_from_slice(&img);
        labels.push(digit);
    }
    // Shuffle examples (and labels in lockstep) so batches are mixed.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xs = vec![0.0f32; n * d];
    let mut ls = vec![0usize; n];
    for (new_i, &old_i) in order.iter().enumerate() {
        xs[new_i * d..(new_i + 1) * d].copy_from_slice(&x[old_i * d..(old_i + 1) * d]);
        ls[new_i] = labels[old_i];
    }
    Split { x: Tensor::from_vec(&[n, SIDE, SIDE, 1], xs), labels: ls }
}

/// Generate the digits dataset (train and test from disjoint RNG streams).
pub fn generate(n_train: usize, n_test: usize, rng: &mut Pcg32) -> Dataset {
    let train = make_split(n_train, &mut rng.fork(1));
    let test = make_split(n_test, &mut rng.fork(2));
    Dataset { name: "digits".into(), train, test, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_range_is_unit_interval() {
        let ds = generate(50, 10, &mut Pcg32::seeded(1));
        assert!(ds.train.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn train_and_test_are_disjoint_streams() {
        let ds = generate(20, 20, &mut Pcg32::seeded(1));
        assert_ne!(ds.train.x.data(), ds.test.x.data());
    }

    #[test]
    fn classes_are_balanced_before_shuffle() {
        let ds = generate(100, 10, &mut Pcg32::seeded(2));
        let mut counts = [0usize; 10];
        for &l in &ds.train.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn nearest_clean_template_recovers_label_mostly() {
        // A 1-NN classifier against clean templates should beat chance by
        // a wide margin — the task is learnable but not trivial.
        let ds = generate(200, 10, &mut Pcg32::seeded(3));
        let templates: Vec<Vec<f32>> = (0..10)
            .map(|digit| glyphs::render(digit, SIDE, &glyphs::Jitter::identity()))
            .collect();
        let mut correct = 0;
        for i in 0..ds.train.len() {
            let ex = ds.train.example(i);
            let pred = templates
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da: f32 = ex.iter().zip(*a).map(|(x, y)| (x - y) * (x - y)).sum();
                    let db: f32 = ex.iter().zip(*b).map(|(x, y)| (x - y) * (x - y)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
                .0;
            if pred == ds.train.labels[i] {
                correct += 1;
            }
        }
        // Pixel-space 1-NN against a single clean template is a weak
        // classifier under affine jitter — anything far above the 10%
        // chance level proves class structure survives the jitter (the
        // trained networks reach >90%; see EXPERIMENTS.md).
        let acc = correct as f64 / ds.train.len() as f64;
        assert!(acc > 0.4, "1-NN accuracy only {acc}");
    }
}
