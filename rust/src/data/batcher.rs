//! Minibatch scheduling: shuffled epochs over a [`Split`].
//!
//! The compiled train step has a fixed batch size (baked at AOT time), so
//! the batcher always yields full batches, reshuffling between epochs and
//! carrying the remainder over — the standard "infinite shuffled stream"
//! SGD contract. Evaluation uses [`Batcher::eval_batches`], which walks the
//! split once, padding the final batch by wrapping (the runner subtracts
//! the padded duplicates from the error count).

use super::Split;
use crate::tensor::{ops, Pcg32, Tensor};

/// An infinite shuffled minibatch stream over a split.
pub struct Batcher<'a> {
    split: &'a Split,
    batch: usize,
    n_classes: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg32,
    epoch: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(split: &'a Split, batch: usize, n_classes: usize, rng: Pcg32) -> Self {
        assert!(batch > 0 && !split.is_empty());
        let mut b = Batcher {
            split,
            batch,
            n_classes,
            order: (0..split.len()).collect(),
            cursor: 0,
            rng,
            epoch: 0,
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    /// Epochs completed so far.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Examples consumed so far (monotonic across epochs).
    pub fn examples_seen(&self) -> usize {
        self.epoch * self.split.len() + self.cursor
    }

    /// Next full minibatch: `(x [batch, ...], y_onehot [batch, classes])`.
    pub fn next_batch(&mut self) -> (Tensor, Tensor) {
        let d = self.split.example_len();
        let mut xs = Vec::with_capacity(self.batch * d);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epoch += 1;
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            xs.extend_from_slice(self.split.example(idx));
            labels.push(self.split.labels[idx]);
        }
        let mut shape = vec![self.batch];
        shape.extend_from_slice(self.split.example_shape());
        (Tensor::from_vec(&shape, xs), ops::one_hot(&labels, self.n_classes))
    }

    /// One sequential pass for evaluation: batches of exactly `batch`,
    /// the last one padded by wrapping to the start. Each item is
    /// `(x, y_onehot, n_real)` where `n_real ≤ batch` is the number of
    /// non-padding examples in the batch.
    pub fn eval_batches(
        split: &Split,
        batch: usize,
        n_classes: usize,
    ) -> Vec<(Tensor, Tensor, usize)> {
        let n = split.len();
        let d = split.example_len();
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let n_real = (n - i).min(batch);
            let mut xs = Vec::with_capacity(batch * d);
            let mut labels = Vec::with_capacity(batch);
            for j in 0..batch {
                let idx = if j < n_real { i + j } else { j - n_real }; // wrap-pad
                xs.extend_from_slice(split.example(idx));
                labels.push(split.labels[idx]);
            }
            let mut shape = vec![batch];
            shape.extend_from_slice(split.example_shape());
            out.push((
                Tensor::from_vec(&shape, xs),
                ops::one_hot(&labels, n_classes),
                n_real,
            ));
            i += n_real;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_split(n: usize) -> Split {
        let x: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        Split { x: Tensor::from_vec(&[n, 2], x), labels: (0..n).map(|i| i % 3).collect() }
    }

    #[test]
    fn batches_have_exact_size_and_onehot_labels() {
        let split = toy_split(10);
        let mut b = Batcher::new(&split, 4, 3, Pcg32::seeded(1));
        let (x, y) = b.next_batch();
        assert_eq!(x.shape(), &[4, 2]);
        assert_eq!(y.shape(), &[4, 3]);
        for row in y.data().chunks(3) {
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn one_epoch_visits_every_example_once() {
        let split = toy_split(12);
        let mut b = Batcher::new(&split, 4, 3, Pcg32::seeded(2));
        let mut seen = vec![0usize; 12];
        for _ in 0..3 {
            let (x, _) = b.next_batch();
            for ex in x.data().chunks(2) {
                seen[(ex[0] / 2.0) as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        assert_eq!(b.epoch(), 0);
        b.next_batch();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn examples_seen_monotonic() {
        let split = toy_split(6);
        let mut b = Batcher::new(&split, 4, 3, Pcg32::seeded(3));
        let mut last = 0;
        for _ in 0..5 {
            b.next_batch();
            assert!(b.examples_seen() > last);
            last = b.examples_seen();
        }
        assert_eq!(last, 20);
    }

    #[test]
    fn reshuffles_between_epochs() {
        let split = toy_split(8);
        let mut b = Batcher::new(&split, 8, 3, Pcg32::seeded(4));
        let (e1, _) = b.next_batch();
        let (e2, _) = b.next_batch();
        assert_ne!(e1.data(), e2.data()); // same set, different order
        let mut s1: Vec<i64> = e1.data().iter().map(|&v| v as i64).collect();
        let mut s2: Vec<i64> = e2.data().iter().map(|&v| v as i64).collect();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2);
    }

    #[test]
    fn eval_batches_cover_split_with_wrap_padding() {
        let split = toy_split(10);
        let batches = Batcher::eval_batches(&split, 4, 3);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].2, 4);
        assert_eq!(batches[1].2, 4);
        assert_eq!(batches[2].2, 2); // 2 real + 2 wrap-padding
        assert_eq!(batches[2].0.shape(), &[4, 2]);
        let total: usize = batches.iter().map(|b| b.2).sum();
        assert_eq!(total, 10);
    }
}
