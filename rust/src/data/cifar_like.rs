//! CIFAR10 stand-in: 32×32×3 colour + frequency texture classes,
//! GCN + patchwise-ZCA preprocessed exactly like the paper's CIFAR10
//! pipeline (section 8.2).
//!
//! Each class is a (base colour, texture frequency, texture orientation)
//! triple; examples add phase jitter, amplitude jitter and pixel noise.
//! Not natural images — but after GCN+ZCA the network sees zero-mean,
//! decorrelated inputs with class structure in colour/frequency space,
//! which is the numeric regime (activation ranges, gradient scales) that
//! drives the paper's bit-width findings.

use super::{preprocess, Dataset, Split};
use crate::tensor::{Pcg32, Tensor};

pub const SIDE: usize = 32;

/// Colour channels. **Layout contract**: every example is row-major
/// H×W×C (NHWC once batched) — pixel `(r, c)` channel `ch` lives at
/// flat index `(r * SIDE + c) * CH + ch`. `data::dataset_shape` reports
/// exactly this geometry and the conv stages consume it unchanged; MLP
/// consumers see the same bytes flattened to `SIDE * SIDE * CH`.
pub const CH: usize = 3;

/// Class palette: distinct base colours (r, g, b in [0,1]).
const PALETTE: [(f32, f32, f32); 10] = [
    (0.9, 0.2, 0.2),
    (0.2, 0.9, 0.2),
    (0.2, 0.2, 0.9),
    (0.9, 0.9, 0.2),
    (0.9, 0.2, 0.9),
    (0.2, 0.9, 0.9),
    (0.7, 0.5, 0.3),
    (0.3, 0.7, 0.5),
    (0.5, 0.3, 0.7),
    (0.6, 0.6, 0.6),
];

fn render_example(class: usize, rng: &mut Pcg32) -> Vec<f32> {
    let (br, bg, bb) = PALETTE[class];
    // class-determined texture, example-jittered phase/amplitude
    let freq = 0.25 + 0.18 * (class % 5) as f32;
    let angle = (class as f32) * 0.314;
    let (sa, ca) = angle.sin_cos();
    let phase = rng.uniform_range(0.0, std::f32::consts::TAU);
    let amp = rng.uniform_range(0.25, 0.45);
    let base_jit = rng.uniform_range(-0.1, 0.1);

    let mut img = vec![0.0f32; SIDE * SIDE * CH];
    for r in 0..SIDE {
        for c in 0..SIDE {
            let t = ((ca * c as f32 + sa * r as f32) * freq + phase).sin() * amp;
            let noise = rng.uniform_range(-0.08, 0.08);
            let px = &mut img[(r * SIDE + c) * CH..(r * SIDE + c) * CH + CH];
            px[0] = (br + base_jit + t + noise).clamp(0.0, 1.0);
            px[1] = (bg + base_jit - t * 0.5 + noise).clamp(0.0, 1.0);
            px[2] = (bb + base_jit + t * 0.25 - noise).clamp(0.0, 1.0);
        }
    }
    img
}

fn make_split(n: usize, rng: &mut Pcg32) -> Split {
    let d = SIDE * SIDE * CH;
    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        x.extend(render_example(class, rng));
        labels.push(class);
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xs = vec![0.0f32; n * d];
    let mut ls = vec![0usize; n];
    for (new_i, &old_i) in order.iter().enumerate() {
        xs[new_i * d..(new_i + 1) * d].copy_from_slice(&x[old_i * d..(old_i + 1) * d]);
        ls[new_i] = labels[old_i];
    }
    Split { x: Tensor::from_vec(&[n, SIDE, SIDE, CH], xs), labels: ls }
}

/// Generate + preprocess (GCN then shared patchwise ZCA, paper 8.2).
pub fn generate(n_train: usize, n_test: usize, rng: &mut Pcg32) -> Dataset {
    let mut train = make_split(n_train, &mut rng.fork(1));
    let mut test = make_split(n_test, &mut rng.fork(2));
    preprocess::global_contrast_normalize(&mut train.x, 1e-4);
    preprocess::global_contrast_normalize(&mut test.x, 1e-4);
    preprocess::zca_whiten_patches(&mut train.x, &mut test.x, 1e-2);
    Dataset { name: "cifar_like".into(), train, test, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_and_preprocesses() {
        let ds = generate(64, 16, &mut Pcg32::seeded(1));
        assert_eq!(ds.train.x.shape(), &[64, 32, 32, 3]);
        // post GCN+ZCA: roughly zero-mean
        let mean: f32 =
            ds.train.x.data().iter().sum::<f32>() / ds.train.x.len() as f32;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!(ds.train.x.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_distinguishable_by_mean_colour_pre_preprocessing() {
        let mut rng = Pcg32::seeded(2);
        let split = make_split(100, &mut rng);
        // mean pixel per class differs between at least most class pairs
        let d = SIDE * SIDE * CH;
        let mut means = vec![[0.0f32; 3]; 10];
        let mut counts = [0usize; 10];
        for i in 0..split.len() {
            let l = split.labels[i];
            let ex = &split.x.data()[i * d..(i + 1) * d];
            for px in ex.chunks(3) {
                means[l][0] += px[0];
                means[l][1] += px[1];
                means[l][2] += px[2];
            }
            counts[l] += 1;
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= (cnt * SIDE * SIDE) as f32;
            }
        }
        let mut distinct_pairs = 0;
        for i in 0..10 {
            for j in (i + 1)..10 {
                let dist: f32 = (0..3)
                    .map(|k| (means[i][k] - means[j][k]).powi(2))
                    .sum::<f32>()
                    .sqrt();
                if dist > 0.05 {
                    distinct_pairs += 1;
                }
            }
        }
        assert!(distinct_pairs >= 40, "only {distinct_pairs}/45 colour-separable");
    }
}
